#!/usr/bin/env bash
# CI entry point: tier-1 test suite + kernel-parity job + paged-serving
# parity job + benchmark smoke.
#
#   scripts/ci.sh            # full tier-1 + parity + smoke benches
#   scripts/ci.sh -m 'not slow'   # extra pytest args pass through
set -euo pipefail
cd "$(dirname "$0")/.."
# src for the library, repo root for the benchmarks package
export PYTHONPATH=src:.${PYTHONPATH:+:$PYTHONPATH}

python -m pytest -x -q "$@"

# interpret-mode kernel-parity job: the fused Pallas path must match the
# reference XLA path through the SAME dispatch seam the model uses
# (guaranteed to run even when "$@" filters the main suite)
python -m pytest -x -q tests/test_kernels.py tests/test_dispatch.py

# paged-serving parity job: paged engine (block manager, prefix cache,
# in-loop chunked prefill) must be token-identical to the dense engine,
# with the paged-attention kernel in interpret mode
python -m pytest -x -q tests/test_block_manager.py tests/test_paged_engine.py

# quant-parity job: w8a16 fused kernels and the int8 paged KV cache must
# match their dequantize-then-fp oracles in interpret mode, and the
# quantized engine (weights=int8, kv=int8) must track the fp engine's
# greedy tokens and round-trip prefix sharing / COW / base snapshots
python -m pytest -x -q tests/test_quant.py

# sharded-parity job: the tensor-parallel engine (shard_map over a
# ("data","model") mesh, kv-head-sharded KV pools, vocab-striped readout)
# must be token-identical to the single-device engine on a forced
# 4-device CPU mesh across runtimes / cache modes / kv dtypes, with
# per-shard KV accounting summing to the global figure
XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    python -m pytest -x -q tests/test_sharded_engine.py

# spec-parity job: speculative decode (rank-truncated TT self-drafter,
# DESIGN.md §10) must be greedy-token-identical to the non-speculative
# engine across cache modes / runtimes / kv dtypes / the TP mesh, keep a
# single decode trace, preserve the rejection-sampling distribution, and
# leak no KV blocks; forced 4-device CPU mesh runs the tp4 cases too
# (sampling property tests ride along — they skip without hypothesis)
XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    python -m pytest -x -q tests/test_speculative.py tests/test_property.py

# benchmark smoke: kernel-dispatch + serving benches (assert fused-vs-unfused
# AND paged-vs-dense token parity, nonzero prefix hit rate, paged KV peak
# below the dense reservation, int8 peak KV bytes below fp at equal blocks,
# int8 greedy-token match within tolerance), so regressions and benchmark
# bit-rot fail CI; --json leaves BENCH_kernels.json / BENCH_serving.json at
# the repo root so future PRs can diff the perf trajectory
python benchmarks/run.py --smoke --json

# tensor-parallel serving bench: TP=4 vs TP=1 on a forced 4-device mesh
# (token identity + per-shard KV bytes asserted); merges the
# serving/tp4_vs_tp1 row into the BENCH_serving.json written above
XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    python benchmarks/bench_serving.py --mesh --smoke

# fleet-parity job (DESIGN.md §11): data-axis request striping, the
# disaggregated prefill/decode handoff and the row-parallel TP variant
# must be token-identical to the single-replica column-parallel engine;
# an 8-device mesh runs the dp2 x tp4 bench which asserts token identity
# plus per-replica block accounting and merges the serving/dp2_vs_dp1
# row into BENCH_serving.json
XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python -m pytest -x -q tests/test_fleet_engine.py
XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python benchmarks/bench_serving.py --fleet --smoke

# adapter-paging parity job (DESIGN.md §12): an 8-slot device pool
# serving 256 distinct tasks must be token-identical to the all-resident
# engine with a single decode trace (fault-ins are one pre-jitted
# donated scatter) and zero leaked slot pins; the forced 4-device mesh
# run covers the replicated-pool TP path and per-replica dp registries,
# and the zipf(1.1) bench merges the serving/zipf_256tasks row into
# BENCH_serving.json
XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    python -m pytest -x -q tests/test_adapter_registry.py
python benchmarks/bench_serving.py --multitask --smoke

# chaos-parity job (DESIGN.md §13): request lifecycle (cancel / deadline
# / preemption), the in-graph NaN guard, replica failover and the seeded
# chaos harness — survivors of every fault schedule must stay
# token-identical to the unfaulted run with host-pool invariants audited
# after every step and zero leaked blocks/pins; the 8-device mesh runs
# the dp2 replica-kill cases (kill one decode replica mid-generate,
# drain onto the survivor, match dp1 exactly), and the chaos bench
# merges the serving/chaos_survivors row into BENCH_serving.json
XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python -m pytest -x -q tests/test_chaos.py tests/test_fault_tolerance.py
python benchmarks/bench_serving.py --chaos --smoke

# train-parity job (DESIGN.md §14): the blockwise flash-attention
# backward and the fused linear VJPs must match finite differences and
# their ref twins (f32 <=1e-5 / bf16 <=1e-3 on odd shapes + GQA), the
# T=2048 backward HLO must show no (T, T) materialization, and the
# DMRG-in-training path (warm-moment carry, post-sweep checkpoint
# triple, mesh resharding) must hold on a forced 4-device mesh; the
# train bench asserts the compile-time memory win and sweep-on
# non-divergence and merges its rows into BENCH_train.json
XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    python -m pytest -x -q tests/test_grads.py tests/test_hlo_analysis.py
XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    python -m pytest -x -q tests/test_train_integration.py -k dmrg
XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    python benchmarks/bench_train.py --smoke --json
