#!/usr/bin/env bash
# CI entry point: tier-1 test suite + serving benchmark smoke run.
#
#   scripts/ci.sh            # full tier-1 + serving smoke bench
#   scripts/ci.sh -m 'not slow'   # extra pytest args pass through
set -euo pipefail
cd "$(dirname "$0")/.."
# src for the library, repo root for the benchmarks package
export PYTHONPATH=src:.${PYTHONPATH:+:$PYTHONPATH}

python -m pytest -x -q "$@"
python benchmarks/bench_serving.py --smoke
