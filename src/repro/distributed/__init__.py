from repro.distributed.compression import (  # noqa: F401
    GradCompressor,
    compressed_psum,
    int8_decode,
    int8_encode,
)
from repro.distributed.fault_tolerance import (  # noqa: F401
    FailureInjector,
    SimulatedFailure,
    Watchdog,
    remesh,
)
