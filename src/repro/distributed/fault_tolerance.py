"""Fault tolerance & elasticity.

At 1000+ nodes the failure model is: a chip/host dies mid-run, or a host
straggles persistently. The recovery ladder implemented here:

 1. **Checkpoint/restart** — CheckpointManager snapshots (adapter, optimizer,
    data-iterator state) atomically; `Trainer` auto-resumes from the latest
    snapshot. Because the base model is frozen, snapshots are tiny and can be
    taken every few steps (checkpoint/ckpt.py).
 2. **Elastic remesh** — `remesh` re-device_puts a params pytree onto a new
    mesh (e.g. 2 pods -> 1 pod after a pod loss, or a shrunk data axis).
    Adapter state is replicated (trivially elastic); base params re-shard by
    the same named rules, so any mesh whose axes divide the dims works.
 3. **Straggler watchdog** — per-step wall-clock EWMA; when a step exceeds
    ``threshold``× the EWMA, the trainer checkpoints and (in a real
    deployment) triggers the resize; here the hook is a callback that tests
    can observe.

All of this is exercised by tests/test_fault_tolerance.py with simulated
failures (process-local, as the assignment's CPU container dictates).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
from jax.sharding import Mesh

from repro.sharding import params_sharding


def remesh(params: Any, new_mesh: Mesh) -> Any:
    """Reshard a params pytree onto a new mesh using the named rules.

    Works across mesh *shape* changes (16x16 -> 8x16, 2x16x16 -> 16x16 …):
    sharding specs are derived from parameter names, not from the old mesh.
    """
    shardings = params_sharding(params, new_mesh)
    return jax.device_put(params, shardings)


@dataclasses.dataclass
class Watchdog:
    """Wall-clock straggler detector with EWMA baseline."""
    threshold: float = 3.0
    decay: float = 0.9
    min_steps: int = 5
    on_straggler: Optional[Callable[[int, float, float], None]] = None
    _ewma: float = 0.0
    _steps: int = 0

    def step(self, step_idx: int, dt: float) -> bool:
        """Record one step duration; returns True if flagged as straggler.

        Flagged durations are EXCLUDED from the EWMA update: folding a
        straggler into the baseline inflates the threshold and masks the
        next straggler (a 3x-slow step would raise the baseline ~20% at
        decay=0.9 — two consecutive 2.5x stragglers and only the first
        fires). The baseline tracks healthy steps only.
        """
        flagged = False
        if self._steps >= self.min_steps and dt > self.threshold * self._ewma:
            flagged = True
            if self.on_straggler is not None:
                self.on_straggler(step_idx, dt, self._ewma)
        if not flagged:
            if self._ewma == 0.0:
                self._ewma = dt
            else:
                self._ewma = self.decay * self._ewma + (1 - self.decay) * dt
        self._steps += 1
        return flagged


class SimulatedFailure(RuntimeError):
    """Raised by tests to model a node loss mid-training."""


@dataclasses.dataclass
class FailureInjector:
    """Deterministically fail at a given step — used by integration tests to
    prove restart-resume equivalence."""
    fail_at_step: int = -1

    def check(self, step: int) -> None:
        if step == self.fail_at_step:
            raise SimulatedFailure(f"simulated node failure at step {step}")
