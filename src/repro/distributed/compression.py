"""Gradient compression for the DP all-reduce of adapter gradients.

MetaTT's trainable state is tiny (KBs–MBs), so its DP all-reduce is cheap —
but at 1000+ nodes every collective counts against step latency jitter, and
the same machinery applies to the full-FT baseline (train_base=True) where
gradients are model-sized. Two standard schemes:

  * int8: per-tensor symmetric quantization. All-reduce runs on int8
    (4x bytes saved, bf16->int8 2x), dequantized after. Unbiased within
    half-ULP; tests bound the error.
  * topk: magnitude sparsification with **error feedback** (the residual is
    carried to the next step so the compressed SGD still converges).

``compressed_psum`` is the shard_map building block; ``GradCompressor`` is
the jit-friendly stateless transform used inside the train step when
``TrainConfig.grad_compression != "none"``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def int8_encode(x: jnp.ndarray) -> tuple:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decode(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def topk_encode(x: jnp.ndarray, frac: float) -> tuple:
    flat = x.reshape(-1)
    k = max(int(frac * flat.size), 1)
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = flat[idx]
    return kept, idx, x.shape


def topk_decode(kept, idx, shape) -> jnp.ndarray:
    out = jnp.zeros(int(np.prod(shape)), kept.dtype)
    return out.at[idx].set(kept).reshape(shape)


@dataclasses.dataclass(frozen=True)
class GradCompressor:
    kind: str = "none"        # none | int8 | topk
    topk_frac: float = 0.1

    def init_residual(self, grads) -> Any:
        if self.kind != "topk":
            return None
        return jax.tree_util.tree_map(
            lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def __call__(self, grads, residual=None) -> tuple:
        """Returns (compressed-then-decompressed grads, new residual).
        The roundtrip models what arrives after the compressed all-reduce."""
        if self.kind == "none":
            return grads, residual
        if self.kind == "int8":
            def rt(g):
                q, s = int8_encode(g.astype(jnp.float32))
                return int8_decode(q, s).astype(g.dtype)
            return jax.tree_util.tree_map(rt, grads), residual
        if self.kind == "topk":
            def rt(g, r):
                acc = g.astype(jnp.float32) + r
                kept, idx, shape = topk_encode(acc, self.topk_frac)
                dec = topk_decode(kept, idx, shape)
                return dec.astype(g.dtype), acc - dec
            flat_g, tdef = jax.tree_util.tree_flatten(grads)
            flat_r = tdef.flatten_up_to(residual)
            outs = [rt(g, r) for g, r in zip(flat_g, flat_r)]
            return (tdef.unflatten([o[0] for o in outs]),
                    tdef.unflatten([o[1] for o in outs]))
        raise ValueError(self.kind)


def compressed_psum(x: jnp.ndarray, axis: str, kind: str = "int8"):
    """psum over a shard_map axis with int8 on-the-wire payload."""
    if kind == "none":
        return jax.lax.psum(x, axis)
    xf = x.astype(jnp.float32)
    # shared scale (one scalar pmax) so the int32 sum reconstructs exactly
    scale = jax.lax.pmax(jnp.max(jnp.abs(xf)), axis) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q, axis)
    return (total.astype(jnp.float32) * scale).astype(x.dtype)
