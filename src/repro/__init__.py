"""repro: MetaTT — a global tensor-train adapter for parameter-efficient
fine-tuning, as a production-grade multi-pod JAX framework."""

__version__ = "1.0.0"
