"""Config system.

`ModelConfig` is expressive enough for all 10 assigned architectures plus the
paper's own RoBERTa targets; `ShapeConfig` is one input-shape cell of the
assignment grid; `RunConfig` bundles model + shape + adapter + mesh + trainer
knobs and is what the launcher consumes.

Layer heterogeneity (jamba's 1:7 mamba:attn interleave, xlstm's
sLSTM/mLSTM alternation, MoE-every-k) is expressed as a repeating
**super-block pattern**: `block_pattern` is a tuple of `(mixer, ffn)` pairs
and the model scans over `num_layers / len(block_pattern)` super-blocks.
This keeps the HLO O(pattern) instead of O(num_layers) — essential for
compiling 61-88 layer models at 512 devices (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Optional

import jax.numpy as jnp

MIXERS = ("attn", "mamba", "mlstm", "slstm", "none")
FFNS = ("dense", "moe", "none")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | vlm | audio | ssm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    block_pattern: tuple = (("attn", "dense"),)
    mlp: str = "swiglu"            # swiglu | geglu | gelu
    norm_kind: str = "rmsnorm"     # rmsnorm | layernorm
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    tie_embeddings: bool = True
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0    # kimi-style always-on shared expert(s)
    moe_capacity_factor: float = 2.0  # GShard capacity dispatch (models/moe.py)
    # load-balance/z losses train the ROUTER — which is frozen under PEFT, so
    # they only add compute + a 0.2TB/step probs gather (kimi dry-run, §Perf
    # iteration K3). 0 disables them; set >0 for full fine-tuning.
    moe_aux_weight: float = 0.0
    # --- mamba ---
    mamba_d_state: int = 16
    mamba_expand: int = 2
    mamba_dt_rank: int = 0         # 0 -> ceil(d_model / 16)
    mamba_conv: int = 4
    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0           # fixed encoder length (1500 audio frames)
    # --- frontends (stubs per assignment) ---
    frontend: str = "none"         # none | patch_stub | audio_stub
    frontend_seq: int = 0          # patches/frames prepended to the text seq
    # --- dtypes ---
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Embedding rows padded to a 128 multiple so the vocab dim shards
        cleanly on any 16-way mesh axis (whisper's 51866 otherwise forces
        fully-replicated multi-GB f32 logits — §Perf iteration W2). Padded
        ids are masked out of the loss; real token ids never touch them."""
        return -(-self.vocab_size // 128) * 128

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.resolved_head_dim

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def resolved_dt_rank(self) -> int:
        return self.mamba_dt_rank or -(-self.d_model // 16)

    @property
    def pattern_len(self) -> int:
        return len(self.block_pattern)

    @property
    def num_super_blocks(self) -> int:
        if self.num_layers % self.pattern_len:
            raise ValueError(
                f"{self.name}: num_layers={self.num_layers} not divisible by "
                f"pattern length {self.pattern_len}")
        return self.num_layers // self.pattern_len

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def total_layers(self) -> int:
        """Adapter L axis: encoder layers (if any) + decoder layers."""
        return self.encoder_layers + self.num_layers

    def validate(self) -> "ModelConfig":
        for mixer, ffn in self.block_pattern:
            if mixer not in MIXERS or ffn not in FFNS:
                raise ValueError(f"bad block pattern entry {(mixer, ffn)}")
        _ = self.num_super_blocks
        if any(f == "moe" for _, f in self.block_pattern):
            if not (self.num_experts and self.experts_per_token):
                raise ValueError(f"{self.name}: moe blocks need num_experts")
        return self


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One cell of the assignment's shape grid."""
    name: str                      # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Serving-side quantization of the FROZEN half of the model
    (kernels/quant.py, DESIGN.md §8). MetaTT freezes the base transformer
    by construction, so the base matmul weights and the KV cache are pure
    read-only bandwidth in the decode hot path — int8 halves that traffic
    while the trained TT/LoRA adapter factors stay full precision.

    weights: "none" | "int8" — symmetric int8 of the frozen base matrices
        (attention q/k/v/o and dense-FFN up/gate/down), one f32 scale per
        output channel, or per K-group when ``group_size`` > 0. The rank-r
        adapter epilogue runs in full precision either way.
    kv:      "none" | "int8" — int8 paged KV cache: quantized at write
        time per cache cell (token × kv-head, amax/127 over head_dim),
        scales stored in the SAME paged block layout as the cells, so
        prefix sharing and copy-on-write round-trip the quantized
        representation exactly. Paged cache mode only.
    group_size: K rows per weight-scale group; 0 = one scale per output
        channel (whole-K group). Multiples of 128 keep exactly one scale
        row per kernel K-tile; matrices whose K the group does not divide
        fall back to per-channel.
    """
    weights: str = "none"          # none | int8
    kv: str = "none"               # none | int8
    group_size: int = 0

    @property
    def any(self) -> bool:
        return self.weights != "none" or self.kv != "none"

    def validate(self) -> "QuantConfig":
        for name in ("weights", "kv"):
            v = getattr(self, name)
            if v not in ("none", "int8"):
                raise ValueError(
                    f"QuantConfig.{name}={v!r}; want none | int8")
        if self.group_size and self.group_size % 128 != 0:
            raise ValueError(
                f"QuantConfig.group_size={self.group_size} must be a "
                "multiple of the 128-lane MXU native size (one scale row "
                "per kernel K-tile)")
        return self


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """Kernel-dispatch policy (DESIGN.md §5) — resolved once into a
    ``repro.kernels.dispatch.KernelPolicy`` and threaded through
    ``AdapterCtx`` into every hot-path call site.

    backend: "auto" (Pallas on TPU, reference XLA elsewhere), "pallas"
        (force the fused kernels — with ``interpret=True`` this is the
        CPU correctness path), or "ref" (force the reference XLA path).
    interpret: None -> interpret off-TPU only; explicit bool overrides
        (the parity tests run ``backend="pallas", interpret=True``).
    fuse_linear: route ``adapted_linear`` through the fused base-matmul +
        rank-r epilogue kernel (one HBM round-trip of the output instead
        of three) whenever the adapter folds to lora-form (A, B).
    flash: route attention through the Pallas flash kernels (blockwise
        online softmax for train/prefill, the decode-shaped variant for
        single-token cached decode).
    bm/bn/bk: tt_linear tile overrides (0 -> per-shape heuristic).
    bq/bkv:   flash-attention tile overrides (0 -> per-shape heuristic).
    quant:    frozen-base / KV quantization (QuantConfig); the serving
        engine reads ``quant.weights`` here to int8-quantize the base once
        at construction (ServeConfig.quant is the KV-side twin).
    """
    backend: str = "auto"          # auto | pallas | ref
    interpret: Optional[bool] = None
    fuse_linear: bool = True
    flash: bool = True
    bm: int = 0
    bn: int = 0
    bk: int = 0
    bq: int = 0
    bkv: int = 0
    quant: QuantConfig = QuantConfig()

    def validate(self) -> "KernelConfig":
        self.quant.validate()
        if self.backend not in ("auto", "pallas", "ref"):
            raise ValueError(f"unknown kernel backend {self.backend!r}; "
                             "want auto | pallas | ref")
        # bm tiles the sublane (row) axis — 8-multiples are legal (f32
        # sublane); tt_linear_batched_a's slot axis defaults to bm=8
        if self.bm and self.bm % 8 != 0:
            raise ValueError(
                f"tile override bm={self.bm} must be a multiple of the "
                "8-row f32 sublane")
        for name in ("bn", "bk", "bq", "bkv"):
            v = getattr(self, name)
            if v and v % 128 != 0:
                raise ValueError(
                    f"tile override {name}={v} must be a multiple of the "
                    "128-lane MXU native size")
        return self


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculative multi-token decode (serving/speculative.py,
    DESIGN.md §10). The drafter is a RANK-TRUNCATED slice of the shared
    TT cores — TT bond ranks nest, so keeping the leading ``draft_rank``
    bond columns of G1 / C / G4 (or of the pre-folded lora-form A) yields
    a cheaper adapter that shares the frozen base, the KV layout and the
    task routing with the target model. Per engine step the drafter
    proposes ``spec_k`` tokens against a parallel draft KV region; the
    target model scores all k+1 positions in ONE co-batched pass (the
    chunked-prefill (B, C) path) and an in-graph accept rule commits the
    longest valid prefix — exact argmax match under greedy sampling,
    rejection sampling under temperature (output distribution provably
    unchanged). Rejected positions need no KV rollback: later steps
    overwrite their cells before any attention mask reaches them.

    spec_k: draft tokens proposed per engine step; 0 disables
        speculation (the default — the engine is then bit-identical in
        structure to the non-speculative one).
    draft_rank: TT bond rank of the drafter; 0 keeps the full rank
        (drafter == target adapter — useful to isolate the harness).
        Applies to metatt (live and lora-form) and plain lora runtimes;
        other adapter kinds fall back to the full-rank factors.
    draft_layer_stride: the drafter keeps every stride-th super-block of
        the frozen base (1 = all layers). The draft KV region shrinks by
        the same factor.
    """
    spec_k: int = 0
    draft_rank: int = 0
    draft_layer_stride: int = 1

    @property
    def enabled(self) -> bool:
        return self.spec_k > 0

    def validate(self) -> "SpecConfig":
        if self.spec_k < 0:
            raise ValueError(f"SpecConfig.spec_k={self.spec_k} must be >= 0")
        if self.draft_rank < 0:
            raise ValueError(
                f"SpecConfig.draft_rank={self.draft_rank} must be >= 0 "
                "(0 = full rank)")
        if self.draft_layer_stride < 1:
            raise ValueError(
                f"SpecConfig.draft_layer_stride={self.draft_layer_stride} "
                "must be >= 1")
        return self


@dataclasses.dataclass(frozen=True)
class RegistryConfig:
    """Paged adapter registry (serving/adapter_registry.py, DESIGN.md
    §12). MetaTT's task mode makes each task's marginal footprint one
    core slice, so the engine can serve an open-ended task population
    from a fixed device pool of ``max_resident_tasks`` slots, faulting
    task slices in host→device on demand (one jitted donated scatter, no
    retrace) and evicting idle residents — S-LoRA-style paging, but the
    unit is a TT core column instead of a whole adapter stack.

    max_resident_tasks: device task-slot pool size K per decode replica.
        0 (default) keeps the whole ``num_tasks`` axis device-resident —
        registry off, the pre-registry engine byte-for-byte. K may be
        smaller than the in-flight batch's distinct-task count only at
        the price of admission backpressure: a request whose task cannot
        get a slot waits until a harvest unpins one.
    eviction: idle-resident replacement policy — "lru" (default;
        recency refreshed on every admission hit) or "fifo" (load order
        only — cheaper bookkeeping, worse under skewed reuse).

    Requires a task-routed runtime (metatt 4+1d); the engine rejects the
    combination otherwise. Works in both cache modes and composes with
    quantization, the serve mesh (pool replicated; swaps happen outside
    shard_map), dp replicas (one registry per replica) and speculative
    decode (drafter slices page together with their target slices).
    """
    max_resident_tasks: int = 0
    eviction: str = "lru"          # lru | fifo

    @property
    def enabled(self) -> bool:
        return self.max_resident_tasks > 0

    def validate(self) -> "RegistryConfig":
        if self.max_resident_tasks < 0:
            raise ValueError(
                f"RegistryConfig.max_resident_tasks="
                f"{self.max_resident_tasks} must be >= 0 (0 = all tasks "
                "device-resident)")
        if self.eviction not in ("lru", "fifo"):
            raise ValueError(
                f"RegistryConfig.eviction={self.eviction!r}; want "
                "lru | fifo")
        return self


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Serving-engine knobs (repro/serving/engine.py).

    cache_mode: "paged" (default) — block/paged KV cache with a host-side
        BlockManager (free list, refcounts, copy-on-write), hash-keyed
        prefix sharing and chunked prefill folded into the one jitted
        decode loop; "dense" — the PR-1 layout (max_batch × cache_len
        reserved per slot, per-bucket prefill graphs), kept as the parity
        baseline.
    max_batch:  decode slots stepped together by the jitted loop.
    cache_len:  per-request bound on prompt_len + max_new_tokens (both
        modes; in paged mode it also sizes the block-table width).
    out_cap:    per-request bound on max_new_tokens.
    page_size:  tokens per KV block (paged mode). 8-multiples keep the
        Pallas paged-attention tile on the f32 sublane grid.
    num_blocks: total KV cache budget in blocks (paged mode); this — not
        the slot count — is what admission is gated on. 0 derives the
        dense-equivalent budget max_batch * ceil(cache_len / page_size).
    prefill_chunk: prompt tokens processed per decode-loop step while a
        slot is prefilling (chunked prefill co-batches with decode in the
        same fixed-shape graph, so there is no per-bucket prefill ladder).
    prefix_cache: share KV blocks between requests with a common prompt
        prefix (hash-chained at page granularity, partial last page
        included; divergence after a shared partial page copies-on-write).
    prompt_buckets: dense mode only — prefill pad buckets.
    quant: QuantConfig — ``quant.kv="int8"`` stores the paged KV pools as
        int8 with per-cell f32 scales in the same block layout (paged mode
        only); ``quant.weights`` here is honored too (merged with
        KernelConfig.quant by the engine).
    mesh_shape: tensor-parallel serving (DESIGN.md §9). Empty tuple
        (default) = single-device engine. A ``(data, model)`` pair builds
        a mesh (sharding/rules.py::serve_mesh) and the engine wraps its
        jitted step graphs in ``shard_map``: KV caches / paged pools
        shard on the kv-head axis over the "model" axis, attention runs
        per-shard on its local head group, the readout computes a
        per-shard vocab stripe and all-gathers the (B, V) logits for
        in-graph sampling. Everything else — TT cores, block table, slot
        state, sampling RNG — is replicated, so greedy decode is
        token-identical to the single-device engine. The "data" axis is
        reserved for replica DP (state is replicated across it today).
        num_heads, num_kv_heads and padded_vocab must each be divisible
        by the "model" axis size.
    tp_axis: mesh axis name the KV/head/vocab sharding applies to
        (default "model"; must be one of the serve-mesh axes).
    router: data-axis request placement policy (serving/router.py,
        DESIGN.md §11) — "least_loaded" (deterministic: fewest queued
        tokens, replica index breaks ties) or "round_robin". Only
        consulted when the mesh's data axis is > 1.
    disagg: split prefill from decode (DESIGN.md §11): a dedicated
        prefill worker pool fills paged KV and hands finished sequences
        to the decode replicas — a host-side block-table transfer plus a
        batched pool-to-pool block copy (BlockManager.migrate_to), no
        retrace. Paged mode only.
    row_parallel: shard the SECOND matmul of each pair — attention
        ``wo``, FFN ``wd`` (with ``wg``/``wu`` column-parallel) —
        row-parallel with a psum epilogue instead of all-gathering the
        activations (DESIGN.md §11). Partial-sum order differs per
        shard, so this trades the column-only mode's bit-exactness for
        one fewer all-gather: near-parity (~1e-3), asserted against the
        default mode as oracle. Needs a serve mesh; incompatible with
        grouped weight quantization (group_size > 0 — scale groups tile
        the K axis the row slice cuts).
    spec: SpecConfig — speculative multi-token decode with the
        rank-truncated TT self-drafter (spec.spec_k > 0 enables it;
        DESIGN.md §10). Works in both cache modes, composes with
        quantization and the serve mesh.
    registry: RegistryConfig — paged adapter registry (DESIGN.md §12).
        ``registry.max_resident_tasks=K`` serves any number of tasks
        from a K-slot device pool per replica, paging task slices on
        demand; 0 keeps every task resident (off).
    preempt_after: recompute preemption for forward progress
        (DESIGN.md §13). When the FIFO head of a replica's admission
        queue has been backpressured for this many CONSECUTIVE host-loop
        iterations, the engine preempts the youngest running request on
        that replica vLLM-recompute-style: its generated tokens are
        harvested, its blocks freed (prompt KV registered in the prefix
        cache, so recompute is cheap) and it re-enqueues behind the
        blocked head with prompt+generated as the new prompt. 0 (the
        default) disables preemption — the head waits for natural
        evictions. Paged, non-disaggregated engines only.

    Data parallelism (DESIGN.md §11): ``mesh_shape=(data, model)`` with
    data > 1 stripes decode slots AND paged-pool blocks across data
    replicas — max_batch and num_blocks are PER-REPLICA figures, each
    replica runs its own Scheduler/BlockManager over its local pool, and
    a front-end Router places requests deterministically, so dp=N greedy
    decode is token-identical to dp=1 on the same request set. Paged
    mode only (the dense layout has no block pool to stripe).
    """
    max_batch: int = 4
    cache_len: int = 64
    out_cap: int = 32
    cache_mode: str = "paged"      # paged | dense
    page_size: int = 16
    num_blocks: int = 0
    prefill_chunk: int = 8
    prefix_cache: bool = True
    prompt_buckets: tuple = ()
    quant: QuantConfig = QuantConfig()
    mesh_shape: tuple = ()         # () | (data, model)
    tp_axis: str = "model"
    router: str = "least_loaded"   # least_loaded | round_robin
    disagg: bool = False
    row_parallel: bool = False
    spec: SpecConfig = SpecConfig()
    registry: RegistryConfig = RegistryConfig()
    preempt_after: int = 0         # 0 = recompute preemption off

    @property
    def pages_per_request(self) -> int:
        """Block-table width: worst-case pages one request can touch."""
        return -(-self.cache_len // self.page_size)

    @property
    def resolved_num_blocks(self) -> int:
        return self.num_blocks or self.max_batch * self.pages_per_request

    def validate(self) -> "ServeConfig":
        if self.cache_mode not in ("paged", "dense"):
            raise ValueError(f"unknown cache_mode {self.cache_mode!r}; "
                             "want paged | dense")
        self.quant.validate()
        self.spec.validate()
        self.registry.validate()
        if self.spec.enabled and self.spec.spec_k + 1 > self.cache_len:
            raise ValueError(
                f"SpecConfig.spec_k={self.spec.spec_k}: the verifier "
                f"scores spec_k+1 positions per step, which must fit in "
                f"cache_len={self.cache_len}")
        if self.quant.kv == "int8" and self.cache_mode != "paged":
            raise ValueError(
                "kv=int8 quantization is implemented for the paged cache "
                "layout only (per-page scale pools); use "
                "cache_mode='paged'")
        for name in ("max_batch", "cache_len", "out_cap", "page_size",
                     "prefill_chunk"):
            if getattr(self, name) < 1:
                raise ValueError(f"ServeConfig.{name} must be >= 1")
        if self.mesh_shape:
            if len(self.mesh_shape) != 2 \
                    or any(int(s) < 1 for s in self.mesh_shape):
                raise ValueError(
                    f"ServeConfig.mesh_shape={self.mesh_shape!r} must be "
                    "a (data, model) pair of positive ints (empty for "
                    "single-device serving)")
            if self.tp_axis not in ("data", "model"):
                raise ValueError(
                    f"ServeConfig.tp_axis={self.tp_axis!r} must name a "
                    "serve-mesh axis (data | model)")
            if int(self.mesh_shape[0]) > 1 and self.cache_mode != "paged":
                raise ValueError(
                    "data-parallel serving (mesh_shape data axis > 1) "
                    "stripes the paged block pool across replicas; use "
                    "cache_mode='paged'")
        if self.router not in ("least_loaded", "round_robin"):
            raise ValueError(
                f"ServeConfig.router={self.router!r}; want "
                "least_loaded | round_robin")
        if self.disagg and self.cache_mode != "paged":
            raise ValueError(
                "disaggregated prefill/decode hands off paged KV blocks; "
                "use cache_mode='paged'")
        if self.row_parallel:
            if not self.mesh_shape:
                raise ValueError(
                    "row_parallel is a serve-TP variant; set mesh_shape")
            if self.quant.group_size:
                raise ValueError(
                    "row_parallel row-slices the K axis of wo/wd, which "
                    f"grouped quant scales (group_size="
                    f"{self.quant.group_size}) tile; use per-channel "
                    "scales (group_size=0)")
        if self.cache_mode == "paged" and self.page_size % 8 != 0:
            raise ValueError(
                f"page_size={self.page_size} must be a multiple of the "
                "8-row f32 sublane (the paged-attention kernel tiles "
                "(page, head_dim) blocks)")
        if self.preempt_after < 0:
            raise ValueError(
                f"ServeConfig.preempt_after={self.preempt_after} must be "
                ">= 0 (0 disables recompute preemption)")
        if self.preempt_after and self.cache_mode != "paged":
            raise ValueError(
                "recompute preemption frees paged KV blocks; it needs "
                "cache_mode='paged'")
        if self.preempt_after and self.disagg:
            raise ValueError(
                "preempt_after targets decode-side admission; the "
                "disaggregated prefill worker has its own pool and is "
                "not preemptible (set preempt_after=0 with disagg=True)")
        if self.cache_mode == "paged" \
                and self.resolved_num_blocks < self.pages_per_request:
            raise ValueError(
                f"num_blocks={self.resolved_num_blocks} cannot hold even "
                f"one worst-case request ({self.pages_per_request} pages "
                f"of {self.page_size} for cache_len={self.cache_len})")
        return self


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 1e-3               # paper's MetaTT grid: {1e-3, 5e-4}
    betas: tuple = (0.9, 0.999)
    eps: float = 1e-8
    weight_decay: float = 0.0      # paper App. D: weight_decay = 0.0
    warmup_ratio: float = 0.06     # paper App. A.3
    grad_clip: float = 3.0         # paper App. B: max grad norm 3.0
    schedule: str = "linear"       # linear | cosine | constant


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    microbatch: int = 0            # 0 -> no gradient accumulation
    remat: str = "block"           # none | block (checkpoint each super-block)
    seed: int = 42                 # one of the paper's seeds (App. D)
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: str = ""
    ckpt_keep: int = 3
    grad_compression: str = "none"  # none | int8 | topk
    train_base: bool = False       # True -> full fine-tuning baseline (FT row)
    # DMRG-in-training: transport AdamW moments through each sweep (warm
    # carry, core/dmrg.py) instead of the paper's cold re-initialization
    dmrg_warm_moments: bool = True


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    shape: tuple = (1,)
    axes: tuple = ("data",)
    multi_pod: bool = False


@dataclasses.dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    adapter_kind: str = "metatt"   # metatt | lora | vera | lotr | none
    adapter_variant: str = "4d"    # metatt only: 4d | 5d | 4+1d | 4+ed
    adapter_rank: int = 8
    adapter_alpha: float = 4.0
    adapter_matrices: tuple = ()   # () -> arch default
    num_tasks: int = 0
    optimizer: OptimizerConfig = OptimizerConfig()
    train: TrainConfig = TrainConfig()
    kernels: KernelConfig = KernelConfig()
