from repro.config.base import (  # noqa: F401
    SHAPES,
    MeshConfig,
    ModelConfig,
    OptimizerConfig,
    RunConfig,
    ShapeConfig,
    TrainConfig,
)
