"""Unified adapter runtime for serving (paper §2.4 + Eq. (4)/(6)).

One MetaTT checkpoint can be served three ways; the runtime picks the mode
and hands the engine a uniform (spec, base, broadcast, per_layer) bundle:

  live   — the TT contraction runs per decode step (G1 / C[l,t,m] / G4:
           two rank-r GEMMs + one r×D GEMM per adapted matrix). Supports
           per-request task routing on the 4+1d task axis.
  lora   — ``core/merge.to_lora_form`` pre-folds the middle cores into the
           left boundary once (A = α·G1·C), so serving runs exactly two
           GEMMs per adapted matrix — "matching the speeds of LoRA" per the
           paper. Also supports per-request task routing (the task axis
           survives the fold as a leading axis of A).
  merged — ``core/merge.fold_transformer`` adds ΔW into the frozen weights
           (zero serving overhead). The 4+1d task axis is frozen to ONE
           task id at fold time, so mixed-task batches must use live/lora.
  none   — base model only.

Task routing: runtimes whose mode keeps the task axis (live/lora on a 4+1d
adapter) report ``tasked=True``; the engine then threads a per-slot (B,)
task-id vector into every adapter delta, which gathers per-row C[l, t_b, m]
slices from the SHARED tensor train — one decode batch mixes tasks with no
per-task adapter stacks (contrast LoRETTA / TT-LoRA deployments). Tasked
runtimes are also the ones the adapter registry can page
(``RegistryConfig(max_resident_tasks=K)``, DESIGN.md §12): the engine
swaps ``per_layer``'s task axis for a K-slot device pool and the (B,)
vector carries pool-slot indices instead — the runtime bundle itself is
unchanged, which is why the registry composes with every tasked mode.

Kernel fusion: under ``Engine(..., kernels=KernelConfig(...))`` both the
live and lora runtimes serve through the fused Pallas seam — paged-cache
attention runs the block-table kernel (kernels/paged_attention.py), and
on single-token steps the per-slot task gather lands in the
``tt_linear_batched_a`` kernel's leading A axis, one fused kernel per
adapted matrix (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

from repro.core import merge
from repro.peft import api as peft_api

MODES = ("live", "lora", "merged", "none")


@dataclasses.dataclass
class AdapterRuntime:
    """Everything the serving engine needs to run one adapter mode."""
    mode: str
    spec: peft_api.AdapterSpec     # effective spec (NONE for merged/none)
    base: Any                      # effective base weights (folded for merged)
    broadcast: Any
    per_layer: Any
    tasked: bool = False           # per-request task ids route the adapter
    folded_task: Optional[int] = None

    @classmethod
    def build(cls, mode: str, base, spec: peft_api.AdapterSpec, adapter,
              frozen=None, *, model_cfg=None,
              task: Optional[int] = None) -> "AdapterRuntime":
        """base: frozen model weights; (spec, adapter, frozen): the trained
        adapter; model_cfg: repro ModelConfig (required for mode="merged");
        task: the task id frozen into the weights for mode="merged" on a
        4+1d adapter (defaults to 0 for the 4d variants)."""
        if mode not in MODES:
            raise ValueError(f"unknown runtime mode {mode!r}; want {MODES}")
        frozen = frozen or {}
        if mode == "none" or spec.kind == "none":
            return cls(mode="none", spec=peft_api.NONE, base=base,
                       broadcast={}, per_layer=None)
        # any 4+1d adapter routes by task (delta_out requires an index even
        # when num_tasks == 1); 4+ed's extra axis is expert-, not request-,
        # indexed, so it is not request-routed here.
        has_tasks = spec.kind == "metatt" and spec.cfg.variant == "4+1d"
        if mode == "live":
            bc, pl = peft_api.adapter_factors(spec, adapter, frozen)
            return cls(mode="live", spec=spec, base=base, broadcast=bc,
                       per_layer=pl, tasked=has_tasks)
        if spec.kind != "metatt":
            raise ValueError(
                f"runtime mode {mode!r} pre-merges TT cores and only applies "
                f"to metatt adapters (got {spec.kind!r}); use mode='live'")
        if mode == "lora":
            if spec.cfg.variant == "4+ed":
                raise ValueError(
                    "4+ed expert routing (models/moe.py) contracts g1/C "
                    "directly; serve MoE-expert adapters with mode='live'")
            form = merge.to_lora_form(adapter, spec.cfg)
            return cls(mode="lora", spec=spec, base=base,
                       broadcast={"g4": form.b}, per_layer={"a": form.a},
                       tasked=has_tasks)
        # merged: fold ΔW into every adapted weight, serve with NO adapter
        if model_cfg is None:
            raise ValueError("mode='merged' needs model_cfg to locate every "
                             "adapted weight in the base pytree")
        fold_task = task
        if spec.cfg.variant in ("4+1d", "4+ed") and fold_task is None:
            fold_task = 0
        folded = merge.fold_transformer(adapter, spec.cfg, base, model_cfg,
                                        task=fold_task)
        return cls(mode="merged", spec=peft_api.NONE, base=folded,
                   broadcast={}, per_layer=None, folded_task=fold_task)

    def check_task(self, task: int) -> None:
        """Reject requests whose task id this runtime cannot honor."""
        if self.tasked:
            if not 0 <= task < self.spec.cfg.num_tasks:
                raise ValueError(
                    f"task id {task} out of range for num_tasks="
                    f"{self.spec.cfg.num_tasks}")
            return
        # untasked runtime: only the one task it serves (the folded slice,
        # or task 0 for task-axis-free adapters) may be requested — serving
        # anything else would silently ignore the routing the client asked
        # for.
        served = self.folded_task if self.folded_task is not None else 0
        if task != served:
            raise ValueError(
                f"runtime (mode={self.mode}) has no task routing and serves "
                f"task {served} only; request for task {task} needs a "
                "live/lora runtime on a 4+1d adapter")
