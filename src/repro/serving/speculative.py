"""Speculative multi-token decode with a rank-truncated TT self-drafter
(DESIGN.md §10).

MetaTT gives the serving engine a drafter for free: TT bond ranks NEST —
the rank-r' adapter obtained by slicing the leading r' bond columns of the
shared cores (``g1[:, :r']``, ``c[..., :r', :r']``, ``g4[:r', :]``) is
exactly the truncation the paper's DMRG rank adaptation optimizes over,
and the ultra-low-rank regime is where TT-LoRA / LoRETTA show adapted
models stay surprisingly close to their full-rank versions. The drafter
therefore shares the frozen base weights (optionally every stride-th
super-block of them), the paged KV block tables, the task routing and the
sampling configuration with the target model — only the adapter factors
(and optionally the layer count) shrink.

This module is pure function-of-arrays: drafter construction happens once
at engine build (host-side slicing of concrete arrays), and the accept
rules are jnp functions living inside the engine's jitted while_loop.

Accept rules (serving/engine.py wires them in):

  * greedy   — commit the longest draft prefix matching the verifier's
    per-column argmax, plus the verifier's own next token ("bonus").
    Because attention is causal, column i of the one-pass verification
    depends only on tokens <= i, so the committed stream is IDENTICAL to
    non-speculative greedy decode for ANY drafter — quality only moves
    throughput, never tokens.
  * sampling — Leviathan-style rejection sampling: accept draft d_j with
    probability min(1, p_{j-1}(d_j) / q_j(d_j)); on the first rejection
    emit a token from the residual norm(max(p - q, 0)); if every draft
    survives, emit a bonus token from p_k. The marginal of the committed
    stream equals sampling from p directly — the output DISTRIBUTION is
    provably unchanged by speculation.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import SpecConfig

# ---------------------------------------------------------------------------
# drafter construction (host-side, once per engine)
# ---------------------------------------------------------------------------


def truncate_factors(kind: str, broadcast, per_layer, draft_rank: int):
    """Rank-truncate an AdapterRuntime's (broadcast, per_layer) factor
    bundle to TT bond rank ``draft_rank`` (0 = keep full rank).

    Handles the layouts the serving runtimes produce:
      * metatt live:      broadcast {"g1": (Din, r), "g4": (r, Dout)},
                          per_layer {"c": (L, [T,] M, r, r)}
      * metatt lora-form: broadcast {"g4": (r, Dout)},
                          per_layer {"a": (L, [T,] M, Din, r)}
      * plain lora:       per_layer {"a": (L, M, Din, r),
                                     "b": (L, M, r, Dout)}
    Other kinds (vera / lotr / merged / none) return unchanged — the
    drafter then equals the target adapter and speculation still works
    (it just cannot be cheaper on the adapter side).
    """
    if draft_rank <= 0:
        return broadcast, per_layer
    rd = draft_rank
    bc = dict(broadcast) if broadcast else {}
    pl = dict(per_layer) if per_layer else None
    if kind == "metatt" and pl is not None:
        if "g1" in bc:
            bc["g1"] = bc["g1"][:, :rd]
        if "g4" in bc:
            bc["g4"] = bc["g4"][:rd, :]
        if "c" in pl:
            pl["c"] = pl["c"][..., :rd, :rd]
        if "a" in pl:
            pl["a"] = pl["a"][..., :rd]
        return bc, pl
    if kind == "lora" and pl is not None and "a" in pl and "b" in pl:
        return bc, {"a": pl["a"][..., :rd], "b": pl["b"][..., :rd, :]}
    return broadcast, per_layer


def stride_base(base, stride: int) -> Tuple[Any, int]:
    """Keep every ``stride``-th super-block of the frozen base. Returns
    (draft_base, nb_draft). Leaves of ``base["blocks"]`` are stacked on a
    leading nb axis (int8-packed {"q8","scale"} leaves included), so one
    tree_map slices them all; embed / final_norm are SHARED with the
    target (same arrays — no extra memory)."""
    nb = jax.tree_util.tree_leaves(base["blocks"])[0].shape[0]
    if stride <= 1:
        return base, nb
    blocks = jax.tree_util.tree_map(lambda a: a[::stride], base["blocks"])
    nb_draft = len(range(0, nb, stride))
    draft = dict(base)
    draft["blocks"] = blocks
    return draft, nb_draft


def stride_per_layer(per_layer, nb: int, p: int, stride: int):
    """Slice the adapter's per-layer factors (leading axis L = nb * p) to
    the drafter's layer subset: reshape L -> (nb, p), keep every
    stride-th super-block, flatten back."""
    if per_layer is None or stride <= 1:
        return per_layer

    def one(a):
        g = a.reshape((nb, p) + a.shape[1:])[::stride]
        return g.reshape((-1,) + a.shape[1:])

    return jax.tree_util.tree_map(one, per_layer)


def build_drafter(spec_cfg: SpecConfig, adapter_kind: str, base, broadcast,
                  per_layer, pattern_len: int) -> Tuple[Any, Any, Any, int]:
    """(draft_base, draft_broadcast, draft_per_layer, nb_draft) — the
    weight bundle the engine passes to the drafter's step graphs. Called
    once at engine construction on concrete (possibly int8-packed)
    arrays; the jitted loop never slices."""
    bc, pl = truncate_factors(adapter_kind, broadcast, per_layer,
                              spec_cfg.draft_rank)
    dbase, nb = stride_base(base, spec_cfg.draft_layer_stride)
    full_nb = jax.tree_util.tree_leaves(base["blocks"])[0].shape[0]
    pl = stride_per_layer(pl, full_nb, pattern_len,
                          spec_cfg.draft_layer_stride)
    return dbase, bc, pl, nb


# ---------------------------------------------------------------------------
# in-graph accept rules (inside the engine's jitted while_loop)
# ---------------------------------------------------------------------------


def greedy_verify(draft: jnp.ndarray,
                  verify_argmax: jnp.ndarray) -> Tuple[jnp.ndarray,
                                                       jnp.ndarray]:
    """draft: (B, k) drafter proposals; verify_argmax: (B, k+1) per-column
    argmax of the one-pass verification logits (column i scored after
    consuming token i of [committed, d_1..d_k]).

    Returns (emitted (B, k+1), n_accepted (B,)). Under acceptance
    d_j == verify_argmax[:, j-1], so the emitted stream IS the verifier's
    argmax stream — token-identical to non-speculative greedy decode."""
    acc = (draft == verify_argmax[:, :-1]).astype(jnp.int32)
    n = jnp.cumprod(acc, axis=1).sum(axis=1)
    return verify_argmax, n


def rejection_verify(key, draft: jnp.ndarray, draft_probs: jnp.ndarray,
                     target_probs: jnp.ndarray) -> Tuple[jnp.ndarray,
                                                         jnp.ndarray]:
    """Rejection-sampling accept (temperature / top-k / top-p decoding).

    draft: (B, k) tokens drawn d_j ~ q_j; draft_probs: (B, k, V) the q_j;
    target_probs: (B, k+1, V) the target distributions p_0..p_k (p_{j-1}
    is the target's distribution for the token draft d_j proposed).
    Accept d_j with prob min(1, p_{j-1}(d_j)/q_j(d_j)); at the first
    rejection emit from the residual norm(max(p_n - q_{n+1}, 0)); if all
    k survive, emit a bonus token from p_k. Returns
    (emitted (B, k+1), n_accepted (B,)): emitted[:, :n] == accepted
    drafts, emitted[:, n] the correction/bonus draw. The marginal law of
    the committed tokens equals autoregressive sampling from p."""
    b, k = draft.shape
    ku, kr = jax.random.split(key)
    u = jax.random.uniform(ku, (b, k))
    p_at_d = jnp.take_along_axis(target_probs[:, :k], draft[..., None],
                                 axis=-1)[..., 0]
    q_at_d = jnp.take_along_axis(draft_probs, draft[..., None],
                                 axis=-1)[..., 0]
    acc = u < jnp.minimum(p_at_d / jnp.maximum(q_at_d, 1e-20), 1.0)
    n = jnp.cumprod(acc.astype(jnp.int32), axis=1).sum(axis=1)   # (B,)
    # distribution at the correction position: residual when a draft was
    # rejected (n < k), the plain target p_k for the bonus token
    p_n = jnp.take_along_axis(target_probs, n[:, None, None],
                              axis=1)[:, 0]                       # (B, V)
    q_n = jnp.take_along_axis(draft_probs,
                              jnp.clip(n, 0, k - 1)[:, None, None],
                              axis=1)[:, 0]
    res = jnp.maximum(p_n - jnp.where((n < k)[:, None], q_n, 0.0), 0.0)
    z = res.sum(axis=-1, keepdims=True)
    res = jnp.where(z > 0, res / jnp.maximum(z, 1e-20), p_n)
    corr = jax.random.categorical(
        kr, jnp.log(jnp.maximum(res, 1e-38)), axis=-1).astype(jnp.int32)
    cols = jnp.arange(k + 1)[None, :]
    dpad = jnp.pad(draft, ((0, 0), (0, 1)))
    emitted = jnp.where(cols < n[:, None], dpad, corr[:, None])
    return emitted, n


def column_penalty_masks(base_mask: Optional[jnp.ndarray],
                         draft: jnp.ndarray, vocab: int):
    """Per-column repetition-penalty masks for the one-pass verification.

    Column i's distribution governs the token emitted AFTER d_1..d_i, so
    its penalty set is the emitted history plus the in-chunk prefix
    {d_1..d_i} — exactly what the non-speculative engine would have
    accumulated token by token (under acceptance d_j equals the committed
    stream). base_mask: (B, V) or None; draft: (B, k). Returns
    (B, k+1, V) or None when no penalty is active."""
    if base_mask is None:
        return None
    oh = jax.nn.one_hot(draft, vocab, dtype=jnp.bool_)        # (B, k, V)
    cum = jnp.cumsum(oh, axis=1).astype(bool)
    cum = jnp.pad(cum, ((0, 0), (1, 0), (0, 0)))              # col 0: none
    return base_mask[:, None, :] | cum
