"""Host-side KV block management for the paged serving engine.

The device holds one flat pool of KV blocks per layer (leaves shaped
``(nb, num_blocks, page_size, kv_heads, head_dim)``, models/transformer.py
``init_paged_caches``); everything about *which* request owns *which*
block lives here, in plain Python, where it is cheap to test:

  * ``BlockManager`` — free list + per-block reference counts. A block is
    writable only while its refcount is exactly 1 (one slot, no sharers);
    the engine copies-on-write before a slot ever writes into a block it
    shares (the copy itself is a device op, ``transformer.copy_cache_block``
    — this module only decides *when*).
  * ``PrefixCache`` — hash-chained prompt-prefix index. Each full prompt
    page is keyed by ``(parent_key, page_tokens)``, so a chain lookup walks
    the prompt page by page; the final partial page is cached too (keyed by
    its exact token tuple under the same parent) and matched by longest
    common token prefix — that is what makes warm requests that *diverge*
    mid-page share the page and then copy-on-write. The cache holds one
    refcount on every cached block; eviction (LRU over chain leaves) only
    frees blocks no live slot references.

MetaTT context: on a task-routed (4+1d) runtime, ANY task-adapted matrix
(q/v in the paper's default) perturbs the residual stream, so prefix KV
at layers >= 1 is task-dependent even where the k/v projections
themselves are frozen — tasked runtimes therefore key chains per task id
(the ``namespace`` argument). What the ONE shared tensor train still
buys over per-task LoRA/TT-LoRA stacks: every task lives in one engine
with one block pool (shared capacity, one admission queue), untasked /
merged / single-task runtimes share one global namespace, and within a
task the common system-prompt prefix of a request stream is cached once.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.serving.lru import LRUClock


class BlockManager:
    """Free list + refcounts over ``num_blocks`` KV blocks of ``page_size``
    tokens. Pure host state; no jax.

    COW rule (the one invariant everything else leans on): a block is
    WRITABLE only at refcount exactly 1 — one slot, no prefix-cache
    sharers. The scheduler checks ``writable`` at admit time and, when a
    request's first writable position lands inside a shared page,
    allocates a fresh block and schedules ONE device copy
    (``transformer.copy_cache_block``) before the slot ever decodes;
    the jitted loop itself never copies or allocates.

    Sharding note (DESIGN.md §9): block ids are shard-agnostic — pools
    shard on the kv-head axis, never on blocks, so id ``bid`` addresses
    row ``bid`` of EVERY shard's pool and one host-side decision is
    valid on all shards. One BlockManager serves any mesh size.
    """

    def __init__(self, num_blocks: int, page_size: int):
        """num_blocks: pool capacity; page_size: tokens per block (both
        >= 1). All blocks start free with refcount 0."""
        if num_blocks < 1 or page_size < 1:
            raise ValueError((num_blocks, page_size))
        self.num_blocks = num_blocks
        self.page_size = page_size
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._ref = [0] * num_blocks

    # -- introspection -------------------------------------------------
    @property
    def free_blocks(self) -> int:
        """Blocks currently allocatable (refcount 0)."""
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        """Blocks held by at least one slot or the prefix cache."""
        return self.num_blocks - len(self._free)

    def refcount(self, bid: int) -> int:
        """Current reference count of block ``bid`` (0 = free)."""
        return self._ref[bid]

    # -- alloc / share / free ------------------------------------------
    def alloc(self) -> int:
        """Take a free block with refcount 1. Raises if the pool is empty
        (callers check ``free_blocks`` / run cache eviction first)."""
        if not self._free:
            raise RuntimeError("KV block pool exhausted")
        bid = self._free.pop()
        assert self._ref[bid] == 0, bid
        self._ref[bid] = 1
        return bid

    def ref(self, bid: int) -> int:
        """Add a reference to an in-use block (prefix sharing)."""
        if self._ref[bid] <= 0:
            raise ValueError(f"ref of free block {bid}")
        self._ref[bid] += 1
        return bid

    def deref(self, bid: int) -> bool:
        """Drop one reference; returns True if the block was freed."""
        if self._ref[bid] <= 0:
            raise ValueError(f"deref of free block {bid}")
        self._ref[bid] -= 1
        if self._ref[bid] == 0:
            self._free.append(bid)
            return True
        return False

    def writable(self, bid: int) -> bool:
        """A slot may write into a block only if nobody else (slot or
        prefix cache) also holds it — otherwise copy-on-write first."""
        return self._ref[bid] == 1

    # -- cross-pool migration (disaggregated prefill, DESIGN.md §11) ---
    def migrate_to(self, dst: "BlockManager",
                   blocks: List[int]) -> Optional[List[Tuple[int, int]]]:
        """Transfer ownership of ``blocks`` from this pool to ``dst``:
        allocate one twin per block in ``dst`` (refcount 1) and drop this
        pool's reference. Returns the ``(src, dst)`` id pairs — the
        device-side batched block copy the engine runs between the two
        physical pools — or None (nothing moved, no refs touched) when
        ``dst`` cannot supply enough blocks; the caller retries after
        decode-side evictions.

        This is the prefill→decode handoff's host half: block ids are
        pool-local, so the transfer is pure bookkeeping — refcounts move,
        page order is preserved, and the prefill-side blocks return to
        their free list (or stay pinned by the prefill prefix cache if it
        also holds a ref)."""
        if dst.free_blocks < len(blocks):
            return None
        pairs = []
        for bid in blocks:
            if self._ref[bid] <= 0:
                raise ValueError(f"migrate of free block {bid}")
            pairs.append((bid, dst.alloc()))
        for bid in blocks:
            self.deref(bid)
        return pairs


@dataclasses.dataclass
class _Entry:
    key: tuple                 # (parent_key, tokens) — the chain hash key
    block: int
    parent: Optional[tuple]
    tokens: Tuple[int, ...]    # tokens stored in this page (may be partial)
    full: bool                 # len(tokens) == page_size
    children: int = 0


#: chain root sentinel (start of every prompt)
_ROOT = ("root",)


@dataclasses.dataclass
class PrefixMatch:
    """Result of a prefix-cache lookup: device-visible block ids covering
    the first ``tokens`` prompt tokens (refs already taken)."""
    blocks: List[int]
    tokens: int


class PrefixCache:
    """Hash-chained prompt-prefix → KV-block index (see module docstring).

    The cache owns one refcount per cached block, so cached blocks survive
    the requests that produced them; ``evict_lru`` releases leaf entries
    (no cached children, no live-slot references) when the pool runs dry.
    ``namespace`` isolates chains (used to key per-task when the adapter
    adapts k/v projections per task — KV then differs across tasks).
    """

    def __init__(self, bm: BlockManager):
        """bm: the pool whose blocks this cache pins (one refcount per
        cached entry). Starts empty."""
        self.bm = bm
        self._entries: Dict[tuple, _Entry] = {}
        self._partials: Dict[tuple, List[tuple]] = {}  # parent -> entry keys
        # recency over entry keys — same helper the AdapterRegistry uses
        # over pool slots, so both caches share one eviction ordering
        self._clock = LRUClock()

    def __len__(self) -> int:
        """Number of cached page entries (== pinned blocks)."""
        return len(self._entries)

    @property
    def cached_blocks(self) -> int:
        """Blocks currently pinned by the cache (one per entry)."""
        return len(self._entries)

    def _touch(self, e: _Entry) -> None:
        self._clock.touch(e.key)

    @staticmethod
    def _root(namespace) -> tuple:
        return _ROOT if namespace is None else (_ROOT, namespace)

    # -- lookup --------------------------------------------------------
    def match(self, tokens, namespace=None) -> PrefixMatch:
        """Longest cached prefix of ``tokens``. Takes one ref per matched
        block (caller derefs on release). Full pages chain exactly; the
        remainder matches a cached partial page by longest common token
        prefix (shared-then-diverge requests reuse the page and COW)."""
        page = self.bm.page_size
        toks = [int(t) for t in tokens]
        blocks: List[int] = []
        n = 0
        parent = self._root(namespace)
        for i in range(0, len(toks) - page + 1, page):
            key = (parent, tuple(toks[i:i + page]))
            e = self._entries.get(key)
            if e is None:
                break
            self._touch(e)
            blocks.append(self.bm.ref(e.block))
            n += page
            parent = key
        rest = toks[n:]
        if rest:
            best, best_n = None, 0
            for key in self._partials.get(parent, ()):
                e = self._entries[key]
                common = 0
                for a, b in zip(rest, e.tokens):
                    if a != b:
                        break
                    common += 1
                if common > best_n:
                    best, best_n = e, common
            if best is not None and best_n > 0:
                self._touch(best)
                blocks.append(self.bm.ref(best.block))
                n += best_n
        return PrefixMatch(blocks=blocks, tokens=n)

    # -- registration --------------------------------------------------
    def register(self, tokens, table: List[int], namespace=None) -> int:
        """Index a finished request's prompt pages (the engine calls this
        at evict time, when every prompt cell's KV has been computed).

        tokens: the full prompt; table[i]: the block holding page i. Pages
        already cached are skipped (the request derefs its own copy later);
        new pages gain a cache refcount. Cells past the prompt in the last
        partial page may hold generated-token KV — harmless, a future
        sharer masks cells beyond its own position and copies-on-write
        before writing. Returns the number of newly cached blocks.
        """
        page = self.bm.page_size
        toks = [int(t) for t in tokens]
        parent = self._root(namespace)
        added = 0
        for pi in range(-(-len(toks) // page)):
            ptoks = tuple(toks[pi * page:(pi + 1) * page])
            full = len(ptoks) == page
            key = (parent, ptoks)
            e = self._entries.get(key)
            if e is None:
                e = _Entry(key=key, block=self.bm.ref(table[pi]),
                           parent=parent, tokens=ptoks, full=full)
                self._entries[key] = e
                if parent in self._entries:
                    self._entries[parent].children += 1
                if not full:
                    self._partials.setdefault(parent, []).append(key)
                added += 1
            self._touch(e)
            if not full:
                break
            parent = key
        return added

    # -- eviction ------------------------------------------------------
    def _evictable(self) -> List[_Entry]:
        return [e for e in self._entries.values()
                if e.children == 0 and self.bm.refcount(e.block) == 1]

    def drainable_count(self) -> int:
        """How many cached blocks COULD come back to the pool if eviction
        ran to exhaustion right now: an entry drains iff nothing but the
        cache holds it and its whole subtree drains (leaf-first order).
        The scheduler checks this before evicting anything, so infeasible
        admissions never destroy cache state they cannot benefit from."""
        kids: Dict[tuple, List[_Entry]] = {}
        for e in self._entries.values():
            kids.setdefault(e.parent, []).append(e)
        memo: Dict[tuple, bool] = {}

        def drains(e: _Entry) -> bool:
            if e.key not in memo:
                memo[e.key] = (self.bm.refcount(e.block) == 1
                               and all(drains(c)
                                       for c in kids.get(e.key, ())))
            return memo[e.key]

        return sum(1 for e in self._entries.values() if drains(e))

    def evict_lru(self, need_blocks: int) -> int:
        """Free least-recently-used leaf entries until ``need_blocks``
        blocks came back to the pool (or nothing more is evictable).
        Returns how many blocks were freed."""
        freed = 0
        while freed < need_blocks:
            cands = self._evictable()
            if not cands:
                break
            e = self._entries[self._clock.oldest(c.key for c in cands)]
            self._drop(e)
            freed += 1
        return freed

    def _drop(self, e: _Entry) -> None:
        del self._entries[e.key]
        self._clock.forget(e.key)
        if e.parent in self._entries:
            self._entries[e.parent].children -= 1
        if not e.full:
            sibs = self._partials.get(e.parent)
            if sibs:
                sibs.remove(e.key)
                if not sibs:
                    del self._partials[e.parent]
        self.bm.deref(e.block)

    def clear(self) -> None:
        """Drop every entry and release the cache's refcounts (blocks
        still held by live slots stay allocated)."""
        for e in list(self._entries.values()):
            self._drop(e)
