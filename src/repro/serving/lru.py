"""Shared LRU bookkeeping for the host-side caches (DESIGN.md §12).

Both residency managers in the serving stack — the ``PrefixCache``
(KV-block prefix index, block_manager.py) and the ``AdapterRegistry``
(device task-slot pool, adapter_registry.py) — need the same primitive:
a monotonic recency clock over hashable keys, where eviction picks the
least-recently-touched entry among whatever subset the caller deems
evictable (unpinned leaves for the prefix cache, unpinned slots for the
registry). ``LRUClock`` is that primitive, extracted so the eviction
ordering is implemented — and property-tested (tests/test_property.py)
— exactly once.

Pure host state, no jax. The clock never decides *what* is evictable;
callers pass the candidate set and get the stalest member back.
"""
from __future__ import annotations

from typing import Dict, Hashable, Iterable, Optional


class LRUClock:
    """Monotonic recency clock: ``touch`` stamps a key with the next tick,
    ``oldest`` returns the least-recently-touched of a candidate set.

    Keys never touched rank older than any touched key (tick 0), and ties
    — only possible among never-touched keys — break toward the earliest
    candidate in iteration order, keeping eviction deterministic.
    """

    def __init__(self) -> None:
        self._tick = 0
        self._ticks: Dict[Hashable, int] = {}

    def __len__(self) -> int:
        return len(self._ticks)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._ticks

    def touch(self, key: Hashable) -> int:
        """Stamp ``key`` as most-recently-used; returns its new tick."""
        self._tick += 1
        self._ticks[key] = self._tick
        return self._tick

    def forget(self, key: Hashable) -> None:
        """Drop ``key``'s stamp (evicted / released entries)."""
        self._ticks.pop(key, None)

    def tick_of(self, key: Hashable) -> int:
        """Current stamp of ``key`` (0 = never touched == infinitely old)."""
        return self._ticks.get(key, 0)

    def oldest(self, candidates: Iterable[Hashable]) -> Optional[Hashable]:
        """The least-recently-touched member of ``candidates`` (None when
        empty). ``min`` is stable, so equal-tick (never-touched) keys fall
        back to candidate order — deterministic for list inputs."""
        cands = list(candidates)
        if not cands:
            return None
        return min(cands, key=self.tick_of)
