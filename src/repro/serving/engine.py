"""Continuous-batching serving engine with a paged KV cache.

Architecture (README §Serving, DESIGN.md §7):

  * The engine owns ``max_batch`` decode SLOTS stepped together by ONE
    jitted ``jax.lax.while_loop``. Per-slot device state lives in a single
    fixed-shape pytree; request metadata stays on the host.
  * PAGED KV CACHE (default): k/v live in a flat pool of
    ``num_blocks × page_size`` blocks per layer; each slot owns a block
    table mapping logical pages to physical blocks. A host-side
    ``BlockManager`` (free list, refcounts, copy-on-write) owns the pool;
    the ``Scheduler`` admits requests by FREE BLOCKS, not free slots —
    memory is reserved per request need, not worst-case per slot.
  * PREFIX SHARING: prompt pages are indexed in a hash-chained
    ``PrefixCache`` at request completion; later requests sharing a prompt
    prefix map the cached blocks into their table instead of recomputing
    them (refcounted; divergence inside a shared partial page
    copies-on-write at admit time, so the decode loop never stops for a
    copy). Chains are namespaced per task id on task-routed runtimes —
    any task-adapted matrix perturbs the residual stream, so deep-layer
    prefix KV is task-dependent even with frozen k/v projections; what
    ONE global MetaTT adapter buys over per-task LoRA stacks is one
    engine and one block pool for every task (see block_manager.py).
  * IN-LOOP CHUNKED PREFILL: the while_loop body processes a fixed
    ``(B, prefill_chunk)`` token block — prefilling slots consume up to
    ``prefill_chunk`` prompt tokens per step while decode slots carry one
    real token, co-batched in the SAME graph. There is no separate prefill
    function and no per-bucket recompile ladder: the step compiles once
    for all prompt lengths (the dense mode's ``_bucket`` ladder survives
    only behind ``ServeConfig(cache_mode="dense")``, the parity baseline).
  * The loop returns to the host exactly when some slot finishes — the
    host EVICTS it (harvests the output row, returns blocks to the pool /
    prefix cache) and ADMITS pending requests into freed slots while other
    slots keep generating.
  * TASK ROUTING: each slot carries a task id; with a 4+1d adapter under
    the live/lora runtime the (B,) slot task vector gathers per-row
    C[l, t_b, m] slices from the one shared tensor train (paper
    Eq. (4)/(6)) — a single decode batch mixes tasks.
  * ADAPTER PAGING (DESIGN.md §12): with
    ``ServeConfig(registry=RegistryConfig(max_resident_tasks=K))`` the
    per-task factor axis on device shrinks to a fixed K-slot pool per
    replica; the full factors stay host-side and a host AdapterRegistry
    (task → slot, pins, LRU eviction — the BlockManager pattern applied
    to adapters) pages task slices in via one jitted donated scatter
    per fault. The slot task vector then carries POOL-SLOT indices, the
    Scheduler gates admission on slot availability exactly like block
    availability, and prefix-cache namespaces stay keyed on the TASK ID
    so an evicted-and-readmitted task still warm-hits its cached
    prompts. One engine serves an open-ended task population (paper
    Eq. (4)/(6): per-task marginal cost = one core slice).
  * QUANTIZED SERVING (DESIGN.md §8): MetaTT's base is frozen by
    construction, so base weights + KV cache are pure read-only
    bandwidth. ``QuantConfig(weights="int8")`` packs the base matmul
    leaves once at construction (the fused w8a16 kernels dequantize
    in-register; the TT delta stays fp); ``kv="int8"`` stores paged KV
    cells as int8 with per-cell scale pools in the same block layout, so
    the same num_blocks HBM budget holds ~2x (bf16) the tokens and
    prefix sharing / COW round-trip the quantized representation.
  * TENSOR-PARALLEL SERVING (DESIGN.md §9): with
    ``ServeConfig(mesh_shape=(data, model))`` the engine builds a mesh
    (sharding/rules.py::serve_mesh) and wraps every jitted step graph —
    admit, COW, the prefill+decode while_loop — in ``shard_map``. The
    K/V (and int8 scale) pools shard on the KV-HEAD axis over "model":
    each shard scatters and attends only its contiguous head group
    against its local pool shard, the readout computes a per-shard
    vocab stripe, and the (B, V) logits are all-gathered for in-graph
    sampling — the only collectives in the loop. TT cores, block
    tables, slot state, task ids and the sampling PRNG are replicated,
    and ALL admission / eviction / COW decisions stay host-side on the
    shard-agnostic BlockManager (one block id indexes every shard's
    pool), so sharded greedy decode is token-identical to the
    single-device engine and per-shard peak KV bytes are 1/|model| of
    the global figure (``EngineStats.kv_bytes_peak_per_shard``).
  * FLEET SERVING (DESIGN.md §11): the second mesh axis stripes the
    engine data-parallel — a host-side ``Router`` places each request on
    one of |data| decode REPLICAS (deterministic least-loaded or
    round-robin), each replica owning its own ``max_batch`` slot stripe,
    its own ``num_blocks`` stripe of the paged pools (block ids are
    replica-local) and its own Scheduler/BlockManager/PrefixCache. The
    jitted step graphs shard_map over BOTH axes, so each data shard
    decodes only its own slot stripe — admit/COW writes on the other
    replicas drop via out-of-bounds sentinels. ``ServeConfig(disagg=
    True)`` additionally splits prefill from decode: a prefill WORKER
    (a second state+pool pair with identical geometry, so it reuses the
    same compiled graphs — decode_traces stays 1) chunk-prefills
    prompts and emits the first token, then the host hands the sequence
    to a decode replica by migrating its prompt blocks pool-to-pool
    (BlockManager.migrate_to + transformer.migrate_cache_blocks); the
    prefix cache lives with the prefill pool. ``row_parallel=True``
    switches wo/wd to row-sharded weights with a psum epilogue
    (models/layers.py::serve_rp_linear) — near-parity (~1e-3) against
    the column-only mode, which stays the bit-exact parity oracle.

The engine requires attention-pattern models (stateful mixers — mamba /
xlstm — have no position-indexed cache to page).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Any, Callable, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.checkpoint import ckpt as ckpt_lib
from repro.config.base import (KernelConfig, ModelConfig, QuantConfig,
                               ServeConfig)
from repro.kernels import dispatch as kernel_dispatch
from repro.kernels import quant as quant_lib
from repro.models import transformer
from repro.peft import api as peft_api
from repro.serving import adapter_registry
from repro.serving import chaos as chaos_mod
from repro.serving import sampling as sampling_lib
from repro.serving import speculative as spec_lib
from repro.serving.adapter_registry import AdapterRegistry
from repro.serving.adapter_runtime import AdapterRuntime
from repro.serving.block_manager import BlockManager, PrefixCache
from repro.serving.router import Router
from repro.serving.scheduler import Scheduler
from repro.serving.stats import EngineStats
from repro.sharding import (serve_cache_pspec, serve_cache_sharding,
                            serve_dp_index, serve_mesh, serve_tp_slice,
                            set_serve_dp, set_serve_rp, set_serve_tp)
from repro.sharding.compat import shard_map


@dataclasses.dataclass
class Request:
    """One generation request. prompt: 1-D int token ids (list/np/jnp).

    deadline_s: optional wall-clock budget measured from ``generate``
    entry — a request still unfinished when it expires ends with status
    TIMEOUT and whatever tokens it produced. request_id: host-side
    handle for ``Engine.cancel`` (defaults to the request's batch
    index)."""
    prompt: Any
    max_new_tokens: int
    task: int = 0
    deadline_s: Optional[float] = None
    request_id: Optional[Any] = None


# terminal request statuses (DESIGN.md §13). PREEMPTED and replica
# failover are not terminal: the victim re-enters the queue through the
# recompute path and still ends in one of these (RequestResult.preemptions
# records how many recompute round-trips it survived).
FINISHED = "FINISHED"
CANCELLED = "CANCELLED"
TIMEOUT = "TIMEOUT"
FAILED = "FAILED"


class RequestResult(NamedTuple):
    """Per-request outcome of one ``generate`` call
    (``engine.last_results``). ``tokens`` holds everything the request
    emitted — possibly fewer than max_new_tokens when it was cancelled,
    timed out or failed; FAILED requests (in-graph NaN/inf logit
    detection) keep the tokens emitted BEFORE the fault."""
    tokens: np.ndarray
    status: str
    n_generated: int
    preemptions: int = 0


def _pad_caches(caches, cfg: ModelConfig, batch: int, cache_len: int,
                num_super_blocks: Optional[int] = None):
    """Place length-T prefill caches into a fixed cache_len-wide template.
    ``num_super_blocks`` sizes the template for the speculative drafter's
    layer-strided sub-model."""
    template = transformer.init_caches(cfg, batch, cache_len,
                                       cfg.compute_dtype,
                                       num_super_blocks=num_super_blocks)
    if caches is None:
        return template

    def pad(c, z):
        return jax.lax.dynamic_update_slice(z, c.astype(z.dtype),
                                            (0,) * c.ndim)

    return [jax.tree_util.tree_map(pad, c, t)
            for c, t in zip(caches, template)]


class DecodeState(NamedTuple):
    """Dense-mode loop-carried per-slot device state. ``dcaches`` is the
    speculative drafter's parallel KV region (None when speculation is
    off); steps/drafted/accepted are loop-carried int32 scalar counters
    the host reads off the final state (stats.py)."""
    tok: jnp.ndarray        # (B, 1)  last sampled token per slot
    pos: jnp.ndarray        # (B,)    cache position tok will be written at
    remaining: jnp.ndarray  # (B,)    tokens still to sample
    active: jnp.ndarray     # (B,)    slot is mid-generation
    widx: jnp.ndarray       # (B,)    next column of the output buffer
    out: jnp.ndarray        # (B, out_cap) generated tokens
    task: jnp.ndarray       # (B,)    per-slot task id (4+1d routing)
    key: jnp.ndarray        # PRNG key (in-graph sampling)
    caches: Any             # transformer KV caches, batch axis = slots
    dcaches: Any = None     # drafter KV caches (speculative decode)
    steps: Any = 0          # loop iterations (engine steps)
    drafted: Any = 0        # drafter tokens proposed
    accepted: Any = 0       # drafter tokens accepted by the verifier
    failed: Any = None      # (B,) bool: in-graph NaN guard tripped


class PagedState(NamedTuple):
    """Paged-mode loop-carried per-slot device state. A slot is either
    PREFILLING (done < plen: the body consumes up to ``prefill_chunk``
    prompt tokens per step) or DECODING (one sampled token per step) —
    both co-batched in the same fixed-shape graph. Block tables are NOT
    loop-carried: they only change at admit/evict boundaries, which the
    loop already crosses, so the host passes them as a plain argument."""
    tok: jnp.ndarray        # (B, 1)  last sampled token per slot
    prompt: jnp.ndarray     # (B, Lp) full prompt tokens (right-padded)
    plen: jnp.ndarray       # (B,)    prompt length
    done: jnp.ndarray       # (B,)    tokens whose KV is in cache
    remaining: jnp.ndarray  # (B,)    tokens still to sample
    active: jnp.ndarray     # (B,)    slot is mid-request
    widx: jnp.ndarray       # (B,)    next column of the output buffer
    out: jnp.ndarray        # (B, out_cap) generated tokens
    task: jnp.ndarray       # (B,)    per-slot task id (4+1d routing)
    key: jnp.ndarray        # PRNG key (in-graph sampling)
    caches: Any             # paged KV pools (leaves (nb, N, page, KV, hd))
    dcaches: Any = None     # drafter KV pools, same block tables
    steps: Any = 0          # loop iterations (engine steps)
    drafted: Any = 0        # drafter tokens proposed
    accepted: Any = 0       # drafter tokens accepted by the verifier
    failed: Any = None      # (B,) bool: in-graph NaN guard tripped


class Engine:
    """Continuous-batching engine over an AdapterRuntime.

    ``serve`` (config.base.ServeConfig) picks the cache layout: "paged"
    (default — block/paged cache, prefix sharing, in-loop chunked prefill)
    or "dense" (the PR-1 slot layout, kept as the parity baseline). The
    legacy keyword arguments populate a ServeConfig when ``serve`` is not
    given. ``cache_len`` bounds prompt_len + max_new_tokens per request;
    ``out_cap`` bounds max_new_tokens. ``generate`` serves any number of
    requests through the fixed slots, admitting/evicting as they finish;
    per-call observability lands on ``engine.last_stats``.

    ``serve.mesh_shape=(data, model)`` makes the engine tensor-parallel
    (DESIGN.md §9): KV caches shard on the kv-head axis over "model"
    inside shard_map-wrapped step graphs, token-identically to the
    single-device engine (greedy). num_heads / num_kv_heads /
    padded_vocab must divide the "model" axis size.
    """

    def __init__(self, model_cfg: ModelConfig, runtime: AdapterRuntime, *,
                 max_batch: Optional[int] = None,
                 cache_len: Optional[int] = None,
                 out_cap: Optional[int] = None,
                 prompt_buckets: Optional[Sequence[int]] = None,
                 sampling: sampling_lib.SamplingConfig =
                 sampling_lib.SamplingConfig(),
                 seed: int = 0,
                 kernels: Optional[KernelConfig] = None,
                 serve: Optional[ServeConfig] = None):
        for mixer, _ in model_cfg.block_pattern:
            if mixer != "attn":
                raise NotImplementedError(
                    f"slot engine needs attention KV caches; mixer {mixer!r} "
                    "carries stateful caches that cannot be slot-inserted "
                    "or paged")
        if model_cfg.is_encdec:
            raise NotImplementedError("enc-dec serving is not slotted yet")
        if runtime.tasked and runtime.spec.adapts("moe_down"):
            # moe_down deltas apply over expert-sorted (E, C, ff) blocks
            # (models/moe.py), whose leading axis is experts — a per-request
            # (B,) task vector cannot index them.
            raise NotImplementedError(
                "per-request task routing does not reach the expert-sorted "
                "moe_down path; serve this adapter with a scalar task "
                "(per-task engines) or drop moe_down from matrix_types")
        legacy = dict(max_batch=max_batch, cache_len=cache_len,
                      out_cap=out_cap, prompt_buckets=prompt_buckets)
        if serve is None:
            serve = ServeConfig(
                max_batch=max_batch if max_batch is not None else 4,
                cache_len=cache_len if cache_len is not None else 64,
                out_cap=out_cap if out_cap is not None else 32,
                prompt_buckets=(tuple(prompt_buckets)
                                if prompt_buckets is not None else ()))
        elif any(v is not None for v in legacy.values()):
            given = [k for k, v in legacy.items() if v is not None]
            raise ValueError(
                f"pass serving shape knobs either via serve=ServeConfig "
                f"or via keyword arguments, not both (got serve= and "
                f"{given})")
        self.sv = serve.validate()
        self.cfg = model_cfg
        self.rt = runtime
        self.max_batch = self.sv.max_batch
        self.cache_len = self.sv.cache_len
        self.out_cap = self.sv.out_cap
        self.prompt_buckets = tuple(sorted(self.sv.prompt_buckets))
        self.sampling = sampling.validate()
        # tensor-parallel serving (DESIGN.md §9): the mesh is built once;
        # every step graph below is shard_map-wrapped over it. Head /
        # vocab groups are sliced contiguously per shard, so the sharded
        # dims must divide the TP axis (no silent replicated fallback —
        # the KV-pool memory claim would quietly evaporate).
        self.mesh = None
        self._tp = 1
        self._dp = 1                    # data replicas (DESIGN.md §11)
        self._dp_axis = "data"
        if self.sv.mesh_shape:
            self.mesh = serve_mesh(self.sv.mesh_shape)
            self._tp = int(self.mesh.shape[self.sv.tp_axis])
            # whichever mesh axis is NOT tensor-parallel stripes the
            # engine data-parallel: replica slot stripes + pool stripes
            self._dp_axis = ("data" if self.sv.tp_axis == "model"
                             else "model")
            self._dp = int(self.mesh.shape[self._dp_axis])
            if self._dp > 1 and self.sv.cache_mode != "paged":
                raise ValueError(
                    "data-axis request striping needs cache_mode='paged' "
                    "(replica pool stripes are paged block stripes)")
            for dim, name in ((model_cfg.num_heads, "num_heads"),
                              (model_cfg.num_kv_heads, "num_kv_heads"),
                              (model_cfg.padded_vocab, "padded_vocab")):
                if dim % self._tp:
                    raise ValueError(
                        f"{name}={dim} is not divisible by the "
                        f"{self.sv.tp_axis}-axis size {self._tp}; the "
                        "sharded engine slices contiguous head / vocab "
                        "groups per shard")
            if self.sv.row_parallel and model_cfg.d_ff % self._tp:
                raise ValueError(
                    f"row_parallel serving row-slices the ffn-down "
                    f"weight: d_ff={model_cfg.d_ff} must be divisible by "
                    f"the {self.sv.tp_axis}-axis size {self._tp}")
        # resolved once; static inside the jitted step graphs. With a
        # (4+1)d adapter the fused decode route is the batched-A kernel
        # (kernels/tt_linear.py::tt_linear_batched_a); paged attention
        # routes through kernels/paged_attention.py.
        self.policy = kernel_dispatch.resolve(kernels)
        # quantization (DESIGN.md §8): KernelConfig.quant and
        # ServeConfig.quant merge (int8 wins) — the base is packed ONCE
        # here, so every prefill/decode graph reads int8 weight leaves;
        # the KV side sizes the paged pools below.
        kq = (kernels.quant if isinstance(kernels, KernelConfig)
              else QuantConfig())
        sq = self.sv.quant
        self.quant = QuantConfig(
            weights="int8" if "int8" in (kq.weights, sq.weights) else "none",
            kv="int8" if "int8" in (kq.kv, sq.kv) else "none",
            group_size=kq.group_size or sq.group_size).validate()
        self._kv_quant = self.quant.kv == "int8"
        if self._kv_quant and self.sv.cache_mode != "paged":
            raise ValueError(
                "kv=int8 quantization needs cache_mode='paged' (the int8 "
                "cells and their scale pools live in the paged block "
                "layout)")
        if self.sv.row_parallel and self.quant.group_size:
            # config.base catches ServeConfig.quant; the KernelConfig
            # merge can re-introduce grouped scales, so re-check here
            raise ValueError(
                "row_parallel is incompatible with grouped int8 scales "
                "(group_size > 0): scale groups tile the contraction "
                "axis the row slices cut; use per-channel group_size=0")
        base = runtime.base
        if self.quant.weights == "int8":
            base = quant_lib.quantize_base(
                base, group_size=self.quant.group_size)
        # paged adapter registry (DESIGN.md §12): with
        # registry.max_resident_tasks=K the engine keeps a fixed K-slot
        # device pool per replica instead of the whole num_tasks axis —
        # the full factors stay HOST-side and admission faults task
        # slices in on demand. The per-slot (B,) task vector then carries
        # POOL-SLOT indices, so the traced task gather (and with it
        # decode_traces == 1) is untouched; only its index space shrinks.
        self.reg_cfg = self.sv.registry
        self._reg_on = self.reg_cfg.enabled
        if self._reg_on and not runtime.tasked:
            raise ValueError(
                f"RegistryConfig.max_resident_tasks="
                f"{self.reg_cfg.max_resident_tasks} needs a task-routed "
                "runtime (metatt 4+1d live/lora with num_tasks set); "
                "untasked/merged runtimes have no per-task slices to page")
        self._host_per_layer = None
        per_layer = runtime.per_layer
        if self._reg_on:
            self._host_per_layer = jax.device_get(runtime.per_layer)
            per_layer = self._commit_pool(adapter_registry.pool_factors(
                runtime.per_layer,
                self._dp * self.reg_cfg.max_resident_tasks))
        self._key = jax.random.PRNGKey(seed)
        self._weights = (base, runtime.broadcast, per_layer)
        # speculative decode (DESIGN.md §10): the drafter is a
        # rank-truncated / layer-strided slice of the SAME weight bundle
        # (sliced here once, on the possibly int8-packed base), proposing
        # spec_k tokens per engine step that the target verifies in one
        # co-batched pass inside the decode while_loop.
        self.spec = self.sv.spec
        self._spec_on = self.spec.enabled
        self._draft_weights = ()
        self._nb_draft = self.cfg.num_super_blocks
        self._host_draft_pl = None
        if self._spec_on:
            dbase, dbc, dpl, self._nb_draft = spec_lib.build_drafter(
                self.spec, self.rt.spec.kind, base, runtime.broadcast,
                runtime.per_layer, len(self.cfg.block_pattern))
            if self._reg_on:
                # the drafter factors are leading bond columns of the
                # SAME task slices (speculative.truncate_factors keeps
                # the task axis), so they page with their target slice:
                # one fault scatters both pools at the same slot
                self._host_draft_pl = jax.device_get(dpl)
                dpl = self._commit_pool(adapter_registry.pool_factors(
                    dpl, self._dp * self.reg_cfg.max_resident_tasks))
            self._draft_weights = (dbase, dbc, dpl)
        # the step graphs take target weights (+ drafter weights when
        # speculating) as leading args so none bake in as constants
        self._step_weights = self._weights + self._draft_weights
        if self._reg_on:
            # ONE jitted donated scatter per fault: the pool keeps its
            # shape and the slot index is traced, so every fault reuses
            # the same compile; donation makes it an in-place slot write.
            # Plain jit OUTSIDE shard_map — the pool is committed
            # replicated on the serve mesh (_commit_pool), so a replicated
            # update between loop exits is valid on every shard without
            # touching the sharded step graphs.
            if self._spec_on:
                self._afault = jax.jit(
                    lambda pl, dpl, slot, col, dcol: (
                        adapter_registry.scatter_slot(pl, slot, col),
                        adapter_registry.scatter_slot(dpl, slot, dcol)),
                    donate_argnums=(0, 1))
            else:
                self._afault = jax.jit(adapter_registry.scatter_slot,
                                       donate_argnums=(0,))
        self._decode_traces = 0
        self._prefill_traces = 0
        self.last_stats = self._new_stats()
        # request lifecycle (DESIGN.md §13): ids queued for cancellation
        # (consumed by the running generate), per-generate results with
        # status, and the live-bookkeeping handle chaos audits read
        self._cancel_ids = set()
        self.last_results: List[RequestResult] = []
        self._live = None
        self._chaos = None
        if self.sv.cache_mode == "dense":
            # dense mode has no Scheduler; the engine drives its (single)
            # registry directly in the dense admission/harvest loop.
            # _build_host_pools recreates the paged-mode registries.
            self.registries = ([AdapterRegistry(
                self.reg_cfg.max_resident_tasks,
                policy=self.reg_cfg.eviction)] if self._reg_on else [])
            self._prefill = jax.jit(self._prefill_impl)
            self._init_dense()
        else:
            self._init_paged()

    # ------------------------------------------------------------------
    # step-graph construction (single-device jit, or jit(shard_map) over
    # the serve mesh — DESIGN.md §9)
    # ------------------------------------------------------------------

    def _rep_spec(self, tree):
        """Fully-replicated PartitionSpec pytree matching ``tree``."""
        return jax.tree_util.tree_map(lambda _: P(), tree)

    def _commit_pool(self, tree):
        """Commit an adapter slot pool replicated onto the serve mesh
        (identity without one). Faulting runs through a plain jit, so
        the pool must carry an explicit replicated sharding — otherwise
        the fault output lands single-device and the shard_mapped step
        would reject it."""
        if self.mesh is None:
            return tree
        return jax.device_put(
            tree, jax.sharding.NamedSharding(self.mesh, P()))

    def _shard_mapped(self, fn, in_specs, out_specs):
        """Wrap a step impl in ``shard_map`` over the serve mesh (identity
        without one). The wrapper installs the serve-TP trace context
        (sharding.set_serve_tp) around tracing, which is what makes the
        attention / readout call sites slice this shard's head and vocab
        groups; it is cleared before control returns to the host."""
        if self.mesh is None:
            return fn
        axis, tp = self.sv.tp_axis, self._tp
        rp = bool(self.sv.row_parallel)
        dp = (self._dp_axis, self._dp) if self._dp > 1 else None

        def traced(*args):
            set_serve_tp(axis, tp)
            set_serve_rp(rp)
            if dp is not None:
                set_serve_dp(*dp)
            try:
                return fn(*args)
            finally:
                set_serve_tp(None)
                set_serve_rp(False)
                set_serve_dp(None)

        return shard_map(traced, mesh=self.mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)

    def _init_dense(self) -> None:
        """Jit (and, on a mesh, shard_map) the dense-mode step graphs.
        Sharded layout: decode caches (nb, B, S, KV, hd) shard the
        kv-head axis on "model"; prefill stays a plain replicated jit
        (it computes full-width caches that admit slices per shard).
        With speculation the drafter weights ride as three extra leading
        args and the drafter's KV region as a state field, so the decode
        graph's donate index shifts from 3 to 6."""
        don = 6 if self._spec_on else 3
        if self.mesh is None:
            self._admit = jax.jit(self._admit_impl, donate_argnums=(0,))
            self._decode = jax.jit(self._decode_impl, donate_argnums=(don,))
            self._kill = jax.jit(self._kill_dense_impl, donate_argnums=(0,))
            return
        template = transformer.init_caches(
            self.cfg, self.max_batch, self.cache_len, self.cfg.compute_dtype)
        dspec = P()
        d1spec = P()
        if self._spec_on:
            dtemplate = transformer.init_caches(
                self.cfg, self.max_batch, self.cache_len,
                self.cfg.compute_dtype, num_super_blocks=self._nb_draft)
            dspec = serve_cache_pspec(dtemplate, self.sv.tp_axis)
            d1spec = self._rep_spec(dtemplate)
        sspec = DecodeState(
            tok=P(), pos=P(), remaining=P(), active=P(), widx=P(),
            out=P(), task=P(), key=P(),
            caches=serve_cache_pspec(template, self.sv.tp_axis),
            dcaches=dspec, steps=P(), drafted=P(), accepted=P(),
            failed=P())
        wspec = tuple(self._rep_spec(w) for w in self._step_weights)
        self._admit = jax.jit(self._shard_mapped(
            self._admit_impl,
            (sspec, P(), self._rep_spec(template), d1spec, P(), P(), P(),
             P()), sspec), donate_argnums=(0,))
        self._decode = jax.jit(self._shard_mapped(
            self._decode_impl, (*wspec, sspec, P()), sspec),
            donate_argnums=(don,))
        self._kill = jax.jit(self._shard_mapped(
            self._kill_dense_impl, (sspec, P()), sspec),
            donate_argnums=(0,))

    def _init_paged(self) -> None:
        sv = self.sv
        self._chunk = min(sv.prefill_chunk, sv.cache_len)
        if self._spec_on:
            # the verifier scores [committed tok, k drafts] in one pass
            # through the SAME (B, C) co-batched graph chunked prefill
            # uses, so the chunk must fit k+1 columns (validated
            # spec_k + 1 <= cache_len in config.base)
            self._chunk = max(self._chunk, self.spec.spec_k + 1)
        self._page = sv.page_size
        self._num_blocks = sv.resolved_num_blocks
        # table width: worst-case pages per request, plus sentinel columns
        # so pad-column writes past a request's allocation land out of
        # table instead of clamping into a real page
        self._p_tab = (sv.pages_per_request
                       + max(1, -(-self._chunk // self._page)))
        self._lp = sv.cache_len + self._chunk   # prompt buffer width
        self._disagg = sv.disagg
        # data-axis striping (DESIGN.md §11): |data| decode replicas,
        # each owning max_batch slots and a num_blocks stripe of the
        # pools; _num_blocks / max_batch are PER-REPLICA figures and all
        # host-side block ids are replica-local.
        self._slots = self._dp * self.max_batch
        # any task-adapted matrix (q/v by default) perturbs the residual
        # stream, so layer>=1 prefix KV is task-dependent even where k/v
        # projections are frozen — tasked runtimes key prefix chains per
        # task id; untasked runtimes (one task, merged, none) share one
        # namespace across all requests
        self._kv_tasked = self.rt.tasked
        self._build_host_pools()
        self._tables = np.full((self._slots, self._p_tab),
                               self._num_blocks, np.int32)
        self._block_bytes = self._kv_bytes(self._page)
        if self._spec_on:
            # the drafter's parallel KV region: same block geometry, same
            # host-side tables, 1/stride the layers — its bytes ride the
            # same per-block accounting
            self._block_bytes += self._kv_bytes(
                self._page, num_super_blocks=self._nb_draft)
        # the physical block pools persist ACROSS generate calls — the
        # prefix cache indexes into them, so warm requests reuse KV
        # computed by earlier calls (the drafter pools too: prompt cells
        # carry drafter KV written by the in-loop sync pass, so prefix
        # hits warm BOTH models)
        self._paged_caches = self._fresh_pools()
        self._draft_pools = (self._fresh_pools(
            num_super_blocks=self._nb_draft) if self._spec_on else None)
        self._pf_caches = self._pf_draft_pools = None
        if self._disagg:
            # the prefill worker's state/pool pair shares every shape
            # with the decode side, so the jitted graphs below serve
            # both without retracing (decode_traces stays 1)
            self._pf_tables = np.full((self._slots, self._p_tab),
                                      self._num_blocks, np.int32)
            self._pf_caches = self._fresh_pools()
            self._pf_draft_pools = (self._fresh_pools(
                num_super_blocks=self._nb_draft) if self._spec_on
                else None)
        don = 6 if self._spec_on else 3
        if self.mesh is None:
            self._padmit = jax.jit(self._paged_admit_impl,
                                   donate_argnums=(0,))
            self._pcow = jax.jit(self._cow_impl, donate_argnums=(0,))
            self._pdecode = jax.jit(self._paged_decode_impl,
                                    donate_argnums=(don,))
            self._pkill = jax.jit(self._kill_paged_impl,
                                  donate_argnums=(0,))
            if self._disagg:
                self._pmigrate = jax.jit(self._migrate_impl,
                                         donate_argnums=(0,))
            return
        # sharded step graphs (DESIGN.md §9/§11): pools shard on the
        # kv-head axis over "model" and on the BLOCKS axis over the data
        # axis; slot-striped state leaves, tables and the loop counters
        # shard their leading axis over "data" when |data| > 1, so each
        # replica's while_loop sees only its own slot stripe. On a
        # single data shard everything below reduces exactly to the §9
        # layout (replicated slot state, replicated tables).
        fleet = self._dp > 1
        dpax = self._dp_axis if fleet else None
        sl = P(self._dp_axis) if fleet else P()
        cspec = serve_cache_pspec(self._paged_caches, self.sv.tp_axis,
                                  dp_axis=dpax)
        dspec = (serve_cache_pspec(self._draft_pools, self.sv.tp_axis,
                                   dp_axis=dpax)
                 if self._spec_on else P())
        sspec = PagedState(
            tok=sl, prompt=sl, plen=sl, done=sl, remaining=sl,
            active=sl, widx=sl, out=sl, task=sl, key=sl,
            caches=cspec, dcaches=dspec,
            steps=sl, drafted=sl, accepted=sl, failed=sl)
        wspec = tuple(self._rep_spec(w) for w in self._step_weights)
        self._padmit = jax.jit(self._shard_mapped(
            self._paged_admit_impl,
            (sspec, P(), P(), P(), P(), P(), P(), P(), P()), sspec),
            donate_argnums=(0,))
        self._pcow = jax.jit(self._shard_mapped(
            self._cow_impl, (sspec, P(), P(), P()), sspec),
            donate_argnums=(0,))
        self._pdecode = jax.jit(self._shard_mapped(
            self._paged_decode_impl, (*wspec, sspec, sl, sl), sspec),
            donate_argnums=(don,))
        self._pkill = jax.jit(self._shard_mapped(
            self._kill_paged_impl, (sspec, P()), sspec),
            donate_argnums=(0,))
        if self._disagg:
            self._pmigrate = jax.jit(self._shard_mapped(
                self._migrate_impl,
                (sspec, cspec, dspec, P(), P(), P()), sspec),
                donate_argnums=(0,))

    def _build_host_pools(self) -> None:
        """(Re)build the host-side per-replica admission machinery:
        request router, block managers, prefix caches and schedulers —
        one of each per data replica, plus a parallel prefill-worker set
        under disaggregation (where the prefix cache lives with the
        PREFILL pool and decode replicas skip registration). ``bm`` /
        ``prefix`` / ``sched`` stay as replica-0 aliases for callers
        from the single-replica era."""
        sv = self.sv
        self.router = Router(self._dp, sv.router)
        self.bms = [BlockManager(self._num_blocks, self._page)
                    for _ in range(self._dp)]
        # adapter registries (DESIGN.md §12): one per data replica —
        # replica r owns the global pool-slot stripe [r*K, (r+1)*K).
        # Under disaggregation the prefill worker and the decode replica
        # SHARE one registry: the pin taken at prefill admission carries
        # through the handoff and is released once, at decode harvest.
        self.registries = ([AdapterRegistry(self.reg_cfg.max_resident_tasks,
                                            policy=self.reg_cfg.eviction)
                            for _ in range(self._dp)]
                           if self._reg_on else [])
        regs = self.registries or [None] * self._dp
        if self._disagg:
            self.prefixes = [None] * self._dp
            self._pf_bms = [BlockManager(self._num_blocks, self._page)
                            for _ in range(self._dp)]
            self._pf_prefixes = [
                PrefixCache(bm) if sv.prefix_cache else None
                for bm in self._pf_bms]
            self._pf_scheds = [
                Scheduler(bm, px, self.last_stats, registry=reg)
                for bm, px, reg in zip(self._pf_bms, self._pf_prefixes,
                                       regs)]
        else:
            self.prefixes = [PrefixCache(bm) if sv.prefix_cache else None
                             for bm in self.bms]
            self._pf_bms, self._pf_prefixes, self._pf_scheds = [], [], []
        self.scheds = [Scheduler(bm, px, self.last_stats, registry=reg)
                       for bm, px, reg in zip(self.bms, self.prefixes,
                                              regs)]
        self.bm = self.bms[0]
        self.prefix = (self._pf_prefixes[0] if self._disagg
                       else self.prefixes[0])
        self.sched = (self._pf_scheds[0] if self._disagg
                      else self.scheds[0])

    def _rebuild_replica_pools(self, r: int) -> None:
        """Failover (DESIGN.md §13): replace replica ``r``'s host-side
        admission state — block manager, prefix cache, adapter registry,
        scheduler(s) — with fresh empty instances. The old pools indexed
        KV on a replica that no longer serves; every request they backed
        has already been harvested and re-routed, so nothing references
        them. The replica-0 aliases are kept pointing at the live
        objects for single-replica-era callers."""
        sv = self.sv
        self.bms[r] = BlockManager(self._num_blocks, self._page)
        if self._reg_on:
            self.registries[r] = AdapterRegistry(
                self.reg_cfg.max_resident_tasks,
                policy=self.reg_cfg.eviction)
        reg = self.registries[r] if self._reg_on else None
        if self._disagg:
            self.prefixes[r] = None
            self._pf_bms[r] = BlockManager(self._num_blocks, self._page)
            self._pf_prefixes[r] = (PrefixCache(self._pf_bms[r])
                                    if sv.prefix_cache else None)
            old_pf = self._pf_scheds[r]
            self._pf_scheds[r] = Scheduler(
                self._pf_bms[r], self._pf_prefixes[r], old_pf.stats,
                registry=reg)
        else:
            self.prefixes[r] = (PrefixCache(self.bms[r])
                                if sv.prefix_cache else None)
        old = self.scheds[r]
        self.scheds[r] = Scheduler(self.bms[r], self.prefixes[r],
                                   old.stats, registry=reg)
        if r == 0:
            self.bm = self.bms[0]
            self.prefix = (self._pf_prefixes[0] if self._disagg
                           else self.prefixes[0])
            self.sched = (self._pf_scheds[0] if self._disagg
                          else self.scheds[0])

    def _fresh_pools(self, num_super_blocks: Optional[int] = None):
        """Zero paged K/V (+ int8 scale) pools, kv-head-sharded over the
        serve mesh when one is configured (the host-side BlockManager is
        shard-agnostic: one block id addresses row ``bid`` of every
        shard's pool). With |data| > 1 the pool holds dp stripes of
        ``_num_blocks`` blocks, sharded on the blocks axis — each
        replica's manager addresses its local stripe with local ids.
        ``num_super_blocks`` sizes the speculative drafter's parallel
        pool region."""
        caches = transformer.init_paged_caches(
            self.cfg, self._dp * self._num_blocks, self._page,
            self.cfg.compute_dtype, kv_quant=self._kv_quant,
            num_super_blocks=num_super_blocks)
        if self.mesh is not None:
            caches = jax.device_put(caches, serve_cache_sharding(
                caches, self.mesh, self.sv.tp_axis,
                dp_axis=self._dp_axis if self._dp > 1 else None))
        return caches

    def _new_stats(self, requests: int = 0) -> EngineStats:
        """Fresh per-generate stats object (cache mode / dtypes / shard
        count are engine constants; counters start at zero)."""
        return EngineStats(
            cache_mode=self.sv.cache_mode, requests=requests,
            weights_dtype=("int8" if self.quant.weights == "int8"
                           else "fp"),
            kv_dtype="int8" if self._kv_quant else "fp",
            shards=self._tp,
            max_resident_tasks=self.reg_cfg.max_resident_tasks)

    def _kv_bytes(self, tokens: int,
                  num_super_blocks: Optional[int] = None) -> int:
        """GLOBAL (all-shard) device bytes of k+v cache for ``tokens``
        cells across every layer — the one formula behind both the paged
        block size and the dense-reservation equivalent the benchmarks
        compare against; under TP each shard holds 1/``shards`` of it
        (EngineStats.block_bytes_per_shard does the division). In
        int8 KV mode a cell costs kv_dim int8 bytes plus one f32 scale
        per kv head (k and v each) — roughly half the bf16 cost and a
        quarter of f32, so the same num_blocks budget holds ~2x (bf16) to
        ~4x (f32) the tokens. ``num_super_blocks`` overrides the layer
        count for the drafter's strided region."""
        nb = (self.cfg.num_super_blocks if num_super_blocks is None
              else num_super_blocks)
        layers = nb * len(self.cfg.block_pattern)
        if self._kv_quant:
            per_cell = self.cfg.kv_dim + 4 * self.cfg.num_kv_heads
        else:
            per_cell = (self.cfg.kv_dim
                        * jnp.dtype(self.cfg.compute_dtype).itemsize)
        return 2 * layers * tokens * per_cell

    def _adapter_fault_in(self, r: int, slot: int, task: int) -> None:
        """Scatter one task's host factor slices into pool slot
        ``r * K + slot`` — the device half of an adapter fault
        (DESIGN.md §12). ONE jitted donated scatter covering the live
        C-column / lora-form A-slice (and, when speculating, the
        drafter's truncated twin at the same slot); the pool shape and
        the traced slot index keep the compile cached, so faults never
        retrace. Runs host-side between decode-loop exits and OUTSIDE
        shard_map: the pool is committed replicated on the serve mesh,
        so a replicated functional update is valid on every shard
        without entering the sharded step graphs."""
        g = jnp.int32(r * self.reg_cfg.max_resident_tasks + slot)
        col = adapter_registry.task_slice(self._host_per_layer, task)
        base, bc, pl = self._weights
        if self._spec_on:
            dbase, dbc, dpl = self._draft_weights
            dcol = adapter_registry.task_slice(self._host_draft_pl, task)
            pl, dpl = self._afault(pl, dpl, g, col, dcol)
            self._draft_weights = (dbase, dbc, dpl)
        else:
            pl = self._afault(pl, g, col)
        self._weights = (base, bc, pl)
        self._step_weights = self._weights + self._draft_weights
        self.registries[r].mark_loaded(task)

    def _reset_paged_pool(self) -> None:
        """Drop every block (and the prefix index) — used when a failed
        generate leaves slot refcounts or donated buffers inconsistent."""
        self._build_host_pools()
        self._tables[:] = self._num_blocks
        self._paged_caches = self._fresh_pools()
        if self._spec_on:
            self._draft_pools = self._fresh_pools(
                num_super_blocks=self._nb_draft)
        if self._disagg:
            self._pf_tables[:] = self._num_blocks
            self._pf_caches = self._fresh_pools()
            if self._spec_on:
                self._pf_draft_pools = self._fresh_pools(
                    num_super_blocks=self._nb_draft)

    # ------------------------------------------------------------------
    # dense mode: jitted pieces (weights passed as args so they are never
    # baked into the executable as constants)
    # ------------------------------------------------------------------

    def _prefill_impl(self, base, bc, pl, tokens, last_idx, task):
        """tokens (1, Pb) right-padded -> (last-position logits (V,),
        caches padded to cache_len)."""
        self._prefill_traces += 1       # python side effect: runs per trace
        out = transformer.forward(base, self.cfg, self.rt.spec, bc, pl,
                                  tokens, task=task, policy=self.policy)
        # nb from the caches themselves: the same graph prefills the
        # speculative drafter's layer-strided sub-model (fewer blocks)
        nb = jax.tree_util.tree_leaves(out.caches)[0].shape[0]
        caches = _pad_caches(out.caches, self.cfg, 1, self.cache_len,
                             num_super_blocks=nb)
        last = jnp.take(out.logits[0], last_idx, axis=0)
        return last, caches

    def _admit_impl(self, state: DecodeState, slot, caches1, dcaches1,
                    last_logits, plen, n_new, task_id) -> DecodeState:
        """Insert a prefilled request into slot ``slot`` and sample its
        first token from the prefill logits (counted toward the output).
        Inside the sharded graph the replicated full-width prefill cache
        is sliced to this shard's kv-head stripe before insertion
        (serve_tp_slice no-ops on a single device). ``dcaches1`` is the
        drafter's prefill of the same prompt (None unless speculating)."""
        key, sub = jax.random.split(state.key)
        t0 = sampling_lib.sample(last_logits[None], sub, self.sampling)[0]
        caches1 = jax.tree_util.tree_map(
            lambda c: serve_tp_slice(c, 3), caches1)
        caches = transformer.insert_cache_slot(state.caches, caches1, slot)
        dcaches = state.dcaches
        if self._spec_on:
            dcaches1 = jax.tree_util.tree_map(
                lambda c: serve_tp_slice(c, 3), dcaches1)
            dcaches = transformer.insert_cache_slot(state.dcaches, dcaches1,
                                                    slot)
        return state._replace(
            dcaches=dcaches,
            tok=jax.lax.dynamic_update_slice(state.tok, t0[None, None],
                                             (slot, 0)),
            pos=state.pos.at[slot].set(plen),
            remaining=state.remaining.at[slot].set(n_new - 1),
            active=state.active.at[slot].set(n_new > 1),
            widx=state.widx.at[slot].set(1),
            out=state.out.at[slot].set(0).at[slot, 0].set(t0),
            task=state.task.at[slot].set(task_id),
            failed=state.failed.at[slot].set(False),
            key=key, caches=caches)

    # -- fleet helpers (DESIGN.md §11) ---------------------------------

    def _key_of(self, s):
        """The (2,)-shaped PRNG key for THIS shard's loop: with |data| >
        1 the state carries one key row per replica (their loops may run
        different iteration counts, so a replicated key would desync)."""
        return s.key[0] if self._dp > 1 else s.key

    def _wrap_key(self, k):
        """Inverse of ``_key_of`` for the loop-carried update."""
        return k[None] if self._dp > 1 else k

    def _fleet_key(self, key):
        """Initial state key: per-replica fold_in rows with |data| > 1
        (distinct sampling streams per replica), the plain key otherwise
        — the single-replica engines keep their exact historical
        draws."""
        if self._dp > 1:
            return jax.vmap(lambda i: jax.random.fold_in(key, i))(
                jnp.arange(self._dp))
        return key

    def _zero_ctr(self):
        """Loop counter zero: one int32 per data replica (counts diverge
        across replica loops), a scalar on a single replica."""
        return (jnp.zeros((self._dp,), jnp.int32) if self._dp > 1
                else jnp.int32(0))

    def _loop_cond(self, active0):
        """while_loop predicate: run until some slot's active set changes.
        With |data| > 1 the predicate is made GLOBAL via psums over the
        data axis, so every replica executes the same iteration count —
        divergent per-replica trip counts around the in-loop "model"
        collectives are never relied on. A replica whose stripe is idle
        spins harmlessly: its rows are inactive, so every write drops."""
        if self._dp == 1:
            def cond(s):
                return jnp.any(s.active) & jnp.all(s.active == active0)
            return cond

        def cond(s):
            alive = jnp.any(s.active).astype(jnp.int32)
            changed = jnp.any(s.active != active0).astype(jnp.int32)
            alive = jax.lax.psum(alive, self._dp_axis)
            changed = jax.lax.psum(changed, self._dp_axis)
            return (alive > 0) & (changed == 0)
        return cond

    # -- speculative building blocks (shared by both cache modes) ------

    def _propose(self, lg, mask, key):
        """One drafter proposal from logits ``lg`` (B, V): the token and
        (under a sampling method) the EXACT distribution q it was drawn
        from — the rejection rule needs q, not the raw logits. Greedy
        proposes the argmax and needs no q (accept is exact match)."""
        if self.sampling.method == "greedy":
            d = jnp.argmax(sampling_lib.process_logits(
                lg, self.sampling, penalty_mask=mask),
                axis=-1).astype(jnp.int32)
            return d, None
        q = sampling_lib.token_probs(lg, self.sampling, penalty_mask=mask)
        d = jax.random.categorical(
            key, jnp.log(jnp.maximum(q, 1e-38)), axis=-1).astype(jnp.int32)
        return d, q

    def _spec_accept(self, L, draft, q_probs, base_mask, key):
        """Accept/reject ``draft`` (B, k) against the verifier's one-pass
        logits ``L`` (B, k+1, V). Greedy: longest argmax-matching prefix
        plus the verifier's own next token — committed tokens are
        IDENTICAL to non-speculative greedy decode. Sampling: Leviathan
        rejection sampling against the exact per-column target
        distributions — the output distribution is unchanged. Per-column
        repetition-penalty masks extend ``base_mask`` with the in-chunk
        draft prefix, matching what sequential decode would have
        accumulated."""
        col_masks = spec_lib.column_penalty_masks(base_mask, draft,
                                                  L.shape[-1])
        if self.sampling.method == "greedy":
            g = jnp.argmax(sampling_lib.process_logits(
                L, self.sampling, penalty_mask=col_masks),
                axis=-1).astype(jnp.int32)
            return spec_lib.greedy_verify(draft, g)
        p = sampling_lib.token_probs(L, self.sampling,
                                     penalty_mask=col_masks)
        return spec_lib.rejection_verify(key, draft, q_probs, p)

    def _decode_impl(self, base, bc, pl, *rest) -> DecodeState:
        """Jitted continuous decode: step all active slots until one
        finishes (or none remain) — the host only sees slot boundaries.
        With speculation the drafter weights arrive as three extra args
        and each loop iteration commits up to spec_k+1 tokens per slot:
        k drafter single-token steps (plus one write-only step syncing
        the last draft's KV into the drafter cache), ONE multi-token
        verifier pass scoring all k+1 columns, and the in-graph accept
        rule — all inside the same single-trace while_loop.

        ``nan_at`` (B,) int32 is the chaos NaN-injection threshold per
        slot (-1 = never, the production value — it is a traced arg, so
        chaos runs share the single compiled graph). Independent of
        injection, every step checks its logits finite IN-GRAPH: a
        non-finite row stops emitting, deactivates, and raises its
        ``failed`` flag for the host to fail the request (DESIGN.md
        §13)."""
        if self._spec_on:
            dbase, dbc, dpl, state, nan_at = rest
        else:
            state, nan_at = rest
        self._decode_traces += 1        # python side effect: runs per trace
        active0 = state.active
        rows = jnp.arange(self.max_batch)
        K = self.spec.spec_k
        V = self.cfg.padded_vocab
        rp_on = self.sampling.repetition_penalty != 1.0

        def cond(s):
            return jnp.any(s.active) & jnp.all(s.active == active0)

        def body(s):
            task = s.task if self.rt.tasked else None
            logits, caches = transformer.decode_step(
                base, self.cfg, self.rt.spec, bc, pl, s.tok, s.caches,
                s.pos, task=task, policy=self.policy)
            # NaN guard: poison injected rows (chaos), then fail any row
            # whose logits are non-finite instead of sampling garbage
            inject = s.active & (nan_at >= 0) & (s.widx >= nan_at)
            logits = jnp.where(inject[:, None], jnp.nan, logits)
            bad = s.active & ~jnp.all(jnp.isfinite(logits), axis=-1)
            key, sub = jax.random.split(s.key)
            pm = (sampling_lib.history_mask(s.out, s.widx, V)
                  if rp_on else None)
            nxt = sampling_lib.sample(logits, sub, self.sampling,
                                      penalty_mask=pm)
            # inactive (and failing) slots write to column out_cap -> drop
            emit = s.active & ~bad
            col = jnp.where(emit, s.widx, self.out_cap)
            out = s.out.at[rows, col].set(nxt, mode="drop")
            adv = emit.astype(jnp.int32)
            tok = jnp.where(emit[:, None], nxt[:, None], s.tok)
            return DecodeState(
                tok=tok, pos=s.pos + adv, remaining=s.remaining - adv,
                active=s.active & (s.remaining > 1) & ~bad,
                widx=s.widx + adv,
                out=out, task=s.task, key=key, caches=caches,
                dcaches=s.dcaches, steps=s.steps + 1,
                drafted=s.drafted, accepted=s.accepted,
                failed=s.failed | bad)

        def spec_body(s):
            task = s.task if self.rt.tasked else None
            keys = jax.random.split(s.key, K + 2)
            base_mask = (sampling_lib.history_mask(s.out, s.widx, V)
                         if rp_on else None)
            # drafter phase: K proposals + 1 write-only step that lands
            # the last draft's KV in the drafter cache (the next round's
            # first drafter step attends it when every draft is accepted)
            dc = s.dcaches
            tok_j = s.tok
            drafts, qs = [], []
            mask_j = base_mask
            for j in range(K + 1):
                lg, dc = transformer.decode_step(
                    dbase, self.cfg, self.rt.spec, dbc, dpl, tok_j, dc,
                    s.pos + j, task=task, policy=self.policy)
                if j == K:
                    break
                d_j, q_j = self._propose(lg, mask_j, keys[1 + j])
                drafts.append(d_j)
                if q_j is not None:
                    qs.append(q_j)
                if rp_on:
                    oh = jax.nn.one_hot(d_j, V, dtype=jnp.bool_)
                    mask_j = oh if mask_j is None else (mask_j | oh)
                tok_j = d_j[:, None]
            d = jnp.stack(drafts, axis=1)                   # (B, K)
            # verifier: ONE multi-token pass over [committed tok, drafts]
            toks_v = jnp.concatenate([s.tok, d], axis=1)    # (B, K+1)
            L, caches = transformer.decode_step(
                base, self.cfg, self.rt.spec, bc, pl, toks_v, s.caches,
                s.pos, task=task, policy=self.policy, all_logits=True)
            # NaN guard over the verifier logits (chaos injection poisons
            # them first): a bad row commits nothing and fails
            inject = s.active & (nan_at >= 0) & (s.widx >= nan_at)
            L = jnp.where(inject[:, None, None], jnp.nan, L)
            bad = s.active & ~jnp.all(jnp.isfinite(L), axis=(1, 2))
            q = jnp.stack(qs, axis=1) if qs else None
            emitted, n = self._spec_accept(L, d, q, base_mask, keys[K + 1])
            m = jnp.where(s.active & ~bad,
                          jnp.minimum(n + 1, s.remaining), 0)
            cols = jnp.arange(K + 1)[None, :]
            outcol = jnp.where(cols < m[:, None], s.widx[:, None] + cols,
                               self.out_cap)
            out = s.out.at[rows[:, None], outcol].set(emitted, mode="drop")
            last = jnp.take_along_axis(
                emitted, jnp.maximum(m - 1, 0)[:, None], axis=1)
            tok = jnp.where((m > 0)[:, None], last, s.tok)
            nact = jnp.sum(s.active.astype(jnp.int32))
            return DecodeState(
                tok=tok, pos=s.pos + m, remaining=s.remaining - m,
                active=s.active & (s.remaining > m) & ~bad,
                widx=s.widx + m,
                out=out, task=s.task, key=keys[0], caches=caches,
                dcaches=dc, steps=s.steps + 1,
                drafted=s.drafted + K * nact,
                accepted=s.accepted + jnp.sum(jnp.where(s.active, n, 0)),
                failed=s.failed | bad)

        return jax.lax.while_loop(
            cond, spec_body if self._spec_on else body, state)

    # ------------------------------------------------------------------
    # paged mode: jitted pieces
    # ------------------------------------------------------------------

    def _paged_admit_impl(self, state: PagedState, slot, prompt_row, plen,
                          done0, n_new, task_id, tok0, w0) -> PagedState:
        """Place request metadata into slot ``slot`` (a GLOBAL slot id —
        each data replica rewrites it to a local row and non-owners drop
        the writes via the out-of-bounds sentinel). No prefill here — the
        decode loop's chunked-prefill path consumes the prompt starting
        at ``done0`` (tokens [0, done0) came from the prefix cache; the
        scheduler guarantees done0 <= plen - 1 so the last prompt token
        always runs through the model for its logits). The disaggregated
        handoff re-admits a prefilled sequence with ``done0 == plen``,
        its already-emitted first token as ``tok0`` and ``w0 = 1`` so
        the slot decodes immediately; plain admissions pass
        ``tok0 = w0 = 0``."""
        b = self.max_batch
        ls = slot - serve_dp_index() * b
        ls = jnp.where((ls >= 0) & (ls < b), ls, b)     # non-owner: drop
        return state._replace(
            prompt=state.prompt.at[ls].set(prompt_row, mode="drop"),
            plen=state.plen.at[ls].set(plen, mode="drop"),
            done=state.done.at[ls].set(done0, mode="drop"),
            remaining=state.remaining.at[ls].set(n_new, mode="drop"),
            active=state.active.at[ls].set(True, mode="drop"),
            widx=state.widx.at[ls].set(w0, mode="drop"),
            out=state.out.at[ls].set(0, mode="drop")
                     .at[ls, 0].set(jnp.where(w0 > 0, tok0, 0),
                                    mode="drop"),
            tok=state.tok.at[ls, 0].set(tok0, mode="drop"),
            task=state.task.at[ls].set(task_id, mode="drop"),
            failed=state.failed.at[ls].set(False, mode="drop"))

    def _kill_dense_impl(self, state: DecodeState, slot) -> DecodeState:
        """Abort one dense slot between loop exits: mark it dead in-graph
        so the next decode call never steps it (DESIGN.md §13). The host
        harvests the output row BEFORE calling this (the state is
        donated)."""
        return state._replace(
            active=state.active.at[slot].set(False),
            remaining=state.remaining.at[slot].set(0),
            failed=state.failed.at[slot].set(False))

    def _kill_paged_impl(self, state: PagedState, slot) -> PagedState:
        """Abort one paged slot between loop exits (cancel / deadline /
        preemption victim / failover drain). Same ownership gating as
        ``_paged_admit_impl``: ``slot`` is global, non-owner replicas
        drop the write via the sentinel row. The slot's block-table row
        is reset host-side right after, so any stale prefill writes the
        row could still route land on the sentinel and drop."""
        b = self.max_batch
        ls = slot - serve_dp_index() * b
        ls = jnp.where((ls >= 0) & (ls < b), ls, b)     # non-owner: drop
        return state._replace(
            active=state.active.at[ls].set(False, mode="drop"),
            remaining=state.remaining.at[ls].set(0, mode="drop"),
            failed=state.failed.at[ls].set(False, mode="drop"))

    def _cow_impl(self, state: PagedState, src, dst, rep) -> PagedState:
        """Copy-on-write one physical block (all layers) — scheduled at
        admit time so the decode loop never writes a shared block. The
        block ids are LOCAL to replica ``rep``'s pool stripe; the other
        replicas redirect the write to the sentinel row and drop it. The
        drafter pools are indexed by the SAME block tables, so the copy
        covers them too: shared prefix blocks carry the drafter's KV
        (task-namespaced prefix keys guarantee the same drafter weights
        produced it)."""
        dst = jnp.where(rep == serve_dp_index(), dst, self._num_blocks)
        repl = dict(caches=transformer.copy_cache_block(state.caches,
                                                        src, dst))
        if self._spec_on:
            repl["dcaches"] = transformer.copy_cache_block(state.dcaches,
                                                           src, dst)
        return state._replace(**repl)

    def _migrate_impl(self, state: PagedState, src_caches, src_dcaches,
                      src_ids, dst_ids, rep) -> PagedState:
        """Disaggregated handoff, device half: batched copy of a finished
        prefill's prompt blocks from the prefill worker's pools into the
        decode pools (DESIGN.md §11). ``src_ids``/``dst_ids`` are
        fixed-width (p_tab,) local-id vectors padded with the sentinel;
        replicas other than ``rep`` sentinel the whole destination
        vector, so only the owning stripe lands writes."""
        dst_ids = jnp.where(rep == serve_dp_index(), dst_ids,
                            self._num_blocks)
        repl = dict(caches=transformer.migrate_cache_blocks(
            state.caches, src_caches, src_ids, dst_ids))
        if self._spec_on:
            repl["dcaches"] = transformer.migrate_cache_blocks(
                state.dcaches, src_dcaches, src_ids, dst_ids)
        return state._replace(**repl)

    def _paged_decode_impl(self, base, bc, pl, *rest) -> PagedState:
        """One jitted while_loop co-batching chunked prefill and decode:
        every step runs a fixed (B, C) token block — prefilling slots
        consume up to C prompt tokens, decoding slots one sampled token
        (pad columns' cache writes are overwritten by the step that owns
        those positions; sentinel table entries drop out-of-allocation
        writes). Compiles ONCE for all prompt lengths.

        With speculation the drafter weights arrive as three extra args
        and decoding slots commit up to spec_k+1 tokens per iteration.
        The verifier's multi-column pass IS the chunked-prefill (B, C)
        pass — prefilling rows keep consuming prompt chunks through it
        while decoding rows score [committed tok, d_1..d_k] in columns
        0..k. The drafter runs against parallel KV pools addressed by the
        SAME block tables; per-row position routing keeps the two row
        classes from clobbering each other's drafter KV: during the k+1
        single-token drafter steps, prefilling rows write at
        out-of-table positions (sentinel drop), and during the one
        prompt-sync pass, decoding rows do."""
        if self._spec_on:
            dbase, dbc, dpl, state, tables, nan_at = rest
        else:
            state, tables, nan_at = rest
        self._decode_traces += 1        # python side effect: runs per trace
        active0 = state.active
        C = self._chunk
        K = self.spec.spec_k
        V = self.cfg.padded_vocab
        rp_on = self.sampling.repetition_penalty != 1.0
        rows = jnp.arange(self.max_batch)
        # any position >= p_tab * page indexes past the block table ->
        # the sentinel row -> writes drop, reads return garbage the mask
        # already excludes
        oob = jnp.int32(self._p_tab * self._page)
        cond = self._loop_cond(active0)

        def body(s):
            is_pf = s.done < s.plen
            start = jnp.where(is_pf, s.done, 0)
            chunk = jax.vmap(
                lambda p, st: jax.lax.dynamic_slice(p, (st,), (C,)))(
                    s.prompt, start)
            ntok = jnp.where(is_pf, jnp.minimum(C, s.plen - s.done), 1)
            dec = jnp.pad(s.tok, ((0, 0), (0, C - 1)))
            toks = jnp.where(is_pf[:, None], chunk, dec)
            task = s.task if self.rt.tasked else None
            logits, caches = transformer.paged_step(
                base, self.cfg, self.rt.spec, bc, pl, toks, s.caches,
                tables, s.done, ntok - 1, task=task, policy=self.policy)
            # NaN guard (DESIGN.md §13): chaos poisons injected rows,
            # then ANY non-finite logit row stops emitting and raises
            # its failed flag for the host to fail the request
            inject = s.active & (nan_at >= 0) & (s.widx >= nan_at)
            logits = jnp.where(inject[:, None], jnp.nan, logits)
            bad = s.active & ~jnp.all(jnp.isfinite(logits), axis=-1)
            key, sub = jax.random.split(self._key_of(s))
            pm = (sampling_lib.history_mask(s.out, s.widx, V)
                  if rp_on else None)
            nxt = sampling_lib.sample(logits, sub, self.sampling,
                                      penalty_mask=pm)
            new_done = s.done + ntok
            # a slot emits a token when its step reached the last prompt
            # position (prefill -> first token) or is decoding
            produced = s.active & (new_done >= s.plen) & ~bad
            col = jnp.where(produced, s.widx, self.out_cap)
            out = s.out.at[rows, col].set(nxt, mode="drop")
            adv = produced.astype(jnp.int32)
            tok = jnp.where(produced[:, None], nxt[:, None], s.tok)
            return PagedState(
                tok=tok, prompt=s.prompt, plen=s.plen, done=new_done,
                remaining=s.remaining - adv,
                active=s.active & ((s.remaining > 1) | ~produced) & ~bad,
                widx=s.widx + adv, out=out, task=s.task,
                key=self._wrap_key(key), caches=caches,
                dcaches=s.dcaches, steps=s.steps + 1,
                drafted=s.drafted, accepted=s.accepted,
                failed=s.failed | bad)

        def spec_body(s):
            is_pf = s.done < s.plen
            start = jnp.where(is_pf, s.done, 0)
            chunk = jax.vmap(
                lambda p, st: jax.lax.dynamic_slice(p, (st,), (C,)))(
                    s.prompt, start)
            ntok_pf = jnp.minimum(C, s.plen - s.done)
            task = s.task if self.rt.tasked else None
            keys = jax.random.split(self._key_of(s), K + 3)
            base_mask = (sampling_lib.history_mask(s.out, s.widx, V)
                         if rp_on else None)
            zero = jnp.zeros_like(s.done)
            # --- drafter phase: K proposals + 1 write-only step landing
            # the last draft's KV (needed next round when all K are
            # accepted). Prefilling rows route their writes out of table.
            dc = s.dcaches
            tok_j = s.tok
            drafts, qs = [], []
            mask_j = base_mask
            for j in range(K + 1):
                dpos = jnp.where(is_pf, oob, s.done + j)
                lg, dc = transformer.paged_step(
                    dbase, self.cfg, self.rt.spec, dbc, dpl, tok_j, dc,
                    tables, dpos, zero, task=task, policy=self.policy)
                if j == K:
                    break
                d_j, q_j = self._propose(lg, mask_j, keys[1 + j])
                drafts.append(d_j)
                if q_j is not None:
                    qs.append(q_j)
                if rp_on:
                    oh = jax.nn.one_hot(d_j, V, dtype=jnp.bool_)
                    mask_j = oh if mask_j is None else (mask_j | oh)
                tok_j = d_j[:, None]
            d = jnp.stack(drafts, axis=1)                   # (B, K)
            # prefilling rows also feed the prompt chunk through the
            # DRAFTER so its cache tracks the prompt; decoding rows'
            # pad columns route out of table (protecting d_1..d_K).
            # cond-gated: pure decode iterations skip the whole pass.
            dec_pad = jnp.pad(s.tok, ((0, 0), (0, C - 1)))

            def sync(dcc):
                toks0 = jnp.where(is_pf[:, None], chunk, dec_pad)
                spos = jnp.where(is_pf, s.done, oob)
                _, dcc = transformer.paged_step(
                    dbase, self.cfg, self.rt.spec, dbc, dpl, toks0, dcc,
                    tables, spos, zero, task=task, policy=self.policy)
                return dcc

            dc = jax.lax.cond(jnp.any(is_pf), sync, lambda dcc: dcc, dc)
            # --- verify: ONE (B, C) pass — prompt chunk for prefilling
            # rows, [committed tok, drafts] for decoding rows
            dv = jnp.pad(jnp.concatenate([s.tok, d], axis=1),
                         ((0, 0), (0, C - (K + 1))))
            toks_v = jnp.where(is_pf[:, None], chunk, dv)
            L, caches = transformer.paged_step(
                base, self.cfg, self.rt.spec, bc, pl, toks_v, s.caches,
                tables, s.done, zero, task=task, policy=self.policy,
                all_logits=True)
            # NaN guard (DESIGN.md §13): poison chaos-injected rows,
            # then fail any row whose relevant logit columns are
            # non-finite — the verifier block for decoding rows, the
            # last-prompt column for prefilling rows
            inject = s.active & (nan_at >= 0) & (s.widx >= nan_at)
            L = jnp.where(inject[:, None, None], jnp.nan, L)
            # prefilling rows: baseline single-token emission off the
            # last real prompt column
            sel = jnp.clip(jnp.where(is_pf, ntok_pf - 1, 0), 0, C - 1)
            Lsel = L[rows, sel]
            fin_dec = jnp.all(jnp.isfinite(L[:, :K + 1]), axis=(1, 2))
            fin_pf = jnp.all(jnp.isfinite(Lsel), axis=-1)
            bad = s.active & ~jnp.where(is_pf, fin_pf, fin_dec)
            nxt_pf = sampling_lib.sample(Lsel, keys[K + 2], self.sampling,
                                         penalty_mask=base_mask)
            # decoding rows: accept/reject over the first K+1 columns
            q = jnp.stack(qs, axis=1) if qs else None
            emitted, n = self._spec_accept(L[:, :K + 1], d, q, base_mask,
                                           keys[K + 1])
            new_done_pf = s.done + ntok_pf
            produced_pf = s.active & (new_done_pf >= s.plen)
            m = jnp.where(is_pf, produced_pf.astype(jnp.int32),
                          jnp.where(s.active,
                                    jnp.minimum(n + 1, s.remaining), 0))
            m = jnp.where(bad, 0, m)    # a failing row commits nothing
            em = jnp.where(is_pf[:, None],
                           jnp.broadcast_to(nxt_pf[:, None],
                                            emitted.shape), emitted)
            cols = jnp.arange(K + 1)[None, :]
            outcol = jnp.where(cols < m[:, None], s.widx[:, None] + cols,
                               self.out_cap)
            out = s.out.at[rows[:, None], outcol].set(em, mode="drop")
            last = jnp.take_along_axis(
                em, jnp.maximum(m - 1, 0)[:, None], axis=1)
            tok = jnp.where((m > 0)[:, None], last, s.tok)
            new_done = jnp.where(is_pf, new_done_pf, s.done + m)
            dec_act = s.active & ~is_pf
            nact = jnp.sum(dec_act.astype(jnp.int32))
            return PagedState(
                tok=tok, prompt=s.prompt, plen=s.plen, done=new_done,
                remaining=s.remaining - m,
                active=(s.active & ((s.remaining > m) | (m == 0))
                        & ~bad),
                widx=s.widx + m, out=out, task=s.task,
                key=self._wrap_key(keys[0]), caches=caches, dcaches=dc,
                steps=s.steps + 1, drafted=s.drafted + K * nact,
                accepted=s.accepted + jnp.sum(jnp.where(dec_act, n, 0)),
                failed=s.failed | bad)

        return jax.lax.while_loop(
            cond, spec_body if self._spec_on else body, state)

    # ------------------------------------------------------------------
    # base-weight snapshot (quantized serving restarts, DESIGN.md §8)
    # ------------------------------------------------------------------

    @property
    def base_weights(self):
        """The base pytree the step graphs actually read — with
        weights=int8 these are the packed ``{"q8", "scale"}`` leaves."""
        return self._weights[0]

    def save_base_snapshot(self, path: str) -> str:
        """Snapshot the (possibly int8-quantized) serving base to one
        ``.npz`` so a restart loads packed weights instead of
        re-quantizing the fp base (checkpoint/ckpt.py)."""
        return ckpt_lib.save_base_snapshot(path, self._weights[0])

    def load_base_snapshot(self, path: str) -> None:
        """Replace the serving base with a snapshot saved by an engine of
        the same model/quant configuration (the current base is the
        structure/dtype template)."""
        base = ckpt_lib.load_base_snapshot(path, self._weights[0])
        self._weights = (base,) + self._weights[1:]

    # ------------------------------------------------------------------
    # host-side orchestration
    # ------------------------------------------------------------------

    def init_state(self, key) -> DecodeState:
        b, cap = self.max_batch, self.out_cap
        z = functools.partial(jnp.zeros, dtype=jnp.int32)
        return DecodeState(
            tok=z((b, 1)), pos=z((b,)), remaining=z((b,)),
            active=jnp.zeros((b,), bool), widx=z((b,)), out=z((b, cap)),
            task=z((b,)), key=key,
            caches=transformer.init_caches(self.cfg, b, self.cache_len,
                                           self.cfg.compute_dtype),
            dcaches=(transformer.init_caches(
                self.cfg, b, self.cache_len, self.cfg.compute_dtype,
                num_super_blocks=self._nb_draft)
                if self._spec_on else None),
            steps=jnp.int32(0), drafted=jnp.int32(0),
            accepted=jnp.int32(0), failed=jnp.zeros((b,), bool))

    def _blank_paged_state(self, key, caches, dcaches) -> PagedState:
        """Zeroed slot state over ``caches`` — the slot axis spans ALL
        data replicas (``_slots = |data| * max_batch``); the PRNG key and
        loop counters gain a per-replica leading axis with |data| > 1."""
        b, cap = self._slots, self.out_cap
        z = functools.partial(jnp.zeros, dtype=jnp.int32)
        return PagedState(
            tok=z((b, 1)), prompt=z((b, self._lp)), plen=z((b,)),
            done=z((b,)), remaining=z((b,)),
            active=jnp.zeros((b,), bool), widx=z((b,)), out=z((b, cap)),
            task=z((b,)), key=self._fleet_key(key), caches=caches,
            dcaches=dcaches, steps=self._zero_ctr(),
            drafted=self._zero_ctr(), accepted=self._zero_ctr(),
            failed=jnp.zeros((b,), bool))

    def init_paged_state(self, key) -> PagedState:
        """Fresh per-slot state over the engine's PERSISTENT block pools
        (ownership of the pool buffers moves into the donated state; the
        host loop hands them back at the end of generate)."""
        caches, self._paged_caches = self._paged_caches, None
        dcaches = None
        if self._spec_on:
            dcaches, self._draft_pools = self._draft_pools, None
        return self._blank_paged_state(key, caches, dcaches)

    def _init_pf_state(self, key) -> PagedState:
        """Fresh PREFILL-WORKER slot state over the prefill pools
        (DESIGN.md §11) — structurally identical to the decode state, so
        every jitted step graph serves both workers from one trace."""
        caches, self._pf_caches = self._pf_caches, None
        dcaches = None
        if self._spec_on:
            dcaches, self._pf_draft_pools = self._pf_draft_pools, None
        return self._blank_paged_state(key, caches, dcaches)

    def _bucket(self, plen: int) -> int:
        for bkt in self.prompt_buckets:
            if bkt >= plen:
                return min(bkt, self.cache_len)
        # no bucket fits: next power of two keeps recompiles logarithmic
        n = 8
        while n < plen:
            n *= 2
        return min(n, self.cache_len)   # prefill cache is cache_len wide

    def _validate_request(self, req: Request):
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        plen = int(prompt.shape[0])
        if plen < 1:
            raise ValueError("empty prompt")
        if not 1 <= req.max_new_tokens <= self.out_cap:
            raise ValueError(
                f"max_new_tokens={req.max_new_tokens} not in [1, out_cap="
                f"{self.out_cap}]")
        if plen + req.max_new_tokens > self.cache_len:
            raise ValueError(
                f"prompt ({plen}) + max_new_tokens ({req.max_new_tokens}) "
                f"exceeds cache_len={self.cache_len}")
        if self.sv.cache_mode == "paged":
            # reject what can NEVER be admitted: a request whose
            # worst-case page count exceeds the whole replica pool would
            # backpressure forever at the FIFO head and livelock the
            # queue behind it (strictly >: an exact fit drains the pool
            # and admits)
            total = -(-(plen + req.max_new_tokens) // self._page)
            if total > self._num_blocks:
                raise ValueError(
                    f"request needs {total} KV pages "
                    f"(ceil(({plen}+{req.max_new_tokens})/{self._page})) "
                    f"but a replica pool holds only {self._num_blocks} "
                    "blocks — it could never be admitted (raise "
                    "num_blocks or split the request)")
        self.rt.check_task(req.task)
        return prompt, plen

    def cancel(self, request_id) -> None:
        """Queue ``request_id`` (Request.request_id, default its batch
        index) for cancellation. Safe to call before generate (the
        request is dropped at submission) or from a chaos/audit hook
        mid-generate: the host loop aborts the request between jitted
        steps — blocks deref'd, adapter pin dropped, status CANCELLED
        with the tokens emitted so far (DESIGN.md §13)."""
        self._cancel_ids.add(request_id)

    def generate(self, requests: Sequence[Request], *,
                 key=None, chaos=None) -> List[np.ndarray]:
        """Serve ``requests`` through the slots; returns, per request, the
        generated token ids (np.ndarray — length max_new_tokens unless
        the request was cancelled / timed out / failed). Fills
        ``self.last_stats`` (tokens/sec, KV blocks in use, prefix-cache
        hit rate, admit/evict counts — serving/stats.py) and
        ``self.last_results`` (one RequestResult per request: tokens,
        terminal status, preemption count — DESIGN.md §13).

        ``chaos``: optional serving.chaos.ChaosInjector driving seeded
        fault injection (forced alloc failures, scatter failures,
        replica kill, NaN logits, scripted cancels) with per-step
        invariant audits.

        Without an explicit ``key`` the engine advances its own PRNG
        stream, so successive calls draw fresh samples under
        temperature/top-k (greedy is key-independent either way)."""
        for req in requests:
            self._validate_request(req)  # fail fast, before any decode work
        if key is None:
            self._key, key = jax.random.split(self._key)
        self.last_stats = self._new_stats(requests=len(requests))
        self._chaos = chaos
        # request lifecycle bookkeeping: rid -> indices (cancel handle),
        # absolute deadlines, terminal statuses, recompute carry-over
        self._rids = [req.request_id if req.request_id is not None
                      else idx for idx, req in enumerate(requests)]
        t0 = time.perf_counter()
        self._abs_deadline = {
            idx: (t0 + req.deadline_s if req.deadline_s is not None
                  else None)
            for idx, req in enumerate(requests)}
        self._req_status = {}
        self._req_preempts = {}
        try:
            if self.sv.cache_mode == "dense":
                results = self._generate_dense(requests, key)
            else:
                results = self._generate_paged(requests, key)
        finally:
            self._chaos = None
            self._cancel_ids.clear()
        st = self.last_stats
        st.wall_s = time.perf_counter() - t0
        st.tokens_generated = sum(len(r) for r in results)
        st.decode_traces = self._decode_traces
        st.prefill_traces = self._prefill_traces
        self.last_results = [
            RequestResult(tokens=r, status=self._req_status.get(i, FINISHED),
                          n_generated=len(r),
                          preemptions=self._req_preempts.get(i, 0))
            for i, r in enumerate(results)]
        return results

    # -- dense ---------------------------------------------------------

    def _admit_request(self, state: DecodeState, slot: int, req: Request,
                       task_ref: Optional[int] = None) -> DecodeState:
        """``task_ref``: the index the device graphs gather the adapter
        with — the registry's pool slot on paging engines (the pooled
        factors are indexed by slot), the task id itself otherwise."""
        prompt, plen = self._validate_request(req)
        t = req.task if task_ref is None else task_ref
        pb = self._bucket(plen)
        padded = jnp.zeros((1, pb), jnp.int32).at[0, :plen].set(prompt)
        task = jnp.int32(t) if self.rt.tasked else None
        last, caches1 = self._prefill(*self._weights, padded,
                                      jnp.int32(plen - 1), task)
        dcaches1 = jnp.int32(0)         # placeholder leaf when spec is off
        if self._spec_on:
            # drafter prefill through the SAME jitted fn (its own trace —
            # the drafter's cache template has nb_draft super-blocks)
            _, dcaches1 = self._prefill(*self._draft_weights, padded,
                                        jnp.int32(plen - 1), task)
        self.last_stats.admitted += 1
        return self._admit(state, jnp.int32(slot), caches1, dcaches1, last,
                           jnp.int32(plen), jnp.int32(req.max_new_tokens),
                           jnp.int32(t))

    def _generate_dense(self, requests, key) -> List[np.ndarray]:
        st = self.last_stats
        st.page_size = self.cache_len
        st.num_blocks = self.max_batch
        st.block_bytes = self._kv_bytes(self.cache_len)
        if self._spec_on:
            st.block_bytes += self._kv_bytes(
                self.cache_len, num_super_blocks=self._nb_draft)
        # dense reserves the whole max_batch × cache_len cache up front
        st.kv_blocks_peak = self.max_batch
        chaos = self._chaos
        state = self.init_state(key)
        pending = collections.deque(enumerate(requests))
        results: List[Optional[np.ndarray]] = [None] * len(requests)
        meta: List[Optional[int]] = [None] * self.max_batch
        nan_at = np.full((self.max_batch,), -1, np.int32)
        hstep = 0

        def abort_status(idx):
            if self._rids[idx] in self._cancel_ids:
                return CANCELLED
            dl = self._abs_deadline[idx]
            if dl is not None and time.perf_counter() >= dl:
                return TIMEOUT
            return None

        while pending or any(m is not None for m in meta):
            if chaos is not None:
                ev = chaos.tick(hstep)
                for rid in ev["cancels"]:
                    self._cancel_ids.add(rid)
            hstep += 1
            # ---- request lifecycle: cancels / deadlines (DESIGN.md §13)
            keep = collections.deque()
            for idx, req in pending:
                stt = abort_status(idx)
                if stt is None:
                    keep.append((idx, req))
                    continue
                results[idx] = np.zeros((0,), np.int32)
                self._req_status[idx] = stt
                if stt is CANCELLED:
                    st.cancelled += 1
                else:
                    st.timeouts += 1
            pending.clear()
            pending.extend(keep)
            for slot in range(self.max_batch):
                if meta[slot] is None:
                    continue
                idx = meta[slot]
                stt = abort_status(idx)
                if stt is None:
                    continue
                # harvest BEFORE the donating kill invalidates the state
                out = np.asarray(state.out)
                w = int(np.asarray(state.widx)[slot])
                results[idx] = out[slot, :w].copy()
                self._req_status[idx] = stt
                if stt is CANCELLED:
                    st.cancelled += 1
                else:
                    st.timeouts += 1
                state = self._kill(state, jnp.int32(slot))
                nan_at[slot] = -1
                if self._reg_on:
                    self.registries[0].release(requests[idx].task)
                meta[slot] = None
                st.evicted += 1
            # admit pending requests into free slots (dense mode has no
            # Scheduler, so the engine gates on adapter residency here:
            # a head whose task cannot get a pool slot waits for a
            # harvest to unpin one — in-flight slots guarantee progress)
            for slot in range(self.max_batch):
                if meta[slot] is None and pending:
                    idx, req = pending[0]
                    task_ref = None
                    if self._reg_on:
                        acq = self.registries[0].acquire(req.task)
                        if acq is None:
                            st.adapter_waits += 1
                            st.backpressure_waits += 1
                            break
                        if (acq.fault and chaos is not None
                                and chaos.fail_scatter()):
                            # simulated scatter failure: roll the pin
                            # back; the slot stays mapped-but-UNLOADED
                            # and the retry faults again
                            self.registries[0].release(req.task)
                            st.backpressure_waits += 1
                            break
                        if acq.fault:
                            st.adapter_faults += 1
                            if acq.evicted is not None:
                                st.adapter_evictions += 1
                            self._adapter_fault_in(0, acq.slot, req.task)
                        else:
                            st.adapter_hits += 1
                        task_ref = acq.slot
                    pending.popleft()
                    state = self._admit_request(state, slot, req, task_ref)
                    meta[slot] = idx
                    nan_at[slot] = (chaos.nan_for(self._rids[idx])
                                    if chaos is not None else -1)
            # decode every active slot until one finishes
            if bool(np.any(np.asarray(state.active))):
                state = self._decode(*self._step_weights, state,
                                     jnp.asarray(nan_at))
                st.decode_calls += 1
            # evict finished slots (also catches max_new_tokens == 1)
            active = np.asarray(state.active)
            out = np.asarray(state.out)
            widx = np.asarray(state.widx)
            failedv = np.asarray(state.failed)
            for slot in range(self.max_batch):
                if meta[slot] is not None and not active[slot]:
                    idx = meta[slot]
                    results[idx] = out[slot, : int(widx[slot])].copy()
                    if failedv[slot]:
                        # in-graph NaN guard tripped: fail the request
                        # with whatever it emitted before the fault
                        self._req_status[idx] = FAILED
                        st.failed_requests += 1
                        st.numerics_faults += 1
                    if self._reg_on:
                        self.registries[0].release(requests[idx].task)
                    meta[slot] = None
                    nan_at[slot] = -1
                    st.evicted += 1
        self._read_spec_stats(state, st)
        return results  # type: ignore[return-value]

    def _read_spec_stats(self, state, st) -> None:
        """Fold the loop-carried speculation counters into EngineStats.
        With |data| > 1 the counters are per-replica rows: the lockstep
        global loop predicate makes steps identical across replicas
        (max == any row), while drafted/accepted count each replica's
        own rows and sum."""
        st.spec_k = self.spec.spec_k
        st.spec_steps = int(np.asarray(state.steps).max())
        st.draft_tokens = int(np.asarray(state.drafted).sum())
        st.accepted_tokens = int(np.asarray(state.accepted).sum())

    # -- paged ---------------------------------------------------------

    def _generate_paged(self, requests, key) -> List[np.ndarray]:
        st = self.last_stats
        st.page_size = self._page
        st.num_blocks = (self._num_blocks * self._dp
                         * (2 if self._disagg else 1))
        st.block_bytes = self._block_bytes
        st.data_shards = self._dp
        chaos = self._chaos
        for sc in self.scheds + self._pf_scheds:
            sc.stats = st               # block/prefix counters land here
            sc.fault_hook = chaos.fail_alloc if chaos is not None else None
        # per-slot chaos NaN thresholds (-1 = never — the production
        # value; passed as a traced arg so decode_traces stays 1)
        self._nan_at = np.full((self._slots,), -1, np.int32)
        self._pf_nan = np.full((self._slots,), -1, np.int32)
        state = self.init_paged_state(key)
        self._tables[:] = self._num_blocks
        pf_state = None
        if self._disagg:
            pf_state = self._init_pf_state(jax.random.fold_in(key, 1))
            self._pf_tables[:] = self._num_blocks
        # deterministic placement: the router stripes every request over
        # the data replicas up front (per-replica FIFO order = arrival
        # order), so dp decode is reproducible run to run. Queue entries
        # are dicts because the recompute path (preemption / failover)
        # re-enqueues a request with a GROWN prompt and a shrunk token
        # budget (prompt' = prompt + generated, max_new' = max_new - n).
        pendings = [collections.deque() for _ in range(self._dp)]
        rcost = {}
        for idx, req in enumerate(requests):
            prompt, plen = self._validate_request(req)
            cost = plen + req.max_new_tokens
            r = self.router.route(cost)
            rcost[idx] = (r, cost)
            pendings[r].append(dict(idx=idx, req=req, prompt=prompt,
                                    plen=plen,
                                    max_new=req.max_new_tokens,
                                    task=req.task))
        results: List[Optional[np.ndarray]] = [None] * len(requests)
        try:
            state, pf_state = self._paged_loop(state, pf_state, pendings,
                                               rcost, results, st)
        except BaseException:
            self._reset_paged_pool()    # slot refs / donated pool are gone
            raise
        self._paged_caches = state.caches
        if self._spec_on:
            self._draft_pools = state.dcaches
        if self._disagg:
            self._pf_caches = pf_state.caches
            if self._spec_on:
                self._pf_draft_pools = pf_state.dcaches
        self._read_spec_stats(state, st)
        return results  # type: ignore[return-value]

    def _paged_loop(self, state, pf_state, pendings, rcost, results, st):
        """Host half of fleet serving: per-replica admission (straight
        into decode slots, or into the prefill worker under
        disaggregation), the prefill→decode block handoff, stepping the
        worker loops, and harvesting finished slots. Returns the final
        (decode, prefill) states so generate can hand the pool buffers
        back.

        Each iteration additionally runs the request-lifecycle machinery
        (DESIGN.md §13): chaos events, cancel/deadline sweeps that abort
        slots between jitted steps (harvest -> register safe prefix ->
        deref blocks -> drop pin -> in-graph kill), recompute preemption
        of the youngest running request when the FIFO head has been
        backpressured ``preempt_after`` consecutive iterations, and
        replica failover (drain a marked-down replica through the same
        recompute re-admission). While the loop runs, its bookkeeping is
        published on ``self._live`` for ``serving.chaos.audit``."""
        R, B = self._dp, self.max_batch
        chaos = self._chaos
        meta: List[Optional[dict]] = [None] * self._slots
        pf_meta: List[Optional[dict]] = [None] * self._slots
        handoffs = [collections.deque() for _ in range(R)]
        rstat = [dict(replica=r, admitted=0, evicted=0, queue_depth=0,
                      backpressure_waits=0, kv_blocks_peak=0)
                 for r in range(R)]
        pf_stat = (dict(replica=-1, admitted=0, evicted=0, queue_depth=0,
                        backpressure_waits=0, kv_blocks_peak=0,
                        handoffs=0) if self._disagg else None)
        ttft, tpot = [], []
        # idx -> tokens harvested before a preemption / failover kill;
        # the recompute re-admission carries them in the grown prompt
        # and ``finish`` prepends them to the final output
        prior: dict = {}
        # consecutive iterations each replica's FIFO head was blocked
        blocked = [0] * R
        # admission order; the preemption victim is the YOUNGEST running
        # request (max seq) — deterministic, vLLM-recompute style
        seq_ctr = [0]
        self._live = dict(meta=meta, pf_meta=pf_meta, handoffs=handoffs,
                          pendings=pendings, rcost=rcost, results=results)

        def note_peaks(r):
            """Per-replica and global peak-block accounting (manual here
            because handoff allocations bypass Scheduler.plan)."""
            rstat[r]["kv_blocks_peak"] = max(
                rstat[r]["kv_blocks_peak"], self.bms[r].used_blocks)
            if pf_stat is not None:
                pf_stat["kv_blocks_peak"] = max(
                    pf_stat["kv_blocks_peak"],
                    max(bm.used_blocks for bm in self._pf_bms))
            used = sum(bm.used_blocks
                       for bm in self.bms + self._pf_bms)
            st.kv_blocks_peak = max(st.kv_blocks_peak, used)

        def finish(idx, toks, status=None):
            """Terminal bookkeeping for one request: prepend any
            recompute carry-over, record the result + status, refund the
            router (no-op on a replica that was marked down)."""
            arr = np.array(toks, np.int32).reshape(-1)
            pr = prior.pop(idx, None)
            if pr:
                arr = np.concatenate([np.asarray(pr, np.int32), arr])
            results[idx] = arr
            if status is not None:
                self._req_status[idx] = status
            rr, cost = rcost[idx]
            self.router.complete(rr, cost)

        def abort_status(idx):
            if self._rids[idx] in self._cancel_ids:
                return CANCELLED
            dl = self._abs_deadline[idx]
            if dl is not None and time.perf_counter() >= dl:
                return TIMEOUT
            return None

        def count_status(status):
            if status is CANCELLED:
                st.cancelled += 1
            elif status is TIMEOUT:
                st.timeouts += 1

        def abort_decode_slot(slot, status, state):
            """Abort one in-flight decode slot with exact host unwind:
            harvest the output row FIRST (the kill donates the state),
            index the already-computed KV for prefix reuse (prompt +
            generated tokens whose cells are written — skipped when the
            KV is suspect, i.e. status FAILED), deref every block, drop
            the adapter pin, then mask the slot dead in-graph and
            sentinel its table row."""
            m = meta[slot]
            r = slot // B
            out = np.asarray(state.out)
            w = int(np.asarray(state.widx)[slot])
            done = int(np.asarray(state.done)[slot])
            gen = out[slot, :w].astype(np.int32)
            full = np.concatenate([np.asarray(m["prompt"], np.int32), gen])
            known = min(done, len(full))    # tokens with computed KV
            reg = (not self._disagg) and status is not FAILED
            self.scheds[r].release(
                full[:known], m["blocks"], namespace=m["ns"],
                register=reg,
                task=m["task"] if self._reg_on else None)
            state = self._pkill(state, jnp.int32(slot))
            self._tables[slot] = self._num_blocks
            self._nan_at[slot] = -1
            meta[slot] = None
            rstat[r]["evicted"] += 1
            finish(m["idx"], gen, status)
            return state

        def abort_pf_slot(slot, status, pf_state):
            """Abort one mid-prefill slot on the prefill worker: register
            the prompt prefix whose KV is already computed (unless
            FAILED), deref, unpin, kill."""
            m = pf_meta[slot]
            r = slot // B
            done = int(np.asarray(pf_state.done)[slot])
            prompt = np.asarray(m["prompt"], np.int32)
            known = min(done, len(prompt))
            reg = status is not FAILED
            self._pf_scheds[r].release(
                prompt[:known], m["blocks"], namespace=m["ns"],
                register=reg,
                task=m["task"] if self._reg_on else None)
            pf_state = self._pkill(pf_state, jnp.int32(slot))
            self._pf_tables[slot] = self._num_blocks
            self._pf_nan[slot] = -1
            pf_meta[slot] = None
            pf_stat["evicted"] += 1
            finish(m["idx"], [], status)
            return pf_state

        def sweep(state, pf_state):
            """Apply cancels and expired deadlines everywhere a request
            can live: queues, handoff buffers, prefill slots, decode
            slots."""
            swept = False
            for r in range(R):
                keep = collections.deque()
                for ent in pendings[r]:
                    stt = abort_status(ent["idx"])
                    if stt is None:
                        keep.append(ent)
                        continue
                    finish(ent["idx"], [], stt)
                    count_status(stt)
                    swept = True
                pendings[r].clear()
                pendings[r].extend(keep)
                keep = collections.deque()
                for h in handoffs[r]:
                    stt = abort_status(h["idx"])
                    if stt is None:
                        keep.append(h)
                        continue
                    # handoff entries hold PREFILL-pool blocks, already
                    # prefix-registered at pf harvest: deref only
                    self._pf_scheds[r].release(
                        h["prompt"], h["blocks"], namespace=h["ns"],
                        register=False,
                        task=h["task"] if self._reg_on else None)
                    finish(h["idx"], [h["t0"]], stt)
                    count_status(stt)
                    swept = True
                handoffs[r].clear()
                handoffs[r].extend(keep)
            for slot in range(self._slots):
                if meta[slot] is not None:
                    stt = abort_status(meta[slot]["idx"])
                    if stt is not None:
                        state = abort_decode_slot(slot, stt, state)
                        count_status(stt)
                        swept = True
                if pf_meta[slot] is not None:
                    stt = abort_status(pf_meta[slot]["idx"])
                    if stt is not None:
                        pf_state = abort_pf_slot(slot, stt, pf_state)
                        count_status(stt)
                        swept = True
            return state, pf_state, swept

        def preempt_one(r, state):
            """vLLM-recompute preemption: kill the youngest running
            request on replica ``r``, harvest its tokens, free its
            blocks (registering the computed KV so the recompute is a
            warm prefix hit), and re-enqueue it right behind the blocked
            head with prompt' = prompt + generated and the shrunk token
            budget. Deterministic: victim = max admission seq."""
            cand = [s for s in range(r * B, (r + 1) * B)
                    if meta[s] is not None]
            if not cand:
                return state, False
            victim = max(cand, key=lambda s: meta[s]["seq"])
            m = meta[victim]
            out = np.asarray(state.out)
            w = int(np.asarray(state.widx)[victim])
            done = int(np.asarray(state.done)[victim])
            gen = out[victim, :w].astype(np.int32)
            full = np.concatenate([np.asarray(m["prompt"], np.int32), gen])
            known = min(done, len(full))
            self.scheds[r].release(
                full[:known], m["blocks"], namespace=m["ns"],
                register=True,
                task=m["task"] if self._reg_on else None)
            state = self._pkill(state, jnp.int32(victim))
            self._tables[victim] = self._num_blocks
            self._nan_at[victim] = -1
            meta[victim] = None
            rstat[r]["evicted"] += 1
            prior.setdefault(m["idx"], []).extend(int(t) for t in gen)
            self._req_preempts[m["idx"]] = (
                self._req_preempts.get(m["idx"], 0) + 1)
            st.preemptions += 1
            ent = dict(idx=m["idx"], req=m["req"], prompt=full,
                       plen=len(full), max_new=m["max_new"] - w,
                       task=m["task"])
            pendings[r].insert(1, ent)  # right behind the blocked head
            return state, True

        def drain_replica(rdead, state, pf_state):
            """Replica failover (DESIGN.md §13): mark ``rdead`` down in
            the router, write off its device stripe, and push every
            request it held — in-flight decode slots (tokens harvested),
            prefill slots, handoff entries, queued requests — back
            through the router onto healthy replicas via the recompute
            re-admission path. The dead replica's host pools are rebuilt
            empty (its refcounts indexed KV that no longer serves)."""
            self.router.mark_down(rdead)
            st.replicas_lost += 1
            moved = []
            out = np.asarray(state.out)
            widx = np.asarray(state.widx)
            for slot in range(rdead * B, (rdead + 1) * B):
                m = meta[slot]
                if m is None:
                    continue
                w = int(widx[slot])
                gen = out[slot, :w].astype(np.int32)
                prior.setdefault(m["idx"], []).extend(int(t) for t in gen)
                newp = np.concatenate(
                    [np.asarray(m["prompt"], np.int32), gen])
                moved.append(dict(idx=m["idx"], req=m["req"], prompt=newp,
                                  plen=len(newp),
                                  max_new=m["max_new"] - w,
                                  task=m["task"]))
                state = self._pkill(state, jnp.int32(slot))
                self._tables[slot] = self._num_blocks
                self._nan_at[slot] = -1
                meta[slot] = None
                st.failover_requests += 1
            if self._disagg:
                for slot in range(rdead * B, (rdead + 1) * B):
                    m = pf_meta[slot]
                    if m is None:
                        continue
                    moved.append(dict(
                        idx=m["idx"], req=m["req"],
                        prompt=np.asarray(m["prompt"], np.int32),
                        plen=m["plen"], max_new=m["max_new"],
                        task=m["task"]))
                    pf_state = self._pkill(pf_state, jnp.int32(slot))
                    self._pf_tables[slot] = self._num_blocks
                    self._pf_nan[slot] = -1
                    pf_meta[slot] = None
                    st.failover_requests += 1
                for h in handoffs[rdead]:
                    prior.setdefault(h["idx"], []).append(int(h["t0"]))
                    newp = np.concatenate(
                        [np.asarray(h["prompt"], np.int32),
                         np.asarray([h["t0"]], np.int32)])
                    moved.append(dict(idx=h["idx"], req=h["req"],
                                      prompt=newp, plen=len(newp),
                                      max_new=h["max_new"] - 1,
                                      task=h["task"]))
                    st.failover_requests += 1
                handoffs[rdead].clear()
            while pendings[rdead]:
                moved.append(pendings[rdead].popleft())
                st.failover_requests += 1
            self._rebuild_replica_pools(rdead)
            if chaos is not None:
                self.scheds[rdead].fault_hook = chaos.fail_alloc
                if self._disagg:
                    self._pf_scheds[rdead].fault_hook = chaos.fail_alloc
            for ent in moved:
                cost = ent["plen"] + ent["max_new"]
                r2 = self.router.route(cost)    # raises when none are up
                rcost[ent["idx"]] = (r2, cost)
                pendings[r2].append(ent)
            return state, pf_state

        hstep = 0
        try:
            state, pf_state = self._paged_loop_iterations(
                state, pf_state, pendings, rcost, results, st, meta,
                pf_meta, handoffs, rstat, pf_stat, ttft, tpot, prior,
                blocked, seq_ctr, note_peaks, finish, sweep, preempt_one,
                drain_replica, hstep)
        finally:
            self._live = None
            for sc in self.scheds + self._pf_scheds:
                sc.fault_hook = None
        for r in range(R):
            rstat[r]["queue_depth"] = len(pendings[r])
        if ttft:
            st.ttft_s = sum(ttft) / len(ttft)
        if tpot:
            st.tpot_s = sum(tpot) / len(tpot)
        st.replica_stats = rstat + ([pf_stat] if pf_stat else [])
        return state, pf_state

    def _paged_loop_iterations(self, state, pf_state, pendings, rcost,
                               results, st, meta, pf_meta, handoffs,
                               rstat, pf_stat, ttft, tpot, prior, blocked,
                               seq_ctr, note_peaks, finish, sweep,
                               preempt_one, drain_replica, hstep):
        """The iteration body of ``_paged_loop`` (split out so the
        closure scaffolding above stays readable). One iteration =
        chaos events -> lifecycle sweep -> admission (+ preemption) ->
        handoff -> one jitted step per worker -> harvests -> audit."""
        R, B = self._dp, self.max_batch
        chaos = self._chaos

        while (any(pendings) or any(handoffs)
               or any(m is not None for m in meta)
               or any(m is not None for m in pf_meta)):
            progressed = False
            faults0 = (chaos.alloc_faults + chaos.scatter_faults
                       if chaos is not None else 0)
            # ---- chaos events: scripted cancels and the replica kill
            if chaos is not None:
                ev = chaos.tick(hstep)
                for rid in ev["cancels"]:
                    self._cancel_ids.add(rid)
                if ev["kill"] is not None:
                    state, pf_state = drain_replica(int(ev["kill"]),
                                                    state, pf_state)
                    progressed = True
            hstep += 1
            # ---- request lifecycle: cancels / expired deadlines
            state, pf_state, swept = sweep(state, pf_state)
            progressed = progressed or swept
            # ---- admission: pending -> prefill worker (disagg) or
            # straight into this replica's decode slots. Strict FIFO per
            # replica: a blocked head waits for evictions rather than
            # being overtaken (and, with preempt_after set, eventually
            # preempts the youngest running request).
            for r in range(R):
                if not self.router.is_up(r):
                    continue
                scheds = self._pf_scheds if self._disagg else self.scheds
                head_blocked = False
                admitted_any = False
                for slot in range(r * B, (r + 1) * B):
                    mrow = pf_meta if self._disagg else meta
                    if mrow[slot] is not None or not pendings[r]:
                        continue
                    ent = pendings[r][0]
                    prompt, plen = ent["prompt"], ent["plen"]
                    ns = ent["task"] if self._kv_tasked else None
                    # the prefill worker computes prompt KV only (its one
                    # emission needs no extra page), so plan with 0 new
                    # tokens there; decode-side pages come at handoff
                    plan = scheds[r].plan(
                        np.asarray(prompt).tolist(),
                        0 if self._disagg else ent["max_new"],
                        namespace=ns,
                        task=ent["task"] if self._reg_on else None)
                    if plan is None:    # backpressure: out of KV blocks
                        #                 or of adapter slots
                        (pf_stat if self._disagg
                         else rstat[r])["backpressure_waits"] += 1
                        head_blocked = True
                        break
                    if (self._reg_on and plan.adapter_fault
                            and chaos is not None
                            and chaos.fail_scatter()):
                        # simulated adapter-scatter failure BEFORE any
                        # device work: unwind the whole admission —
                        # deref the planned blocks, roll the pin back
                        # (the slot stays mapped-but-UNLOADED; the
                        # retry faults again), uncount the admission
                        for bid in plan.blocks:
                            scheds[r].bm.deref(bid)
                        self.registries[r].release(ent["task"])
                        st.admitted -= 1
                        (pf_stat if self._disagg
                         else rstat[r])["backpressure_waits"] += 1
                        st.backpressure_waits += 1
                        head_blocked = True
                        break
                    pendings[r].popleft()
                    progressed = True
                    admitted_any = True
                    # adapter paging (DESIGN.md §12): the device state
                    # carries the POOL-SLOT index (replica-offset into
                    # the dp-striped pool), never the task id; a cold
                    # task's slice is scattered in first
                    task_ref = ent["task"]
                    if self._reg_on:
                        if plan.adapter_fault:
                            self._adapter_fault_in(r, plan.adapter_slot,
                                                   ent["task"])
                        task_ref = (r * self.reg_cfg.max_resident_tasks
                                    + plan.adapter_slot)
                    target = pf_state if self._disagg else state
                    if plan.cow is not None:
                        target = self._pcow(
                            target, jnp.int32(plan.cow[0]),
                            jnp.int32(plan.cow[1]), jnp.int32(r))
                    tab = (self._pf_tables if self._disagg
                           else self._tables)
                    row = np.full((self._p_tab,), self._num_blocks,
                                  np.int32)
                    row[:len(plan.blocks)] = plan.blocks
                    tab[slot] = row
                    prow = np.zeros((self._lp,), np.int32)
                    prow[:plen] = prompt
                    target = self._padmit(
                        target, jnp.int32(slot), jnp.asarray(prow),
                        jnp.int32(plen), jnp.int32(plan.n_cached),
                        jnp.int32(1 if self._disagg
                                  else ent["max_new"]),
                        jnp.int32(task_ref), jnp.int32(0), jnp.int32(0))
                    seq_ctr[0] += 1
                    rid = self._rids[ent["idx"]]
                    nan_vec = self._pf_nan if self._disagg else self._nan_at
                    nan_vec[slot] = (chaos.nan_for(rid)
                                     if chaos is not None else -1)
                    mrow[slot] = dict(idx=ent["idx"], req=ent["req"],
                                      prompt=prompt,
                                      plen=plen, blocks=plan.blocks,
                                      ns=ns, task=ent["task"],
                                      task_ref=task_ref,
                                      max_new=ent["max_new"],
                                      seq=seq_ctr[0],
                                      t_admit=time.perf_counter(),
                                      t_first=None)
                    if self._disagg:
                        pf_state = target
                        pf_stat["admitted"] += 1
                    else:
                        state = target
                        rstat[r]["admitted"] += 1
                note_peaks(r)
                # ---- recompute preemption (DESIGN.md §13): the FIFO
                # head has been backpressured preempt_after consecutive
                # iterations — free the youngest running request so
                # mixed long/short workloads cannot livelock
                if head_blocked and not admitted_any:
                    blocked[r] += 1
                else:
                    blocked[r] = 0
                N = self.sv.preempt_after
                if (N and not self._disagg and blocked[r] >= N
                        and pendings[r]):
                    state, did = preempt_one(r, state)
                    if did:
                        blocked[r] = 0
                        progressed = True
            # ---- handoff: finished prefills -> decode slots ----
            if self._disagg:
                for r in range(R):
                    if not self.router.is_up(r):
                        continue
                    while handoffs[r]:
                        h = handoffs[r][0]
                        slot = next(
                            (s for s in range(r * B, (r + 1) * B)
                             if meta[s] is None), None)
                        total = -(-(h["plen"] + h["max_new"])
                                  // self._page)
                        if (slot is None
                                or self.bms[r].free_blocks < total):
                            rstat[r]["backpressure_waits"] += 1
                            st.backpressure_waits += 1
                            break       # retried after the next eviction
                        handoffs[r].popleft()
                        progressed = True
                        npf = len(h["blocks"])
                        pairs = self._pf_bms[r].migrate_to(
                            self.bms[r], h["blocks"])
                        assert pairs is not None    # free checked above
                        dst = ([d for _, d in pairs]
                               + [self.bms[r].alloc()
                                  for _ in range(total - npf)])
                        src_ids = np.full((self._p_tab,),
                                          self._num_blocks, np.int32)
                        dst_ids = src_ids.copy()
                        src_ids[:npf] = h["blocks"]
                        dst_ids[:npf] = dst[:npf]
                        state = self._pmigrate(
                            state, pf_state.caches,
                            (pf_state.dcaches if self._spec_on
                             else jnp.int32(0)),
                            jnp.asarray(src_ids), jnp.asarray(dst_ids),
                            jnp.int32(r))
                        row = np.full((self._p_tab,), self._num_blocks,
                                      np.int32)
                        row[:total] = dst
                        self._tables[slot] = row
                        prow = np.zeros((self._lp,), np.int32)
                        prow[:h["plen"]] = h["prompt"]
                        # done0 == plen and w0 = 1: the slot decodes
                        # immediately from the migrated prompt KV, with
                        # the prefill-emitted t0 already in the output
                        state = self._padmit(
                            state, jnp.int32(slot), jnp.asarray(prow),
                            jnp.int32(h["plen"]), jnp.int32(h["plen"]),
                            jnp.int32(h["max_new"] - 1),
                            jnp.int32(h["task_ref"]), jnp.int32(h["t0"]),
                            jnp.int32(1))
                        rstat[r]["admitted"] += 1
                        pf_stat["handoffs"] += 1
                        # the adapter pin taken at prefill admission rides
                        # the handoff (pf + decode share the replica's
                        # registry) and is released at decode harvest
                        meta[slot] = dict(
                            idx=h["idx"], req=h["req"],
                            prompt=h["prompt"], plen=h["plen"],
                            blocks=dst, ns=h["ns"], task=h["task"],
                            task_ref=h["task_ref"],
                            max_new=h["max_new"], seq=h["seq"],
                            t_admit=h["t_admit"], t_first=h["t_first"])
                        # the NaN-injection threshold follows the
                        # request onto its decode slot
                        self._nan_at[slot] = (
                            chaos.nan_for(self._rids[h["idx"]])
                            if chaos is not None else -1)
                    note_peaks(r)
            # ---- step the worker loops until some slot finishes ----
            stepped = False
            if (self._disagg
                    and bool(np.any(np.asarray(pf_state.active)))):
                pf_state = self._pdecode(*self._step_weights, pf_state,
                                         jnp.asarray(self._pf_tables),
                                         jnp.asarray(self._pf_nan))
                st.decode_calls += 1
                stepped = True
            if bool(np.any(np.asarray(state.active))):
                state = self._pdecode(*self._step_weights, state,
                                      jnp.asarray(self._tables),
                                      jnp.asarray(self._nan_at))
                st.decode_calls += 1
                stepped = True
            # ---- harvest prefill completions -> handoff queue ----
            if self._disagg:
                pactive = np.asarray(pf_state.active)
                pout = np.asarray(pf_state.out)
                pfailed = np.asarray(pf_state.failed)
                t = time.perf_counter()
                for slot in range(self._slots):
                    m = pf_meta[slot]
                    if m is None or pactive[slot]:
                        continue
                    progressed = True
                    r = slot // B
                    t0 = int(pout[slot, 0])
                    if pfailed[slot]:
                        # in-graph NaN guard tripped during prefill: the
                        # KV is suspect — fail the request, index nothing
                        self._pf_scheds[r].release(
                            m["prompt"], m["blocks"], namespace=m["ns"],
                            register=False,
                            task=m["task"] if self._reg_on else None)
                        self._pf_tables[slot] = self._num_blocks
                        self._pf_nan[slot] = -1
                        pf_meta[slot] = None
                        pf_stat["evicted"] += 1
                        st.failed_requests += 1
                        st.numerics_faults += 1
                        finish(m["idx"], [], FAILED)
                        continue
                    # prompt KV is complete: index it for prefix reuse
                    # BEFORE the handoff derefs the slot's refs, so the
                    # cached entries stay pinned in the prefill pool
                    if self._pf_prefixes[r] is not None:
                        self._pf_prefixes[r].register(
                            m["prompt"], m["blocks"], namespace=m["ns"])
                    self._pf_tables[slot] = self._num_blocks
                    self._pf_nan[slot] = -1
                    pf_meta[slot] = None
                    pf_stat["evicted"] += 1
                    ttft.append(t - m["t_admit"])
                    if m["max_new"] == 1:
                        # the prefill emission IS the whole output
                        self._pf_scheds[r].release(
                            m["prompt"], m["blocks"], namespace=m["ns"],
                            register=False,
                            task=m["task"] if self._reg_on else None)
                        finish(m["idx"], [t0])
                        continue
                    handoffs[r].append(dict(
                        idx=m["idx"], req=m["req"], prompt=m["prompt"],
                        plen=m["plen"], blocks=m["blocks"], ns=m["ns"],
                        task=m["task"], task_ref=m["task_ref"],
                        max_new=m["max_new"], seq=m["seq"],
                        t0=t0, t_admit=m["t_admit"], t_first=t))
            # ---- harvest decode completions ----
            active = np.asarray(state.active)
            out = np.asarray(state.out)
            widx = np.asarray(state.widx)
            failedv = np.asarray(state.failed)
            t = time.perf_counter()
            for slot in range(self._slots):
                m = meta[slot]
                if m is None:
                    continue
                if m["t_first"] is None and widx[slot] > 0:
                    m["t_first"] = t
                    ttft.append(t - m["t_admit"])
                if active[slot]:
                    continue
                progressed = True
                r = slot // B
                ntok = int(widx[slot])
                bad = bool(failedv[slot])
                # prompt pages are fully computed now: index them for
                # prefix reuse (unless the prefill pool's cache already
                # did, or the NaN guard fired — suspect KV is never
                # indexed), return the rest to the free list
                self.scheds[r].release(m["prompt"], m["blocks"],
                                       namespace=m["ns"],
                                       register=not (self._disagg or bad),
                                       task=(m["task"] if self._reg_on
                                             else None))
                self._tables[slot] = self._num_blocks
                self._nan_at[slot] = -1
                rstat[r]["evicted"] += 1
                # phase split is resolvable only when the first token was
                # observed at an earlier loop exit than the completion
                if (m["t_first"] is not None and ntok > 1
                        and m["t_first"] < t):
                    tpot.append((t - m["t_first"]) / (ntok - 1))
                if bad:
                    st.failed_requests += 1
                    st.numerics_faults += 1
                    finish(m["idx"], out[slot, :ntok], FAILED)
                else:
                    finish(m["idx"], out[slot, :ntok])
                meta[slot] = None
            if not (progressed or stepped):
                faults1 = (chaos.alloc_faults + chaos.scatter_faults
                           if chaos is not None else 0)
                if faults1 > faults0:
                    # the stall was manufactured (injected alloc /
                    # scatter faults blocked every admission this
                    # iteration) — retry, this is not a real deadlock
                    continue
                # nothing decoded, admitted, handed off or harvested:
                # the queued work can never fit (classic case: a request
                # needing more KV blocks than the pool can ever free)
                raise RuntimeError(
                    "paged admission deadlock: request needs more KV "
                    "blocks (or adapter slots) than the pool can ever "
                    "free")
            if chaos is not None and chaos.audit_every_step:
                chaos_mod.audit(self)
        return state, pf_state


# ---------------------------------------------------------------------------
# single-shot helpers (the seed's one-request-shape-at-a-time path — the
# Engine above supersedes them for real serving; tests and benchmarks keep
# them as reference decoders).
# ---------------------------------------------------------------------------


def make_serve_step(cfg: ModelConfig, spec: peft_api.AdapterSpec,
                    *, with_enc: bool = False, kernels=None) -> Callable:
    """Single-token decode step (the decode_* dry-run entry point).

    fn(base, adapter, frozen, token (B,1), caches, pos[, enc_out][, task])
    -> (logits, caches). ``pos`` may be a scalar or a (B,) per-row vector;
    ``task`` a scalar or (B,) task-id vector (4+1d routing); ``kernels`` a
    KernelConfig routing the step through the fused Pallas kernels.
    """
    policy = kernel_dispatch.resolve(kernels)

    def step_fn(base, adapter, frozen, token, caches, pos, enc_out=None,
                task=None):
        bc, pl = peft_api.adapter_factors(spec, adapter, frozen)
        return transformer.decode_step(base, cfg, spec, bc, pl, token,
                                       caches, pos, enc_out=enc_out,
                                       task=task, policy=policy)

    return jax.jit(step_fn, donate_argnums=(4,))


def make_prefill(cfg: ModelConfig, spec: peft_api.AdapterSpec,
                 cache_len: int, *, kernels=None) -> Callable:
    """Prefill: run the full prompt, return (logits, caches padded to
    cache_len). Attention caches come back length-T from the forward pass
    and are placed into the fixed-size decode cache."""
    policy = kernel_dispatch.resolve(kernels)

    def prefill_fn(base, adapter, frozen, tokens, enc_embeds=None,
                   embeds=None, task=None):
        bc, pl = peft_api.adapter_factors(spec, adapter, frozen)
        out = transformer.forward(base, cfg, spec, bc, pl, tokens,
                                  embeds=embeds, enc_embeds=enc_embeds,
                                  task=task, policy=policy)
        caches = _pad_caches(out.caches, cfg, tokens.shape[0], cache_len)
        return out.logits, caches, out.enc_out

    return jax.jit(prefill_fn)
