"""Slot-based continuous-batching serving engine with a jitted decode loop.

Architecture (README §Serving):

  * The engine owns ``max_batch`` decode SLOTS. Per-slot device state — KV
    cache rows, current token, cache position, remaining-token budget,
    active flag, output write index, task id — lives in one ``DecodeState``
    pytree; request metadata stays on the host.
  * PREFILL runs per request at batch 1 (prompts right-padded to a bucket
    so a handful of shapes cover all lengths; padded cache cells are never
    attended because the decode mask stops at the slot's position and
    generated tokens overwrite cells before the mask reaches them). The
    resulting cache is written into a free slot's batch row with
    ``dynamic_update_slice`` (transformer.insert_cache_slot).
  * The DECODE loop is a single jitted ``jax.lax.while_loop`` stepping every
    active slot at once; sampling (serving/sampling.py) happens in-graph so
    the loop never leaves the device. It returns control to the host exactly
    when some slot finishes — the host then EVICTS it (harvests the output
    row) and ADMITS the next pending request into the freed slot. In-flight
    slots keep their cache rows and positions across the evict/admit cycle.
  * TASK ROUTING: each slot carries a task id. With a 4+1d adapter under the
    live/lora runtime the (B,) slot task vector gathers per-row C[l, t_b, m]
    slices from the one shared tensor train, so a single decode batch mixes
    tasks (paper Eq. (4)/(6)) — no per-task adapter stacks.

The engine requires attention-pattern models (stateful mixers — mamba/xlstm
— integrate right-padding junk into their prefill state and have no
position-indexed cache to insert at slot granularity).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
from typing import Any, Callable, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import KernelConfig, ModelConfig
from repro.kernels import dispatch as kernel_dispatch
from repro.models import transformer
from repro.peft import api as peft_api
from repro.serving import sampling as sampling_lib
from repro.serving.adapter_runtime import AdapterRuntime


@dataclasses.dataclass
class Request:
    """One generation request. prompt: 1-D int token ids (list/np/jnp)."""
    prompt: Any
    max_new_tokens: int
    task: int = 0


def _pad_caches(caches, cfg: ModelConfig, batch: int, cache_len: int):
    """Place length-T prefill caches into a fixed cache_len-wide template."""
    template = transformer.init_caches(cfg, batch, cache_len,
                                       cfg.compute_dtype)
    if caches is None:
        return template

    def pad(c, z):
        return jax.lax.dynamic_update_slice(z, c.astype(z.dtype),
                                            (0,) * c.ndim)

    return [jax.tree_util.tree_map(pad, c, t)
            for c, t in zip(caches, template)]


class DecodeState(NamedTuple):
    """Loop-carried per-slot device state (leaves fixed-shape pytrees)."""
    tok: jnp.ndarray        # (B, 1)  last sampled token per slot
    pos: jnp.ndarray        # (B,)    cache position tok will be written at
    remaining: jnp.ndarray  # (B,)    tokens still to sample
    active: jnp.ndarray     # (B,)    slot is mid-generation
    widx: jnp.ndarray       # (B,)    next column of the output buffer
    out: jnp.ndarray        # (B, out_cap) generated tokens
    task: jnp.ndarray       # (B,)    per-slot task id (4+1d routing)
    key: jnp.ndarray        # PRNG key (in-graph sampling)
    caches: Any             # transformer KV caches, batch axis = slots


class Engine:
    """Continuous-batching engine over an AdapterRuntime.

    cache_len bounds prompt_len + max_new_tokens per request; out_cap bounds
    max_new_tokens. ``generate`` serves any number of requests through the
    fixed slots, admitting/evicting as they finish.
    """

    def __init__(self, model_cfg: ModelConfig, runtime: AdapterRuntime, *,
                 max_batch: int = 4, cache_len: int = 64, out_cap: int = 32,
                 prompt_buckets: Sequence[int] = (),
                 sampling: sampling_lib.SamplingConfig =
                 sampling_lib.SamplingConfig(),
                 seed: int = 0,
                 kernels: Optional[KernelConfig] = None):
        for mixer, _ in model_cfg.block_pattern:
            if mixer != "attn":
                raise NotImplementedError(
                    f"slot engine needs attention KV caches; mixer {mixer!r} "
                    "carries stateful caches that cannot be slot-inserted "
                    "from a padded prefill")
        if model_cfg.is_encdec:
            raise NotImplementedError("enc-dec serving is not slotted yet")
        if runtime.tasked and runtime.spec.adapts("moe_down"):
            # moe_down deltas apply over expert-sorted (E, C, ff) blocks
            # (models/moe.py), whose leading axis is experts — a per-request
            # (B,) task vector cannot index them.
            raise NotImplementedError(
                "per-request task routing does not reach the expert-sorted "
                "moe_down path; serve this adapter with a scalar task "
                "(per-task engines) or drop moe_down from matrix_types")
        self.cfg = model_cfg
        self.rt = runtime
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.out_cap = out_cap
        self.prompt_buckets = tuple(sorted(prompt_buckets))
        self.sampling = sampling.validate()
        # resolved once; static inside the jitted prefill/decode graphs.
        # With a (4+1)d adapter the fused decode route is the batched-A
        # kernel: each slot's A factor is gathered from the task axis by
        # the slot's task id (kernels/tt_linear.py::tt_linear_batched_a).
        self.policy = kernel_dispatch.resolve(kernels)
        self._key = jax.random.PRNGKey(seed)
        self._weights = (runtime.base, runtime.broadcast, runtime.per_layer)
        self._prefill = jax.jit(self._prefill_impl)
        self._admit = jax.jit(self._admit_impl, donate_argnums=(0,))
        self._decode = jax.jit(self._decode_impl, donate_argnums=(3,))

    # ------------------------------------------------------------------
    # jitted pieces (weights passed as args so they are never baked into
    # the executable as constants)
    # ------------------------------------------------------------------

    def _prefill_impl(self, base, bc, pl, tokens, last_idx, task):
        """tokens (1, Pb) right-padded -> (last-position logits (V,),
        caches padded to cache_len)."""
        out = transformer.forward(base, self.cfg, self.rt.spec, bc, pl,
                                  tokens, task=task, policy=self.policy)
        caches = _pad_caches(out.caches, self.cfg, 1, self.cache_len)
        last = jnp.take(out.logits[0], last_idx, axis=0)
        return last, caches

    def _admit_impl(self, state: DecodeState, slot, caches1,
                    last_logits, plen, n_new, task_id) -> DecodeState:
        """Insert a prefilled request into slot ``slot`` and sample its
        first token from the prefill logits (counted toward the output)."""
        key, sub = jax.random.split(state.key)
        t0 = sampling_lib.sample(last_logits[None], sub, self.sampling)[0]
        caches = transformer.insert_cache_slot(state.caches, caches1, slot)
        return state._replace(
            tok=jax.lax.dynamic_update_slice(state.tok, t0[None, None],
                                             (slot, 0)),
            pos=state.pos.at[slot].set(plen),
            remaining=state.remaining.at[slot].set(n_new - 1),
            active=state.active.at[slot].set(n_new > 1),
            widx=state.widx.at[slot].set(1),
            out=state.out.at[slot].set(0).at[slot, 0].set(t0),
            task=state.task.at[slot].set(task_id),
            key=key, caches=caches)

    def _decode_impl(self, base, bc, pl, state: DecodeState) -> DecodeState:
        """Jitted continuous decode: step all active slots until one
        finishes (or none remain) — the host only sees slot boundaries."""
        active0 = state.active
        rows = jnp.arange(self.max_batch)

        def cond(s):
            return jnp.any(s.active) & jnp.all(s.active == active0)

        def body(s):
            task = s.task if self.rt.tasked else None
            logits, caches = transformer.decode_step(
                base, self.cfg, self.rt.spec, bc, pl, s.tok, s.caches,
                s.pos, task=task, policy=self.policy)
            key, sub = jax.random.split(s.key)
            nxt = sampling_lib.sample(logits, sub, self.sampling)
            # inactive slots write to column out_cap -> dropped
            col = jnp.where(s.active, s.widx, self.out_cap)
            out = s.out.at[rows, col].set(nxt, mode="drop")
            adv = s.active.astype(jnp.int32)
            tok = jnp.where(s.active[:, None], nxt[:, None], s.tok)
            return DecodeState(
                tok=tok, pos=s.pos + adv, remaining=s.remaining - adv,
                active=s.active & (s.remaining > 1), widx=s.widx + adv,
                out=out, task=s.task, key=key, caches=caches)

        return jax.lax.while_loop(cond, body, state)

    # ------------------------------------------------------------------
    # host-side orchestration
    # ------------------------------------------------------------------

    def init_state(self, key) -> DecodeState:
        b, cap = self.max_batch, self.out_cap
        z = functools.partial(jnp.zeros, dtype=jnp.int32)
        return DecodeState(
            tok=z((b, 1)), pos=z((b,)), remaining=z((b,)),
            active=jnp.zeros((b,), bool), widx=z((b,)), out=z((b, cap)),
            task=z((b,)), key=key,
            caches=transformer.init_caches(self.cfg, b, self.cache_len,
                                           self.cfg.compute_dtype))

    def _bucket(self, plen: int) -> int:
        for bkt in self.prompt_buckets:
            if bkt >= plen:
                return min(bkt, self.cache_len)
        # no bucket fits: next power of two keeps recompiles logarithmic
        n = 8
        while n < plen:
            n *= 2
        return min(n, self.cache_len)   # prefill cache is cache_len wide

    def _validate_request(self, req: Request):
        prompt = jnp.asarray(req.prompt, jnp.int32).reshape(-1)
        plen = int(prompt.shape[0])
        if plen < 1:
            raise ValueError("empty prompt")
        if not 1 <= req.max_new_tokens <= self.out_cap:
            raise ValueError(
                f"max_new_tokens={req.max_new_tokens} not in [1, out_cap="
                f"{self.out_cap}]")
        if plen + req.max_new_tokens > self.cache_len:
            raise ValueError(
                f"prompt ({plen}) + max_new_tokens ({req.max_new_tokens}) "
                f"exceeds cache_len={self.cache_len}")
        self.rt.check_task(req.task)
        return prompt, plen

    def _admit_request(self, state: DecodeState, slot: int,
                       req: Request) -> DecodeState:
        prompt, plen = self._validate_request(req)
        pb = self._bucket(plen)
        padded = jnp.zeros((1, pb), jnp.int32).at[0, :plen].set(prompt)
        task = jnp.int32(req.task) if self.rt.tasked else None
        last, caches1 = self._prefill(*self._weights, padded,
                                      jnp.int32(plen - 1), task)
        return self._admit(state, jnp.int32(slot), caches1, last,
                           jnp.int32(plen), jnp.int32(req.max_new_tokens),
                           jnp.int32(req.task))

    def generate(self, requests: Sequence[Request], *,
                 key=None) -> List[np.ndarray]:
        """Serve ``requests`` through the slots; returns, per request, the
        generated token ids (np.ndarray of length max_new_tokens).

        Without an explicit ``key`` the engine advances its own PRNG stream,
        so successive calls draw fresh samples under temperature/top-k
        (greedy is key-independent either way)."""
        for req in requests:
            self._validate_request(req)  # fail fast, before any decode work
        if key is None:
            self._key, key = jax.random.split(self._key)
        state = self.init_state(key)
        pending = collections.deque(enumerate(requests))
        results: List[Optional[np.ndarray]] = [None] * len(requests)
        meta: List[Optional[int]] = [None] * self.max_batch

        while pending or any(m is not None for m in meta):
            # admit pending requests into free slots
            for slot in range(self.max_batch):
                if meta[slot] is None and pending:
                    idx, req = pending.popleft()
                    state = self._admit_request(state, slot, req)
                    meta[slot] = idx
            # decode every active slot until one finishes
            if bool(np.any(np.asarray(state.active))):
                state = self._decode(*self._weights, state)
            # evict finished slots (also catches max_new_tokens == 1)
            active = np.asarray(state.active)
            out = np.asarray(state.out)
            widx = np.asarray(state.widx)
            for slot in range(self.max_batch):
                if meta[slot] is not None and not active[slot]:
                    results[meta[slot]] = out[slot, : int(widx[slot])].copy()
                    meta[slot] = None
        return results  # type: ignore[return-value]


# ---------------------------------------------------------------------------
# single-shot helpers (moved here from train/train_step.py; train_step keeps
# deprecation re-exports). These are the seed's one-request-shape-at-a-time
# path — the Engine above supersedes them for real serving.
# ---------------------------------------------------------------------------


def make_serve_step(cfg: ModelConfig, spec: peft_api.AdapterSpec,
                    *, with_enc: bool = False, kernels=None) -> Callable:
    """Single-token decode step (the decode_* dry-run entry point).

    fn(base, adapter, frozen, token (B,1), caches, pos[, enc_out][, task])
    -> (logits, caches). ``pos`` may be a scalar or a (B,) per-row vector;
    ``task`` a scalar or (B,) task-id vector (4+1d routing); ``kernels`` a
    KernelConfig routing the step through the fused Pallas kernels.
    """
    policy = kernel_dispatch.resolve(kernels)

    def step_fn(base, adapter, frozen, token, caches, pos, enc_out=None,
                task=None):
        bc, pl = peft_api.adapter_factors(spec, adapter, frozen)
        return transformer.decode_step(base, cfg, spec, bc, pl, token,
                                       caches, pos, enc_out=enc_out,
                                       task=task, policy=policy)

    return jax.jit(step_fn, donate_argnums=(4,))


def make_prefill(cfg: ModelConfig, spec: peft_api.AdapterSpec,
                 cache_len: int, *, kernels=None) -> Callable:
    """Prefill: run the full prompt, return (logits, caches padded to
    cache_len). Attention caches come back length-T from the forward pass
    and are placed into the fixed-size decode cache."""
    policy = kernel_dispatch.resolve(kernels)

    def prefill_fn(base, adapter, frozen, tokens, enc_embeds=None,
                   embeds=None, task=None):
        bc, pl = peft_api.adapter_factors(spec, adapter, frozen)
        out = transformer.forward(base, cfg, spec, bc, pl, tokens,
                                  embeds=embeds, enc_embeds=enc_embeds,
                                  task=task, policy=policy)
        caches = _pad_caches(out.caches, cfg, tokens.shape[0], cache_len)
        return out.logits, caches, out.enc_out

    return jax.jit(prefill_fn)
