"""Serving subsystem: continuous-batching engine, adapter runtimes,
in-graph sampling (README §Serving).

  Engine          — slot-based continuous batching, jitted while_loop decode
  AdapterRuntime  — live TT | to_lora_form | fold_into_dense | none
  SamplingConfig  — greedy / temperature / top-k, applied in-graph
"""
from repro.serving.adapter_runtime import AdapterRuntime  # noqa: F401
from repro.serving.engine import (DecodeState, Engine,  # noqa: F401
                                  Request, make_prefill, make_serve_step)
from repro.serving.sampling import SamplingConfig, sample  # noqa: F401
