"""Serving subsystem: continuous-batching engine over a paged KV cache,
adapter runtimes, in-graph sampling (README §Serving, DESIGN.md §7;
tensor-parallel serving over a ("data","model") mesh via
ServeConfig.mesh_shape — DESIGN.md §9).

  Engine          — slot engine, paged KV cache (block manager + scheduler,
                    prefix sharing, in-loop chunked prefill) by default;
                    dense layout behind ServeConfig(cache_mode="dense");
                    shard_map-sharded step graphs when mesh_shape is set
  AdapterRuntime  — live TT | to_lora_form | fold_into_dense | none
  SamplingConfig  — greedy / temperature / top-k / top-p (+ repetition
                    penalty), applied in-graph
  SpecConfig      — speculative decode with a rank-truncated TT
                    self-drafter (DESIGN.md §10)
  BlockManager    — host-side KV block pool: free list, refcounts, COW,
                    cross-pool migration (disaggregated handoff)
  PrefixCache     — hash-chained prompt-prefix -> KV-block index
  Scheduler       — FIFO admission gated on free blocks, not free slots
                    (and, with a registry, on adapter-slot residency)
  AdapterRegistry — task -> device pool-slot residency: pins, LRU/FIFO
                    eviction, fault-in bookkeeping (RegistryConfig.
                    max_resident_tasks serves thousands of tasks from a
                    K-slot pool — DESIGN.md §12)
  LRUClock        — shared recency ordering (PrefixCache + registry)
  Router          — deterministic request placement over data replicas
                    (least-loaded / round-robin, DESIGN.md §11)
  EngineStats     — per-generate observability (engine.last_stats)
  RequestResult   — per-request outcome (engine.last_results): tokens +
                    status (FINISHED / CANCELLED / TIMEOUT / FAILED) +
                    preemption count (DESIGN.md §13)
  ChaosInjector   — seeded fault schedule for resilience testing; audit /
                    audit_pools check the host-state invariants every
                    chaos step (serving/chaos.py, DESIGN.md §13)
"""
from repro.config.base import (RegistryConfig, ServeConfig,  # noqa: F401
                               SpecConfig)
from repro.serving.adapter_registry import (AcquireResult,  # noqa: F401
                                            AdapterRegistry)
from repro.serving.adapter_runtime import AdapterRuntime  # noqa: F401
from repro.serving.chaos import (ChaosInjector, audit,  # noqa: F401
                                 audit_pools)
from repro.serving.lru import LRUClock  # noqa: F401
from repro.serving.block_manager import (BlockManager,  # noqa: F401
                                         PrefixCache)
from repro.serving.engine import (CANCELLED, FAILED,  # noqa: F401
                                  FINISHED, TIMEOUT, DecodeState, Engine,
                                  PagedState, Request, RequestResult,
                                  make_prefill, make_serve_step)
from repro.serving.router import Router  # noqa: F401
from repro.serving.sampling import SamplingConfig, sample  # noqa: F401
from repro.serving.scheduler import Scheduler  # noqa: F401
from repro.serving.stats import EngineStats  # noqa: F401
