"""Lightweight per-``generate`` engine observability.

The engine fills one ``EngineStats`` per ``generate`` call and keeps it on
``engine.last_stats``; ``benchmarks/bench_serving.py`` and
``examples/serve.py`` print it. Everything here is host-side counting —
no device syncs beyond what the engine already does.

Byte accounting is GLOBAL (all shards): ``block_bytes`` / ``kv_bytes_peak``
describe the whole logical cache regardless of the serve mesh, so
paged-vs-dense and int8-vs-fp comparisons read identically on a mesh of 1
and on a TP mesh. ``shards`` records how many ways the kv-head axis is
sharded (1 without a mesh); the ``*_per_shard`` properties divide the
global figures down to what one device actually holds (DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class EngineStats:
    """Counters for one ``Engine.generate`` call (all host-side ints /
    floats; derived rates are properties so serialized dicts stay flat)."""
    cache_mode: str = "paged"
    requests: int = 0
    tokens_generated: int = 0
    wall_s: float = 0.0
    admitted: int = 0
    evicted: int = 0
    decode_calls: int = 0        # host->device decode-loop invocations
    decode_traces: int = 0       # jit (re)traces of the decode graph
    prefill_traces: int = 0      # dense mode: per-bucket prefill compiles
    # --- quantization (DESIGN.md §8) ---
    weights_dtype: str = "fp"    # "fp" | "int8" — frozen base matmul leaves
    kv_dtype: str = "fp"         # "fp" | "int8" — KV cache cells
    # --- KV memory (GLOBAL, all-shard bytes — see module docstring) ---
    page_size: int = 0
    num_blocks: int = 0          # pool budget (paged) / dense equivalent
    kv_blocks_peak: int = 0      # max blocks simultaneously in use
    block_bytes: int = 0         # global device bytes per block (all
    #                              layers, k+v, + per-cell scales in int8
    #                              mode; every shard holds 1/shards of it)
    shards: int = 1              # kv-head shards ("model" axis size; 1 =
    #                              single device, DESIGN.md §9)
    # --- fleet serving (DESIGN.md §11) ---
    data_shards: int = 1         # decode replicas ("data" axis size)
    replica_stats: list = dataclasses.field(default_factory=list)
    #   one dict per data replica: {"replica", "admitted", "evicted",
    #   "queue_depth" (requests still pending at the end of generate —
    #   0 unless generate aborted), "backpressure_waits",
    #   "kv_blocks_peak"}. Populated for every paged generate (one entry
    #   on a mesh of 1); under disaggregation the prefill worker reports
    #   as replica -1 with an extra "handoffs" count.
    # --- latency phase split (host-measured, wall-clock) ---
    ttft_s: float = 0.0          # mean time-to-first-token over requests
    tpot_s: float = 0.0          # mean per-token decode latency after the
    #                              first token (time-per-output-token)
    # --- prefix cache ---
    prefix_lookups: int = 0      # admissions that consulted the cache
    prefix_hit_tokens: int = 0   # prompt tokens served from cached blocks
    prefix_lookup_tokens: int = 0  # prompt tokens eligible for reuse
    cow_copies: int = 0          # copy-on-write block copies
    cache_evictions: int = 0     # prefix blocks reclaimed under pressure
    # --- scheduler ---
    backpressure_waits: int = 0  # admissions deferred for lack of blocks
    #                              or of an adapter slot
    # --- adapter registry (DESIGN.md §12) ---
    max_resident_tasks: int = 0  # device task-slot pool size per replica
    #                              (0 = whole task axis resident, registry
    #                              off — the adapter_* counters stay 0)
    adapter_hits: int = 0        # admissions whose task was already pooled
    adapter_faults: int = 0      # host->device task-slice fault-ins
    adapter_evictions: int = 0   # idle residents displaced by a fault
    adapter_waits: int = 0       # admissions deferred: all slots pinned
    #                              (also counted in backpressure_waits)
    # --- speculative decode (DESIGN.md §10) ---
    spec_k: int = 0              # drafts per engine step (0 = spec off)
    spec_steps: int = 0          # decode-loop iterations (engine steps)
    draft_tokens: int = 0        # drafter proposals (active decode rows)
    accepted_tokens: int = 0     # proposals the verifier accepted
    # --- resilience (request lifecycle / failover, DESIGN.md §13) ---
    cancelled: int = 0           # requests ended by Engine.cancel
    timeouts: int = 0            # requests ended by their deadline_s
    preemptions: int = 0         # recompute preemptions (victim re-queued)
    failed_requests: int = 0     # requests ended with status FAILED
    numerics_faults: int = 0     # in-graph NaN/inf logit detections
    replicas_lost: int = 0       # replicas drained via Router.mark_down
    failover_requests: int = 0   # in-flight/queued requests re-routed off
    #                              a dead replica (recompute re-admission)

    @property
    def tokens_per_s(self) -> float:
        """Generated tokens / wall seconds of the generate call (0.0
        before any timed run)."""
        return self.tokens_generated / self.wall_s if self.wall_s else 0.0

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of reuse-eligible prompt tokens served from cached
        blocks (0.0 when nothing was eligible)."""
        if not self.prefix_lookup_tokens:
            return 0.0
        return self.prefix_hit_tokens / self.prefix_lookup_tokens

    @property
    def kv_bytes_peak(self) -> int:
        """Peak GLOBAL (all-shard) KV bytes in use:
        ``kv_blocks_peak * block_bytes``."""
        return self.kv_blocks_peak * self.block_bytes

    @property
    def block_bytes_per_shard(self) -> int:
        """Device bytes one shard holds per block — the kv-head axis is
        sharded ``shards`` ways, every other dim whole, so this is
        exactly ``block_bytes / shards``."""
        return self.block_bytes // max(self.shards, 1)

    @property
    def kv_bytes_peak_per_shard(self) -> int:
        """Peak KV bytes resident on ONE device:
        ``kv_blocks_peak * block_bytes_per_shard`` (== global peak on a
        mesh of 1; ≈ global / |model| under TP)."""
        return self.kv_blocks_peak * self.block_bytes_per_shard

    @property
    def adapter_hit_rate(self) -> float:
        """Fraction of admissions whose task slice was already in the
        device pool (0.0 when the registry is off or nothing admitted)."""
        n = self.adapter_hits + self.adapter_faults
        return self.adapter_hits / n if n else 0.0

    @property
    def acceptance_rate(self) -> float:
        """Fraction of drafter proposals the verifier accepted (0.0 when
        speculation is off or no decode steps ran)."""
        if not self.draft_tokens:
            return 0.0
        return self.accepted_tokens / self.draft_tokens

    @property
    def tokens_per_step(self) -> float:
        """Committed tokens per decode-loop iteration — speculation's
        whole point is pushing this above 1.0 (chunked prefill steps
        count too, so long prompts dilute it slightly)."""
        if not self.spec_steps:
            return 0.0
        return self.tokens_generated / self.spec_steps

    def summary(self) -> str:
        """One-line human-readable digest (printed by examples/serve.py
        and bench_serving)."""
        return (f"mode={self.cache_mode} w={self.weights_dtype} "
                f"kv={self.kv_dtype} shards={self.shards} "
                + (f"dp={self.data_shards} " if self.data_shards > 1
                   else "")
                + f"reqs={self.requests} "
                f"toks={self.tokens_generated} "
                f"tok/s={self.tokens_per_s:.1f} "
                + (f"ttft={self.ttft_s * 1e3:.1f}ms "
                   f"tpot={self.tpot_s * 1e3:.2f}ms "
                   if self.ttft_s else "")
                + f"kv_blocks_peak={self.kv_blocks_peak}/{self.num_blocks} "
                f"kv_bytes_peak={self.kv_bytes_peak} "
                f"(per_shard={self.kv_bytes_peak_per_shard}) "
                f"prefix_hit_rate={self.prefix_hit_rate:.2f} "
                f"cow={self.cow_copies} admits={self.admitted} "
                f"evicts={self.evicted} waits={self.backpressure_waits} "
                f"decode_traces={self.decode_traces} "
                f"prefill_traces={self.prefill_traces}"
                + (f" adapters={self.max_resident_tasks}slots "
                   f"hit={self.adapter_hit_rate:.2f} "
                   f"faults={self.adapter_faults} "
                   f"aevicts={self.adapter_evictions} "
                   f"awaits={self.adapter_waits}"
                   if self.max_resident_tasks else "")
                + (f" spec_k={self.spec_k} "
                   f"accept={self.acceptance_rate:.2f} "
                   f"tok/step={self.tokens_per_step:.2f}"
                   if self.spec_k else "")
                + (f" cancelled={self.cancelled} "
                   f"timeouts={self.timeouts} "
                   f"preempts={self.preemptions} "
                   f"failed={self.failed_requests} "
                   f"nan_faults={self.numerics_faults} "
                   f"replicas_lost={self.replicas_lost} "
                   f"failover_reqs={self.failover_requests}"
                   if (self.cancelled or self.timeouts or self.preemptions
                       or self.failed_requests or self.numerics_faults
                       or self.replicas_lost) else ""))
