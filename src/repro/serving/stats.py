"""Lightweight per-``generate`` engine observability.

The engine fills one ``EngineStats`` per ``generate`` call and keeps it on
``engine.last_stats``; ``benchmarks/bench_serving.py`` and
``examples/serve.py`` print it. Everything here is host-side counting —
no device syncs beyond what the engine already does.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class EngineStats:
    cache_mode: str = "paged"
    requests: int = 0
    tokens_generated: int = 0
    wall_s: float = 0.0
    admitted: int = 0
    evicted: int = 0
    decode_calls: int = 0        # host->device decode-loop invocations
    decode_traces: int = 0       # jit (re)traces of the decode graph
    prefill_traces: int = 0      # dense mode: per-bucket prefill compiles
    # --- quantization (DESIGN.md §8) ---
    weights_dtype: str = "fp"    # "fp" | "int8" — frozen base matmul leaves
    kv_dtype: str = "fp"         # "fp" | "int8" — KV cache cells
    # --- KV memory ---
    page_size: int = 0
    num_blocks: int = 0          # pool budget (paged) / dense equivalent
    kv_blocks_peak: int = 0      # max blocks simultaneously in use
    block_bytes: int = 0         # device bytes per block (all layers, k+v
    #                              + per-cell scales in int8 mode)
    # --- prefix cache ---
    prefix_lookups: int = 0      # admissions that consulted the cache
    prefix_hit_tokens: int = 0   # prompt tokens served from cached blocks
    prefix_lookup_tokens: int = 0  # prompt tokens eligible for reuse
    cow_copies: int = 0          # copy-on-write block copies
    cache_evictions: int = 0     # prefix blocks reclaimed under pressure
    # --- scheduler ---
    backpressure_waits: int = 0  # admissions deferred for lack of blocks

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_generated / self.wall_s if self.wall_s else 0.0

    @property
    def prefix_hit_rate(self) -> float:
        if not self.prefix_lookup_tokens:
            return 0.0
        return self.prefix_hit_tokens / self.prefix_lookup_tokens

    @property
    def kv_bytes_peak(self) -> int:
        return self.kv_blocks_peak * self.block_bytes

    def summary(self) -> str:
        return (f"mode={self.cache_mode} w={self.weights_dtype} "
                f"kv={self.kv_dtype} reqs={self.requests} "
                f"toks={self.tokens_generated} "
                f"tok/s={self.tokens_per_s:.1f} "
                f"kv_blocks_peak={self.kv_blocks_peak}/{self.num_blocks} "
                f"kv_bytes_peak={self.kv_bytes_peak} "
                f"prefix_hit_rate={self.prefix_hit_rate:.2f} "
                f"cow={self.cow_copies} admits={self.admitted} "
                f"evicts={self.evicted} waits={self.backpressure_waits} "
                f"decode_traces={self.decode_traces} "
                f"prefill_traces={self.prefill_traces}")
