"""Front-end request router for data-parallel serving (DESIGN.md §11).

With ``ServeConfig(mesh_shape=(data, model))`` and data > 1 the engine
runs one DECODE REPLICA per data shard: each replica owns a private
stripe of the decode slots, its own Scheduler/BlockManager over its own
block-pool stripe, and its own admission queue. The Router is the seam
in front of those queues: it places every incoming request on exactly
one replica, deterministically, so a replayed request set routes — and
therefore schedules, prefix-shares and decodes — identically every time
(the dp2-vs-dp1 token-identity tests lean on this).

Policies (ServeConfig.router):

  * ``least_loaded`` (default) — place on the replica with the fewest
    OUTSTANDING TOKENS (sum of prompt + max_new of its unfinished
    requests); ties break toward the lowest replica index. Pure
    host-side counting: ``route`` charges the request's token cost,
    ``complete`` refunds it at eviction.
  * ``round_robin`` — request i goes to replica i mod n, load ignored.

The router never touches device state and never reorders requests
within a replica (per-replica admission stays strict FIFO — the
Scheduler's no-starvation policy is preserved per stripe). The exemplar
seam is NeMo's deploy-time router/worker split; here both sides live in
one process and the "network" is a pair of host deques.

Failover (DESIGN.md §13): ``mark_down(r)`` removes a replica from
placement — its outstanding load is zeroed (the engine re-routes every
in-flight and queued request of a dead replica through ``route`` again,
which charges the healthy replica that receives it) and ``complete`` on
a down replica becomes a no-op (a stale refund for a charge the
mark_down already wrote off). ``route`` raises when every replica is
down. Down-ness lasts for the life of this Router object; the engine
rebuilds its router per generate, so a "repaired" fleet starts clean.
"""
from __future__ import annotations

from typing import List

POLICIES = ("least_loaded", "round_robin")


class Router:
    """Deterministic request placement over ``replicas`` decode replicas.

    Pure host state, no jax. One Router lives on the engine for its
    lifetime; load drains back to zero as requests complete, so
    successive ``generate`` calls start from a clean (but, under
    least_loaded, history-independent — load is zero again) state.
    """

    def __init__(self, replicas: int, policy: str = "least_loaded"):
        if replicas < 1:
            raise ValueError(f"need >= 1 replica, got {replicas}")
        if policy not in POLICIES:
            raise ValueError(f"unknown router policy {policy!r}; "
                             f"want one of {POLICIES}")
        self.replicas = replicas
        self.policy = policy
        self._load = [0] * replicas     # outstanding tokens per replica
        self._rr = 0                    # round-robin cursor
        self._up = [True] * replicas    # mark_down flips to False

    # -- placement -----------------------------------------------------
    def route(self, cost: int) -> int:
        """Place one request of ``cost`` outstanding tokens (prompt +
        max_new); returns the replica index and charges the cost. Down
        replicas are never chosen; raises when none are healthy."""
        up = [i for i in range(self.replicas) if self._up[i]]
        if not up:
            raise RuntimeError("every decode replica is marked down")
        if self.policy == "round_robin":
            while True:
                r = self._rr % self.replicas
                self._rr += 1
                if self._up[r]:
                    break
        else:
            r = min(up, key=lambda i: (self._load[i], i))
        self._load[r] += cost
        return r

    # -- failover (DESIGN.md §13) --------------------------------------
    def mark_down(self, replica: int) -> None:
        """Remove ``replica`` from placement and write off its
        outstanding load (the engine re-routes every request the dead
        replica held, charging whichever healthy replica receives it).
        Idempotent."""
        if not 0 <= replica < self.replicas:
            raise ValueError(
                f"mark_down of unknown replica {replica} "
                f"(have {self.replicas})")
        self._up[replica] = False
        self._load[replica] = 0

    def is_up(self, replica: int) -> bool:
        """Whether ``replica`` is still eligible for placement."""
        return self._up[replica]

    def complete(self, replica: int, cost: int) -> None:
        """Refund a finished request's cost (engine calls at eviction).

        Completions arrive in ANY order relative to routing — a replica
        may fully drain while another still holds earlier requests — so
        the only invariants are per-replica: the refund must match a
        charge still outstanding there. Violations raise (not assert:
        bookkeeping bugs must surface under ``python -O`` too); load
        never goes negative, keeping least-loaded ties deterministic.
        """
        if not 0 <= replica < self.replicas:
            raise ValueError(
                f"complete on unknown replica {replica} "
                f"(have {self.replicas})")
        if cost < 0:
            raise ValueError(f"negative completion cost {cost}")
        if not self._up[replica]:
            return      # stale refund: mark_down already wrote it off
        if cost > self._load[replica]:
            raise ValueError(
                f"completion refund {cost} exceeds replica {replica}'s "
                f"outstanding load {self._load[replica]} — double "
                "complete or cost mismatch with route()")
        self._load[replica] -= cost

    # -- introspection -------------------------------------------------
    def load(self, replica: int) -> int:
        """Outstanding tokens currently charged to ``replica``."""
        return self._load[replica]

    def loads(self) -> List[int]:
        """Per-replica outstanding-token snapshot (copy)."""
        return list(self._load)
