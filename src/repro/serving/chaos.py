"""Seeded chaos injection + invariant audits for the serving engine
(DESIGN.md §13).

The refcounted host state behind continuous batching — BlockManager free
lists, PrefixCache entry refs, AdapterRegistry pins, Router load — is
exactly the state that silently corrupts when an abort / preemption /
failover path forgets one deref. This module provides both halves of the
defense:

  * ``ChaosInjector`` — a deterministic, seeded fault schedule the engine
    consults between jitted steps: forced allocation failures (the
    Scheduler's ``fault_hook`` seam makes ``plan`` report backpressure),
    adapter fault-in scatter failures (the admission unwinds and the slot
    stays mapped-but-unloaded, exercising the registry's transactional
    loaded-flag), replica kill at host step k (``Router.mark_down`` +
    the recompute drain), request cancellations at step k, and per-request
    NaN-logit injection (the IN-GRAPH NaN guard flags the row, the host
    fails the request instead of emitting garbage). The replica-kill
    trigger is ``distributed/fault_tolerance.FailureInjector`` — the same
    fail-at-step primitive the training restart tests use, unified here
    for serving.
  * ``audit(engine)`` / ``audit_pools(...)`` — the invariants every host
    step must preserve: block conservation (free + held == num_blocks,
    free list exactly the refcount-0 set), per-block refcounts equal to
    the number of live holders (slot tables + handoff queues + prefix
    entries), no adapter slot that is pinned but unloaded (the
    transactional scatter contract), registry pin counts equal to live
    requests per task, and router load equal to the outstanding request
    cost per healthy replica. When a ``ChaosInjector`` rides a
    ``generate`` call, the engine runs ``audit`` after EVERY host-loop
    iteration (``audit_every_step=False`` opts out for benchmarks).

Everything here is host-side and jax-free except what it reads off the
engine; injection is deterministic given the seed, so a chaos run is
exactly replayable — the survivor-token-identity assertions in
tests/test_chaos.py and ``bench_serving --chaos`` depend on that.
"""
from __future__ import annotations

import collections
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.distributed.fault_tolerance import (FailureInjector,
                                               SimulatedFailure)


class ChaosInjector:
    """Deterministic seeded fault schedule for one ``generate`` call.

    Parameters
    ----------
    seed: seeds the allocation-failure draw (``alloc_fail_rate``); every
        other fault is scheduled by explicit step/request keys, so a
        chaos run replays exactly.
    kill_replica_at: optional ``(step, replica)`` — at host-loop
        iteration ``step`` the engine marks ``replica`` down and drains
        it through the recompute path. Internally a
        ``fault_tolerance.FailureInjector`` (the training fail-at-step
        primitive) pulls the trigger.
    alloc_fail_steps: host-loop iterations on which every ``plan`` call
        is forced to report backpressure (admission retries later —
        exactly the dry-pool path, but on demand).
    alloc_fail_rate: per-``plan`` probability of a forced failure, drawn
        from the seeded rng (composes with ``alloc_fail_steps``).
    scatter_failures: fail the first N adapter fault-in scatters — the
        admission that triggered them unwinds (blocks deref'd, pin
        released) and the slot stays mapped-but-UNLOADED until a retry's
        scatter succeeds.
    nan_after: ``{request_id: widx}`` — inject NaN logits into that
        request's row once it is about to emit token ``widx`` (0 fails
        it before any output). The in-graph guard converts this to a
        FAILED request + ``EngineStats.numerics_faults``.
    cancel_at: ``{step: [request_id, ...]}`` — call ``Engine.cancel``
        for those ids at host-loop iteration ``step``.
    audit_every_step: run ``audit(engine)`` after every host-loop
        iteration of the generate this injector rides (default True).

    One injector instance should ride ONE generate call —
    ``scatter_failures`` and the kill trigger are consumed statefully.
    """

    def __init__(self, seed: int = 0, *,
                 kill_replica_at: Optional[Tuple[int, int]] = None,
                 alloc_fail_steps: Iterable[int] = (),
                 alloc_fail_rate: float = 0.0,
                 scatter_failures: int = 0,
                 nan_after: Optional[Dict[object, int]] = None,
                 cancel_at: Optional[Dict[int, Sequence[object]]] = None,
                 audit_every_step: bool = True):
        self.seed = seed
        self._rng = np.random.default_rng(seed)
        self._kill = FailureInjector(
            fail_at_step=-1 if kill_replica_at is None
            else int(kill_replica_at[0]))
        self._kill_replica = (None if kill_replica_at is None
                              else int(kill_replica_at[1]))
        self.alloc_fail_steps = frozenset(int(s) for s in alloc_fail_steps)
        self.alloc_fail_rate = float(alloc_fail_rate)
        self._scatter_budget = int(scatter_failures)
        self.nan_after = dict(nan_after or {})
        self.cancel_at = {int(k): tuple(v)
                          for k, v in (cancel_at or {}).items()}
        self.audit_every_step = audit_every_step
        self._step = 0
        # observability: what actually fired (tests assert against these)
        self.alloc_faults = 0
        self.scatter_faults = 0
        self.killed: List[int] = []

    # -- engine-facing hooks -------------------------------------------
    def tick(self, step: int) -> dict:
        """Events for host-loop iteration ``step``: a replica to kill
        (or None) and request ids to cancel."""
        self._step = step
        kill = None
        try:
            self._kill.check(step)
        except SimulatedFailure:
            kill = self._kill_replica
            self._kill.fail_at_step = -1        # one shot
            self.killed.append(kill)
        return dict(kill=kill, cancels=self.cancel_at.get(step, ()))

    def fail_alloc(self) -> bool:
        """Scheduler ``fault_hook``: force this ``plan`` call to report
        backpressure?"""
        fire = (self._step in self.alloc_fail_steps
                or (self.alloc_fail_rate > 0.0
                    and self._rng.random() < self.alloc_fail_rate))
        if fire:
            self.alloc_faults += 1
        return fire

    def fail_scatter(self) -> bool:
        """Fail the next adapter fault-in scatter? (first N calls)"""
        if self._scatter_budget > 0:
            self._scatter_budget -= 1
            self.scatter_faults += 1
            return True
        return False

    def nan_for(self, request_id) -> int:
        """NaN-injection threshold for ``request_id``'s slot (-1 = no
        injection; the in-graph guard compares ``widx >= threshold``)."""
        return int(self.nan_after.get(request_id, -1))


# ---------------------------------------------------------------------------
# invariant audits
# ---------------------------------------------------------------------------


def audit_pools(bm, prefix, holders: Iterable[List[int]],
                registry=None,
                pinned_tasks: Optional[Iterable[int]] = None) -> None:
    """Component-level invariants over one BlockManager (+ optional
    PrefixCache / AdapterRegistry). Raises AssertionError on violation.

    holders: one block-id list per live holder (slot, handoff entry…) —
    each appearance counts one reference; the prefix cache adds one per
    cached entry. pinned_tasks: one task id per live pin holder.
    """
    expected = collections.Counter()
    for blocks in holders:
        for bid in blocks:
            expected[bid] += 1
    if prefix is not None:
        for e in prefix._entries.values():
            expected[e.block] += 1
    free = set(bm._free)
    assert len(free) == len(bm._free), \
        f"free list holds duplicates: {sorted(bm._free)}"
    for bid in range(bm.num_blocks):
        rc = bm.refcount(bid)
        assert rc == expected.get(bid, 0), (
            f"block {bid}: refcount {rc} != {expected.get(bid, 0)} "
            "live holders (leak or double-free)")
        assert (rc == 0) == (bid in free), (
            f"block {bid}: refcount {rc} but "
            f"{'in' if bid in free else 'not in'} the free list")
    assert bm.free_blocks + bm.used_blocks == bm.num_blocks
    if registry is not None:
        pins = collections.Counter()
        for t in (pinned_tasks or ()):
            pins[t] += 1
        for task, n in pins.items():
            slot = registry.slot_of(task)
            assert slot is not None, \
                f"task {task} has {n} live pins but no slot mapping"
        for slot in range(registry.num_slots):
            task = registry._task_of.get(slot)
            want = pins.get(task, 0) if task is not None else 0
            assert registry._pins[slot] == want, (
                f"adapter slot {slot} (task {task}): {registry._pins[slot]} "
                f"pins != {want} live holders")
            if registry._pins[slot] > 0:
                assert registry._loaded[slot], (
                    f"adapter slot {slot} (task {task}) is pinned but "
                    "UNLOADED — a request would decode a stale/zero "
                    "column (transactional scatter contract broken)")
        # mapping bijection
        assert registry._slot_of == {
            t: s for s, t in registry._task_of.items()}


def audit(engine) -> None:
    """Engine-level invariants, valid between host-loop iterations and at
    rest. Raises AssertionError on violation.

    Mid-generate the engine publishes its live bookkeeping on
    ``engine._live`` (meta / pf_meta / handoffs / results / rcost); at
    rest every pool must hold prefix-cache blocks only and carry zero
    adapter pins — "the pool drains to empty".
    """
    if getattr(engine, "sv", None) is None \
            or engine.sv.cache_mode != "paged":
        return
    live = getattr(engine, "_live", None)
    R, B = engine._dp, engine.max_batch
    meta = live["meta"] if live else [None] * engine._slots
    pf_meta = live["pf_meta"] if live else [None] * engine._slots
    handoffs = (live["handoffs"] if live
                else [[] for _ in range(R)])
    for r in range(R):
        stripe = range(r * B, (r + 1) * B)
        dec_holders = [meta[s]["blocks"] for s in stripe
                       if meta[s] is not None]
        pinned = [meta[s]["task"] for s in stripe if meta[s] is not None]
        if engine._disagg:
            pf_holders = ([pf_meta[s]["blocks"] for s in stripe
                           if pf_meta[s] is not None]
                          + [h["blocks"] for h in handoffs[r]])
            pinned += ([pf_meta[s]["task"] for s in stripe
                        if pf_meta[s] is not None]
                       + [h["task"] for h in handoffs[r]])
            audit_pools(engine._pf_bms[r], engine._pf_prefixes[r],
                        pf_holders)
            audit_pools(engine.bms[r], None, dec_holders)
        else:
            audit_pools(engine.bms[r], engine.prefixes[r], dec_holders)
        if engine._reg_on:
            audit_pools(
                BlockManagerStub(), None, [],
                registry=engine.registries[r],
                pinned_tasks=pinned if engine._reg_on else None)
    # router load == outstanding cost per healthy replica
    if live is not None:
        results, rcost = live["results"], live["rcost"]
        want = [0] * R
        for idx, (r, cost) in rcost.items():
            if results[idx] is None:
                want[r] += cost
        for r in range(R):
            if not engine.router.is_up(r):
                continue
            assert engine.router.load(r) == want[r], (
                f"replica {r}: router load {engine.router.load(r)} != "
                f"{want[r]} outstanding request cost")
    else:
        assert all(ld == 0 for ld in engine.router.loads()), \
            f"router load nonzero at rest: {engine.router.loads()}"


class BlockManagerStub:
    """A zero-block stand-in so ``audit_pools`` can check a registry
    alone (engine-level audit checks blocks and registry separately —
    decode blocks and adapter pins have different holder sets)."""
    num_blocks = 0
    free_blocks = 0
    used_blocks = 0
    _free: List[int] = []

    def refcount(self, bid: int) -> int:    # pragma: no cover
        raise IndexError(bid)
