"""Admission scheduler for the paged serving engine.

Admission is gated on **free KV blocks**, not free slots: a request enters
a slot only when the block pool (after prefix-cache reuse and, if needed,
LRU eviction of unpinned cached blocks) can supply every page it may ever
touch — ``ceil((prompt + max_new_tokens) / page_size)`` pages, minus the
shared prefix, plus one copy-on-write block when the first writable
position lands inside a shared page. Allocating the worst case up front
means the jitted decode loop never has to stop for an allocation or a COW:
all device-side bookkeeping happens at admit/evict boundaries, which the
loop already crosses (the engine's host loop admits into freed slots).

Policy is strict FIFO — the head request either fits or everybody waits
(no starvation; documented tradeoff vs. best-fit packing). ``plan`` returns
None under backpressure; the engine decodes on, finishing slots return
blocks, and the head is retried.

Under tensor-parallel serving (DESIGN.md §9) nothing here changes:
admission runs host-side on shard-agnostic block ids (pools shard on the
kv-head axis, never on blocks), so one deterministic decision is valid
on every shard and the replicated block table stays the single source of
truth — per-shard schedulers would have to agree on placement via
collectives instead.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.serving.adapter_registry import AdapterRegistry
from repro.serving.block_manager import BlockManager, PrefixCache
from repro.serving.stats import EngineStats


@dataclasses.dataclass
class AdmitPlan:
    """Everything the engine needs to place one request into a slot:
    ``blocks[i]`` is the physical block backing logical page ``i``
    (refs already taken), ``n_cached`` the prompt tokens whose KV is
    already in those blocks (the chunked prefill starts at ``done0 =
    n_cached``), ``cow`` an optional ``(src, dst)`` device block copy to
    run before decoding, ``total_pages == len(blocks)``."""
    blocks: List[int]            # physical block per logical page
    n_cached: int                # prompt tokens already in cache (done0)
    cow: Optional[Tuple[int, int]] = None   # (src, dst) device block copy
    total_pages: int = 0
    # adapter registry (DESIGN.md §12): pool slot the request's task is
    # pinned into (None when the registry is off), and whether the engine
    # must fault the task slice onto the device before this slot decodes
    adapter_slot: Optional[int] = None
    adapter_fault: bool = False


class Scheduler:
    """FIFO admission over a BlockManager (+ optional PrefixCache)."""

    def __init__(self, bm: BlockManager, prefix: Optional[PrefixCache],
                 stats: Optional[EngineStats] = None,
                 registry: Optional[AdapterRegistry] = None):
        """bm: the block pool; prefix: optional prefix cache consulted /
        populated at admit / release; stats: counter sink (the engine
        swaps in its per-generate EngineStats); registry: optional
        adapter-slot pool — when set, admission additionally gates on
        task residency (DESIGN.md §12)."""
        self.bm = bm
        self.prefix = prefix
        self.stats = stats if stats is not None else EngineStats()
        self.registry = registry
        # chaos seam (serving/chaos.py): when set, consulted at the top
        # of every plan() — returning True forces this admission attempt
        # to report backpressure, exercising the retry path on demand
        self.fault_hook = None

    def _alloc(self, n: int) -> Optional[List[int]]:
        """n fresh blocks, evicting LRU prefix blocks under pressure —
        but only when eviction can actually make the allocation succeed:
        a head request backpressured on slot-pinned blocks must not drain
        the prefix cache on every futile retry."""
        short = n - self.bm.free_blocks
        if short > 0 and self.prefix is not None \
                and self.prefix.drainable_count() >= short:
            self.stats.cache_evictions += self.prefix.evict_lru(short)
        if self.bm.free_blocks < n:
            return None
        return [self.bm.alloc() for _ in range(n)]

    def plan(self, prompt, max_new: int, *,
             namespace=None, task=None) -> Optional[AdmitPlan]:
        """Try to admit one request; None means not enough blocks — or,
        with a registry, no adapter slot (the caller keeps decoding and
        retries after the next eviction / harvest).

        prompt: host int sequence; namespace: prefix-cache chain key space
        (None = shared across tasks; the engine passes the TASK ID — not
        the pool slot — when the adapter makes k/v projections
        task-dependent, so a task evicted from the adapter pool and
        re-admitted later still warm-hits its cached prefixes).
        task: task id to pin into the adapter pool (registry engines
        only; ignored when no registry is attached).

        Adapter residency is acquired FIRST: slots are the scarcer
        resource (K per replica vs hundreds of blocks) and the acquire
        is trivially reversible — on block failure the pin is dropped
        and the slot stays mapped-but-unloaded, so nothing was wasted.
        """
        if self.fault_hook is not None and self.fault_hook():
            # injected allocation failure (ChaosInjector): same contract
            # as a dry pool — the caller keeps decoding and retries
            self.stats.backpressure_waits += 1
            return None
        acq = None
        if self.registry is not None and task is not None:
            acq = self.registry.acquire(task)
            if acq is None:
                # every pool slot is pinned by an in-flight request —
                # adapter backpressure, same retry contract as a dry
                # block pool
                self.stats.adapter_waits += 1
                self.stats.backpressure_waits += 1
                return None
        page = self.bm.page_size
        plen = len(prompt)
        total_pages = -(-(plen + max_new) // page)
        shared: List[int] = []
        n_cached = 0
        if self.prefix is not None:
            m = self.prefix.match(prompt, namespace=namespace)
            shared, n_cached = m.blocks, m.tokens
            # at least the last prompt token must run through the model —
            # its logits seed the first sampled token
            n_cached = min(n_cached, plen - 1)
        n_shared_pages = len(shared)
        # first writable position: inside a shared page -> COW one block
        cow_needed = (n_cached // page) < n_shared_pages
        need = (total_pages - n_shared_pages) + (1 if cow_needed else 0)
        fresh = self._alloc(need)
        if fresh is None and shared:
            # the match's own refs pin the matched blocks (unevictable),
            # which can starve a pool that would fit this request cold —
            # drop the match and retry with every page fresh before
            # reporting backpressure
            for bid in shared:
                self.bm.deref(bid)
            shared, n_cached, n_shared_pages, cow_needed = [], 0, 0, False
            need = total_pages
            fresh = self._alloc(need)
        if fresh is None:
            for bid in shared:
                self.bm.deref(bid)
            if acq is not None:
                # roll the pin back; the slot stays mapped-but-UNLOADED,
                # so the successful retry faults the slice in properly
                self.registry.release(task)
            self.stats.backpressure_waits += 1
            return None
        cow = None
        if cow_needed:
            dst = fresh.pop(0)
            wpage = n_cached // page
            src = shared[wpage]
            cow = (src, dst)
            self.bm.deref(src)
            shared[wpage] = dst
            self.stats.cow_copies += 1
        blocks = shared + fresh
        assert len(blocks) == total_pages, (len(blocks), total_pages)
        # stats count ADMISSIONS only — a backpressured head retries
        # plan() many times and must not multi-count lookups/hits
        if self.prefix is not None:
            self.stats.prefix_lookups += 1
            self.stats.prefix_lookup_tokens += plen - 1
            self.stats.prefix_hit_tokens += n_cached
        self.stats.admitted += 1
        self.stats.kv_blocks_peak = max(self.stats.kv_blocks_peak,
                                        self.bm.used_blocks)
        if acq is not None:
            if acq.fault:
                self.stats.adapter_faults += 1
                if acq.evicted is not None:
                    self.stats.adapter_evictions += 1
            else:
                self.stats.adapter_hits += 1
        return AdmitPlan(blocks=blocks, n_cached=n_cached, cow=cow,
                         total_pages=total_pages,
                         adapter_slot=None if acq is None else acq.slot,
                         adapter_fault=acq is not None and acq.fault)

    def release(self, prompt, blocks: List[int], *, namespace=None,
                register: bool = True, task=None) -> None:
        """Finished request: index its prompt pages into the prefix cache
        (their KV is now fully computed), then drop the slot's refs —
        pages holding only generated tokens go straight back to the free
        list. ``register=False`` skips the prefix indexing (disaggregated
        decode replicas skip it — the prefix cache lives with the PREFILL
        pool, whose scheduler already registered the prompt pages there;
        DESIGN.md §11). ``task``: drop the request's adapter-slot pin
        (registry engines; the slot stays resident for future hits)."""
        if register and self.prefix is not None and len(prompt) > 0:
            self.prefix.register(prompt, blocks, namespace=namespace)
        for bid in blocks:
            self.bm.deref(bid)
        if self.registry is not None and task is not None:
            self.registry.release(task)
        self.stats.evicted += 1
