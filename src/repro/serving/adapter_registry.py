"""Host-side adapter residency for multi-task serving (DESIGN.md §12).

MetaTT's task mode makes the per-task marginal cost ONE core slice
(paper Eq. (4)/(6)): a live runtime adds ``C[:, t]`` (L, M, r, r), a
lora runtime adds ``A[:, t]`` (L, M, d_in, r). The engine therefore does
not need the whole ``num_tasks`` axis device-resident — it keeps a
fixed-shape POOL of ``K`` task slots on device and pages task slices in
on demand, exactly like the paged KV cache treats token pages:

  * ``AdapterRegistry`` (this module) is the host half — task_id → pool
    slot mapping, per-slot pins held by in-flight requests, LRU (or
    FIFO) eviction of idle residents. Pure Python, mirror of
    BlockManager/PrefixCache; the shared ``LRUClock`` provides the
    recency ordering.
  * The device half is one jitted donated scatter per fault
    (``pool.at[:, slot].set(host_slice)``, engine ``_afault``): the pool
    shape and the traced slot index never change, so ``decode_traces``
    stays pinned at 1 no matter how many thousand tasks flow through.
  * In the decode state the per-slot ``(B,)`` task vector simply carries
    POOL-SLOT indices instead of task ids — the traced gather in
    core/metatt.py ``delta_out`` / core/merge.py ``lora_form_delta`` is
    unchanged; only its index space shrank from ``num_tasks`` to ``K``.

Slot lifecycle (one slot, over time)::

      free ──acquire(miss)──> mapped+pinned ──release──> mapped+idle
       ^                          ^                          │
       │                          └────acquire(hit)──────────┤
       └────────── (clear) ───────────evict (new task faults)┘

``acquire`` is transactional against the DEVICE scatter: a slot reports
``fault=True`` until the engine confirms the scatter ran
(``mark_loaded``), so an admission that acquires a slot but then fails
KV-block allocation (and releases the pin) leaves the slot
mapped-but-unloaded — the retry faults again instead of decoding a
stale or zero column.

Pytree helpers at the bottom (``task_slice`` / ``scatter_slot`` /
``pool_factors``) implement the host↔pool data motion over whole
per-layer factor dicts, dispatching per adapter form ("c" live, "a"
lora, anything else — e.g. quantized ``{"q8","scale"}`` leaf dicts —
generically on the shared task-axis-1 layout).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax

from repro.core import merge as merge_lib
from repro.core import metatt as metatt_lib
from repro.serving.lru import LRUClock

POLICIES = ("lru", "fifo")


@dataclasses.dataclass
class AcquireResult:
    """Outcome of one ``acquire``: the pool slot the task maps to (the
    index the decode state carries), whether the engine must run the
    fault-in scatter before using it, and — on an evicting fault — which
    resident task was displaced."""
    slot: int
    fault: bool
    evicted: Optional[int] = None


class AdapterRegistry:
    """task_id → device pool slot, with pins and LRU/FIFO eviction.

    Pure host state, no jax (mirror of BlockManager). ``num_slots`` is
    ``RegistryConfig.max_resident_tasks``; under data-parallel serving
    each decode replica owns a private registry over its own pool stripe
    (slots here are replica-local; the engine offsets by ``r * K`` when
    writing device state).

    Pin discipline: one pin per in-flight request (taken at admission
    via ``acquire``, dropped at harvest via ``release``). A pinned slot
    is never evicted — when every slot is pinned by distinct in-flight
    tasks, ``acquire`` returns None and admission backpressures exactly
    like a dry KV-block pool.
    """

    def __init__(self, num_slots: int, policy: str = "lru"):
        if num_slots < 1:
            raise ValueError(f"need >= 1 adapter slot, got {num_slots}")
        if policy not in POLICIES:
            raise ValueError(f"unknown eviction policy {policy!r}; "
                             f"want one of {POLICIES}")
        self.num_slots = num_slots
        self.policy = policy
        self._slot_of: Dict[int, int] = {}      # task id -> slot
        self._task_of: Dict[int, int] = {}      # slot -> task id
        self._pins = [0] * num_slots            # in-flight requests per slot
        self._loaded = [False] * num_slots      # device scatter confirmed
        self._free: List[int] = list(range(num_slots - 1, -1, -1))
        self._clock = LRUClock()                # recency over slot indices

    # -- introspection -------------------------------------------------
    def __len__(self) -> int:
        """Number of resident (mapped) tasks."""
        return len(self._slot_of)

    @property
    def resident_tasks(self) -> List[int]:
        """Task ids currently mapped to a slot (loaded or not)."""
        return sorted(self._slot_of)

    @property
    def pinned_slots(self) -> int:
        """Slots pinned by at least one in-flight request."""
        return sum(1 for p in self._pins if p > 0)

    def pin_count(self, task: int) -> int:
        """In-flight requests currently pinning ``task`` (0 if absent)."""
        slot = self._slot_of.get(task)
        return 0 if slot is None else self._pins[slot]

    def slot_of(self, task: int) -> Optional[int]:
        """Pool slot ``task`` is mapped to, or None."""
        return self._slot_of.get(task)

    # -- acquire / load / release --------------------------------------
    def acquire(self, task: int) -> Optional[AcquireResult]:
        """Pin ``task`` into a slot for one admission.

        Hit (mapped and loaded): pin + recency touch, no device work.
        Miss: take a free slot, else evict the least-recently-used
        UNPINNED resident; either way the result says ``fault=True`` and
        the engine must scatter the slice and ``mark_loaded`` before the
        slot's column is read. None ⇒ every slot is pinned (admission
        backpressure; the caller retries after a harvest releases pins).
        """
        slot = self._slot_of.get(task)
        evicted = None
        if slot is None:
            if self._free:
                slot = self._free.pop()
            else:
                slot = self._clock.oldest(
                    s for s in range(self.num_slots) if self._pins[s] == 0)
                if slot is None:
                    return None
                evicted = self._task_of.pop(slot)
                del self._slot_of[evicted]
                self._loaded[slot] = False
            self._slot_of[task] = slot
            self._task_of[slot] = task
        self._pins[slot] += 1
        # fifo ranks by load order only; lru also refreshes on every hit
        if self.policy == "lru" or not self._loaded[slot]:
            self._clock.touch(slot)
        return AcquireResult(slot=slot, fault=not self._loaded[slot],
                             evicted=evicted)

    def mark_loaded(self, task: int) -> None:
        """Engine confirmation that the device scatter for ``task``'s
        slot ran — until then every ``acquire`` keeps reporting a fault."""
        slot = self._slot_of.get(task)
        if slot is None:
            raise ValueError(f"mark_loaded of unmapped task {task}")
        self._loaded[slot] = True

    def release(self, task: int) -> None:
        """Drop one pin (request finished / admission rolled back). The
        slot stays mapped — an idle resident is a future hit — until an
        eviction reclaims it."""
        slot = self._slot_of.get(task)
        if slot is None or self._pins[slot] <= 0:
            raise ValueError(f"release of unpinned task {task}")
        self._pins[slot] -= 1

    def clear(self) -> None:
        """Forget every mapping and pin (engine pool reset)."""
        self._slot_of.clear()
        self._task_of.clear()
        self._pins = [0] * self.num_slots
        self._loaded = [False] * self.num_slots
        self._free = list(range(self.num_slots - 1, -1, -1))
        self._clock = LRUClock()


# --------------------------------------------------------------------------
# pool data motion (device half's pytree plumbing)
# --------------------------------------------------------------------------
#
# Per-layer factor dicts map adapter-form keys to arrays (or to
# quantized {"q8","scale"} sub-dicts) whose TASK MODE IS AXIS 1:
# live "c" (L, T, M, r, r), lora "a" (L, T, M, d_in, r). The named
# core helpers document that contract; unknown keys fall through to the
# same axis-1 slice/scatter generically, so int8-quantized or future
# leaves page without new code here.

def _take_fn(key):
    if key == "c":
        return metatt_lib.take_task_slice
    if key == "a":
        return merge_lib.lora_task_slice
    return lambda x, task: x[:, task]


def _put_fn(key):
    if key == "c":
        return metatt_lib.put_task_slice
    if key == "a":
        return merge_lib.lora_task_put
    return lambda pool, slot, col: pool.at[:, slot].set(
        col.astype(pool.dtype))


def task_slice(per_layer: dict, task) -> dict:
    """Extract ONE task's column from every per-task factor leaf —
    the host-side slice the fault-in scatter ships to the device."""
    out = {}
    for key, leaf in per_layer.items():
        take = _take_fn(key)
        out[key] = jax.tree_util.tree_map(lambda x: take(x, task), leaf)
    return out


def scatter_slot(per_layer: dict, slot, col: dict) -> dict:
    """Write one task column (``task_slice`` output) into pool slot
    ``slot`` of every leaf. Functional and shape-preserving, so the
    engine jits it ONCE with the pool donated and a traced slot index —
    faults never retrace."""
    out = {}
    for key, leaf in per_layer.items():
        put = _put_fn(key)
        out[key] = jax.tree_util.tree_map(
            lambda pool, c: put(pool, slot, c), leaf, col[key])
    return out


def pool_factors(per_layer: dict, num_slots: int) -> dict:
    """A zeroed pool with the task axis (axis 1) resized to
    ``num_slots`` — the fixed device geometry the jitted step sees.
    Slot contents are all-zero (ΔW == 0, a valid no-op adapter) until a
    fault loads them; the registry's loaded-flags guarantee no request
    decodes against an unloaded slot."""
    import jax.numpy as jnp

    def widen(x):
        return jnp.zeros(x.shape[:1] + (num_slots,) + x.shape[2:], x.dtype)

    return jax.tree_util.tree_map(widen, per_layer)
