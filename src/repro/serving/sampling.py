"""In-graph token sampling for the serving decode loop.

Every sampler is a pure jnp function of (logits, key) so it lives INSIDE the
jitted ``lax.while_loop`` decode body (repro/serving/engine.py) — the loop
never leaves the device to pick a token. The method/temperature/top_k/top_p/
repetition_penalty knobs are static (baked into the trace); the PRNG key is
loop-carried state.

The logits transform is factored into ``process_logits`` so that ``sample``
(the decode loop), ``token_probs`` (the speculative accept rule —
serving/speculative.py needs the exact distribution the sampler draws from,
or rejection sampling would not preserve the output distribution) and the
property tests all share ONE implementation of the masking/penalty math.

Under tensor-parallel serving (DESIGN.md §9) sampling runs REPLICATED:
every shard holds the all-gathered (B, V) logits and the same loop-carried
key, so each draws the identical token and the decode loop stays in
lockstep across the mesh with no extra collective.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.attention import NEG_INF


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    """method: "greedy" | "temperature" | "top_k" | "top_p".

    greedy ignores temperature/top_k/top_p; top_k masks to the k highest
    logits and top_p (nucleus) to the smallest set whose cumulative
    probability reaches p, both before the temperature-scaled categorical
    draw. ``repetition_penalty`` (CTRL-style) composes with EVERY method,
    greedy included: logits of already-emitted token ids are divided by
    the penalty when positive and multiplied when negative, so emitted
    ids can only be demoted, never promoted. 1.0 disables it.
    """
    method: str = "greedy"
    temperature: float = 1.0
    top_k: int = 0
    top_p: float = 1.0
    repetition_penalty: float = 1.0

    def validate(self) -> "SamplingConfig":
        if self.method not in ("greedy", "temperature", "top_k", "top_p"):
            raise ValueError(f"unknown sampling method {self.method!r}")
        if self.method == "top_k" and self.top_k <= 0:
            raise ValueError("top_k sampling needs top_k >= 1")
        if self.method == "top_p" and not 0.0 < self.top_p <= 1.0:
            raise ValueError(
                f"top_p sampling needs 0 < top_p <= 1 (got {self.top_p})")
        if self.method != "greedy" and self.temperature <= 0:
            raise ValueError("temperature must be > 0")
        if self.repetition_penalty <= 0:
            raise ValueError(
                f"repetition_penalty={self.repetition_penalty} must be > 0 "
                "(1.0 disables it)")
        return self


def _top_p_mask(lg: jnp.ndarray, p: float) -> jnp.ndarray:
    """Nucleus mask: keep the highest-probability tokens whose cumulative
    mass BEFORE each token is < p — the top-1 token always survives, so
    the mask never empties at any p in (0, 1]."""
    srt = jnp.sort(lg, axis=-1)[..., ::-1]
    probs = jax.nn.softmax(srt, axis=-1)
    csum = jnp.cumsum(probs, axis=-1)
    keep = (csum - probs) < p
    nkeep = jnp.maximum(keep.sum(axis=-1, keepdims=True), 1)
    thresh = jnp.take_along_axis(srt, nkeep - 1, axis=-1)
    return jnp.where(lg >= thresh, lg, NEG_INF)


def process_logits(logits: jnp.ndarray, cfg: SamplingConfig, *,
                   penalty_mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """The full static logits transform the sampler draws from:
    repetition penalty (where ``penalty_mask`` marks already-emitted ids)
    -> temperature -> top-k / top-p masking. Works on any (..., V) shape.
    Greedy returns penalty-adjusted logits only (argmax is scale-free)."""
    lg = logits.astype(jnp.float32)
    if penalty_mask is not None and cfg.repetition_penalty != 1.0:
        rp = cfg.repetition_penalty
        pen = jnp.where(lg > 0, lg / rp, lg * rp)
        lg = jnp.where(penalty_mask, pen, lg)
    if cfg.method == "greedy":
        return lg
    lg = lg / cfg.temperature
    if cfg.method == "top_k":
        kth = jax.lax.top_k(lg, cfg.top_k)[0][..., -1:]
        lg = jnp.where(lg < kth, NEG_INF, lg)
    elif cfg.method == "top_p":
        lg = _top_p_mask(lg, cfg.top_p)
    return lg


def sample(logits: jnp.ndarray, key, cfg: SamplingConfig, *,
           penalty_mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """logits (..., V) -> sampled token ids (...,) int32."""
    lg = process_logits(logits, cfg, penalty_mask=penalty_mask)
    if cfg.method == "greedy":
        return jnp.argmax(lg, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)


def token_probs(logits: jnp.ndarray, cfg: SamplingConfig, *,
                penalty_mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """The exact (..., V) distribution ``sample`` draws from — the
    speculative accept rule's p and q (greedy degenerates to a one-hot
    at the argmax, which makes rejection sampling collapse to exact
    argmax matching)."""
    lg = process_logits(logits, cfg, penalty_mask=penalty_mask)
    if cfg.method == "greedy":
        return jax.nn.one_hot(jnp.argmax(lg, axis=-1), lg.shape[-1],
                              dtype=jnp.float32)
    return jax.nn.softmax(lg, axis=-1)


def history_mask(out: jnp.ndarray, widx: jnp.ndarray,
                 vocab: int) -> jnp.ndarray:
    """(B, cap) emitted-token buffer + (B,) valid counts -> (B, V) bool
    mask of already-emitted ids (the repetition penalty's operand).
    Columns >= widx[b] are ignored, so stale buffer contents never
    penalize. Prompt tokens are NOT penalized — only what the engine
    emitted."""
    b, cap = out.shape
    valid = jnp.arange(cap)[None, :] < widx[:, None]
    oh = jax.nn.one_hot(out, vocab, dtype=jnp.bool_)      # (B, cap, V)
    return jnp.any(oh & valid[:, :, None], axis=1)
