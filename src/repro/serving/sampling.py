"""In-graph token sampling for the serving decode loop.

Every sampler is a pure jnp function of (logits, key) so it lives INSIDE the
jitted ``lax.while_loop`` decode body (repro/serving/engine.py) — the loop
never leaves the device to pick a token. The method/temperature/top_k knobs
are static (baked into the trace); the PRNG key is loop-carried state.

Under tensor-parallel serving (DESIGN.md §9) sampling runs REPLICATED:
every shard holds the all-gathered (B, V) logits and the same loop-carried
key, so each draws the identical token and the decode loop stays in
lockstep across the mesh with no extra collective.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.attention import NEG_INF


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    """method: "greedy" | "temperature" | "top_k".

    greedy ignores temperature/top_k; top_k masks to the k highest logits
    before the temperature-scaled categorical draw.
    """
    method: str = "greedy"
    temperature: float = 1.0
    top_k: int = 0

    def validate(self) -> "SamplingConfig":
        if self.method not in ("greedy", "temperature", "top_k"):
            raise ValueError(f"unknown sampling method {self.method!r}")
        if self.method == "top_k" and self.top_k <= 0:
            raise ValueError("top_k sampling needs top_k >= 1")
        if self.method != "greedy" and self.temperature <= 0:
            raise ValueError("temperature must be > 0")
        return self


def sample(logits: jnp.ndarray, key, cfg: SamplingConfig) -> jnp.ndarray:
    """logits (B, V) -> sampled token ids (B,) int32."""
    if cfg.method == "greedy":
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lg = logits.astype(jnp.float32) / cfg.temperature
    if cfg.method == "top_k":
        kth = jax.lax.top_k(lg, cfg.top_k)[0][..., -1:]
        lg = jnp.where(lg < kth, NEG_INF, lg)
    return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)
