"""AdamW + schedules + clipping, from scratch (no optax in this env).

Matches the paper's training setup: AdamW (LH17) with weight_decay=0.0,
warmup_ratio=0.06, grad-clip 3.0 (paper App. A.3 / B / D). ``reinit_state``
implements the paper's §3.3 requirement that Adam moments be re-initialized
after every DMRG truncation (parameter shapes change).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.config.base import OptimizerConfig


@dataclasses.dataclass
class AdamWState:
    step: jnp.ndarray     # ()
    mu: Any               # pytree like params
    nu: Any

    def tree_flatten(self):
        return (self.step, self.mu, self.nu), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    AdamWState, AdamWState.tree_flatten, AdamWState.tree_unflatten)


def init_state(params) -> AdamWState:
    z = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32),
                               params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=z,
                      nu=jax.tree_util.tree_map(jnp.copy, z))


def reinit_state(params) -> AdamWState:
    """Fresh moments after a DMRG rank change (paper §3.3)."""
    return init_state(params)


def carry_state(state: AdamWState, mu, nu) -> AdamWState:
    """Warm-moment carry across a DMRG resplit: install moments that were
    transported through the sweep (core/dmrg.py ``moments=``) and KEEP the
    step counter — a sweep is a reparameterization, not a restart, so the
    bias-correction schedule must not rewind (the old zero-reinit also
    silently reset ``step`` to 0, restarting warmup-scale updates)."""
    return AdamWState(
        step=state.step,
        mu=jax.tree_util.tree_map(
            lambda m: jnp.asarray(m, jnp.float32), mu),
        nu=jax.tree_util.tree_map(
            lambda v: jnp.maximum(jnp.asarray(v, jnp.float32), 0.0), nu))


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    n = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), n


def make_schedule(cfg: OptimizerConfig, total_steps: int) -> Callable:
    warm = max(int(cfg.warmup_ratio * total_steps), 1)

    def sched(step):
        s = step.astype(jnp.float32)
        warm_lr = cfg.lr * (s + 1) / warm
        frac = jnp.clip((s - warm) / jnp.maximum(total_steps - warm, 1),
                        0.0, 1.0)
        if cfg.schedule == "cosine":
            decay = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        elif cfg.schedule == "linear":
            decay = 1.0 - frac
        else:
            decay = 1.0
        return jnp.where(s < warm, warm_lr, cfg.lr * decay)

    return sched


def update(grads, state: AdamWState, params, cfg: OptimizerConfig,
           lr: jnp.ndarray):
    """One AdamW step. Returns (new_params, new_state, grad_norm)."""
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    b1, b2 = cfg.betas
    t = state.step + 1
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=t, mu=new_m, nu=new_v), gnorm
