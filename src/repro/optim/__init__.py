from repro.optim.adamw import (  # noqa: F401
    AdamWState,
    clip_by_global_norm,
    global_norm,
    init_state,
    make_schedule,
    reinit_state,
    update,
)
