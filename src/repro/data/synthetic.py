"""Synthetic data pipeline.

GLUE is not redistributable offline, so the accuracy-shaped experiments run
on deterministic synthetic tasks with the same interface:

* ``lm_stream`` — learnable LM data: tokens follow a random order-1 Markov
  chain (fixed by seed), so next-token loss has signal and training curves
  are meaningful.
* ``classification_tasks`` — T GLUE-like sequence-classification tasks (the
  multi-task experiments of paper §3.2): each task has its own labeling rule
  over a shared token distribution; the label is supervised as the last
  token of the sequence, so the same LM loss machinery applies.

Iterators are **stateful and resumable**: ``state()`` returns a dict that
``restore()`` accepts — the checkpoint manager persists it so a restart
continues the exact data order (fault-tolerance requirement).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass
class LMStream:
    vocab_size: int
    seq_len: int
    batch: int
    seed: int = 0
    branching: int = 4      # out-degree of the Markov chain (lower=easier)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # sparse random transition table: each token can be followed by
        # ``branching`` tokens with random fixed probabilities
        nxt = rng.integers(0, self.vocab_size,
                           (self.vocab_size, self.branching))
        p = rng.dirichlet(np.ones(self.branching), self.vocab_size)
        self._next, self._p = nxt, p
        self._step = 0

    def state(self) -> dict:
        return {"step": self._step, "seed": self.seed}

    def restore(self, state: dict) -> None:
        assert state["seed"] == self.seed, "data stream seed mismatch"
        self._step = int(state["step"])

    def _sample(self, rng):
        toks = np.empty((self.batch, self.seq_len), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab_size, self.batch)
        for t in range(1, self.seq_len):
            choice = (rng.random((self.batch, 1))
                      > np.cumsum(self._p[toks[:, t - 1]], -1)).sum(-1)
            choice = np.minimum(choice, self.branching - 1)
            toks[:, t] = self._next[toks[:, t - 1], choice]
        return toks

    def __next__(self) -> dict:
        rng = np.random.default_rng((self.seed, self._step))
        self._step += 1
        toks = self._sample(rng)
        return {"tokens": toks,
                "mask": np.ones_like(toks, np.float32)}

    def __iter__(self) -> Iterator[dict]:
        return self


@dataclasses.dataclass
class ClassificationTasks:
    """T synthetic classification tasks for the MTL experiments (§3.2).

    Task t's rule: label = (token at position t) mod n_classes — each task
    attends to a different position, so the task core must route attention
    differently per task. The label is appended as the final token (from a
    reserved class-token range), so next-token loss on the last position is
    exactly the classification loss.
    """
    vocab_size: int
    seq_len: int
    batch: int
    num_tasks: int
    n_classes: int = 2
    seed: int = 0

    def __post_init__(self):
        assert self.vocab_size > self.n_classes
        self._step = 0

    def state(self) -> dict:
        return {"step": self._step, "seed": self.seed}

    def restore(self, state: dict) -> None:
        self._step = int(state["step"])

    @property
    def class_token_base(self) -> int:
        return self.vocab_size - self.n_classes

    def sample(self, task: int, split: str = "train") -> dict:
        salt = 0 if split == "train" else 10**6
        rng = np.random.default_rng((self.seed, task, self._step + salt))
        if split == "train":
            self._step += 1
        body = rng.integers(0, self.class_token_base,
                            (self.batch, self.seq_len - 1), dtype=np.int32)
        label = (body[:, task % self.seq_len] % self.n_classes).astype(
            np.int32)
        toks = np.concatenate(
            [body, (self.class_token_base + label)[:, None]], axis=1)
        mask = np.zeros_like(toks, np.float32)
        mask[:, -1] = 1.0            # supervise only the label position
        return {"tokens": toks, "mask": mask, "task": np.int32(task),
                "labels": label}

    @staticmethod
    def accuracy(logits_last: np.ndarray, labels: np.ndarray,
                 class_token_base: int, n_classes: int) -> float:
        """logits_last: (B, V) logits at the position predicting the label."""
        cls = logits_last[:, class_token_base:class_token_base + n_classes]
        return float((cls.argmax(-1) == labels).mean())
