from repro.data.synthetic import ClassificationTasks, LMStream  # noqa: F401
