"""VeRA baseline (Kopiczko et al., ICLR 2024) — parameter-sharing comparison.

A single pair of *frozen random* matrices A ∈ R^{d_in×r}, B ∈ R^{r×d_out} is
shared across all layers/matrix types; only per-(l,m) scaling vectors are
trained:

  Δy = (((x · A) ⊙ d_{l,m}) · B) ⊙ g_{l,m}

with d ∈ R^r (init d_init = 0.1) and g ∈ R^{d_out} (init 0 → ΔW = 0 at init).
Trainable parameter count L·M·(r + D) — matches the paper's Table 1 rows
(RoBERTa-base r=1024 → 43k, large r=256 → 61k).

The paper's App. A.3 re-benchmarks VeRA with frozen classifier heads; our
trainer reproduces that by only ever training adapter params unless
``train_base=True``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class VeRAConfig:
    num_layers: int
    matrix_types: tuple
    d_in: tuple
    d_out: tuple
    rank: int
    d_init: float = 0.1
    alpha: float = 1.0
    seed: int = 0          # frozen A/B are derived from this, checkpoint-free
    dtype: Any = jnp.float32

    @property
    def num_matrices(self) -> int:
        return len(self.matrix_types)

    @property
    def d_in_max(self) -> int:
        return max(self.d_in)

    @property
    def d_out_max(self) -> int:
        return max(self.d_out)

    def m_index(self, name: str) -> int:
        return self.matrix_types.index(name)

    def num_params(self) -> int:
        """Trainable only (frozen shared A/B are excluded, as in the paper)."""
        return sum(self.num_layers * (self.rank + do) for do in self.d_out)


def paper_count(D: int, L: int, M: int, r: int) -> int:
    """L·M·(r + D)."""
    return L * M * (r + D)


def init_params(cfg: VeRAConfig, key) -> tuple:
    l, m, r = cfg.num_layers, cfg.num_matrices, cfg.rank
    trainable = {
        "d": jnp.full((l, m, r), cfg.d_init, cfg.dtype),
        "g": jnp.zeros((l, m, cfg.d_out_max), cfg.dtype),
    }
    fkey = jax.random.PRNGKey(cfg.seed)
    k1, k2 = jax.random.split(fkey)
    frozen = {
        "a": (jax.random.normal(k1, (cfg.d_in_max, r), cfg.dtype)
              / jnp.sqrt(cfg.d_in_max)),
        "b": (jax.random.normal(k2, (r, cfg.d_out_max), cfg.dtype)
              / jnp.sqrt(r)),
    }
    return trainable, frozen


def delta(cfg: VeRAConfig, broadcast: dict, layer_slice: dict, x: jnp.ndarray,
          mi: int) -> jnp.ndarray:
    a = broadcast["a"][: x.shape[-1]].astype(x.dtype)
    b = broadcast["b"][:, : cfg.d_out[mi]].astype(x.dtype)
    d = layer_slice["d"][mi].astype(x.dtype)
    g = layer_slice["g"][mi][: cfg.d_out[mi]].astype(x.dtype)
    return cfg.alpha * ((((x @ a) * d) @ b) * g)
