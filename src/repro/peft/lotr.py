"""LoTR baseline (Bershatsky et al. 2024) — low tensor-rank weight adaptation.

ΔW_{l,m} = U · S_{l,m} · Vᵀ with *shared* end factors U ∈ R^{d_in×r},
V ∈ R^{d_out×r} and a per-(layer, matrix) trainable core S ∈ R^{r×r}.
Parameter count 2Dr + L·M·r² — matches the paper's Table 1 rows
(base r=40 → 100k, r=80 → 276k, r=88 → 321k; large r=64 → 328k).

Structurally LoTR is MetaTT-4D with the (L, M) axes *merged into a single
core* — i.e. it spends L·M·r² on the middle where MetaTT spends (L+M)·r²,
which is exactly the compression gap the paper exploits (§1.1, Table 1).

Init: U, V random normal; S = 0 → ΔW = 0 at init.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LoTRConfig:
    num_layers: int
    matrix_types: tuple
    d_in: tuple
    d_out: tuple
    rank: int
    alpha: float = 1.0
    dtype: Any = jnp.float32

    @property
    def num_matrices(self) -> int:
        return len(self.matrix_types)

    @property
    def d_in_max(self) -> int:
        return max(self.d_in)

    @property
    def d_out_max(self) -> int:
        return max(self.d_out)

    def m_index(self, name: str) -> int:
        return self.matrix_types.index(name)

    def num_params(self) -> int:
        r = self.rank
        return (self.d_in_max * r + self.d_out_max * r
                + self.num_layers * self.num_matrices * r * r)


def paper_count(D: int, L: int, M: int, r: int) -> int:
    """2Dr + LMr²."""
    return 2 * D * r + L * M * r * r


def init_params(cfg: LoTRConfig, key) -> dict:
    k1, k2 = jax.random.split(key)
    r = cfg.rank
    return {
        "u": (jax.random.normal(k1, (cfg.d_in_max, r), cfg.dtype)
              / jnp.sqrt(cfg.d_in_max)),
        "v": (jax.random.normal(k2, (cfg.d_out_max, r), cfg.dtype)
              / jnp.sqrt(r)),
        "s": jnp.zeros((cfg.num_layers, cfg.num_matrices, r, r), cfg.dtype),
    }


def delta(cfg: LoTRConfig, broadcast: dict, layer_slice: dict, x: jnp.ndarray,
          mi: int) -> jnp.ndarray:
    u = broadcast["u"][: x.shape[-1]].astype(x.dtype)
    vt = broadcast["v"][: cfg.d_out[mi]].T.astype(x.dtype)
    s = layer_slice["s"][mi].astype(x.dtype)
    return cfg.alpha * (((x @ u) @ s) @ vt)
