"""Unified adapter API.

Every PEFT method in this framework — MetaTT (the paper), and the baselines
it compares against (LoRA, VeRA, LoTR) — implements the same functional
contract so models are adapter-agnostic:

  trainable, frozen = init_adapter(spec, key)
  broadcast, per_layer = adapter_factors(spec, trainable, frozen)
      # once per step; ``per_layer`` has a leading L axis and is fed to the
      # layer scan as xs, ``broadcast`` is closed over.
  dy = adapter_delta(spec, broadcast, layer_slice, x, m, task=...)
      # inside a layer; returns the low-rank update α·x·ΔW_{l,m} (or 0).

The split into (broadcast, per_layer) is what makes every method O(1) in HLO
size under ``jax.lax.scan`` over layers, and it is also where MetaTT's
step-level pre-merge of the middle cores happens (DESIGN.md §3).

Shared-projection note: for MetaTT, q and v deltas at the same layer share
``P = x·G1``. We deliberately compute it per call — XLA CSE merges the two
identical GEMMs under jit, keeping this API simple.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Union

import jax
import jax.numpy as jnp

from repro.core import merge as _merge
from repro.core import metatt as _metatt
from repro.peft import lora as _lora
from repro.peft import lotr as _lotr
from repro.peft import vera as _vera

AdapterConfig = Union[_metatt.MetaTTConfig, "_lora.LoRAConfig",
                      "_vera.VeRAConfig", "_lotr.LoTRConfig", None]


@dataclasses.dataclass(frozen=True)
class AdapterSpec:
    """Static description of the adapter attached to a model.

    kind: "metatt" | "lora" | "vera" | "lotr" | "none"
    cfg:  the per-kind config (carries dims/rank/alpha/matrix_types).
    """
    kind: str
    cfg: AdapterConfig = None

    @property
    def matrix_types(self) -> tuple:
        return () if self.kind == "none" else self.cfg.matrix_types

    def adapts(self, m: str) -> bool:
        return self.kind != "none" and m in self.cfg.matrix_types


NONE = AdapterSpec(kind="none")


def init_adapter(spec: AdapterSpec, key) -> tuple:
    """Returns (trainable, frozen) param pytrees. ``frozen`` holds
    non-trainable method state (VeRA's shared random A/B); {} otherwise."""
    if spec.kind == "none":
        return {}, {}
    if spec.kind == "metatt":
        return _metatt.init_params(spec.cfg, key), {}
    if spec.kind == "lora":
        return _lora.init_params(spec.cfg, key), {}
    if spec.kind == "vera":
        return _vera.init_params(spec.cfg, key)
    if spec.kind == "lotr":
        return _lotr.init_params(spec.cfg, key), {}
    raise ValueError(f"unknown adapter kind {spec.kind!r}")


def adapter_factors(spec: AdapterSpec, trainable, frozen) -> tuple:
    """(broadcast, per_layer) — per-step precompute. per_layer leading dim L."""
    if spec.kind == "none":
        return {}, None
    if spec.kind == "metatt":
        f = _metatt.step_factors(trainable, spec.cfg)
        return {"g1": f.g1, "g4": f.g4}, {"c": f.c}
    if spec.kind == "lora":
        return {}, trainable          # {"a": (L,M,Din,r), "b": (L,M,r,Dout)}
    if spec.kind == "vera":
        return frozen, trainable      # frozen {"a","b"}, trainable {"d","g"}
    if spec.kind == "lotr":
        return {"u": trainable["u"], "v": trainable["v"]}, \
               {"s": trainable["s"]}
    raise ValueError(spec.kind)


def adapter_delta(spec: AdapterSpec, broadcast, layer_slice, x: jnp.ndarray,
                  m: str, *, task: Optional[Any] = None) -> jnp.ndarray | None:
    """Low-rank delta for matrix type ``m`` at the current layer, or None if
    this matrix type is not adapted. ``layer_slice`` is per_layer[l]."""
    if not spec.adapts(m):
        return None
    cfg = spec.cfg
    mi = cfg.m_index(m) if hasattr(cfg, "m_index") else \
        cfg.matrix_types.index(m)
    if spec.kind == "metatt":
        # two factor layouts exist for metatt: {"c": ...} is the canonical
        # per-step form from adapter_factors; {"a": ...} is the pre-merged
        # to_lora_form produced only by serving AdapterRuntime("lora") —
        # middle cores folded into A, so the delta is two GEMMs (paper §2.4).
        if "c" in layer_slice:
            f = _metatt.StepFactors(g1=broadcast["g1"], c=None,
                                    g4=broadcast["g4"])
            p = _metatt.project_in(f, cfg, x, m)
            return _metatt.delta_out(f, cfg, p, layer_slice["c"], m,
                                     task=task)
        if "a" in layer_slice:
            return _merge.lora_form_delta(layer_slice["a"], broadcast["g4"],
                                          cfg, x, m, task=task)
        raise ValueError(
            f"metatt per-layer factors must contain 'c' or 'a'; got "
            f"{sorted(layer_slice)}")
    if spec.kind == "lora":
        return _lora.delta(cfg, layer_slice, x, mi)
    if spec.kind == "vera":
        return _vera.delta(cfg, broadcast, layer_slice, x, mi)
    if spec.kind == "lotr":
        return _lotr.delta(cfg, broadcast, layer_slice, x, mi)
    raise ValueError(spec.kind)


def lora_form_factors(spec: AdapterSpec, broadcast, layer_slice, m: str, *,
                      task: Optional[Any] = None):
    """Fold the current layer's adapter for matrix type ``m`` into lora-form
    ``(A, B, alpha)`` with Δy = α·(x·A)·B — the shape the fused Pallas
    kernel consumes (kernels/dispatch.py, DESIGN.md §5).

    Every kind folds: MetaTT pre-merges A = G1·C[l(,t),m] (two tiny r×r
    GEMMs, activation-independent — cf. the paper's §2.4 serving merge and
    the TT-LoRA / LoRETTA two-GEMM deployments); LoRA is already (A, B);
    VeRA scales its frozen pair by the trained d/g vectors; LoTR folds the
    core into U. Returns None when ``m`` is not adapted. With a (B,) task
    vector (4+1d per-request routing) A gains a leading slot axis — the
    ``tt_linear_batched_a`` kernel's operand.

    Factors are returned in parameter dtype; callers cast to the activation
    dtype (mirroring the unfused delta paths).
    """
    if not spec.adapts(m):
        return None
    cfg = spec.cfg
    mi = cfg.m_index(m) if hasattr(cfg, "m_index") else \
        cfg.matrix_types.index(m)
    d_in, d_out = cfg.d_in[mi], cfg.d_out[mi]
    if spec.kind == "metatt":
        if "a" in layer_slice:           # serving "lora" runtime: pre-folded
            a_l = layer_slice["a"]
            if cfg.variant == "4+1d":
                if task is None:
                    raise ValueError("variant 4+1d needs a task index")
                a = a_l[task, mi]
            elif cfg.variant == "4+ed":
                a = a_l[0 if task is None else task, mi]
            else:
                a = a_l[mi]
            return a[..., :d_in, :], broadcast["g4"][:, :d_out], 1.0
        c_l = layer_slice["c"]
        if cfg.variant == "4+1d":
            if task is None:
                raise ValueError("variant 4+1d needs a task index")
            c_lm = c_l[task, mi]         # scalar: (r, r); (B,): (B, r, r)
        elif cfg.variant == "4+ed":
            c_lm = c_l[0 if task is None else task, mi]
        else:
            c_lm = c_l[mi]
        g1 = broadcast["g1"][:d_in]
        a = jnp.einsum("dr,...rs->...ds", g1, c_lm)
        return a, broadcast["g4"][:, :d_out], cfg.alpha
    if spec.kind == "lora":
        return (layer_slice["a"][mi][:d_in],
                layer_slice["b"][mi][:, :d_out], cfg.alpha / cfg.rank)
    if spec.kind == "vera":
        # (((x·A)⊙d)·B)⊙g == x·(A·diag(d))·(B·diag(g))
        a = broadcast["a"][:d_in] * layer_slice["d"][mi][None, :]
        b = broadcast["b"][:, :d_out] * layer_slice["g"][mi][None, :d_out]
        return a, b, cfg.alpha
    if spec.kind == "lotr":
        a = broadcast["u"][:d_in] @ layer_slice["s"][mi]
        return a, broadcast["v"][:d_out].T, cfg.alpha
    raise ValueError(spec.kind)


def count_trainable(spec: AdapterSpec, trainable) -> int:
    return int(sum(jnp.size(x) for x in jax.tree_util.tree_leaves(trainable)))
