"""PEFT adapters: MetaTT (the paper) + the baselines it compares against."""
from repro.peft.api import (  # noqa: F401
    NONE,
    AdapterSpec,
    adapter_delta,
    adapter_factors,
    count_trainable,
    init_adapter,
)
from repro.peft.lora import LoRAConfig  # noqa: F401
from repro.peft.lotr import LoTRConfig  # noqa: F401
from repro.peft.vera import VeRAConfig  # noqa: F401
