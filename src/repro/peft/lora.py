"""LoRA baseline (Hu et al. 2021) — the paper's primary comparison point.

Per adapted matrix (layer l, type m):  ΔW_{l,m} = A_{l,m} · B_{l,m},
A ∈ R^{d_in×r} ~ N(0, 1/r) …actually Kaiming-ish N(0, σ²), B = 0, scaled by
α/r (the standard LoRA convention).  Parameter count 2·L·M·D·r — the
product-across-modes scaling MetaTT's sum-across-modes improves on
(paper §2.4).

Weights are stored scan-stacked: a (L, M, d_in_max, r), b (L, M, r, d_out_max)
with boundary slicing for heterogeneous shapes, mirroring MetaTT so the two
are drop-in interchangeable in the model zoo.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LoRAConfig:
    num_layers: int
    matrix_types: tuple
    d_in: tuple
    d_out: tuple
    rank: int
    alpha: float = 8.0
    dtype: Any = jnp.float32

    @property
    def num_matrices(self) -> int:
        return len(self.matrix_types)

    @property
    def d_in_max(self) -> int:
        return max(self.d_in)

    @property
    def d_out_max(self) -> int:
        return max(self.d_out)

    def m_index(self, name: str) -> int:
        return self.matrix_types.index(name)

    def num_params(self) -> int:
        # exact (with boundary slicing the padded entries still count as
        # allocated-but-unused only when dims differ; report the paper's
        # effective count which sums true dims):
        r = self.rank
        return sum(self.num_layers * (di * r + r * do)
                   for di, do in zip(self.d_in, self.d_out))


def paper_count(D: int, L: int, M: int, r: int) -> int:
    """2LMDr (paper §2.4)."""
    return 2 * L * M * D * r


def init_params(cfg: LoRAConfig, key) -> dict:
    l, m, r = cfg.num_layers, cfg.num_matrices, cfg.rank
    a = (jax.random.normal(key, (l, m, cfg.d_in_max, r), cfg.dtype)
         / jnp.sqrt(cfg.d_in_max))
    b = jnp.zeros((l, m, r, cfg.d_out_max), cfg.dtype)
    return {"a": a, "b": b}


def delta(cfg: LoRAConfig, layer_slice: dict, x: jnp.ndarray,
          mi: int) -> jnp.ndarray:
    a = layer_slice["a"][mi][: x.shape[-1]]
    b = layer_slice["b"][mi][:, : cfg.d_out[mi]]
    scale = cfg.alpha / cfg.rank
    return scale * ((x @ a.astype(x.dtype)) @ b.astype(x.dtype))
