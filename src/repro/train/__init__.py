from repro.train.train_step import (  # noqa: F401
    TrainState,
    init_train_state,
    make_full_ft_step,
    make_train_step,
    reinit_after_dmrg,
)
from repro.train.trainer import Trainer  # noqa: F401
