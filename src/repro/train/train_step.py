"""Jitted train / serve steps.

train_step: PEFT semantics — ``jax.value_and_grad`` over the adapter pytree
only; the frozen base weights are a non-differentiated argument (no grads,
no optimizer state, no master copy — the memory model that makes 1T-param
fine-tuning fit, DESIGN.md §4). Supports microbatch gradient accumulation
(lax.scan), remat-per-super-block, and optional gradient compression.

The serving helpers (make_prefill / make_serve_step) live in
repro.serving.engine, next to the continuous-batching Engine.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig, OptimizerConfig, TrainConfig
from repro.distributed.compression import GradCompressor
from repro.kernels import dispatch as kernel_dispatch
from repro.models import model as model_lib
from repro.optim import adamw
from repro.peft import api as peft_api


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TrainState:
    adapter: Any
    opt: adamw.AdamWState
    residual: Any          # top-k compression error feedback (or None)
    step: jnp.ndarray

    def tree_flatten(self):
        return (self.adapter, self.opt, self.residual, self.step), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_train_state(adapter, compressor: GradCompressor) -> TrainState:
    return TrainState(adapter=adapter, opt=adamw.init_state(adapter),
                      residual=compressor.init_residual(adapter),
                      step=jnp.zeros((), jnp.int32))


def reinit_after_dmrg(state: TrainState, new_adapter,
                      compressor: GradCompressor,
                      moments=None) -> TrainState:
    """Rank change: rebuild the optimizer state for the new core shapes.

    moments: optional ``(mu, nu)`` pytrees transported through the sweep
    (core/dmrg.py ``moments=``) — the warm path keeps Adam statistics AND
    the step counter across the resplit. Without them, fall back to the
    paper's §3.3 fresh re-initialization (which restarts bias correction).
    """
    if moments is not None:
        opt = adamw.carry_state(state.opt, *moments)
    else:
        opt = adamw.init_state(new_adapter)
    return TrainState(adapter=new_adapter, opt=opt,
                      residual=compressor.init_residual(new_adapter),
                      step=state.step)


def make_train_step(cfg: ModelConfig, spec: peft_api.AdapterSpec,
                    opt_cfg: OptimizerConfig, train_cfg: TrainConfig,
                    total_steps: int, *, chunk: int = 0,
                    donate: bool = True, kernels=None) -> Callable:
    """Returns jitted fn(state, base, frozen, batch) -> (state, metrics).

    kernels: KernelConfig (or resolved KernelPolicy) — routes the Eq. (5)
    hot path through the fused Pallas kernels (kernels/dispatch.py)."""
    schedule = adamw.make_schedule(opt_cfg, total_steps)
    compressor = GradCompressor(train_cfg.grad_compression)
    remat = train_cfg.remat != "none"
    policy = kernel_dispatch.resolve(kernels)

    def loss(adapter, base, frozen, batch):
        return model_lib.loss_fn(adapter, base, frozen, batch, cfg, spec,
                                 remat=remat, chunk=chunk, policy=policy)

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def step_fn(state: TrainState, base, frozen, batch):
        nmb = train_cfg.microbatch
        if nmb and nmb > 1:
            def micro(acc, mb):
                (l, m), g = grad_fn(state.adapter, base, frozen, mb)
                acc_g, acc_l = acc
                return (jax.tree_util.tree_map(jnp.add, acc_g, g),
                        acc_l + l), m
            zero = (jax.tree_util.tree_map(
                lambda a: jnp.zeros_like(a, jnp.float32), state.adapter),
                jnp.zeros((), jnp.float32))
            mbs = jax.tree_util.tree_map(
                lambda a: a.reshape((nmb, a.shape[0] // nmb) + a.shape[1:]),
                batch)
            (grads, lsum), ms = jax.lax.scan(micro, zero, mbs)
            grads = jax.tree_util.tree_map(lambda g: g / nmb, grads)
            loss_val = lsum / nmb
            metrics = {k: jnp.mean(v) for k, v in ms.items()}
        else:
            (loss_val, metrics), grads = grad_fn(state.adapter, base, frozen,
                                                 batch)
        grads, residual = compressor(grads, state.residual)
        lr = schedule(state.opt.step)
        new_adapter, new_opt, gnorm = adamw.update(
            grads, state.opt, state.adapter, opt_cfg, lr)
        new_state = TrainState(adapter=new_adapter, opt=new_opt,
                               residual=residual, step=state.step + 1)
        metrics = dict(metrics, loss=loss_val, grad_norm=gnorm, lr=lr)
        return new_state, metrics

    return jax.jit(step_fn, donate_argnums=(0,) if donate else ())


def make_full_ft_step(cfg: ModelConfig, opt_cfg: OptimizerConfig,
                      train_cfg: TrainConfig, total_steps: int) -> Callable:
    """Full fine-tuning baseline (paper Table 1 "FT" row): differentiates the
    base weights. fn(base, opt_state, batch) -> (base, opt_state, metrics)."""
    schedule = adamw.make_schedule(opt_cfg, total_steps)
    spec = peft_api.NONE

    def loss(base, batch):
        return model_lib.loss_fn({}, base, {}, batch, cfg, spec,
                                 remat=train_cfg.remat != "none")

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def step_fn(base, opt_state, batch):
        (loss_val, metrics), grads = grad_fn(base, batch)
        lr = schedule(opt_state.step)
        new_base, new_opt, gnorm = adamw.update(grads, opt_state, base,
                                                opt_cfg, lr)
        return new_base, new_opt, dict(metrics, loss=loss_val,
                                       grad_norm=gnorm)

    return jax.jit(step_fn, donate_argnums=(0, 1))
