"""Training loop: checkpoint/restart, DMRG rank-adaptive sweeps, straggler
watchdog, multi-task cycling.

The loop is deliberately host-driven (the paper's §3.3 uses a custom loop for
the same reason: DMRG changes the *model shapes* mid-run, which no jitted
graph can do). Rank changes trigger: sweep (with AdamW moments transported
through each two-site resplit when ``train.dmrg_warm_moments`` — the
paper's cold re-init is the fallback) → re-place the rank-changed cores +
moments onto the ambient GSPMD mesh → automatic re-jit via new shapes.
Sweeps run BEFORE the boundary checkpoint and the applied schedule position
is recorded in checkpoint meta, so a resume lands on the post-sweep
(params, opt-state, schedule-position) triple instead of silently losing
the rank change.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.config.base import RunConfig
from repro.core import dmrg as dmrg_lib
from repro.core import tt
from repro.distributed import FailureInjector, GradCompressor, Watchdog
from repro.sharding import rules
from repro.models import model as model_lib
from repro.peft import api as peft_api
from repro.train import train_step as ts


@dataclasses.dataclass
class Trainer:
    run: RunConfig
    data: Any                                  # iterator with state()/restore()
    total_steps: int
    steps_per_epoch: int = 0                   # 0 -> no epoch semantics
    rank_schedule: Optional[dmrg_lib.RankSchedule] = None
    failure_injector: Optional[FailureInjector] = None
    on_metrics: Optional[Callable[[int, dict], None]] = None
    eval_fn: Optional[Callable[[Any], dict]] = None
    task_cycle: tuple = ()                     # MTL: task ids for joint training

    def __post_init__(self):
        run = self.run
        self.cfg = run.model
        self.spec = model_lib.build_adapter_spec(run)
        key = jax.random.PRNGKey(run.train.seed)
        params = model_lib.init_params(self.cfg, self.spec, key)
        self.base, self.frozen = params["base"], params["frozen"]
        self.compressor = GradCompressor(run.train.grad_compression)
        self.state = ts.init_train_state(params["adapter"], self.compressor)
        self.step_fn = ts.make_train_step(
            self.cfg, self.spec, run.optimizer, run.train, self.total_steps,
            kernels=run.kernels)
        self.ckpt = (CheckpointManager(run.train.ckpt_dir,
                                       keep=run.train.ckpt_keep)
                     if run.train.ckpt_dir else None)
        self.watchdog = Watchdog()
        self.straggler_events: list = []
        self.watchdog.on_straggler = lambda s, dt, ew: \
            self.straggler_events.append((s, dt, ew))
        self.history: list = []
        self._dmrg_applied: list = []      # epochs whose sweep already ran
        self._resume()

    # ------------------------------------------------------------------
    def _resume(self) -> None:
        if self.ckpt is None:
            return
        got = self.ckpt.restore_latest(self.state)
        if got is None:
            return
        step, state, meta = got
        self.state = state
        if "data_state" in meta and hasattr(self.data, "restore"):
            self.data.restore(meta["data_state"])
        dm = meta.get("dmrg") or {}
        self._dmrg_applied = list(dm.get("applied_epochs", []))
        extra = (f" (dmrg epochs {self._dmrg_applied}, "
                 f"ranks {tuple(dm.get('ranks', ()))})" if dm else "")
        print(f"[trainer] resumed from checkpoint step {step}{extra}")

    def _save(self, step: int) -> None:
        if self.ckpt is None:
            return
        meta = {}
        if hasattr(self.data, "state"):
            meta["data_state"] = self.data.state()
        adapter = self.state.adapter
        if isinstance(adapter, dict) and "cores" in adapter:
            # schedule position rides with the reshaped params/opt-state so
            # a resume can't silently lose a rank change
            meta["dmrg"] = {
                "applied_epochs": list(self._dmrg_applied),
                "ranks": [int(r) for r in tt.ranks(adapter["cores"])],
            }
        self.ckpt.save(step, self.state, meta)

    # ------------------------------------------------------------------
    def _maybe_dmrg(self, step: int) -> None:
        """End-of-epoch DMRG sweep per the rank schedule (paper Fig. 2)."""
        if (self.rank_schedule is None or not self.steps_per_epoch
                or self.spec.kind != "metatt"):
            return
        if step == 0 or step % self.steps_per_epoch:
            return
        epoch = step // self.steps_per_epoch
        target = self.rank_schedule.rank_after_epoch(epoch)
        if target is None or epoch in self._dmrg_applied:
            return
        warm = self.run.train.dmrg_warm_moments
        moments = (self.state.opt.mu, self.state.opt.nu) if warm else None
        res = dmrg_lib.dmrg_sweep(self.state.adapter, target_rank=target,
                                  moments=moments)
        n_before = peft_api.count_trainable(self.spec, self.state.adapter)
        n_after = peft_api.count_trainable(self.spec, res.params)
        self.state = ts.reinit_after_dmrg(self.state, res.params,
                                          self.compressor,
                                          moments=res.moments)
        # the host-side resplit left stale placements: put the rank-changed
        # cores + moments back onto the ambient mesh before the retrace
        self.state = rules.reshard_after_reshape(self.state)
        self._dmrg_applied.append(epoch)
        print(f"[trainer] DMRG sweep @step {step}: ranks -> {res.ranks} "
              f"params {n_before} -> {n_after} "
              f"({'warm' if warm else 'cold'} moments)")

    # ------------------------------------------------------------------
    def _next_batch(self, step: int) -> dict:
        if self.task_cycle:
            task = self.task_cycle[step % len(self.task_cycle)]
            raw = self.data.sample(task)
        else:
            raw = next(self.data)
        return {k: jnp.asarray(v) for k, v in raw.items()
                if k in ("tokens", "mask", "task", "embeds", "enc_embeds")}

    def train(self, steps: Optional[int] = None) -> list:
        steps = steps or self.total_steps
        start = int(self.state.step)
        for step in range(start, steps):
            if self.failure_injector is not None:
                self.failure_injector.check(step)
            batch = self._next_batch(step)
            t0 = time.perf_counter()
            self.state, metrics = self.step_fn(self.state, self.base,
                                               self.frozen, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            self.watchdog.step(step, dt)
            metrics["step_time_s"] = dt
            self.history.append((step, metrics))
            if self.on_metrics is not None:
                self.on_metrics(step, metrics)
            # sweep BEFORE the boundary checkpoint: a save at an epoch edge
            # must capture the post-sweep triple, or a resume from it would
            # silently lose the rank change
            self._maybe_dmrg(step + 1)
            if self.run.train.ckpt_every and \
                    (step + 1) % self.run.train.ckpt_every == 0:
                self._save(step + 1)
        if self.ckpt is not None:
            self._save(steps)
            self.ckpt.wait()
        return self.history

    # ------------------------------------------------------------------
    def losses(self) -> np.ndarray:
        return np.array([m["loss"] for _, m in self.history])
