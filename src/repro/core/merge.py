"""Inference-time core merging (paper §2.4).

"During inference, one can match the speeds of LoRA by adding a single
pre-computation step where one can merge the middle tensor cores with G1 or
G4 once the adapters are trained."

``to_lora_form`` folds the middle cores into the *left* boundary, producing a
per-(layer, matrix[, task]) pair (A, B) with A ∈ R^{L,M,D_in,r}, B ∈ R^{r,D_out}
— exactly a (shared-B) LoRA adapter, so the serving path runs two GEMMs per
adapted matrix, identical to LoRA. ``fold_into_dense`` goes one step further
and adds ΔW into the frozen weights (zero serving overhead), which is what
the serving example uses by default.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.metatt import MetaTTConfig, Params, step_factors


@dataclasses.dataclass
class LoRAForm:
    """Merged serving form: y += alpha already folded into A."""
    a: jnp.ndarray  # (L, [T,] M, d_in_max, r)
    b: jnp.ndarray  # (r, d_out_max)

    def delta(self, cfg: MetaTTConfig, x, layer: int, m: str,
              task: int | None = None):
        return lora_form_delta(self.a[layer], self.b, cfg, x, m, task=task)


def to_lora_form(params: Params, cfg: MetaTTConfig) -> LoRAForm:
    f = step_factors(params, cfg)
    # fold alpha and the middle cores into the left factor:
    # A[l, m] = alpha * G1 @ C[l, m]   -> (..., d_in, r_last)
    a = cfg.alpha * jnp.einsum("dr,...rs->...ds", f.g1, f.c)
    return LoRAForm(a=a, b=f.g4)


def lora_form_delta(a_l: jnp.ndarray, b: jnp.ndarray, cfg: MetaTTConfig,
                    x: jnp.ndarray, m: str, *,
                    task=None) -> jnp.ndarray:
    """Delta from one layer-slice of ``to_lora_form`` factors (the serving
    runtime's "lora" mode — two GEMMs per adapted matrix, alpha pre-folded).

    a_l: ``LoRAForm.a[layer]`` — ([T,] M, d_in_max, r); b: (r, d_out_max).
    task: scalar or per-request (B,) vector (4+1d batched task routing).
    """
    mi = cfg.m_index(m)
    if cfg.variant == "4+1d":
        if task is None:
            raise ValueError("variant 4+1d needs a task index")
        a = a_l[task, mi]
    elif cfg.variant == "4+ed":
        a = a_l[0 if task is None else task, mi]
    else:
        a = a_l[mi]
    a = a[..., : x.shape[-1], :].astype(x.dtype)
    bb = b[:, : cfg.d_out[mi]].astype(x.dtype)
    if a.ndim == 3:                   # (B, d_in, r): per-request task gather
        p = jnp.einsum("b...d,bdr->b...r", x, a)
    else:
        p = x @ a
    return p @ bb


def lora_task_slice(a: jnp.ndarray, task) -> jnp.ndarray:
    """One task's column of the merged lora-form ``LoRAForm.a``.

    Task-routed (4+1d) lora factors are (L, T, M, d_in_max, r) — the task
    mode is AXIS 1, same layout contract as the live factor
    (core/metatt.py ``take_task_slice``). The serving adapter registry
    pages these (L, M, d_in_max, r) slices; ``LoRAForm.b`` is task-shared
    and never moves.
    """
    return a[:, task]


def lora_task_put(pool: jnp.ndarray, slot, col: jnp.ndarray) -> jnp.ndarray:
    """Scatter one lora-form task slice into pool slot ``slot`` — inverse
    of ``lora_task_slice`` over a (L, K, M, d_in_max, r) pooled factor."""
    return pool.at[:, slot].set(col.astype(pool.dtype))


def fold_into_dense(params: Params, cfg: MetaTTConfig,
                    weights: dict, *, task: int | None = None,
                    layers=None) -> dict:
    """Return a copy of ``weights`` with ΔW added into each adapted matrix.

    ``weights`` maps matrix-type name -> stacked (L', d_in, d_out) array (the
    scan-stacked layout used by the model zoo). ``layers`` optionally names
    the global layer ids (length L') each stacked row corresponds to —
    ``None`` means rows 0..L-1 of the full TT layer axis. Zero serving
    overhead after this fold; un-merging is exact (subtract the same delta).
    """
    f = step_factors(params, cfg)
    c_full = f.c if layers is None else jnp.take(
        f.c, jnp.asarray(layers, jnp.int32), axis=0)
    out = dict(weights)
    for mi, name in enumerate(cfg.matrix_types):
        if name not in weights:
            continue
        w = weights[name]
        c = c_full[:, task, mi] if task is not None else c_full[:, mi]
        delta = cfg.alpha * jnp.einsum(
            "dr,lrs,se->lde",
            f.g1[: w.shape[1]], c, f.g4[:, : w.shape[2]])
        out[name] = (w + delta.astype(w.dtype))
    return out


# --------------------------------------------------------------------------
# whole-model fold (all pattern positions, all super-blocks)
# --------------------------------------------------------------------------

# adapted matrix type -> (required mixer kind or None, block group, weight).
# Pattern entry p of blocks holds layers [p, P+p, 2P+p, ...] stacked over nb
# (transformer._split_layers layout), so its C slice is c[p::P].
_FOLD_PATHS = {
    "attn_q": ("attn", "mixer", "wq"), "attn_k": ("attn", "mixer", "wk"),
    "attn_v": ("attn", "mixer", "wv"), "attn_o": ("attn", "mixer", "wo"),
    "xattn_q": (None, "xattn", "wq"), "xattn_k": (None, "xattn", "wk"),
    "xattn_v": (None, "xattn", "wv"), "xattn_o": (None, "xattn", "wo"),
    "ffn_gate": (None, "ffn", "wg"), "ffn_up": (None, "ffn", "wu"),
    "ffn_down": (None, "ffn", "wd"),
    "mamba_in": ("mamba", "mixer", "w_in"),
    "mamba_out": ("mamba", "mixer", "w_out"),
    "mlstm_q": ("mlstm", "mixer", "wq"), "mlstm_v": ("mlstm", "mixer", "wv"),
    "mlstm_o": ("mlstm", "mixer", "w_out"),
    "slstm_z": ("slstm", "mixer", "w_z"),
    "slstm_o": ("slstm", "mixer", "w_out"),
}


def _fold_block_list(params, cfg, blocks, pattern, layer_ids, task):
    """Fold ΔW into one block list (leaves (nb, d_in, d_out), one entry per
    pattern position). layer_ids: (nb*P,) global TT layer ids in scan order."""
    p_len = len(pattern)
    out = []
    for p, blk in enumerate(blocks):
        mixer_kind, ffn_kind = pattern[p]
        nblk = {k: (dict(v) if isinstance(v, dict) else v)
                for k, v in blk.items()}
        if (ffn_kind == "moe" and "s_wg" in nblk.get("ffn", {})
                and any(t.startswith("ffn_") for t in cfg.matrix_types)):
            # the live path adapts the shared-expert FFN (models/moe.py
            # dense_ffn on s_wg/s_wu/s_wd); folding it isn't supported, and
            # skipping it would silently diverge from live serving.
            raise ValueError(
                "ffn_* adapters on a MoE block with shared experts cannot "
                "be folded; use the live or lora runtime")
        weights = {}
        dests = {}
        for name in cfg.matrix_types:
            req, grp, wn = _FOLD_PATHS[name]
            if req is not None and req != mixer_kind:
                continue
            if grp not in nblk or wn not in nblk[grp]:
                continue
            weights[name] = nblk[grp][wn]
            dests[name] = (grp, wn)
        if weights:
            merged = fold_into_dense(params, cfg, weights, task=task,
                                     layers=layer_ids[p::p_len])
            for name, (grp, wn) in dests.items():
                nblk[grp][wn] = merged[name]
        out.append(nblk)
    return out


def fold_transformer(params: Params, cfg: MetaTTConfig, base: dict,
                     model_cfg, *, task: int | None = None) -> dict:
    """Fold ΔW into EVERY adapted weight of a transformer base — all pattern
    positions and all super-blocks (and the encoder stack for enc-dec
    models), not just blocks[0]. Returns a new base pytree; ``model_cfg`` is
    the repro.config.base.ModelConfig the base was built from.

    For the 4+1d/4+ed variants the fold freezes ONE slice of the task/expert
    axis into the dense weights, so ``task`` must be given; mixed-task
    serving needs the live or lora runtime instead.
    """
    unfoldable = [t for t in cfg.matrix_types if t not in _FOLD_PATHS]
    if unfoldable:
        raise ValueError(
            f"matrix types {unfoldable} cannot be folded into dense weights; "
            "serve them with the live or lora adapter runtime")
    if cfg.variant in ("4+1d", "4+ed") and task is None:
        raise ValueError(
            f"variant {cfg.variant} folds a single task/expert slice — pass "
            "task=<id> (mixed-task batches need the live/lora runtime)")
    out = dict(base)
    off = model_cfg.encoder_layers if model_cfg.is_encdec else 0
    dec_ids = np.arange(model_cfg.num_layers) + off
    out["blocks"] = _fold_block_list(params, cfg, base["blocks"],
                                     model_cfg.block_pattern, dec_ids, task)
    if model_cfg.is_encdec and "enc_blocks" in base:
        # deferred: models.transformer -> peft.api -> core.merge is a cycle
        from repro.models.transformer import ENC_PATTERN
        enc_ids = np.arange(model_cfg.encoder_layers)
        out["enc_blocks"] = _fold_block_list(
            params, cfg, base["enc_blocks"], ENC_PATTERN, enc_ids, task)
    return out
