"""Inference-time core merging (paper §2.4).

"During inference, one can match the speeds of LoRA by adding a single
pre-computation step where one can merge the middle tensor cores with G1 or
G4 once the adapters are trained."

``to_lora_form`` folds the middle cores into the *left* boundary, producing a
per-(layer, matrix[, task]) pair (A, B) with A ∈ R^{L,M,D_in,r}, B ∈ R^{r,D_out}
— exactly a (shared-B) LoRA adapter, so the serving path runs two GEMMs per
adapted matrix, identical to LoRA. ``fold_into_dense`` goes one step further
and adds ΔW into the frozen weights (zero serving overhead), which is what
the serving example uses by default.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.metatt import MetaTTConfig, Params, step_factors


@dataclasses.dataclass
class LoRAForm:
    """Merged serving form: y += alpha already folded into A."""
    a: jnp.ndarray  # (L, [T,] M, d_in_max, r)
    b: jnp.ndarray  # (r, d_out_max)

    def delta(self, cfg: MetaTTConfig, x, layer: int, m: str,
              task: int | None = None):
        mi = cfg.m_index(m)
        a = (self.a[layer, task, mi] if task is not None
             else self.a[layer, mi])
        a = a[: x.shape[-1]]
        b = self.b[:, : cfg.d_out[mi]]
        return (x @ a.astype(x.dtype)) @ b.astype(x.dtype)


def to_lora_form(params: Params, cfg: MetaTTConfig) -> LoRAForm:
    f = step_factors(params, cfg)
    # fold alpha and the middle cores into the left factor:
    # A[l, m] = alpha * G1 @ C[l, m]   -> (..., d_in, r_last)
    a = cfg.alpha * jnp.einsum("dr,...rs->...ds", f.g1, f.c)
    return LoRAForm(a=a, b=f.g4)


def fold_into_dense(params: Params, cfg: MetaTTConfig,
                    weights: dict, *, task: int | None = None) -> dict:
    """Return a copy of ``weights`` with ΔW added into each adapted matrix.

    ``weights`` maps matrix-type name -> stacked (L, d_in, d_out) array (the
    scan-stacked layout used by the model zoo). Zero serving overhead after
    this fold; un-merging is exact (subtract the same delta).
    """
    f = step_factors(params, cfg)
    out = dict(weights)
    for mi, name in enumerate(cfg.matrix_types):
        if name not in weights:
            continue
        w = weights[name]
        c = f.c[:, task, mi] if task is not None else f.c[:, mi]
        delta = cfg.alpha * jnp.einsum(
            "dr,lrs,se->lde",
            f.g1[: w.shape[1]], c, f.g4[:, : w.shape[2]])
        out[name] = (w + delta.astype(w.dtype))
    return out
