"""MetaTT core: the paper's contribution as a composable JAX module."""
from repro.core.metatt import (  # noqa: F401
    MetaTTConfig,
    apply,
    delta_out,
    init_params,
    materialize_delta,
    num_params,
    paper_count_4d,
    paper_count_5d,
    paper_count_lora,
    project_in,
    step_factors,
    zero_at_init,
)
from repro.core.dmrg import (  # noqa: F401
    RankSchedule,
    SweepResult,
    dmrg_sweep,
    two_site_sweep,
)
from repro.core.merge import LoRAForm, fold_into_dense, to_lora_form  # noqa: F401
