"""MetaTT adapters (paper §2.2–§2.4).

One *global* tensor train parameterizes the low-rank update of every adapted
linear map in the network:

  MetaTT-4D    ΔW[D_in, L, M, D_out]              (paper Eq. (2), (5))
  MetaTT-5D    ΔW[D_in, L, M, H, D_out/H]         (paper Eq. (3))
  MetaTT-(4+1)D ΔW[D_in, L, T, M, D_out]          (paper Eq. (4)/(6), task axis)
  MetaTT-(4+E)D ΔW[D_in, L, E, M, D_out]          (expert axis — the paper's
                "expert partitions" extension, §4; used for MoE archs)

Parameters are stored as the *canonical* TT core list (see core/tt.py), which
makes the DMRG sweep (core/dmrg.py) operate on MetaTT params directly.

Heterogeneous shapes (GQA kv-dim, GeGLU d_ff, mamba projections) are handled
by **boundary-core slicing** (DESIGN.md §4): the boundary cores are sized to
``max`` input/output dims and matrix type ``m`` reads ``G1[:d_in(m)]`` /
``G4[:, :d_out(m)]``.  When all adapted matrices are d×d this reduces exactly
to the paper's construction.

The hot-path contraction is factored for TPU (DESIGN.md §3):

  per step :  C[l, m] = G2[l] · G3[m]           (tiny r×r merges, once/step)
  per layer:  P       = x · G1                  (shared across m with same d_in)
  per matrix: Δy      = α · (P · C[l, m]) · G4  (one r×r + one r×D matmul)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tt

Params = dict  # {"cores": [c0, c1, ...]} — a pytree of jnp arrays


@dataclasses.dataclass(frozen=True)
class MetaTTConfig:
    """Static configuration of a MetaTT adapter.

    variant: "4d" | "5d" | "4+1d" | "4+ed"
    matrix_types: names of adapted matrix types — the M axis (paper default
        ("q", "v"), App. A.2).
    d_in / d_out: per-matrix-type input/output dims, parallel to matrix_types.
    rank: uniform bond rank (paper trains uniform ranks; DMRG may later make
        them non-uniform — runtime shapes come from the params, not from here).
    num_heads/head_dim: 5d only — H is the *query* head count; matrix types
        with fewer kv heads use the leading slices (head-major layout).
    num_tasks / num_experts: size of the extra axis for 4+1d / 4+ed.
    """
    num_layers: int
    matrix_types: tuple
    d_in: tuple
    d_out: tuple
    rank: int
    variant: str = "4d"
    alpha: float = 1.0
    num_heads: int = 0
    head_dim: int = 0
    num_tasks: int = 0
    num_experts: int = 0
    init: str = ""          # "" -> default per-variant scheme
    dtype: Any = jnp.float32

    # ---- derived ------------------------------------------------------
    @property
    def num_matrices(self) -> int:
        return len(self.matrix_types)

    @property
    def d_in_max(self) -> int:
        return max(self.d_in)

    @property
    def d_out_max(self) -> int:
        if self.variant == "5d":
            return self.num_heads * self.head_dim
        return max(self.d_out)

    @property
    def mode_sizes(self) -> tuple:
        L, M = self.num_layers, self.num_matrices
        if self.variant == "4d":
            return (self.d_in_max, L, M, self.d_out_max)
        if self.variant == "5d":
            return (self.d_in_max, L, M, self.num_heads, self.head_dim)
        if self.variant == "4+1d":
            return (self.d_in_max, L, self.num_tasks, M, self.d_out_max)
        if self.variant == "4+ed":
            return (self.d_in_max, L, self.num_experts, M, self.d_out_max)
        raise ValueError(f"unknown variant {self.variant}")

    @property
    def default_init(self) -> str:
        n = len(self.mode_sizes)
        return "-".join(["ze"] + ["id"] * (n - 1))

    @property
    def init_scheme(self) -> str:
        return self.init or self.default_init

    def m_index(self, name: str) -> int:
        return self.matrix_types.index(name)

    def num_params(self) -> int:
        shapes = self.mode_sizes
        d = len(shapes)
        bonds = [1] + [self.rank] * (d - 1) + [1]
        return int(sum(bonds[k] * shapes[k] * bonds[k + 1] for k in range(d)))


# --------------------------------------------------------------------------
# paper's closed-form parameter counts (§2.4) — used by tests to pin our
# implementation to the paper's Table 1 numbers.
# --------------------------------------------------------------------------

def paper_count_4d(D: int, L: int, M: int, r: int) -> int:
    """MetaTT-4D: 2Dr + (L+M)r^2   (paper §2.4)."""
    return 2 * D * r + (L + M) * r * r


def paper_count_5d(D: int, H: int, L: int, M: int, r: int) -> int:
    """MetaTT-5D: (D + D/H)r + (L+M+H)r^2   (paper §2.4)."""
    return (D + D // H) * r + (L + M + H) * r * r


def paper_count_lora(D: int, L: int, M: int, r: int) -> int:
    """LoRA: 2LMDr   (paper §2.4)."""
    return 2 * L * M * D * r


# --------------------------------------------------------------------------
# init (paper App. A.1): scheme string like "ze-id-id-id", one token per core:
#   ze -> zeros, id -> rectangular identity per slice, no -> Normal(0, 0.2).
# Any scheme with >=1 "ze" core guarantees ΔW == 0 at init (paper requirement).
# --------------------------------------------------------------------------

def _init_core(key, tok: str, shape, dtype):
    r_prev, n, r_next = shape
    if tok == "ze":
        return jnp.zeros(shape, dtype)
    if tok == "id":
        if r_prev == 1:                      # left boundary: (n, r) rect-eye
            return jnp.eye(n, r_next, dtype=dtype)[None]
        if r_next == 1:                      # right boundary: (r, n) rect-eye
            return jnp.eye(r_prev, n, dtype=dtype)[:, :, None]
        eye = jnp.eye(r_prev, r_next, dtype=dtype)
        return jnp.broadcast_to(eye[:, None, :], shape).astype(dtype)
    if tok == "no":
        return 0.2 * jax.random.normal(key, shape, dtype)
    raise ValueError(f"unknown init token {tok!r}")


def init_params(cfg: MetaTTConfig, key) -> Params:
    shapes = cfg.mode_sizes
    d = len(shapes)
    toks = cfg.init_scheme.split("-")
    if len(toks) != d:
        raise ValueError(
            f"init scheme {cfg.init_scheme!r} has {len(toks)} tokens for a "
            f"{d}-core TT")
    if "ze" not in toks:
        raise ValueError(
            "at least one core must be zero-initialized so that ΔW == 0 at "
            "the start of fine-tuning (paper App. A.1)")
    bonds = [1] + [cfg.rank] * (d - 1) + [1]
    keys = jax.random.split(key, d)
    cores = [
        _init_core(keys[k], toks[k], (bonds[k], shapes[k], bonds[k + 1]),
                   cfg.dtype)
        for k in range(d)
    ]
    return {"cores": cores}


def num_params(params: Params) -> int:
    return tt.num_params(params["cores"])


# --------------------------------------------------------------------------
# hot-path contraction
# --------------------------------------------------------------------------

@dataclasses.dataclass
class StepFactors:
    """Activation-independent merged factors, computed once per step.

    g1:  (d_in_max, r_first)      — left boundary core
    c:   (L, [T|E,] M, r_first, r_last)  — merged middle cores
    g4:  (r_last, d_out_max)      — merged right side (5d: head core folded in)
    """
    g1: jnp.ndarray
    c: jnp.ndarray
    g4: jnp.ndarray


def step_factors(params: Params, cfg: MetaTTConfig) -> StepFactors:
    """Merge the middle cores once per training step (DESIGN.md §3).

    Mathematically identical to the paper's sequential contraction (Eq. (5));
    it just exploits that G2[l]·G3[m] does not depend on the activations, so
    merging it once per step removes two rank-r GEMMs per adapted matrix call.
    """
    cores = params["cores"]
    g1 = cores[0][0]                       # (Din, r1)
    if cfg.variant == "4d":
        c = jnp.einsum("alb,bmc->lmac", cores[1], cores[2])
        g4 = cores[3][..., 0]              # (r3, Dout)
    elif cfg.variant == "5d":
        c = jnp.einsum("alb,bmc->lmac", cores[1], cores[2])
        # fold head core into the right boundary: (r3, H, hd) -> (r3, H*hd)
        bh = jnp.einsum("chr,rd->chd", cores[3], cores[4][..., 0])
        g4 = bh.reshape(bh.shape[0], -1)
    elif cfg.variant in ("4+1d", "4+ed"):
        # order (D, L, T|E, M, D): C[l, t, m] = G2[l] G3[t] G4[m]
        c = jnp.einsum("alb,btc,cmd->ltmad", cores[1], cores[2], cores[3])
        g4 = cores[4][..., 0]
    else:
        raise ValueError(cfg.variant)
    return StepFactors(g1=g1, c=c, g4=g4)


def project_in(f: StepFactors, cfg: MetaTTConfig, x: jnp.ndarray,
               m: str) -> jnp.ndarray:
    """P = x · G1[:d_in(m)] — shared across matrix types with equal d_in."""
    d_in = cfg.d_in[cfg.m_index(m)]
    g1 = f.g1 if d_in == f.g1.shape[0] else f.g1[:d_in]
    return x @ g1.astype(x.dtype)


def delta_out(f: StepFactors, cfg: MetaTTConfig, p: jnp.ndarray,
              c_l: jnp.ndarray, m: str, *,
              task: jnp.ndarray | int | None = None) -> jnp.ndarray:
    """α · (P · C[l, t(b), m]) · G4[:, :d_out(m)].

    c_l: this layer's slice of ``StepFactors.c`` — shape (M, r, r) for
    4d/5d, (T|E, M, r, r) for the 5-core variants (supplied by the scan).
    task: task/expert index for 4+1d/4+ed. Either a scalar (whole batch on
    one task) or a (B,) vector of per-request task ids — the vector form
    gathers a per-row C[l, t_b, m] slice from the shared TT so one batch
    mixes tasks (the serving engine's multi-task routing, paper Eq. (4)/(6)).
    """
    mi = cfg.m_index(m)
    batched = False
    if cfg.variant == "4+1d":
        if task is None:
            raise ValueError("variant 4+1d needs a task index")
        batched = jnp.ndim(task) >= 1
        c_lm = c_l[task, mi]          # scalar: (r, r); (B,): (B, r, r)
    elif cfg.variant == "4+ed":
        # non-expert matrix types read the shared slice 0 of the expert axis;
        # expert-indexed application happens inside the MoE sorted path
        # (models/moe.py::_expert_delta).
        batched = task is not None and jnp.ndim(task) >= 1
        c_lm = c_l[0 if task is None else task, mi]
    else:
        c_lm = c_l[mi]
    d_out = cfg.d_out[mi]
    g4 = f.g4 if d_out == f.g4.shape[1] else f.g4[:, :d_out]
    c_lm = c_lm.astype(p.dtype)
    if batched:
        # per-request routing: row b of p (B, ..., r) hits its own C slice.
        # (einsum rather than @ so a 2-D p cannot silently outer-broadcast.)
        q = jnp.einsum("b...r,brs->b...s", p, c_lm)
    else:
        q = p @ c_lm
    return cfg.alpha * (q @ g4.astype(p.dtype))


def take_task_slice(c: jnp.ndarray, task) -> jnp.ndarray:
    """One task's column of the merged live factor ``StepFactors.c``.

    The task mode is AXIS 1 of the (L, T, M, r, r) factor — the paper's
    Eq. (4)/(6) marginal cost made literal: everything a single task adds
    to the shared TT is this (L, M, r, r) slice. The serving adapter
    registry (serving/adapter_registry.py) pages exactly these columns
    between host and a fixed device slot pool.
    """
    return c[:, task]


def put_task_slice(pool: jnp.ndarray, slot, col: jnp.ndarray) -> jnp.ndarray:
    """Scatter one task column into slot ``slot`` of a pooled factor —
    inverse of ``take_task_slice``; ``pool`` is (L, K, M, r, r) with K the
    pool width. Functional (`.at[...]`), so it jits and donates cleanly.
    """
    return pool.at[:, slot].set(col.astype(pool.dtype))


def apply(params: Params, cfg: MetaTTConfig, x: jnp.ndarray, layer: int,
          m: str, *, task: int | None = None) -> jnp.ndarray:
    """Reference single-call path: α · x·G1·G2[l](·G3[t])·G3[m]·G4 (Eq. (5)).

    Used by tests and by the non-scan (eager) model path. The scan path uses
    step_factors + project_in/delta_out with C pre-sliced by the scan.
    """
    f = step_factors(params, cfg)
    p = project_in(f, cfg, x, m)
    return delta_out(f, cfg, p, f.c[layer], m, task=task)


def materialize_delta(params: Params, cfg: MetaTTConfig, layer: int, m: str,
                      *, task: int | None = None) -> jnp.ndarray:
    """Dense ΔW_{l,m} (d_in(m), d_out(m)) — tests/small dims only."""
    mi = cfg.m_index(m)
    f = step_factors(params, cfg)
    c_l = f.c[layer]
    c_lm = c_l[task, mi] if cfg.variant in ("4+1d", "4+ed") else c_l[mi]
    g1 = f.g1[: cfg.d_in[mi]]
    g4 = f.g4[:, : cfg.d_out[mi]]
    return cfg.alpha * (g1 @ c_lm @ g4)


def zero_at_init(params: Params, cfg: MetaTTConfig) -> bool:
    """Check the paper's init invariant: every ΔW slice is exactly zero."""
    f = step_factors(params, cfg)
    return bool(jnp.all(f.g1 == 0) or jnp.all(f.g4 == 0)
                or jnp.all(f.c == 0))
