"""Tensor-train (TT) algebra used by the MetaTT adapter.

A TT of order ``d`` represents a tensor ``G[i1,...,id]`` as a product of
per-mode cores ``C_k`` of shape ``(r_{k-1}, n_k, r_k)`` with ``r_0 = r_d = 1``
(Oseledets 2011; paper Eq. (1)).  This module implements the *generic* TT
operations — contraction, materialization, neighbour-core merging, truncated
SVD re-splitting and canonicalization — on a plain list of jnp arrays, so the
MetaTT variants (core/metatt.py) and the DMRG sweep (core/dmrg.py) share one
set of well-tested primitives.

All functions are pure and jit-compatible unless they change array *shapes*
(truncation), which is inherently a host-side / trace-time operation.
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp
import numpy as np

Cores = list  # list[jnp.ndarray], each of shape (r_{k-1}, n_k, r_k)


def validate_cores(cores: Sequence[jnp.ndarray]) -> None:
    """Raise ValueError unless ``cores`` is a well-formed TT."""
    if not cores:
        raise ValueError("empty TT")
    if cores[0].shape[0] != 1 or cores[-1].shape[-1] != 1:
        raise ValueError(
            f"boundary ranks must be 1, got {cores[0].shape[0]} and "
            f"{cores[-1].shape[-1]}")
    for k in range(len(cores) - 1):
        if cores[k].ndim != 3 or cores[k + 1].ndim != 3:
            raise ValueError("TT cores must be rank-3 (r_prev, n, r_next)")
        if cores[k].shape[-1] != cores[k + 1].shape[0]:
            raise ValueError(
                f"bond mismatch between core {k} and {k+1}: "
                f"{cores[k].shape} vs {cores[k+1].shape}")


def ranks(cores: Sequence[jnp.ndarray]) -> tuple:
    """Internal bond dimensions (r_1, ..., r_{d-1})."""
    return tuple(int(c.shape[-1]) for c in cores[:-1])


def mode_sizes(cores: Sequence[jnp.ndarray]) -> tuple:
    return tuple(int(c.shape[1]) for c in cores)


def num_params(cores: Sequence[jnp.ndarray]) -> int:
    return int(sum(np.prod(c.shape) for c in cores))


def materialize(cores: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """Contract a TT back into the full dense tensor (tests / tiny dims only).

    Returns an array of shape ``(n_1, ..., n_d)``.
    """
    validate_cores(cores)
    out = cores[0]  # (1, n1, r1)
    for core in cores[1:]:
        # (..., r) x (r, n, r') -> (..., n, r')
        out = jnp.tensordot(out, core, axes=[[-1], [0]])
    # squeeze the two boundary ranks of size 1
    return out.reshape(out.shape[1:-1])


def slice_matrix(cores: Sequence[jnp.ndarray], idx: Sequence[int]) -> jnp.ndarray:
    """Dense matrix ``G[:, idx..., :]`` for a TT whose first/last modes are the
    matrix dimensions and whose middle modes are indexed by ``idx``.

    E.g. for MetaTT-4D cores (D_in, L, M, D_out) and idx=(l, m), returns the
    ``ΔW_{l,m}`` dense matrix of shape (D_in, D_out).
    """
    if len(idx) != len(cores) - 2:
        raise ValueError(f"need {len(cores)-2} middle indices, got {len(idx)}")
    left = cores[0][0]  # (n1, r1)
    mid = None
    for core, i in zip(cores[1:-1], idx):
        m = core[:, i, :]  # (r_prev, r_next)
        mid = m if mid is None else mid @ m
    right = cores[-1][..., 0]  # (r_last, n_d)
    if mid is None:
        return left @ right
    return left @ mid @ right


def merge_pair(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """MERGE of Algorithm 1: contract neighbouring cores into one 4-tensor
    ``(r_prev, n_a, n_b, r_next)``."""
    return jnp.einsum("iar,rbj->iabj", a, b)


def split_merged(merged: jnp.ndarray, rank: int | None = None,
                 *, left_orthogonal: bool = True,
                 rtol: float | None = None,
                 max_rank: int | None = None):
    """tSVD + re-split of Algorithm 1 (one step of a DMRG sweep).

    merged: (r_prev, n_a, n_b, r_next).
    rank: hard target bond rank; if None, rank is chosen adaptively from
        singular values with relative tolerance ``rtol`` (capped by max_rank).
    left_orthogonal: if True the left factor is the isometry (U) — used in the
        left-to-right sweep; otherwise the right factor absorbs nothing and
        the left absorbs S (right-to-left sweep, line 9 of Algorithm 1).

    Returns (core_a, core_b, sigma) with core_a (r_prev, n_a, r),
    core_b (r, n_b, r_next) and the retained singular values sigma.
    """
    r_prev, n_a, n_b, r_next = merged.shape
    mat = merged.reshape(r_prev * n_a, n_b * r_next)
    u, s, vt = jnp.linalg.svd(mat, full_matrices=False)
    if rank is None:
        if rtol is None:
            raise ValueError("need rank or rtol")
        keep = int(np.asarray(jnp.sum(s > rtol * s[0])))
        keep = max(keep, 1)
        if max_rank is not None:
            keep = min(keep, max_rank)
    else:
        keep = min(rank, s.shape[0])
    u, s, vt = u[:, :keep], s[:keep], vt[:keep, :]
    if left_orthogonal:
        a = u
        b = (s[:, None] * vt)
    else:
        a = u * s[None, :]
        b = vt
    return (a.reshape(r_prev, n_a, keep),
            b.reshape(keep, n_b, r_next),
            s)


def truncation_error(merged: jnp.ndarray, rank: int) -> jnp.ndarray:
    """Frobenius-norm error of the rank-``rank`` tSVD of a merged pair.

    By Eckart–Young this equals sqrt(sum of squared dropped singular values);
    used by property tests.
    """
    r_prev, n_a, n_b, r_next = merged.shape
    s = jnp.linalg.svd(merged.reshape(r_prev * n_a, n_b * r_next),
                       compute_uv=False)
    return jnp.sqrt(jnp.sum(s[rank:] ** 2))


def left_canonicalize(cores: Cores) -> Cores:
    """QR-sweep left→right so every core but the last is a left isometry.

    Keeps ranks; puts the TT in the canonical form DMRG expects before a
    right-to-left truncation sweep (numerically optimal local truncations).
    """
    out = [c for c in cores]
    for k in range(len(out) - 1):
        r_prev, n, r_next = out[k].shape
        q, r = jnp.linalg.qr(out[k].reshape(r_prev * n, r_next))
        keep = q.shape[1]
        out[k] = q.reshape(r_prev, n, keep)
        out[k + 1] = jnp.tensordot(r, out[k + 1], axes=[[1], [0]])
    return out


def tt_norm(cores: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """Frobenius norm of the full tensor, computed in TT form (no materialize)."""
    # transfer-matrix contraction: E = sum_n core[:,n,:]^T ⊗ core[:,n,:]
    env = None
    for c in cores:
        if env is None:
            env = jnp.einsum("inr,ins->rs", c, c)
        else:
            env = jnp.einsum("ij,inr,jns->rs", env, c, c)
    return jnp.sqrt(jnp.abs(env[0, 0]))


def random_tt(key, shape: Sequence[int], rank: int | Sequence[int],
              scale: float = 0.2) -> Cores:
    """Random-normal TT with given mode sizes and (uniform or per-bond) ranks."""
    import jax

    d = len(shape)
    if isinstance(rank, int):
        bonds = [1] + [rank] * (d - 1) + [1]
    else:
        bonds = [1] + list(rank) + [1]
        if len(bonds) != d + 1:
            raise ValueError("rank list must have d-1 entries")
    keys = jax.random.split(key, d)
    cores = []
    for k in range(d):
        cores.append(scale * jax.random.normal(
            keys[k], (bonds[k], shape[k], bonds[k + 1]), dtype=jnp.float32))
    return cores
