"""DMRG-inspired rank-adaptive sweep (paper §3.3, Algorithm 1).

Starting from a (sufficiently high-rank) TT, a sweep merges neighbouring
cores, truncates with an SVD to a target rank, and re-splits:

  left→right:  G_i ← U,   G_{i+1} ← S·Vᵀ     (i = 1 .. d-1)
  right→left:  G_{i-1} ← U·S,   G_i ← Vᵀ     (i = d .. 2)

After a sweep the bond ranks (and hence parameter shapes) change. The paper
(§3.3) re-initializes the Adam moments; beyond that we can also *transport*
them through the sweep (``moments=``): every two-site step replaces the pair
(a, b) with (a', b') related by per-side transfer matrices (old ≈ new ·
transfer, computed by pseudo-inverse projection), so the gradient EMAs map
through the chain rule (mu' = mu · tᵀ on the left bond side, sᵀ · mu on the
right) and the second moments through the elementwise-SQUARED coefficients
(exact if per-coordinate gradients were independent; always preserves
non-negativity). This keeps warm optimizer statistics across a mid-training
rank change instead of restarting Adam cold — see
optim/adamw.py::carry_state and train/trainer.py.

Beyond the paper's fixed-target sweep we also provide:
  * adaptive truncation by relative singular-value tolerance (`rtol`),
  * a left-canonicalization pre-pass so the right-to-left truncations are
    locally optimal (standard DMRG practice; the paper's Algorithm 1 is the
    plain two-pass variant, which we keep as the default for faithfulness),
  * per-bond rank schedules (paper Fig. 2 uses 10 → … → 4).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp

from repro.core import tt
from repro.core.metatt import MetaTTConfig, Params


@dataclasses.dataclass(frozen=True)
class SweepResult:
    params: Params
    ranks: tuple
    # singular-value spectra per bond from the final (right-to-left) pass —
    # the diagnostic the paper uses to pick rank schedules (App. C).
    spectra: tuple
    # transported optimizer moments, mirroring the ``moments=`` input
    # pytrees with the post-sweep core shapes (None when not requested)
    moments: tuple | None = None


def _transport_pair(mom_cores, i, old_a, old_b, new_a, new_b) -> None:
    """Transport moment cores at bond ``i`` through one two-site update.

    ``mom_cores`` is ``(mu_list, nu_list)`` of per-core moment arrays,
    mutated in place. The transfer matrices project old factors onto the
    new ones (old ≈ new · transfer, via pseudo-inverse); first moments map
    linearly through them, second moments through the squared coefficients
    so they stay non-negative.
    """
    ra, rn = old_a.shape[-1], new_a.shape[-1]
    mat_oa = old_a.reshape(-1, ra).astype(jnp.float32)
    mat_na = new_a.reshape(-1, rn).astype(jnp.float32)
    t = jnp.linalg.pinv(mat_na) @ mat_oa                      # (r_new, r_old)
    mat_ob = old_b.reshape(ra, -1).astype(jnp.float32)
    mat_nb = new_b.reshape(rn, -1).astype(jnp.float32)
    s = mat_ob @ jnp.linalg.pinv(mat_nb)                      # (r_old, r_new)
    mu, nu = mom_cores
    for lst, ca, cb in ((mu, t.T, s.T), (nu, t.T ** 2, s.T ** 2)):
        lst[i] = (lst[i].reshape(-1, ra).astype(jnp.float32) @ ca
                  ).reshape(new_a.shape)
        lst[i + 1] = (cb @ lst[i + 1].reshape(ra, -1).astype(jnp.float32)
                      ).reshape(new_b.shape)


def dmrg_sweep(params: Params, target_rank: int | Sequence[int] | None = None,
               *, rtol: float | None = None, max_rank: int | None = None,
               canonicalize: bool = False,
               moments: tuple | None = None) -> SweepResult:
    """One full DMRG sweep (Algorithm 1). Host-side: changes array shapes.

    target_rank: hard per-bond target (int -> uniform). If None, ranks are
        chosen adaptively from singular values via ``rtol`` (and capped at
        ``max_rank``).
    canonicalize: QR left-canonicalize first (beyond-paper numerical nicety).
    moments: optional ``(mu, nu)`` params-like pytrees (AdamW first/second
        moments); their cores are transported through every two-site step
        (see module docstring) and come back on ``SweepResult.moments``
        with the post-sweep shapes.
    """
    cores = list(params["cores"])
    d = len(cores)
    nbonds = d - 1
    if target_rank is None and rtol is None:
        raise ValueError("need target_rank or rtol")
    if isinstance(target_rank, int):
        targets = [target_rank] * nbonds
    elif target_rank is not None:
        targets = list(target_rank)
        if len(targets) != nbonds:
            raise ValueError(f"need {nbonds} per-bond targets")
    else:
        targets = [None] * nbonds

    mom_cores = None
    if moments is not None:
        mom_cores = tuple(list(m["cores"]) for m in moments)

    if canonicalize:
        if mom_cores is None:
            cores = tt.left_canonicalize(cores)
        else:
            # inline QR pass so each gauge move transports the moments too
            for i in range(d - 1):
                r_prev, n, r_next = cores[i].shape
                q, r = jnp.linalg.qr(cores[i].reshape(r_prev * n, r_next))
                new_a = q.reshape(r_prev, n, q.shape[1])
                new_b = jnp.tensordot(r, cores[i + 1], axes=[[1], [0]])
                _transport_pair(mom_cores, i, cores[i], cores[i + 1],
                                new_a, new_b)
                cores[i], cores[i + 1] = new_a, new_b

    # left -> right (lines 1-5): G_i <- U (isometry), G_{i+1} <- S Vt
    for i in range(d - 1):
        merged = tt.merge_pair(cores[i], cores[i + 1])
        a, b, _ = tt.split_merged(merged, targets[i], left_orthogonal=True,
                                  rtol=rtol, max_rank=max_rank)
        if mom_cores is not None:
            _transport_pair(mom_cores, i, cores[i], cores[i + 1], a, b)
        cores[i], cores[i + 1] = a, b

    # right -> left (lines 6-10): G_{i-1} <- U S, G_i <- Vt
    spectra = [None] * nbonds
    for i in range(d - 1, 0, -1):
        merged = tt.merge_pair(cores[i - 1], cores[i])
        a, b, s = tt.split_merged(merged, targets[i - 1],
                                  left_orthogonal=False,
                                  rtol=rtol, max_rank=max_rank)
        if mom_cores is not None:
            _transport_pair(mom_cores, i - 1, cores[i - 1], cores[i], a, b)
        cores[i - 1], cores[i] = a, b
        spectra[i - 1] = s

    out = dict(params)
    out["cores"] = cores
    out_moments = None
    if moments is not None:
        out_moments = tuple(
            {**dict(m), "cores": list(mc)}
            for m, mc in zip(moments, mom_cores))
    return SweepResult(params=out, ranks=tt.ranks(cores),
                       spectra=tuple(spectra), moments=out_moments)


@dataclasses.dataclass(frozen=True)
class RankSchedule:
    """Epoch -> target-rank schedule for interspersed DMRG sweeps.

    The paper (Fig. 2 / App. C) reduces ranks *slowly* from a high starting
    rank (10) down to the final target (4), sweeping right after chosen
    epochs; between sweeps AdamW trains at fixed shapes.
    """
    milestones: tuple  # ((epoch, rank), ...) sorted by epoch

    @staticmethod
    def linear(start_rank: int, end_rank: int, start_epoch: int,
               every: int = 1, step: int = 1) -> "RankSchedule":
        ms, r, e = [], start_rank, start_epoch
        while r > end_rank:
            r = max(end_rank, r - step)
            ms.append((e, r))
            e += every
        return RankSchedule(tuple(ms))

    def rank_after_epoch(self, epoch: int) -> int | None:
        """Target rank if a sweep is scheduled right after ``epoch``."""
        for e, r in self.milestones:
            if e == epoch:
                return r
        return None

    @property
    def final_rank(self) -> int:
        return self.milestones[-1][1]


def two_site_sweep(params: Params, loss_fn, target_rank: int, *,
                   inner_steps: int = 3, lr: float = 1e-2) -> SweepResult:
    """Two-site DMRG with *local loss optimization* — the paper's App. C
    second proposed extension ("use powerful local optimizers to minimize
    directly the loss function with respect to each merged tensor at each
    step of the DMRG-inspired sweep").

    At each bond: merge the neighbouring cores, take ``inner_steps`` plain
    gradient steps on the MERGED tensor against ``loss_fn(params)`` (all
    other cores frozen — the true DMRG local problem), then tSVD-split back
    to ``target_rank``. This both adapts ranks AND descends the training
    loss inside the sweep, instead of only projecting (Algorithm 1).

    loss_fn: params-dict -> scalar. Host-side (shapes change).
    """
    import jax

    cores = list(params["cores"])
    d = len(cores)

    def local_loss(merged, i, rest):
        # exact (lossless) resplit: the merged matricization is
        # (r_prev·n_a) × (n_b·r_next), so its rank is at most the SMALLER
        # of the two dims (split_merged clamps to the singular-value count
        # anyway, but asking for the true bound keeps the factor shapes
        # minimal instead of allocating r_prev·n_a columns at right bonds).
        exact = min(merged.shape[0] * merged.shape[1],
                    merged.shape[2] * merged.shape[3])
        a, b, _ = tt.split_merged(merged, rank=exact)
        cs = list(rest)
        cs[i], cs[i + 1] = a, b
        return loss_fn({"cores": cs})

    spectra = [None] * (d - 1)
    for direction in (range(d - 1), range(d - 2, -1, -1)):
        for i in direction:
            merged = tt.merge_pair(cores[i], cores[i + 1])
            # exactly inner_steps gradients: grad-then-step (the old
            # step-then-regrad form computed one unused gradient per bond)
            for _ in range(inner_steps):
                g = jax.grad(local_loss)(merged, i, cores)
                merged = merged - lr * g
            left = isinstance(direction, range) and direction.step != -1
            a, b, s = tt.split_merged(merged, target_rank,
                                      left_orthogonal=left)
            cores[i], cores[i + 1] = a, b
            spectra[i] = s
    out = dict(params)
    out["cores"] = cores
    return SweepResult(params=out, ranks=tt.ranks(cores),
                       spectra=tuple(spectra))


def reconstruction_error(params: Params, swept: Params) -> float:
    """Relative Frobenius error ||G - G̃|| / ||G|| between two TTs of the
    same mode sizes, computed fully in TT form (no materialization).

    Host-side float64: the ‖a‖² − 2⟨a,b⟩ + ‖b‖² form cancels catastrophically
    in fp32 when the TTs are close (which is exactly when we care).
    """
    import numpy as np

    a = [np.asarray(c, dtype=np.float64) for c in params["cores"]]
    b = [np.asarray(c, dtype=np.float64) for c in swept["cores"]]

    def inner(x, y):
        env = None
        for cx, cy in zip(x, y):
            if env is None:
                env = np.einsum("inr,ins->rs", cx, cy)
            else:
                env = np.einsum("ij,inr,jns->rs", env, cx, cy)
        return env[0, 0]

    aa, ab, bb = inner(a, a), inner(a, b), inner(b, b)
    num = np.sqrt(max(aa - 2 * ab + bb, 0.0))
    den = np.sqrt(max(aa, 1e-300))
    return float(num / den)
