"""Mamba (selective SSM) mixer — jamba's dominant block type.

TPU adaptation of the CUDA selective-scan kernel (DESIGN.md §3): the
recurrence h_t = ā_t ⊙ h_{t-1} + b̄_t is a first-order linear recurrence, so
train/prefill uses a **chunked associative scan**: ``lax.scan`` over time
chunks carrying h, with ``lax.associative_scan`` inside each (checkpointed)
chunk. Live memory is O(B × chunk × d_inner × d_state) instead of O(T × …),
and the backward pass recomputes per chunk — the same blocking idea as the
original kernel, re-expressed for XLA/TPU. Decode is the O(1) recurrent step.

Adapter hook: in/out projections are matrix types "mamba_in"/"mamba_out"
(heterogeneous dims — MetaTT's boundary-core slicing handles them).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models.layers import AdapterCtx, adapted_linear
from repro.sharding import BATCH, SEQ, maybe_shard


def _ssm_coeffs(x, w, cfg: ModelConfig):
    """x: (B, T, di) post-conv/silu -> (da, db) of the recurrence plus C, D.

    da = exp(dt ⊙ A): (B,T,di,ds);  db = dt ⊙ B ⊙ x: (B,T,di,ds).
    """
    dt_rank, ds = cfg.resolved_dt_rank, cfg.mamba_d_state
    xdbc = x @ w["w_x"].astype(x.dtype)                  # (B,T,dtr+2ds)
    dt, b, c = jnp.split(xdbc, [dt_rank, dt_rank + ds], axis=-1)
    dt = jax.nn.softplus(dt @ w["w_dt"].astype(x.dtype)
                         + w["dt_bias"].astype(x.dtype))  # (B,T,di)
    a = -jnp.exp(w["a_log"].astype(jnp.float32))          # (di, ds)
    da = jnp.exp(dt.astype(jnp.float32)[..., None] * a)   # (B,T,di,ds)
    db = (dt[..., None] * b[:, :, None, :] * x[..., None]).astype(jnp.float32)
    return da, db, c, w["d"].astype(jnp.float32)


def _assoc_combine(l, r):
    al, bl = l
    ar, br = r
    return al * ar, ar * bl + br


def _chunk_scan(da, db, h0, chunk: int):
    """Chunked linear recurrence: returns (h_all (B,T,di,ds), h_last)."""
    b, t, di, ds = da.shape
    n = t // chunk
    da_c = da.reshape(b, n, chunk, di, ds).transpose(1, 0, 2, 3, 4)
    db_c = db.reshape(b, n, chunk, di, ds).transpose(1, 0, 2, 3, 4)

    @jax.checkpoint
    def body(h, xs):
        da_i, db_i = xs                                  # (B, chunk, di, ds)
        # fold carry into the first step's additive term
        db_i = db_i.at[:, 0].add(da_i[:, 0] * h)
        aa, hh = jax.lax.associative_scan(_assoc_combine, (da_i, db_i), axis=1)
        return hh[:, -1], hh

    h_last, hs = jax.lax.scan(body, h0, (da_c, db_c))
    return hs.transpose(1, 0, 2, 3, 4).reshape(b, t, di, ds), h_last


def _causal_conv(x, w_conv, bias):
    """Depthwise causal conv1d. x: (B,T,di), w_conv: (K, di)."""
    k = w_conv.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):                                   # K is 4 — unrolled
        out = out + pad[:, i:i + x.shape[1]] * w_conv[i].astype(x.dtype)
    return out + bias.astype(x.dtype)


def mamba_mixer(x: jnp.ndarray, w: dict, ctx: AdapterCtx, cfg: ModelConfig, *,
                cache: Optional[dict] = None,
                chunk: int = 256):
    """x: (B, T, d_model) -> (y, new_cache).

    cache (decode): {"h": (B, di, ds), "conv": (B, K-1, di)}.
    """
    b, t, _ = x.shape
    di = cfg.mamba_d_inner
    xz = adapted_linear(x, w["w_in"], ctx, "mamba_in")   # (B,T,2*di)
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = maybe_shard(xi, BATCH, None, "model")

    if cache is None:
        xi = jax.nn.silu(_causal_conv(xi, w["conv_w"], w["conv_b"]))
        da, db, c, d_skip = _ssm_coeffs(xi, w, cfg)
        h0 = jnp.zeros((b, di, cfg.mamba_d_state), jnp.float32)
        if t % chunk == 0 and t > chunk:
            hs, h_last = _chunk_scan(da, db, h0, chunk)
        else:
            _, hs = jax.lax.associative_scan(_assoc_combine, (da, db), axis=1)
            h_last = hs[:, -1]
        y = jnp.einsum("btds,bts->btd", hs, c.astype(jnp.float32))
        # returned so a prefill can seed subsequent decode steps
        new_cache = {"h": h_last,
                     "conv": xi[:, -(w["conv_w"].shape[0] - 1):]}
    else:
        # ---- decode: O(1) state update
        conv_win = jnp.concatenate([cache["conv"], xi], axis=1)  # (B,K,di)
        k = w["conv_w"].shape[0]
        xi = jnp.einsum("bkd,kd->bd", conv_win,
                        w["conv_w"].astype(xi.dtype))[:, None] \
            + w["conv_b"].astype(xi.dtype)
        xi = jax.nn.silu(xi)
        da, db, c, d_skip = _ssm_coeffs(xi, w, cfg)
        h = da[:, 0] * cache["h"] + db[:, 0]             # (B, di, ds)
        y = jnp.einsum("bds,bts->btd", h, c.astype(jnp.float32))
        new_cache = {"h": h, "conv": conv_win[:, 1:]}

    y = y + d_skip * xi.astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = adapted_linear(y, w["w_out"], ctx, "mamba_out")
    return maybe_shard(out, BATCH, SEQ, None), new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    di = cfg.mamba_d_inner
    return {
        "h": jnp.zeros((batch, di, cfg.mamba_d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.mamba_conv - 1, di), dtype),
    }
