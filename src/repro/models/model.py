"""Model-level API: adapter-spec construction, init, loss.

``build_adapter_spec`` is where the paper meets the model zoo: it enumerates
the adapted matrix types (the TT's M axis) with their per-type dims, choosing
arch-appropriate defaults (paper default q/v for attention archs; mamba /
xlstm projections for the SSM archs — DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig, RunConfig
from repro.core.metatt import MetaTTConfig
from repro.models import transformer
from repro.peft import api as peft_api
from repro.peft.lora import LoRAConfig
from repro.peft.lotr import LoTRConfig
from repro.peft.vera import VeRAConfig


def matrix_dims(cfg: ModelConfig) -> dict:
    """matrix type -> (d_in, d_out) for every adaptable linear map."""
    d, q, kv, ff = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.d_ff
    out = {}
    mixers = {m for m, _ in cfg.block_pattern}
    if "attn" in mixers or cfg.is_encdec:
        out.update({"attn_q": (d, q), "attn_k": (d, kv), "attn_v": (d, kv),
                    "attn_o": (q, d)})
    if cfg.is_encdec:
        out.update({"xattn_q": (d, q), "xattn_k": (d, kv),
                    "xattn_v": (d, kv), "xattn_o": (q, d)})
    if "mamba" in mixers:
        di = cfg.mamba_d_inner
        out.update({"mamba_in": (d, 2 * di), "mamba_out": (di, d)})
    if "mlstm" in mixers:
        out.update({"mlstm_q": (d, d), "mlstm_v": (d, d), "mlstm_o": (d, d)})
    if "slstm" in mixers:
        out.update({"slstm_z": (d, d), "slstm_o": (d, d)})
    if ff:
        out.update({"ffn_gate": (d, ff), "ffn_up": (d, ff),
                    "ffn_down": (ff, d)})
    if any(f == "moe" for _, f in cfg.block_pattern):
        out["moe_down"] = (ff, d)
    return out


def default_matrices(cfg: ModelConfig, variant: str = "4d") -> tuple:
    """Paper default: attention q/v (App. A.2); arch-family extensions for
    blocks that have no attention."""
    mixers = {m for m, _ in cfg.block_pattern}
    out = []
    if "attn" in mixers or cfg.is_encdec:
        out += ["attn_q", "attn_v"]
    if cfg.is_encdec:
        out += ["xattn_q", "xattn_v"]
    if "mamba" in mixers:
        out += ["mamba_in", "mamba_out"]
    if "mlstm" in mixers:
        out += ["mlstm_q", "mlstm_v"]
    if "slstm" in mixers:
        out += ["slstm_z"]
    if variant == "4+ed":
        out += ["moe_down"]
    return tuple(out)


def build_adapter_spec(run: RunConfig) -> peft_api.AdapterSpec:
    cfg = run.model
    if run.adapter_kind == "none":
        return peft_api.NONE
    types = run.adapter_matrices or default_matrices(cfg, run.adapter_variant)
    dims = matrix_dims(cfg)
    unknown = [t for t in types if t not in dims]
    if unknown:
        raise ValueError(f"{cfg.name}: matrix types {unknown} not present")
    d_in = tuple(dims[t][0] for t in types)
    d_out = tuple(dims[t][1] for t in types)
    common = dict(num_layers=cfg.total_layers, matrix_types=tuple(types),
                  d_in=d_in, d_out=d_out, rank=run.adapter_rank)
    if run.adapter_kind == "metatt":
        extra = {}
        if run.adapter_variant == "5d":
            if max(d_out) > cfg.q_dim:
                raise ValueError(
                    "5d head-factorized output requires all adapted out dims "
                    f"<= H*head_dim={cfg.q_dim}")
            extra = dict(num_heads=cfg.num_heads,
                         head_dim=cfg.resolved_head_dim)
        elif run.adapter_variant == "4+1d":
            extra = dict(num_tasks=max(run.num_tasks, 1))
        elif run.adapter_variant == "4+ed":
            extra = dict(num_experts=cfg.num_experts)
        acfg = MetaTTConfig(**common, variant=run.adapter_variant,
                            alpha=run.adapter_alpha, **extra)
    elif run.adapter_kind == "lora":
        acfg = LoRAConfig(**common, alpha=run.adapter_alpha * run.adapter_rank)
    elif run.adapter_kind == "vera":
        acfg = VeRAConfig(**common)
    elif run.adapter_kind == "lotr":
        acfg = LoTRConfig(**common, alpha=run.adapter_alpha)
    else:
        raise ValueError(run.adapter_kind)
    return peft_api.AdapterSpec(kind=run.adapter_kind, cfg=acfg)


def init_params(cfg: ModelConfig, spec: peft_api.AdapterSpec, key) -> dict:
    k1, k2 = jax.random.split(key)
    base = transformer.init_base_params(cfg, k1)
    adapter, frozen = peft_api.init_adapter(spec, k2)
    return {"base": base, "adapter": adapter, "frozen": frozen}


def count_params(params: dict) -> dict:
    def n(tree):
        return int(sum(x.size for x in jax.tree_util.tree_leaves(tree)))
    return {"base": n(params["base"]), "adapter": n(params["adapter"]),
            "frozen_adapter": n(params["frozen"])}


# ---------------------------------------------------------------------------


def next_token_loss(logits: jnp.ndarray, tokens: jnp.ndarray,
                    mask: Optional[jnp.ndarray] = None,
                    prefix_len: int = 0,
                    vocab_size: int = 0) -> jnp.ndarray:
    """Next-token CE. logits: (B, Tp+T, V) (Tp = vlm prefix), tokens (B, T).

    Deliberately slice-free on the T axis: position p's target comes from a
    ``roll`` (a cheap collective-permute when T is sequence-sharded) and
    invalid positions are masked elementwise. Slicing ``logits[:, :-1]``
    would force XLA to re-replicate a sequence-sharded logits tensor —
    a multi-GB resharding the kimi-k2 dry-run caught (EXPERIMENTS.md §Perf).
    Computed in f32 with a stable logsumexp (vocab- or T-sharded logits both
    fine; XLA inserts the reduction collectives).
    """
    b, t = tokens.shape
    t_full = logits.shape[1]
    if prefix_len:
        full_tokens = jnp.concatenate(
            [jnp.zeros((b, prefix_len), tokens.dtype), tokens], axis=1)
    else:
        full_tokens = tokens
    targets = jnp.roll(full_tokens, -1, axis=1)          # pos p -> token p+1
    pos = jnp.arange(t_full)[None, :]
    valid = jnp.broadcast_to(
        ((pos >= max(prefix_len - 1, 0)) &
         (pos < prefix_len + t - 1)), (b, t_full)).astype(jnp.float32)
    if mask is not None:
        # mask is per-*target* token: mask[j] gates the prediction of
        # token j, which lives at position prefix+j-1 -> roll to align
        m_full = jnp.concatenate(
            [jnp.ones((b, prefix_len), jnp.float32),
             mask.astype(jnp.float32)], axis=1) if prefix_len else \
            mask.astype(jnp.float32)
        valid = valid * jnp.roll(m_full, -1, axis=1)
    lg = logits.astype(jnp.float32)
    if vocab_size and logits.shape[-1] > vocab_size:
        pad_mask = jnp.arange(logits.shape[-1]) < vocab_size
        lg = jnp.where(pad_mask, lg, -1e30)
    lse = jax.nn.logsumexp(lg, axis=-1)
    true = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    nll = (lse - true) * valid
    return jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1.0)


def loss_fn(adapter, base, frozen, batch: dict, cfg: ModelConfig,
            spec: peft_api.AdapterSpec, *, remat: bool = False,
            chunk: int = 0, aux_weight: float | None = None,
            policy=None) -> tuple:
    """PEFT objective: CE + MoE aux losses. ``adapter`` first so
    jax.value_and_grad(loss_fn) differentiates only the adapter (the frozen
    base never gets a gradient — the memory story that lets 1T-param models
    fine-tune, DESIGN.md §4). ``policy`` is the resolved kernel-dispatch
    policy — the train hot path runs the fused Pallas kernels (forward AND
    backward, via their custom VJPs) when it routes to Pallas."""
    bc, per_layer = peft_api.adapter_factors(spec, adapter, frozen)
    out = transformer.forward(
        base, cfg, spec, bc, per_layer, batch.get("tokens"),
        embeds=batch.get("embeds"), enc_embeds=batch.get("enc_embeds"),
        task=batch.get("task"), remat=remat, chunk=chunk, policy=policy)
    prefix = 0 if batch.get("embeds") is None else batch["embeds"].shape[1]
    loss = next_token_loss(out.logits, batch["tokens"], batch.get("mask"),
                           prefix, vocab_size=cfg.vocab_size)
    aux_weight = cfg.moe_aux_weight if aux_weight is None else aux_weight
    aux_total = sum(out.aux.values()) if out.aux else 0.0
    metrics = {"ce": loss, **out.aux}
    return loss + aux_weight * aux_total, metrics
