"""Mixture-of-Experts with expert parallelism.

Routing: softmax top-k (renormalized). Dispatch is **capacity-based
(GShard-style)**: token-expert pairs are sorted by expert, each expert
processes up to C = capacity_factor · pairs/E_local slots as a *batched*
GEMM (E, C, d) × (E, d, ff); overflow pairs are dropped (aux load-balance
loss keeps routing near-uniform, and the paper's PEFT setting never trains
the experts anyway). We deliberately chose capacity dispatch over
``jax.lax.ragged_dot`` dropless grouping: the batched-GEMM form is what maps
onto the MXU as dense contractions and is also what the dry-run HLO
faithfully costs (ragged_dot's reference lowering is dense-masked —
E_local× flop inflation in the compiled module). Trade-off recorded in
DESIGN.md §3.

Expert parallelism is explicit ``jax.shard_map`` over the "model" mesh axis:
each shard owns E/|model| experts, dispatches exactly the pairs routed to
its local experts (non-local pairs land in a trash slot), and the per-token
combine is a single psum over "model". Expert weights are additionally
FSDP-sharded over "data" on the d_ff dim and all-gathered per-layer inside
the shard (DESIGN.md §4) — this is what lets kimi-k2's ~1T frozen parameters
fit 512 chips.

MetaTT hook: with the (4+E)D variant (paper §4 "expert partitions"), the
expert down-projection gets a TT delta whose middle r×r core is indexed by
the expert owning each capacity block — one tiny batched einsum, zero extra
large GEMMs.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.config.base import ModelConfig
from repro.models.layers import AdapterCtx, adapted_linear, dense_ffn
from repro.sharding import batch_axes, current_mesh
from repro.sharding.compat import shard_map


def _router(x, w_router, n_k):
    logits = (x @ w_router.astype(x.dtype)).astype(jnp.float32)   # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, n_k)                      # (N, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    return logits, probs, top_p, top_i


def aux_losses(logits, probs, top_i, num_experts: int) -> dict:
    """Standard load-balance + router-z losses (Switch/GShard)."""
    n = probs.shape[0]
    onehot = jax.nn.one_hot(top_i, num_experts, dtype=jnp.float32)  # (N,k,E)
    frac_tokens = onehot.sum((0, 1)) / (n * top_i.shape[-1])
    frac_probs = probs.mean(0)
    lb = num_experts * jnp.sum(frac_tokens * frac_probs)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return {"load_balance": lb, "router_z": z}


def _expert_delta(ctx: AdapterCtx, h: jnp.ndarray, lo, n_local: int,
                  d_out: int):
    """Adapter delta on the expert down-projection. h: (E_local, C, ff).

    MetaTT-(4+E)D indexes the middle core by global expert id (paper §4);
    other adapters apply a uniform (expert-independent) delta.
    """
    spec = ctx.spec
    if not spec.adapts("moe_down"):
        return None
    cfg = spec.cfg
    if spec.kind == "metatt" and getattr(cfg, "variant", "") == "4+ed":
        mi = cfg.m_index("moe_down")
        g1 = ctx.broadcast["g1"][: h.shape[-1]].astype(h.dtype)
        g4 = ctx.broadcast["g4"][:, :d_out].astype(h.dtype)
        c_all = ctx.layer["c"]                          # (E, M, r, r)
        c_loc = jax.lax.dynamic_slice_in_dim(c_all, lo, n_local, axis=0)
        c_loc = c_loc[:, mi].astype(h.dtype)            # (E_local, r, r)
        p = h @ g1                                      # (E_local, C, r)
        return cfg.alpha * (jnp.einsum("ecr,ers->ecs", p, c_loc) @ g4)
    if ctx.task is not None and jnp.ndim(ctx.task) >= 1:
        # h is expert-sorted (E_local, C, ff): its leading axis is experts,
        # so a per-request (B,) task vector cannot be gathered against it
        # (and would silently mis-route whenever E_local == B).
        raise NotImplementedError(
            "per-request task vectors cannot index the expert-sorted "
            "moe_down delta; use a scalar task")
    from repro.peft import api as peft_api
    return peft_api.adapter_delta(spec, ctx.broadcast, ctx.layer, h,
                                  "moe_down", task=ctx.task)


def _moe_block(x, top_p, top_i, lo, n_local, w_g, w_u, w_d, ctx: AdapterCtx,
               cfg: ModelConfig):
    """Capacity-dispatched expert FFN for experts [lo, lo+n_local).

    x: (N, d) tokens (all local tokens); returns (N, d) partial output
    covering exactly the pairs owned by this shard's experts.
    """
    n, k = top_i.shape
    pairs = n * k
    # per-expert capacity sized against the GLOBAL expert count: expected
    # pairs per expert = pairs/num_experts regardless of how many are local
    cap = int(cfg.moe_capacity_factor * pairs / max(cfg.num_experts, 1))
    cap = max(min(cap, pairs), 1)
    d = x.shape[-1]

    flat_e = top_i.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(n), k)
    flat_p = top_p.reshape(-1)
    local_e = flat_e - lo
    is_local = (local_e >= 0) & (local_e < n_local)
    sort_key = jnp.where(is_local, local_e, n_local)     # overflow group last
    order = jnp.argsort(sort_key)
    se, st, sp = sort_key[order], flat_t[order], flat_p[order]

    group_sizes = jnp.bincount(se, length=n_local + 1)[:n_local]
    seg_start = jnp.concatenate(
        [jnp.cumsum(group_sizes) - group_sizes,
         jnp.sum(group_sizes)[None]])                    # (n_local+1,)
    pos = jnp.arange(pairs) - seg_start[se]
    keep = (se < n_local) & (pos < cap)
    trash = n_local * cap
    dest = jnp.where(keep, se * cap + pos, trash)

    xs = jnp.take(x, st, axis=0)                         # (pairs, d)
    # gather-based dispatch: slot (e, c) reads sorted pair seg_start[e]+c.
    # (A scatter into the capacity buffer lowers to giant u32 index
    # broadcasts — (pairs, d)-sized temps the dry-run flagged; the gather
    # form is the TPU-friendly one.)
    src = jnp.clip(seg_start[:n_local, None] + jnp.arange(cap)[None, :],
                   0, pairs - 1)                         # (E_local, cap)
    slot_valid = jnp.arange(cap)[None, :] < group_sizes[:, None]
    disp = jnp.where(slot_valid[..., None],
                     jnp.take(xs, src, axis=0), 0).astype(x.dtype)

    act = jax.nn.silu if cfg.mlp == "swiglu" else (
        lambda v: jax.nn.gelu(v, approximate=True))
    wg = w_g.astype(x.dtype)
    wu = w_u.astype(x.dtype)
    wd = w_d.astype(x.dtype)
    h = act(jnp.einsum("ecd,edf->ecf", disp, wg)) * \
        jnp.einsum("ecd,edf->ecf", disp, wu)
    y = jnp.einsum("ecf,efd->ecd", h, wd)
    delta = _expert_delta(ctx, h, lo, n_local, d)
    if delta is not None:
        y = y + delta.astype(y.dtype)

    y_flat = jnp.concatenate(
        [y.reshape(n_local * cap, d), jnp.zeros((1, d), y.dtype)])
    y_pairs = jnp.take(y_flat, dest, axis=0)             # dropped -> zeros
    y_pairs = y_pairs * sp[:, None].astype(y.dtype)
    inv = jnp.argsort(order)
    y_pairs = jnp.take(y_pairs, inv, axis=0)
    # combine in the compute dtype: the gate weights promoted everything to
    # f32, which doubled the EP-combine psum wire bytes (§Perf iteration K2)
    return y_pairs.reshape(n, k, d).sum(axis=1).astype(x.dtype)


def moe_ffn(x: jnp.ndarray, w: dict, ctx: AdapterCtx, cfg: ModelConfig):
    """x: (B, T, d) -> (y, aux). Dispatches to shard_map EP when a mesh with
    a partitionable "model" axis is active; plain local path otherwise
    (identical math — CPU unit tests exercise the same code)."""
    b, t, d = x.shape
    xf = x.reshape(b * t, d)
    n_k, n_e = cfg.experts_per_token, cfg.num_experts
    logits, probs, top_p, top_i = _router(xf, w["router"], n_k)
    aux = (aux_losses(logits, probs, top_i, n_e)
           if cfg.moe_aux_weight > 0 else {})

    mesh = current_mesh()
    ep = (mesh is not None and "model" in mesh.axis_names
          and n_e % mesh.shape["model"] == 0 and mesh.shape["model"] > 1)
    if not ep:
        y = _moe_block(xf, top_p, top_i, 0, n_e, w["e_wg"], w["e_wu"],
                       w["e_wd"], ctx, cfg)
    else:
        n_model = mesh.shape["model"]
        n_local = n_e // n_model
        # token batch spec: keep only the leading batch axes that divide the
        # flat token count (decode with global_batch=1 degrades to fully
        # replicated tokens — every shard computes its local experts)
        bspec = ()
        n_tok = xf.shape[0]
        for ax in batch_axes(mesh):
            prod = int(np.prod([mesh.shape[a] for a in bspec])) if bspec else 1
            if n_tok % (mesh.shape[ax] * prod) == 0:
                bspec = bspec + (ax,)
        bspec = bspec or None
        fsdp = "data" in mesh.axis_names and \
            w["e_wg"].shape[-1] % mesh.shape["data"] == 0 and \
            w["e_wd"].shape[1] % mesh.shape["data"] == 0
        wg_spec = P("model", None, "data" if fsdp else None)
        wd_spec = P("model", "data" if fsdp else None, None)
        # adapter factors + task index ride along fully replicated
        # (shard_map must not close over tracers)
        adapter_in = (ctx.broadcast, ctx.layer, ctx.task)
        adapter_specs = jax.tree_util.tree_map(lambda _: P(), adapter_in)

        def shard_fn(xf_l, top_p_l, top_i_l, wg_l, wu_l, wd_l, adapt):
            bc, ly, task = adapt
            ctx_l = AdapterCtx(ctx.spec, bc, ly, task)
            if fsdp:
                wg_l = jax.lax.all_gather(wg_l, "data", axis=2, tiled=True)
                wu_l = jax.lax.all_gather(wu_l, "data", axis=2, tiled=True)
                wd_l = jax.lax.all_gather(wd_l, "data", axis=1, tiled=True)
            idx = jax.lax.axis_index("model")
            y_l = _moe_block(xf_l, top_p_l, top_i_l, idx * n_local, n_local,
                             wg_l, wu_l, wd_l, ctx_l, cfg)
            return jax.lax.psum(y_l, "model")

        y = shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(bspec, None), P(bspec, None), P(bspec, None),
                      wg_spec, wg_spec, wd_spec, adapter_specs),
            out_specs=P(bspec, None),
            check_vma=False,
        )(xf, top_p, top_i, w["e_wg"], w["e_wu"], w["e_wd"], adapter_in)

    if cfg.num_shared_experts:
        y = y + dense_ffn(xf, {"wg": w["s_wg"], "wu": w["s_wu"],
                               "wd": w["s_wd"]}, ctx, cfg.mlp)
    return y.reshape(b, t, d), aux
