"""Model zoo for the 10 assigned architectures."""
from repro.models.model import (  # noqa: F401
    build_adapter_spec,
    count_params,
    default_matrices,
    init_params,
    loss_fn,
    matrix_dims,
    next_token_loss,
)
from repro.models.transformer import (  # noqa: F401
    decode_step,
    forward,
    init_base_params,
    init_caches,
)
