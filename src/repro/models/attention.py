"""GQA/MQA attention with RoPE, KV cache, cross-attention and a
flash-style chunked path for long sequences.

Three execution paths:
  * naive      — full score matrix; smoke tests / short sequences.
  * chunked    — queries processed in chunks under ``lax.map`` with
                 ``jax.checkpoint`` on the chunk body, so backward recomputes
                 scores per chunk: O(chunk × S) live memory (flash-attention
                 memory behaviour expressed in pure XLA; the Pallas kernel in
                 kernels/flash_attention.py is the TPU-native variant).
  * decode     — one query token against a sequence-sharded KV cache
                 (S over "model": GQA kv-heads are often < |model|, see
                 sharding/rules.py).

Adapter hook: q/k/v/o projections go through ``adapted_linear`` with matrix
types "<prefix>_q" etc., so MetaTT's M axis addresses them (paper §2.2).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.kernels import dispatch
from repro.kernels import quant as quant_lib
from repro.models.layers import (AdapterCtx, adapted_linear, apply_rope,
                                 serve_rp_linear)
from repro.sharding import (BATCH, SEQ, current_mesh, get_serve_rp,
                            maybe_shard, serve_tp_gather, serve_tp_slice)

NEG_INF = -1e30


def _flash_ok(ctx: AdapterCtx) -> bool:
    """Pallas attention applies per device: under an AMBIENT >1-chip
    GSPMD mesh the sharded XLA paths (context-parallel scores,
    sequence-sharded caches) own the layout decisions, so the kernels
    stand down. Inside the serving engine's ``shard_map`` region there is
    no ambient mesh — each shard invokes the kernels on its LOCAL head
    group / cache shard (DESIGN.md §9), which is exactly the
    single-device shape they support."""
    pol = ctx.policy
    if pol is None or not pol.flash_attn:
        return False
    mesh = current_mesh()
    return mesh is None or mesh.size == 1


def _split_heads(x, n_heads, head_dim):
    b, t, _ = x.shape
    return x.reshape(b, t, n_heads, head_dim)


def _gqa_scores(q, k, scale):
    """q: (B,T,KV,G,hd)  k: (B,S,KV,hd) -> (B,KV,G,T,S) in f32."""
    return jnp.einsum("btkgh,bskh->bkgts", q, k,
                      preferred_element_type=jnp.float32) * scale


def _gqa_out(probs, v):
    """probs: (B,KV,G,T,S)  v: (B,S,KV,hd) -> (B,T,KV,G,hd)."""
    return jnp.einsum("bkgts,bskh->btkgh", probs.astype(v.dtype), v)


def _softmax_attend(q, k, v, mask, scale):
    s = _gqa_scores(q, k, scale)
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return _gqa_out(p, v)


def _causal_mask(t, s, q_offset=0):
    qi = jnp.arange(t)[:, None] + q_offset
    ki = jnp.arange(s)[None, :]
    return (qi >= ki)[None, None, None]         # (1,1,1,T,S)


def _chunked_attend(q, k, v, scale, causal, chunk):
    """Query-chunked attention: lax.map over q chunks, checkpointed chunk
    body -> flash-like live memory, recompute in backward."""
    b, t, kv, g, hd = q.shape
    s = k.shape[1]
    n = t // chunk

    @jax.checkpoint
    def one(args):
        qc, off = args                           # (B, chunk, KV, G, hd)
        mask = _causal_mask(chunk, s, off) if causal else None
        return _softmax_attend(qc, k, v, mask, scale)

    qs = q.reshape(b, n, chunk, kv, g, hd).transpose(1, 0, 2, 3, 4, 5)
    offs = jnp.arange(n) * chunk
    out = jax.lax.map(one, (qs, offs))           # (n, B, chunk, KV, G, hd)
    return out.transpose(1, 0, 2, 3, 4, 5).reshape(b, t, kv, g, hd)


def attention(x: jnp.ndarray, w: dict, ctx: AdapterCtx, cfg: ModelConfig, *,
              causal: bool = True,
              positions: Optional[jnp.ndarray] = None,
              prefix: str = "attn",
              use_rope: bool = True,
              chunk: int = 0,
              kv_x: Optional[jnp.ndarray] = None,
              cache: Optional[dict] = None,
              cache_pos: Optional[jnp.ndarray] = None,
              block_tables: Optional[jnp.ndarray] = None):
    """Returns (y, new_cache).

    Self-attention when kv_x is None; cross-attention otherwise (kv_x is the
    encoder output; cache then holds precomputed k/v and is not updated).
    Decode mode when ``cache is not None and x.shape[1] == 1`` for self-attn.
    Paged mode when ``block_tables`` is given: ``cache`` holds flat
    (N, page, KV, hd) block pools and x is a (B, C) chunk of co-batched
    decode/prefill tokens (see _paged_attend).
    """
    hd = cfg.resolved_head_dim
    n_h, n_kv = cfg.num_heads, cfg.num_kv_heads
    g = n_h // n_kv
    scale = hd ** -0.5
    b, t, _ = x.shape

    q = _split_heads(adapted_linear(x, w["wq"], ctx, f"{prefix}_q"), n_h, hd)
    if kv_x is None:
        kv_in = x
    else:
        kv_in = kv_x
    if cache is not None and kv_x is not None and "k" in cache:
        # cross-attention decode: reuse precomputed encoder k/v
        k, v = cache["k"], cache["v"]
        new_cache = cache
    else:
        k = _split_heads(adapted_linear(kv_in, w["wk"], ctx, f"{prefix}_k"),
                         n_kv, hd)
        v = _split_heads(adapted_linear(kv_in, w["wv"], ctx, f"{prefix}_v"),
                         n_kv, hd)
        new_cache = None

    if positions is None:
        positions = jnp.arange(t)
    if use_rope and kv_x is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        if new_cache is None or "k" not in (cache or {}):
            k = apply_rope(k, positions, cfg.rope_theta)

    if block_tables is not None and kv_x is None:
        assert cache is not None and positions.ndim == 2, \
            "paged attention needs a paged cache and (B, C) positions"
        return _paged_attend(x, q, k, v, w, ctx, cache, block_tables,
                             positions, n_h, hd)

    if cache is not None and kv_x is None:
        # ---- self-attention decode: new tokens into a full-length cache.
        # cache_pos is a scalar (whole batch at one position) or a (B,)
        # vector of per-row positions (the serving engine's decode slots —
        # each slot advances independently under continuous batching).
        # t > 1 is the speculative verifier's co-batched pass: token j of
        # row b lands at cache_pos[b]+j, and each query column attends
        # [0, cache_pos+j] through EXACTLY the t == 1 code — per-column
        # bit-identity with t sequential single-token steps (the q/k/v/o
        # projections and FFN still batch all t columns in one GEMM).
        # serve-TP (DESIGN.md §9): inside the engine's shard_map region
        # the cache arrives kv-head-sharded — slice this shard's
        # contiguous head group (q heads stay kv-aligned: H/tp = G·KV/tp)
        # and all-gather the per-head outputs below. No-ops unsharded.
        q = serve_tp_slice(q, 2)
        k = serve_tp_slice(k, 2)
        v = serve_tp_slice(v, 2)
        kv_l = k.shape[2]
        h_l = kv_l * g
        if jnp.ndim(cache_pos) == 0 and t == 1:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, cache_pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, cache_pos, 0, 0))
        else:
            rows = jnp.arange(b)
            cp0 = jnp.broadcast_to(jnp.asarray(cache_pos), (b,))
            ck, cv = cache["k"], cache["v"]
            for j in range(t):
                # mode="drop": columns past the cache end (a draft chunk
                # overhanging cache_len) discard instead of clamping
                ck = ck.at[rows, cp0 + j].set(
                    k[:, j].astype(ck.dtype), mode="drop")
                cv = cv.at[rows, cp0 + j].set(
                    v[:, j].astype(cv.dtype), mode="drop")
        ck = maybe_shard(ck, BATCH, "model", None, None)
        cv = maybe_shard(cv, BATCH, "model", None, None)
        s_len = ck.shape[1]
        cp = jnp.broadcast_to(jnp.asarray(cache_pos), (b,))
        cols = []
        for j in range(t):
            if _flash_ok(ctx):
                # decode-shaped Pallas kernel: per-slot position masking
                # and the GQA broadcast happen inside the dispatch seam
                cols.append(dispatch.decode_attention(
                    q[:, j:j + 1], ck, cv, cp + j, policy=ctx.policy))
            else:
                qh = q[:, j:j + 1].reshape(b, 1, kv_l, g, hd)
                mask = (jnp.arange(s_len)[None, :] <= (cp + j)[:, None]
                        )[:, None, None, None, :]
                cols.append(_softmax_attend(qh, ck, cv, mask, scale))
        out = cols[0] if t == 1 else jnp.concatenate(
            [c.reshape(b, 1, kv_l, g, hd) for c in cols], axis=1)
        out = out.reshape(b, t, h_l, hd)
        # row-parallel serve TP (DESIGN.md §11): keep the local head
        # group — the wo epilogue below row-slices and psums instead of
        # all-gathering the per-head outputs here
        if not get_serve_rp():
            out = serve_tp_gather(out, 2)
        new_cache = {"k": ck, "v": cv}
    else:
        # ---- train / prefill / cross
        mesh = current_mesh()
        n_model = (mesh.shape["model"] if mesh is not None
                   and "model" in mesh.axis_names else 1)
        # TP applies when the QUERY heads divide the model axis (k/v may
        # stay replicated under GQA — they are the cheap operands)
        heads_shardable = n_model == 1 or n_h % n_model == 0
        q = maybe_shard(q, BATCH, None, "model", None)
        k = maybe_shard(k, BATCH, None, "model", None)
        v = maybe_shard(v, BATCH, None, "model", None)
        qh = q.reshape(b, t, n_kv, g, hd)
        eff_causal = causal and kv_x is None
        if _flash_ok(ctx) and (not eff_causal or t == k.shape[1]):
            # train/prefill flash route: blockwise online softmax — the
            # (T, S) score matrix stays out of HBM in both directions (the
            # backward runs the two-pass recompute kernels from the stashed
            # per-row lse, see kernels/dispatch.py)
            out = dispatch.flash_attention(q, k, v, causal=eff_causal,
                                           policy=ctx.policy)
            out = out.reshape(b, t, n_kv, g, hd)
        elif (not heads_shardable and t % n_model == 0
                and t // n_model <= max(chunk, 512)):
            # §Perf iteration W1 (whisper: 20 heads vs 16-way model axis):
            # context-parallel scores — shard the query-T axis of the score
            # tensor over "model"; each chip computes a T/16 query stripe
            # against the full KV instead of all heads redundantly.
            mask = _causal_mask(t, k.shape[1]) if (causal and kv_x is None) \
                else None
            s = _gqa_scores(qh, k, scale)
            s = maybe_shard(s, BATCH, None, None, "model", None)
            if mask is not None:
                s = jnp.where(mask, s, NEG_INF)
            p = jax.nn.softmax(s, axis=-1)
            p = maybe_shard(p, BATCH, None, None, "model", None)
            out = _gqa_out(p, v)
        elif chunk and t % chunk == 0 and t > chunk:
            out = _chunked_attend(qh, k, v, scale, causal and kv_x is None,
                                  chunk)
        else:
            mask = _causal_mask(t, k.shape[1]) if (causal and kv_x is None) \
                else None
            out = _softmax_attend(qh, k, v, mask, scale)
        if cache is not None and kv_x is not None and new_cache is None:
            new_cache = {"k": k, "v": v}     # prefill of a cross cache
        elif kv_x is None and cache is None and new_cache is None:
            new_cache = {"k": k, "v": v}     # prefill returns cache to caller

    # row-parallel: out still carries only this shard's head group —
    # contiguous head slices align with contiguous wo rows, so the
    # row-sliced projection + psum reconstructs the full epilogue
    out = out.reshape(b, t, -1)
    if get_serve_rp():
        y = serve_rp_linear(out, w["wo"], ctx, f"{prefix}_o")
    else:
        y = adapted_linear(out, w["wo"], ctx, f"{prefix}_o")
    return maybe_shard(y, BATCH, SEQ, None), new_cache


def _paged_attend(x, q, k, v, w, ctx: AdapterCtx, cache: dict,
                  block_tables, positions, n_h: int, hd: int):
    """Paged-cache step: scatter the chunk's k/v into the flat block pools
    by block table, then attend with per-slot per-query position masks.

    x: (B, C, d_model) — C co-batched tokens per slot (decode: 1 real
    token; chunked prefill: up to C prompt tokens), token c of slot b at
    absolute position positions[b, c]; q/k/v: projected+RoPE'd heads;
    cache: {"k","v"} (N, page, KV, hd) pools shared by every slot;
    block_tables: (B, P) int32, sentinel >= N for unallocated pages.

    Write-then-attend: a token's own k/v lands in its cell before the
    masked attention reads it, so cells holding stale data (pad columns of
    earlier steps) are always overwritten by the step that owns their
    position before any query's mask reaches them. Writes through
    sentinel or out-of-table pages drop (``mode="drop"``) — that is what
    keeps an evicted slot's garbage out of blocks reassigned to new
    requests.

    int8 KV mode (cache carries ``k_s``/``v_s`` per-cell scale pools,
    DESIGN.md §8): the RoPE'd k and the v quantize at write time — one
    amax/127 scale per (token, kv-head) cell — and scales scatter through
    the SAME block table as the cells, so COW and prefix sharing
    round-trip the quantized representation; attention dequantizes
    in-register inside the paged kernel.

    Serve-TP (DESIGN.md §9): inside the engine's shard_map region the
    pools arrive kv-head-sharded; this shard slices its contiguous
    q/k/v head group (post-RoPE — per-head ops commute with the slice),
    scatters/attends against its LOCAL pool shard only, and the per-head
    outputs are all-gathered before the replicated output projection.
    Block ids, positions and masks are shard-independent, so the
    host-side BlockManager never sees the mesh.
    """
    b, t, _ = x.shape
    q = serve_tp_slice(q, 2)
    k = serve_tp_slice(k, 2)
    v = serve_tp_slice(v, 2)
    n_blocks, page = cache["k"].shape[0], cache["k"].shape[1]
    p_tab = block_tables.shape[1]
    pidx = positions // page                                 # (B, C)
    blk = jnp.take_along_axis(block_tables,
                              jnp.clip(pidx, 0, p_tab - 1), axis=1)
    blk = jnp.where(pidx < p_tab, blk, n_blocks)             # drop, not clamp
    off = positions % page
    quantized = "k_s" in cache
    if quantized:
        k, k_s = quant_lib.quantize_kv(k)
        v, v_s = quant_lib.quantize_kv(v)
    ck = cache["k"].at[blk, off].set(k.astype(cache["k"].dtype),
                                     mode="drop")
    cv = cache["v"].at[blk, off].set(v.astype(cache["v"].dtype),
                                     mode="drop")
    new_cache = {"k": ck, "v": cv}
    scales = {}
    if quantized:
        new_cache["k_s"] = cache["k_s"].at[blk, off].set(k_s, mode="drop")
        new_cache["v_s"] = cache["v_s"].at[blk, off].set(v_s, mode="drop")
        scales = dict(k_scale=new_cache["k_s"], v_scale=new_cache["v_s"])
    pol = ctx.policy if _flash_ok(ctx) else None
    out = dispatch.paged_decode_attention(q, ck, cv, block_tables,
                                          positions[:, 0], policy=pol,
                                          **scales)
    if get_serve_rp():
        # row-parallel (DESIGN.md §11): skip the head all-gather — wo
        # row-slices against the local head group and psums partials
        out = out.reshape(b, t, -1)
        y = serve_rp_linear(out, w["wo"], ctx, "attn_o")
        return maybe_shard(y, BATCH, SEQ, None), new_cache
    out = serve_tp_gather(out, 2)
    out = out.reshape(b, t, n_h * hd)
    y = adapted_linear(out, w["wo"], ctx, "attn_o")
    return maybe_shard(y, BATCH, SEQ, None), new_cache


def init_cache(cfg: ModelConfig, batch: int, length: int, dtype) -> dict:
    hd = cfg.resolved_head_dim
    shape = (batch, length, cfg.num_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_paged_cache(cfg: ModelConfig, num_blocks: int, page_size: int,
                     dtype, kv_quant: bool = False) -> dict:
    """Flat per-layer KV block pool: (num_blocks, page, KV, hd). Which
    request owns which block lives host-side (serving/block_manager.py).
    ``kv_quant`` stores cells as int8 plus per-cell f32 scale pools
    (``k_s``/``v_s``, (num_blocks, page, KV)) in the same block layout."""
    hd = cfg.resolved_head_dim
    shape = (num_blocks, page_size, cfg.num_kv_heads, hd)
    if kv_quant:
        s_shape = shape[:-1]
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_s": jnp.zeros(s_shape, jnp.float32),
                "v_s": jnp.zeros(s_shape, jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
