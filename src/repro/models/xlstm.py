"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory, sequential) — Beck et al. 2024 (arXiv:2405.04517).

mLSTM train/prefill uses the *parallel form* (gated-attention-like, with the
stabilized log-gate matrix D̃), query-chunked exactly like
models/attention.py so live memory is O(chunk × T). Decode uses the O(1)
recurrent form with matrix memory C ∈ R^{H×hd×hd}. Both linear-time at
decode — which is why xlstm runs the ``long_500k`` cell the pure-attention
archs skip.

sLSTM is inherently sequential (recurrent mixing R_· h_{t-1} per head); it
runs as ``lax.scan`` over time with the exponential-gate stabilizer m_t.
The per-step x-projections are hoisted out of the scan as batched GEMMs.

Adapter matrix types: "mlstm_q", "mlstm_v", "slstm_z" (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models.layers import AdapterCtx, adapted_linear
from repro.sharding import BATCH, SEQ, maybe_shard

NEG_INF = -1e30


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------

def _mlstm_parallel(q, k, v, i_raw, logf, chunk: int):
    """Stabilized parallel form. q,k,v: (B,T,H,hd); i_raw/logf: (B,T,H)."""
    b, t, h, hd = q.shape
    scale = hd ** -0.5
    fcum = jnp.cumsum(logf, axis=1)                      # (B,T,H)

    def block(args):
        qc, fc, off = args                               # (B,c,H,hd) (B,c,H)
        # D[t,s] = Fcum[t] - Fcum[s] + i[s]  for s <= t
        dmat = (fc[:, :, None, :] - fcum[:, None, :, :]
                + i_raw[:, None, :, :])                  # (B,c,T,H)
        qi = jnp.arange(qc.shape[1])[:, None] + off
        ki = jnp.arange(t)[None, :]
        dmat = jnp.where((qi >= ki)[None, :, :, None], dmat, NEG_INF)
        m = jnp.max(dmat, axis=2, keepdims=True)         # (B,c,1,H)
        s = jnp.einsum("bthd,bshd->btsh", qc, k,
                       preferred_element_type=jnp.float32) * scale
        s = s * jnp.exp(dmat - m)
        n = jnp.maximum(jnp.abs(s.sum(axis=2)), jnp.exp(-m[:, :, 0]))
        out = jnp.einsum("btsh,bshd->bthd", s.astype(v.dtype), v)
        return out / n[..., None].astype(v.dtype)

    if chunk and t % chunk == 0 and t > chunk:
        n = t // chunk
        qs = q.reshape(b, n, chunk, h, hd).transpose(1, 0, 2, 3, 4)
        fs = fcum.reshape(b, n, chunk, h).transpose(1, 0, 2, 3)
        offs = jnp.arange(n) * chunk
        out = jax.lax.map(jax.checkpoint(block), (qs, fs, offs))
        return out.transpose(1, 0, 2, 3, 4).reshape(b, t, h, hd)
    return block((q, fcum, jnp.int32(0)))


def _mlstm_step(cache, q, k, v, i_raw, logf):
    """Recurrent form, one step. q,k,v: (B,H,hd); i_raw/logf: (B,H)."""
    c_prev, n_prev, m_prev = cache["c"], cache["n"], cache["m"]
    m_new = jnp.maximum(logf + m_prev, i_raw)            # (B,H)
    i_s = jnp.exp(i_raw - m_new)
    f_s = jnp.exp(logf + m_prev - m_new)
    c_new = (f_s[..., None, None] * c_prev
             + i_s[..., None, None] * v[..., :, None] * k[..., None, :])
    n_new = f_s[..., None] * n_prev + i_s[..., None] * k
    hd = q.shape[-1]
    num = jnp.einsum("bhde,bhe->bhd", c_new, q * (hd ** -0.5))
    # stabilized denominator: the state is implicitly scaled by exp(-m), so
    # the max-with-1 of the unstabilized form becomes max(|ñᵀq|, exp(-m))
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhd,bhd->bh", n_new, q * (hd ** -0.5))),
        jnp.exp(-m_new))
    h = num / den[..., None]
    return h, {"c": c_new, "n": n_new, "m": m_new}


def mlstm_mixer(x, w, ctx: AdapterCtx, cfg: ModelConfig, *,
                cache: Optional[dict] = None, chunk: int = 256):
    b, t, d = x.shape
    n_h = cfg.num_heads
    hd = d // n_h
    q = adapted_linear(x, w["wq"], ctx, "mlstm_q").reshape(b, t, n_h, hd)
    k = (x @ w["wk"].astype(x.dtype)).reshape(b, t, n_h, hd)
    v = adapted_linear(x, w["wv"], ctx, "mlstm_v").reshape(b, t, n_h, hd)
    i_raw = (x @ w["w_i"].astype(x.dtype)).astype(jnp.float32)  # (B,T,H)
    logf = jax.nn.log_sigmoid(
        (x @ w["w_f"].astype(x.dtype)).astype(jnp.float32))
    o = jax.nn.sigmoid(x @ w["w_og"].astype(x.dtype))

    if cache is None:
        h = _mlstm_parallel(q.astype(jnp.float32), k.astype(jnp.float32),
                            v, i_raw, logf, chunk)
        new_cache = None
    else:
        h, new_cache = _mlstm_step(
            cache, q[:, 0].astype(jnp.float32), k[:, 0].astype(jnp.float32),
            v[:, 0].astype(jnp.float32), i_raw[:, 0], logf[:, 0])
        h = h[:, None]
    h = (h.reshape(b, t, d)).astype(x.dtype) * o
    y = adapted_linear(h, w["w_out"], ctx, "mlstm_o")
    return maybe_shard(y, BATCH, SEQ, None), new_cache


def init_mlstm_cache(cfg: ModelConfig, batch: int) -> dict:
    n_h = cfg.num_heads
    hd = cfg.d_model // n_h
    return {"c": jnp.zeros((batch, n_h, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, n_h, hd), jnp.float32),
            "m": jnp.full((batch, n_h), NEG_INF, jnp.float32)}


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------

def _slstm_recur(h, r, n_heads):
    """Per-head recurrent mixing: h (B,d) x r (H,hd,hd) -> (B,d)."""
    b, d = h.shape
    hd = d // n_heads
    hh = h.reshape(b, n_heads, hd)
    return jnp.einsum("bhd,hde->bhe", hh, r.astype(h.dtype)).reshape(b, d)


def _slstm_step(carry, xs, r_w, n_heads):
    h, c, n, m = carry
    zx, ix, fx, ox = xs                                  # (B,d) each, f32
    z = jnp.tanh(zx + _slstm_recur(h, r_w["r_z"], n_heads))
    i_raw = ix + _slstm_recur(h, r_w["r_i"], n_heads)
    f_raw = fx + _slstm_recur(h, r_w["r_f"], n_heads)
    o = jax.nn.sigmoid(ox + _slstm_recur(h, r_w["r_o"], n_heads))
    logf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(logf + m, i_raw)
    i_s = jnp.exp(i_raw - m_new)
    f_s = jnp.exp(logf + m - m_new)
    c_new = f_s * c + i_s * z
    n_new = f_s * n + i_s
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return (h_new, c_new, n_new, m_new), h_new


def slstm_mixer(x, w, ctx: AdapterCtx, cfg: ModelConfig, *,
                cache: Optional[dict] = None):
    b, t, d = x.shape
    n_h = cfg.num_heads
    # hoisted x-projections (batched GEMMs outside the scan)
    zx = adapted_linear(x, w["w_z"], ctx, "slstm_z").astype(jnp.float32)
    ix = (x @ w["w_i"].astype(x.dtype)).astype(jnp.float32)
    fx = (x @ w["w_f"].astype(x.dtype)).astype(jnp.float32)
    ox = (x @ w["w_o"].astype(x.dtype)).astype(jnp.float32)

    if cache is None:
        init = tuple(jnp.zeros((b, d), jnp.float32) for _ in range(3)) \
            + (jnp.full((b, d), NEG_INF, jnp.float32),)
        xs = tuple(a.transpose(1, 0, 2) for a in (zx, ix, fx, ox))
        # §Perf iteration X1: a per-timestep scan re-reads the recurrent
        # matrices R from HBM every step (~170 TB/step for train_4k). With
        # ``unroll`` timesteps per scan body, XLA keeps R live across the
        # unrolled steps — HBM weight traffic drops ~unroll x. (The full fix
        # is a Pallas kernel holding R in VMEM for the whole sequence; this
        # is the XLA-expressible version.)
        unroll = 8 if t % 8 == 0 else 1
        (_, _, _, _), hs = jax.lax.scan(
            lambda c, s: _slstm_step(c, s, w, n_h), init, xs,
            unroll=unroll)
        h = hs.transpose(1, 0, 2)                        # (B,T,d)
        new_cache = None
    else:
        carry = (cache["h"], cache["c"], cache["n"], cache["m"])
        carry, h1 = _slstm_step(carry, (zx[:, 0], ix[:, 0], fx[:, 0],
                                        ox[:, 0]), w, n_h)
        h = h1[:, None]
        new_cache = {"h": carry[0], "c": carry[1], "n": carry[2],
                     "m": carry[3]}

    y = adapted_linear(h.astype(x.dtype), w["w_out"], ctx, "slstm_o")
    return maybe_shard(y, BATCH, SEQ, None), new_cache


def init_slstm_cache(cfg: ModelConfig, batch: int) -> dict:
    d = cfg.d_model
    return {"h": jnp.zeros((batch, d), jnp.float32),
            "c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.zeros((batch, d), jnp.float32),
            "m": jnp.full((batch, d), NEG_INF, jnp.float32)}
