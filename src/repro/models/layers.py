"""Shared building blocks for the model zoo."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels import quant as quant_lib
from repro.peft import api as peft_api
from repro.sharding import (BATCH, SEQ, get_serve_rp, get_serve_tp,
                            maybe_shard, serve_psum, serve_tp_slice)


@dataclasses.dataclass
class AdapterCtx:
    """Everything a layer needs to apply the (global) adapter.

    spec is static; broadcast is closed over the scan; layer is this layer's
    slice of the per-layer factors (sliced by the scan / by position);
    task is the MTL task index (4+1d) — None otherwise; policy is the
    resolved kernel-dispatch policy (kernels/dispatch.py) — None keeps the
    unfused reference path.
    """
    spec: peft_api.AdapterSpec
    broadcast: Any
    layer: Any
    task: Optional[Any] = None
    policy: Optional[dispatch.KernelPolicy] = None

    def at(self, layer_slice) -> "AdapterCtx":
        return AdapterCtx(self.spec, self.broadcast, layer_slice, self.task,
                          self.policy)


NO_ADAPTER = AdapterCtx(peft_api.NONE, {}, None)


def adapted_linear(x: jnp.ndarray, w: jnp.ndarray, ctx: AdapterCtx, m: str,
                   b: jnp.ndarray | None = None) -> jnp.ndarray:
    """y = x·W (+ bias) + adapter delta for matrix type ``m``.

    This is the paper's Eq. (5): the frozen pre-trained map plus the TT
    (or baseline-adapter) low-rank update. When the dispatch policy routes
    to Pallas, the adapter is folded into lora-form (A, B) and base matmul
    + rank-r epilogue run as ONE fused kernel — the delta is applied while
    the output tile is still in VMEM instead of three HBM round-trips of
    the (M, N) output (kernels/tt_linear.py).

    ``w`` may be a packed int8 leaf (``{"q8", "scale"}``, kernels/quant.py
    — the serving engine quantizes the frozen base once at construction):
    adapted matmuls then run the fused w8a16 kernels (int8 W tile
    dequantized in-register, fp rank-r epilogue); unadapted ones
    dequantize into the plain XLA matmul (still int8 HBM reads — XLA
    fuses the scale multiply into the GEMM's operand load).
    """
    pol = ctx.policy
    wq = quant_lib.is_quantized(w)
    if pol is not None and pol.fused_linear and ctx.spec.adapts(m):
        form = peft_api.lora_form_factors(ctx.spec, ctx.broadcast, ctx.layer,
                                          m, task=ctx.task)
        if form is not None:
            fa, fb, alpha = form
            fa, fb = fa.astype(x.dtype), fb.astype(x.dtype)
            if fa.ndim == 3:      # (B,) task vector: per-slot A operand
                y = (dispatch.tt_linear_batched_a_q(x, w, fa, fb,
                                                    alpha=alpha, policy=pol)
                     if wq else
                     dispatch.tt_linear_batched_a(x, w.astype(x.dtype), fa,
                                                  fb, alpha=alpha,
                                                  policy=pol))
            else:
                y = (dispatch.tt_linear_q(x, w, fa, fb, alpha=alpha,
                                          policy=pol)
                     if wq else
                     dispatch.tt_linear(x, w.astype(x.dtype), fa, fb,
                                        alpha=alpha, policy=pol))
            if b is not None:
                y = y + b.astype(y.dtype)
            return y
    wd = quant_lib.dequantize(w, x.dtype) if wq else w.astype(x.dtype)
    y = x @ wd
    if b is not None:
        y = y + b.astype(x.dtype)
    d = peft_api.adapter_delta(ctx.spec, ctx.broadcast, ctx.layer, x, m,
                               task=ctx.task)
    if d is not None:
        y = y + d.astype(y.dtype)
    return y


# --------------------------------------------------------------------------
# row-/column-parallel serve-TP linears (DESIGN.md §11). The default serve
# TP keeps every matmul full-width and replicated, paying an all-gather of
# the attention-head outputs instead; behind ServeConfig(row_parallel=True)
# the engine traces these variants: the FIRST matmul of a pair splits its
# OUTPUT columns per shard (exact — no reduction order changes) and the
# SECOND splits its INPUT rows, producing per-shard partial sums that one
# psum reduces. The psum reorders the K-axis reduction, which is why this
# mode is near-parity (~1e-3 in bf16) rather than bit-exact — the
# column-only mode stays the oracle.
# --------------------------------------------------------------------------


def _slice_w(w, axis: int):
    """This shard's stripe of a (possibly int8-packed) weight leaf along
    ``axis`` (negative, from the end). Packed leaves slice the int8 cells;
    per-output-channel scales slice with N (axis -1) and are K-independent
    under a row slice (axis -2) — grouped scales tile K, so ServeConfig
    forbids row_parallel with group_size > 0."""
    if quant_lib.is_quantized(w):
        q = serve_tp_slice(w["q8"], w["q8"].ndim + axis)
        s = w["scale"]
        if axis == -1:
            s = serve_tp_slice(s, s.ndim - 1)
        return {"q8": q, "scale": s}
    return serve_tp_slice(w, w.ndim + axis)


def _apply_linear(x, w, form, pol):
    """base matmul + optional lora-form (A, B, alpha) delta, routed
    through the fused kernels when the policy allows (the sliced-operand
    twin of adapted_linear's fused branch; no bias — rp adds it once
    after the psum)."""
    wq = quant_lib.is_quantized(w)
    if form is not None:
        fa, fb, alpha = form
        fa, fb = fa.astype(x.dtype), fb.astype(x.dtype)
        if pol is not None and pol.fused_linear:
            if fa.ndim == 3:
                return (dispatch.tt_linear_batched_a_q(
                    x, w, fa, fb, alpha=alpha, policy=pol) if wq else
                    dispatch.tt_linear_batched_a(
                        x, w.astype(x.dtype), fa, fb, alpha=alpha,
                        policy=pol))
            return (dispatch.tt_linear_q(x, w, fa, fb, alpha=alpha,
                                         policy=pol) if wq else
                    dispatch.tt_linear(x, w.astype(x.dtype), fa, fb,
                                       alpha=alpha, policy=pol))
        wd = quant_lib.dequantize(w, x.dtype) if wq else w.astype(x.dtype)
        y = x @ wd
        if fa.ndim == 3:        # (B,) task vector: per-slot A operand
            p = jnp.einsum("btk,bkr->btr", x, fa)
        else:
            p = x @ fa
        return y + alpha * (p @ fb)
    wd = quant_lib.dequantize(w, x.dtype) if wq else w.astype(x.dtype)
    return x @ wd


def _lora_form(ctx: AdapterCtx, m: str):
    return (peft_api.lora_form_factors(ctx.spec, ctx.broadcast, ctx.layer,
                                       m, task=ctx.task)
            if ctx.spec.adapts(m) else None)


def serve_cp_linear(x: jnp.ndarray, w, ctx: AdapterCtx, m: str,
                    b=None) -> jnp.ndarray:
    """Column-parallel adapted linear: this shard computes its contiguous
    N/tp output stripe (weight columns, lora-form B columns and the bias
    slice with it). Bitwise-exact per column. Falls back to
    adapted_linear outside a serve-TP trace context."""
    if get_serve_tp() is None:
        return adapted_linear(x, w, ctx, m, b)
    form = _lora_form(ctx, m)
    if form is not None:
        fa, fb, alpha = form
        form = (fa, serve_tp_slice(fb, fb.ndim - 1), alpha)
    y = _apply_linear(x, _slice_w(w, -1), form, ctx.policy)
    if b is not None:
        y = y + serve_tp_slice(b, b.ndim - 1).astype(y.dtype)
    return y


def serve_rp_linear(x: jnp.ndarray, w, ctx: AdapterCtx, m: str,
                    b=None) -> jnp.ndarray:
    """Row-parallel adapted linear: ``x`` arrives SHARDED on its last dim
    (this shard's K/tp contraction rows — attention's local head group,
    the FFN's local d_ff stripe), the weight's K rows and the lora-form A
    rows slice to match, and ONE psum reduces the partial outputs; the
    bias adds once after. Falls back to adapted_linear outside a serve-TP
    trace context (x is then full-width)."""
    if get_serve_tp() is None:
        return adapted_linear(x, w, ctx, m, b)
    form = _lora_form(ctx, m)
    if form is not None:
        fa, fb, alpha = form
        form = (serve_tp_slice(fa, fa.ndim - 2), fb, alpha)
    y = serve_psum(_apply_linear(x, _slice_w(w, -2), form, ctx.policy))
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    h = x.astype(jnp.float32)
    h = h * jax.lax.rsqrt(jnp.mean(h * h, axis=-1, keepdims=True) + eps)
    return (h * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def layernorm(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    h = x.astype(jnp.float32)
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.mean((h - mu) ** 2, axis=-1, keepdims=True)
    h = (h - mu) * jax.lax.rsqrt(var + eps)
    return (h * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def norm(x, weights: dict, eps: float):
    if "b" in weights:
        return layernorm(x, weights["w"], weights["b"], eps)
    return rmsnorm(x, weights["w"], eps)


# --------------------------------------------------------------------------
# RoPE (half-split / llama convention)
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (B, T, n_heads, head_dim); positions: (B, T) or (T,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B?, T, hd/2)
    if ang.ndim == 2:
        ang = ang[None]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# dense FFN variants
# --------------------------------------------------------------------------

def dense_ffn(x: jnp.ndarray, w: dict, ctx: AdapterCtx, kind: str) -> jnp.ndarray:
    """kind: swiglu | geglu | gelu. Adapted matrix types ffn_up / ffn_down
    (off by default — paper adapts attention q/v only, App. A.2).

    Under row-parallel serve TP (DESIGN.md §11) the whole FFN runs
    megatron-style: wg/wu column-parallel (each shard activates its own
    d_ff/tp stripe), wd row-parallel with the psum epilogue — the one
    place the default serve TP leaves real decode FLOPs fully replicated."""
    if get_serve_rp():
        if kind in ("swiglu", "geglu"):
            act = jax.nn.silu if kind == "swiglu" else (
                lambda v: jax.nn.gelu(v, approximate=True))
            h = act(serve_cp_linear(x, w["wg"], ctx, "ffn_gate")) \
                * serve_cp_linear(x, w["wu"], ctx, "ffn_up")
        elif kind == "gelu":
            h = jax.nn.gelu(serve_cp_linear(x, w["wu"], ctx, "ffn_up"),
                            approximate=True)
        else:
            raise ValueError(kind)
        return serve_rp_linear(h, w["wd"], ctx, "ffn_down")
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else (
            lambda v: jax.nn.gelu(v, approximate=True))
        g = act(adapted_linear(x, w["wg"], ctx, "ffn_gate"))
        u = adapted_linear(x, w["wu"], ctx, "ffn_up")
        h = g * u
    elif kind == "gelu":
        h = jax.nn.gelu(adapted_linear(x, w["wu"], ctx, "ffn_up"),
                        approximate=True)
    else:
        raise ValueError(kind)
    h = maybe_shard(h, BATCH, None, "model")
    return adapted_linear(h, w["wd"], ctx, "ffn_down")


def embed_tokens(tokens: jnp.ndarray, embed: jnp.ndarray,
                 compute_dtype) -> jnp.ndarray:
    return jnp.take(embed, tokens, axis=0).astype(compute_dtype)


def lm_logits(h: jnp.ndarray, embed: jnp.ndarray) -> jnp.ndarray:
    """Tied-embedding readout, always vocab-sharded on "model".

    The activations are gathered (MBs) rather than the table (GBs): under
    sequence parallelism h arrives T-sharded on "model" and XLA all-gathers
    it here; constraining logits T-sharded instead would force an all-gather
    (and on CPU an f32 upcast) of the ENTIRE (V, d) embedding — a ~19 GB/chip
    mistake the kimi-k2 dry-run exposed (EXPERIMENTS.md §Perf, iteration 0).
    """
    h = maybe_shard(h, BATCH, None, None)
    logits = h @ embed.T.astype(h.dtype)
    return maybe_shard(logits, BATCH, None, "model")
