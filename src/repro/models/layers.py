"""Shared building blocks for the model zoo."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels import quant as quant_lib
from repro.peft import api as peft_api
from repro.sharding import BATCH, SEQ, maybe_shard


@dataclasses.dataclass
class AdapterCtx:
    """Everything a layer needs to apply the (global) adapter.

    spec is static; broadcast is closed over the scan; layer is this layer's
    slice of the per-layer factors (sliced by the scan / by position);
    task is the MTL task index (4+1d) — None otherwise; policy is the
    resolved kernel-dispatch policy (kernels/dispatch.py) — None keeps the
    unfused reference path.
    """
    spec: peft_api.AdapterSpec
    broadcast: Any
    layer: Any
    task: Optional[Any] = None
    policy: Optional[dispatch.KernelPolicy] = None

    def at(self, layer_slice) -> "AdapterCtx":
        return AdapterCtx(self.spec, self.broadcast, layer_slice, self.task,
                          self.policy)


NO_ADAPTER = AdapterCtx(peft_api.NONE, {}, None)


def adapted_linear(x: jnp.ndarray, w: jnp.ndarray, ctx: AdapterCtx, m: str,
                   b: jnp.ndarray | None = None) -> jnp.ndarray:
    """y = x·W (+ bias) + adapter delta for matrix type ``m``.

    This is the paper's Eq. (5): the frozen pre-trained map plus the TT
    (or baseline-adapter) low-rank update. When the dispatch policy routes
    to Pallas, the adapter is folded into lora-form (A, B) and base matmul
    + rank-r epilogue run as ONE fused kernel — the delta is applied while
    the output tile is still in VMEM instead of three HBM round-trips of
    the (M, N) output (kernels/tt_linear.py).

    ``w`` may be a packed int8 leaf (``{"q8", "scale"}``, kernels/quant.py
    — the serving engine quantizes the frozen base once at construction):
    adapted matmuls then run the fused w8a16 kernels (int8 W tile
    dequantized in-register, fp rank-r epilogue); unadapted ones
    dequantize into the plain XLA matmul (still int8 HBM reads — XLA
    fuses the scale multiply into the GEMM's operand load).
    """
    pol = ctx.policy
    wq = quant_lib.is_quantized(w)
    if pol is not None and pol.fused_linear and ctx.spec.adapts(m):
        form = peft_api.lora_form_factors(ctx.spec, ctx.broadcast, ctx.layer,
                                          m, task=ctx.task)
        if form is not None:
            fa, fb, alpha = form
            fa, fb = fa.astype(x.dtype), fb.astype(x.dtype)
            if fa.ndim == 3:      # (B,) task vector: per-slot A operand
                y = (dispatch.tt_linear_batched_a_q(x, w, fa, fb,
                                                    alpha=alpha, policy=pol)
                     if wq else
                     dispatch.tt_linear_batched_a(x, w.astype(x.dtype), fa,
                                                  fb, alpha=alpha,
                                                  policy=pol))
            else:
                y = (dispatch.tt_linear_q(x, w, fa, fb, alpha=alpha,
                                          policy=pol)
                     if wq else
                     dispatch.tt_linear(x, w.astype(x.dtype), fa, fb,
                                        alpha=alpha, policy=pol))
            if b is not None:
                y = y + b.astype(y.dtype)
            return y
    wd = quant_lib.dequantize(w, x.dtype) if wq else w.astype(x.dtype)
    y = x @ wd
    if b is not None:
        y = y + b.astype(x.dtype)
    d = peft_api.adapter_delta(ctx.spec, ctx.broadcast, ctx.layer, x, m,
                               task=ctx.task)
    if d is not None:
        y = y + d.astype(y.dtype)
    return y


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    h = x.astype(jnp.float32)
    h = h * jax.lax.rsqrt(jnp.mean(h * h, axis=-1, keepdims=True) + eps)
    return (h * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def layernorm(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    h = x.astype(jnp.float32)
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.mean((h - mu) ** 2, axis=-1, keepdims=True)
    h = (h - mu) * jax.lax.rsqrt(var + eps)
    return (h * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def norm(x, weights: dict, eps: float):
    if "b" in weights:
        return layernorm(x, weights["w"], weights["b"], eps)
    return rmsnorm(x, weights["w"], eps)


# --------------------------------------------------------------------------
# RoPE (half-split / llama convention)
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (B, T, n_heads, head_dim); positions: (B, T) or (T,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B?, T, hd/2)
    if ang.ndim == 2:
        ang = ang[None]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# dense FFN variants
# --------------------------------------------------------------------------

def dense_ffn(x: jnp.ndarray, w: dict, ctx: AdapterCtx, kind: str) -> jnp.ndarray:
    """kind: swiglu | geglu | gelu. Adapted matrix types ffn_up / ffn_down
    (off by default — paper adapts attention q/v only, App. A.2)."""
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else (
            lambda v: jax.nn.gelu(v, approximate=True))
        g = act(adapted_linear(x, w["wg"], ctx, "ffn_gate"))
        u = adapted_linear(x, w["wu"], ctx, "ffn_up")
        h = g * u
    elif kind == "gelu":
        h = jax.nn.gelu(adapted_linear(x, w["wu"], ctx, "ffn_up"),
                        approximate=True)
    else:
        raise ValueError(kind)
    h = maybe_shard(h, BATCH, None, "model")
    return adapted_linear(h, w["wd"], ctx, "ffn_down")


def embed_tokens(tokens: jnp.ndarray, embed: jnp.ndarray,
                 compute_dtype) -> jnp.ndarray:
    return jnp.take(embed, tokens, axis=0).astype(compute_dtype)


def lm_logits(h: jnp.ndarray, embed: jnp.ndarray) -> jnp.ndarray:
    """Tied-embedding readout, always vocab-sharded on "model".

    The activations are gathered (MBs) rather than the table (GBs): under
    sequence parallelism h arrives T-sharded on "model" and XLA all-gathers
    it here; constraining logits T-sharded instead would force an all-gather
    (and on CPU an f32 upcast) of the ENTIRE (V, d) embedding — a ~19 GB/chip
    mistake the kimi-k2 dry-run exposed (EXPERIMENTS.md §Perf, iteration 0).
    """
    h = maybe_shard(h, BATCH, None, None)
    logits = h @ embed.T.astype(h.dtype)
    return maybe_shard(logits, BATCH, None, "model")
