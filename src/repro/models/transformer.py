"""Decoder-LM / encoder-decoder assembly.

The network is a ``jax.lax.scan`` over *super-blocks* (config.block_pattern
repeats num_super_blocks times — DESIGN.md §3). All per-layer weights are
stacked on a leading ``nb`` axis; the adapter's per-layer factors (leading
axis L = total layers) are reshaped to (nb, P, ...) and ride through the scan
as xs, so the global TT addresses every layer with O(1) HLO.

Weight layout (one entry per pattern position, each leaf stacked over nb):

  blocks[p] = {"norm1": …, "mixer": {…}, ["norm2": …, "ffn": {…}],
               ["norm3": …, "xattn": {…}]}          (xattn: enc-dec decoder)

KV/state caches mirror the same structure: caches[p] leaves stacked over nb,
threaded through the scan as (xs -> updated ys).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib
from repro.models import xlstm as xlstm_lib
from repro.models.layers import (AdapterCtx, dense_ffn, embed_tokens,
                                 lm_logits, norm)
from repro.peft import api as peft_api
from repro.sharding import (BATCH, SEQ, get_serve_tp, maybe_shard,
                            serve_tp_gather, serve_tp_slice)

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _nrm(key, shape, scale, dtype):
    return scale * jax.random.normal(key, shape, jnp.float32)


def _linear_init(key, d_in, d_out, nb, dtype):
    w = jax.random.normal(key, (nb, d_in, d_out), jnp.float32)
    return (w / jnp.sqrt(d_in)).astype(dtype)


def _norm_init(cfg: ModelConfig, nb):
    w = {"w": jnp.zeros((nb, cfg.d_model), jnp.float32)}
    if cfg.norm_kind == "layernorm":
        w = {"w": jnp.ones((nb, cfg.d_model), jnp.float32),
             "b": jnp.zeros((nb, cfg.d_model), jnp.float32)}
    return w


def _attn_init(cfg: ModelConfig, key, nb, dtype):
    ks = jax.random.split(key, 4)
    return {
        "wq": _linear_init(ks[0], cfg.d_model, cfg.q_dim, nb, dtype),
        "wk": _linear_init(ks[1], cfg.d_model, cfg.kv_dim, nb, dtype),
        "wv": _linear_init(ks[2], cfg.d_model, cfg.kv_dim, nb, dtype),
        "wo": _linear_init(ks[3], cfg.q_dim, cfg.d_model, nb, dtype),
    }


def _mamba_init(cfg: ModelConfig, key, nb, dtype):
    di, ds = cfg.mamba_d_inner, cfg.mamba_d_state
    dtr, k = cfg.resolved_dt_rank, cfg.mamba_conv
    ks = jax.random.split(key, 5)
    a = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, 1))
    return {
        "w_in": _linear_init(ks[0], cfg.d_model, 2 * di, nb, dtype),
        "conv_w": (jax.random.normal(ks[1], (nb, k, di), jnp.float32)
                   / jnp.sqrt(k)).astype(dtype),
        "conv_b": jnp.zeros((nb, di), dtype),
        "w_x": _linear_init(ks[2], di, dtr + 2 * ds, nb, dtype),
        "w_dt": _linear_init(ks[3], dtr, di, nb, dtype),
        "dt_bias": jnp.zeros((nb, di), dtype),
        "a_log": jnp.tile(jnp.log(a)[None], (nb, 1, 1)),
        "d": jnp.ones((nb, di), jnp.float32),
        "w_out": _linear_init(ks[4], di, cfg.d_model, nb, dtype),
    }


def _mlstm_init(cfg: ModelConfig, key, nb, dtype):
    d, h = cfg.d_model, cfg.num_heads
    ks = jax.random.split(key, 7)
    return {
        "wq": _linear_init(ks[0], d, d, nb, dtype),
        "wk": _linear_init(ks[1], d, d, nb, dtype),
        "wv": _linear_init(ks[2], d, d, nb, dtype),
        "w_i": _linear_init(ks[3], d, h, nb, dtype),
        "w_f": _linear_init(ks[4], d, h, nb, dtype),
        "w_og": _linear_init(ks[5], d, d, nb, dtype),
        "w_out": _linear_init(ks[6], d, d, nb, dtype),
    }


def _slstm_init(cfg: ModelConfig, key, nb, dtype):
    d, h = cfg.d_model, cfg.num_heads
    hd = d // h
    ks = jax.random.split(key, 9)
    out = {n: _linear_init(k, d, d, nb, dtype)
           for n, k in zip(("w_z", "w_i", "w_f", "w_o", "w_out"), ks[:5])}
    for n, k in zip(("r_z", "r_i", "r_f", "r_o"), ks[5:]):
        out[n] = (jax.random.normal(k, (nb, h, hd, hd), jnp.float32)
                  / jnp.sqrt(hd)).astype(dtype)
    return out


def _ffn_init(cfg: ModelConfig, key, nb, dtype):
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    w = {"wu": _linear_init(ks[1], d, ff, nb, dtype),
         "wd": _linear_init(ks[2], ff, d, nb, dtype)}
    if cfg.mlp in ("swiglu", "geglu"):
        w["wg"] = _linear_init(ks[0], d, ff, nb, dtype)
    return w


def _moe_init(cfg: ModelConfig, key, nb, dtype):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 7)
    w = {
        "router": _linear_init(ks[0], d, e, nb, jnp.float32),
        "e_wg": (jax.random.normal(ks[1], (nb, e, d, ff), jnp.float32)
                 / jnp.sqrt(d)).astype(dtype),
        "e_wu": (jax.random.normal(ks[2], (nb, e, d, ff), jnp.float32)
                 / jnp.sqrt(d)).astype(dtype),
        "e_wd": (jax.random.normal(ks[3], (nb, e, ff, d), jnp.float32)
                 / jnp.sqrt(ff)).astype(dtype),
    }
    if cfg.num_shared_experts:
        sff = ff * cfg.num_shared_experts
        w["s_wg"] = _linear_init(ks[4], d, sff, nb, dtype)
        w["s_wu"] = _linear_init(ks[5], d, sff, nb, dtype)
        w["s_wd"] = _linear_init(ks[6], sff, d, nb, dtype)
    return w


_MIXER_INIT = {"attn": _attn_init, "mamba": _mamba_init,
               "mlstm": _mlstm_init, "slstm": _slstm_init}


def _block_init(cfg: ModelConfig, key, nb, *, decoder_cross: bool, dtype):
    out = []
    for mixer, ffn in cfg.block_pattern:
        key, k1, k2, k3 = jax.random.split(key, 4)
        blk: dict = {"norm1": _norm_init(cfg, nb)}
        if mixer != "none":
            blk["mixer"] = _MIXER_INIT[mixer](cfg, k1, nb, dtype)
        if decoder_cross:
            blk["norm3"] = _norm_init(cfg, nb)
            blk["xattn"] = _attn_init(cfg, k3, nb, dtype)
        if ffn != "none":
            blk["norm2"] = _norm_init(cfg, nb)
            blk["ffn"] = (_moe_init if ffn == "moe" else _ffn_init)(
                cfg, k2, nb, dtype)
        out.append(blk)
    return out


def init_base_params(cfg: ModelConfig, key) -> dict:
    """Random stand-in for the frozen pre-trained weights."""
    dtype = cfg.param_dtype
    k_embed, k_blocks, k_enc = jax.random.split(key, 3)
    nb = cfg.num_super_blocks
    params = {
        "embed": {"tok": (jax.random.normal(
            k_embed, (cfg.padded_vocab, cfg.d_model), jnp.float32)
            * 0.02).astype(dtype)},
        "blocks": _block_init(cfg, k_blocks, nb,
                              decoder_cross=cfg.is_encdec, dtype=dtype),
        "final_norm": _norm_init(cfg, 1),
    }
    if cfg.is_encdec:
        enc_cfg = dataclasses.replace(cfg, block_pattern=(("attn", "dense"),),
                                      num_layers=cfg.encoder_layers)
        params["enc_blocks"] = _block_init(enc_cfg, k_enc,
                                           cfg.encoder_layers,
                                           decoder_cross=False, dtype=dtype)
        params["enc_final_norm"] = _norm_init(cfg, 1)
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _split_layers(per_layer, nb: int, p: int, offset: int = 0):
    """(L, ...) adapter factors -> (nb, P, ...) for the scan (slice
    [offset : offset + nb*p] of the global layer axis first)."""
    if per_layer is None:
        return None
    def one(a):
        sl = jax.lax.slice_in_dim(a, offset, offset + nb * p, axis=0)
        return sl.reshape((nb, p) + a.shape[1:])
    return jax.tree_util.tree_map(one, per_layer)


def _sublayer(h, blk, mixer, ffn, ctx: AdapterCtx, cfg: ModelConfig, *,
              causal, positions, cache, cache_pos, enc_out, chunk,
              block_tables=None):
    aux = {}
    new_cache = {}
    if mixer != "none":
        hn = norm(h, blk["norm1"], cfg.norm_eps)
        if mixer == "attn":
            y, c = attn_lib.attention(
                hn, blk["mixer"], ctx, cfg, causal=causal,
                positions=positions, chunk=chunk,
                cache=(cache or {}).get("self"), cache_pos=cache_pos,
                block_tables=block_tables)
            if c is not None:
                new_cache["self"] = c
        elif mixer == "mamba":
            y, c = mamba_lib.mamba_mixer(hn, blk["mixer"], ctx, cfg,
                                         cache=(cache or {}).get("ssm"))
            if c is not None:
                new_cache["ssm"] = c
        elif mixer == "mlstm":
            y, c = xlstm_lib.mlstm_mixer(hn, blk["mixer"], ctx, cfg,
                                         cache=(cache or {}).get("mlstm"))
            if c is not None:
                new_cache["mlstm"] = c
        elif mixer == "slstm":
            y, c = xlstm_lib.slstm_mixer(hn, blk["mixer"], ctx, cfg,
                                         cache=(cache or {}).get("slstm"))
            if c is not None:
                new_cache["slstm"] = c
        else:
            raise ValueError(mixer)
        h = h + y
    if "xattn" in blk and enc_out is not None:
        hn = norm(h, blk["norm3"], cfg.norm_eps)
        y, c = attn_lib.attention(hn, blk["xattn"], ctx, cfg, causal=False,
                                  prefix="xattn", use_rope=False,
                                  kv_x=enc_out,
                                  cache=(cache or {}).get("cross"))
        if c is not None:
            new_cache["cross"] = c
        h = h + y
    if ffn != "none":
        hn = norm(h, blk["norm2"], cfg.norm_eps)
        if ffn == "moe":
            y, moe_aux = moe_lib.moe_ffn(hn, blk["ffn"], ctx, cfg)
            aux.update(moe_aux)
        else:
            y = dense_ffn(hn, blk["ffn"], ctx, cfg.mlp)
        h = h + y
    return h, new_cache, aux


def run_blocks(h, blocks, pattern, spec: peft_api.AdapterSpec, broadcast,
               per_layer, cfg: ModelConfig, *, causal=True, positions=None,
               caches=None, cache_pos=None, enc_out=None, layer_offset=0,
               task=None, remat=False, chunk=0, nb=None, policy=None,
               block_tables=None):
    """Scan over super-blocks. blocks: list of per-position dicts (leaves
    stacked over nb). Returns (h, new_caches, aux). ``policy`` is the
    resolved kernel-dispatch policy (kernels/dispatch.py), carried into
    every layer by AdapterCtx. ``block_tables`` switches attention to the
    paged cache layout (one table shared by every layer)."""
    p = len(pattern)
    nb = nb if nb is not None else (
        jax.tree_util.tree_leaves(blocks)[0].shape[0])
    pl = _split_layers(per_layer, nb, p, layer_offset)
    has_cache = caches is not None

    def body(h, xs):
        blks, pl_b, cch = xs
        aux_acc = {}
        new_cch = []
        for i, (mixer, ffn) in enumerate(pattern):
            ly = (None if pl_b is None
                  else jax.tree_util.tree_map(lambda a: a[i], pl_b))
            ctx = AdapterCtx(spec, broadcast, ly, task, policy)
            h, nc, aux = _sublayer(
                h, blks[i], mixer, ffn, ctx, cfg, causal=causal,
                positions=positions,
                cache=(cch[i] if has_cache else None),
                cache_pos=cache_pos, enc_out=enc_out, chunk=chunk,
                block_tables=block_tables)
            new_cch.append(nc)
            for k, v in aux.items():
                aux_acc[k] = aux_acc.get(k, 0.0) + v
        return h, (new_cch, aux_acc)

    if remat:
        body = jax.checkpoint(body)

    xs = (blocks, pl, caches if has_cache else [{} for _ in range(p)])
    h, (new_caches, aux_stack) = jax.lax.scan(body, h, xs, length=nb)
    aux = {k: jnp.sum(v) for k, v in aux_stack.items()}
    return h, new_caches, aux


@dataclasses.dataclass(frozen=True)
class ModelOutputs:
    logits: jnp.ndarray
    aux: dict
    caches: Any = None
    enc_out: Any = None


# the (fixed) encoder super-block pattern — shared with core/merge.py's
# whole-model fold so the two can't drift
ENC_PATTERN = (("attn", "dense"),)


def encode(base, cfg: ModelConfig, enc_embeds, spec, broadcast, per_layer,
           policy=None):
    """Whisper-style encoder over precomputed (stub) frame embeddings."""
    h = maybe_shard(enc_embeds.astype(cfg.compute_dtype), BATCH, SEQ, None)
    pos = jnp.arange(h.shape[1])
    h, _, aux = run_blocks(
        h, base["enc_blocks"], ENC_PATTERN, spec, broadcast,
        per_layer, cfg, causal=False, positions=pos, layer_offset=0,
        nb=cfg.encoder_layers, policy=policy)
    h = norm(h, jax.tree_util.tree_map(lambda a: a[0],
                                       base["enc_final_norm"]), cfg.norm_eps)
    return h, aux


def forward(base, cfg: ModelConfig, spec, broadcast, per_layer, tokens=None,
            *, embeds=None, enc_embeds=None, task=None, remat=False,
            chunk=0, return_caches=False, cache_len=0, policy=None):
    """Train / prefill forward. Returns ModelOutputs with (B, T, V) logits.

    tokens: (B, T) int32; embeds: optional precomputed prefix embeddings
    (B, Tp, d) prepended to the token embeddings (VLM patch stub);
    enc_embeds: encoder-side stub input for enc-dec models; policy: the
    resolved kernel-dispatch policy (None -> reference XLA paths).
    """
    aux = {}
    enc_out = None
    layer_offset = 0
    if cfg.is_encdec:
        enc_out, aux = encode(base, cfg, enc_embeds, spec, broadcast,
                              per_layer, policy=policy)
        layer_offset = cfg.encoder_layers

    h = embed_tokens(tokens, base["embed"]["tok"], cfg.compute_dtype)
    if embeds is not None:
        h = jnp.concatenate([embeds.astype(h.dtype), h], axis=1)
    h = maybe_shard(h, BATCH, SEQ, None)
    t = h.shape[1]
    positions = jnp.arange(t)

    h, new_caches, aux2 = run_blocks(
        h, base["blocks"], cfg.block_pattern, spec, broadcast, per_layer,
        cfg, causal=True, positions=positions, enc_out=enc_out,
        layer_offset=layer_offset, task=task, remat=remat, chunk=chunk,
        caches=None, policy=policy)
    aux.update(aux2)
    h = norm(h, jax.tree_util.tree_map(lambda a: a[0], base["final_norm"]),
             cfg.norm_eps)
    logits = lm_logits(h, base["embed"]["tok"])
    return ModelOutputs(logits=logits, aux=aux, caches=new_caches,
                        enc_out=enc_out)


def init_caches(cfg: ModelConfig, batch: int, length: int, dtype, *,
                num_super_blocks: Optional[int] = None) -> list:
    """Stacked (over nb) cache pytree, one entry per pattern position.
    ``num_super_blocks`` overrides cfg's — the speculative drafter's
    layer-strided sub-model keeps its own (smaller) cache region."""
    nb = (cfg.num_super_blocks if num_super_blocks is None
          else num_super_blocks)

    def stack(tree):
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (nb,) + a.shape), tree)

    out = []
    for mixer, _ in cfg.block_pattern:
        ent = {}
        if mixer == "attn":
            ent["self"] = stack(attn_lib.init_cache(cfg, batch, length,
                                                    dtype))
            # NOTE: cross-attention k/v are recomputed from enc_out each
            # decode step (one GEMM per layer); a real serving deployment
            # prefills them once — see examples/serve.py.
        elif mixer == "mamba":
            ent["ssm"] = stack(mamba_lib.init_mamba_cache(cfg, batch, dtype))
        elif mixer == "mlstm":
            ent["mlstm"] = stack(xlstm_lib.init_mlstm_cache(cfg, batch))
        elif mixer == "slstm":
            ent["slstm"] = stack(xlstm_lib.init_slstm_cache(cfg, batch))
        out.append(ent)
    return out


def init_paged_caches(cfg: ModelConfig, num_blocks: int, page_size: int,
                      dtype, kv_quant: bool = False, *,
                      num_super_blocks: Optional[int] = None) -> list:
    """Paged cache pytree: one flat (nb, num_blocks, page, KV, hd) block
    pool per pattern position. Attention-only — the paged engine rejects
    stateful mixers up front (their caches are not position-indexed).
    ``kv_quant`` makes the pools int8 with per-cell scale pools riding in
    the same block layout (``copy_cache_block`` and the host-side block
    bookkeeping treat them like any other leaf). ``num_super_blocks``
    overrides cfg's for the drafter's layer-strided cache region; both
    regions are indexed by the SAME host-side block tables."""
    nb = (cfg.num_super_blocks if num_super_blocks is None
          else num_super_blocks)

    def stack(tree):
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (nb,) + a.shape), tree)

    out = []
    for mixer, _ in cfg.block_pattern:
        if mixer != "attn":
            raise NotImplementedError(
                f"paged caches are attention-only (got {mixer!r})")
        out.append({"self": stack(attn_lib.init_paged_cache(
            cfg, num_blocks, page_size, dtype, kv_quant=kv_quant))})
    return out


def copy_cache_block(caches, src, dst):
    """Device-side copy-on-write: duplicate physical block ``src`` into
    ``dst`` across every layer of a paged cache pytree (leaves stacked
    (nb, N, page, KV, hd)). ``src``/``dst`` may be traced scalars; the
    host-side BlockManager decides when a copy is needed
    (serving/block_manager.py). A ``dst`` >= N drops the write — the
    data-striped engine passes the sentinel on replicas that do not own
    the copy (block ids are replica-local, DESIGN.md §11)."""
    def one(c):
        return c.at[:, dst].set(c[:, src], mode="drop")
    return jax.tree_util.tree_map(one, caches)


def migrate_cache_blocks(dst_caches, src_caches, src_ids, dst_ids):
    """Batched pool-to-pool block copy: ``dst_caches[:, dst_ids[i]] =
    src_caches[:, src_ids[i]]`` across every layer — the device half of
    the disaggregated prefill→decode handoff (DESIGN.md §11; the host
    half is BlockManager.migrate_to). ``src_ids``/``dst_ids`` are
    fixed-width (P,) int32 vectors so one trace serves every handoff
    size: pad entries (and, under data striping, every entry on replicas
    that do not own the handoff) carry the out-of-pool sentinel and drop
    via ``mode="drop"`` — their clamped source reads are garbage the
    dropped write never lands."""
    def one(d, s):
        return d.at[:, dst_ids].set(s[:, src_ids].astype(d.dtype),
                                    mode="drop")
    return jax.tree_util.tree_map(one, dst_caches, src_caches)


def _serve_logits(h, embed):
    """Tied-embedding readout for the serving step graphs. h: (..., d);
    embed: (V, d), replicated. Returns (..., V) logits — (B, V) for the
    single-token decode step, (B, C, V) when the speculative verifier
    scores every column of a co-batched chunk in one pass.

    Under serve-time tensor parallelism (sharding.get_serve_tp — the
    engine's shard_map region, DESIGN.md §9) each shard computes its
    contiguous padded-vocab column stripe — bitwise equal to the matching
    columns of the replicated readout, since column-splitting a GEMM
    changes no per-element reduction order — and the full logits are
    all-gathered for in-graph sampling: the ONE all-gather of activations
    in the decode step, sized (B, V) per token."""
    if get_serve_tp() is None:
        return lm_logits(h, embed)
    local = serve_tp_slice(embed, 0)
    out = h @ local.T.astype(h.dtype)
    return serve_tp_gather(out, out.ndim - 1)


def paged_step(base, cfg: ModelConfig, spec, broadcast, per_layer, toks,
               caches, block_tables, pos, sel, *, task=None, policy=None,
               all_logits=False):
    """One co-batched decode / chunked-prefill step over a paged cache.

    toks: (B, C) — slot b's tokens at absolute positions pos[b]..pos[b]+C-1
    (decode slots carry 1 real token, prefilling slots up to C prompt
    tokens; trailing columns past a slot's real count are pad whose cache
    writes are overwritten by the step that owns those positions);
    block_tables: (B, P) int32; pos: (B,); sel: (B,) column whose logits
    to return (the slot's last real token). Returns (logits (B, V),
    new caches). ``all_logits`` returns (B, C, V) instead — the
    speculative verifier scores every column (sel is ignored): column c
    attends [0, pos[b]+c], so its logits depend only on tokens <= c
    regardless of what trails in later columns.
    """
    h = embed_tokens(toks, base["embed"]["tok"], cfg.compute_dtype)
    h = maybe_shard(h, BATCH, None, None)
    positions = pos[:, None] + jnp.arange(toks.shape[1])[None, :]
    h, new_caches, _ = run_blocks(
        h, base["blocks"], cfg.block_pattern, spec, broadcast, per_layer,
        cfg, causal=True, positions=positions, caches=caches,
        cache_pos=pos, task=task, policy=policy, block_tables=block_tables)
    h = norm(h, jax.tree_util.tree_map(lambda a: a[0], base["final_norm"]),
             cfg.norm_eps)
    if all_logits:
        return _serve_logits(h, base["embed"]["tok"]), new_caches
    h_sel = h[jnp.arange(h.shape[0]), sel]                  # (B, d)
    logits = _serve_logits(h_sel, base["embed"]["tok"])
    return logits, new_caches


def insert_cache_slot(caches, req_caches, slot):
    """Write a batch-1 cache pytree into batch row ``slot`` of a decode
    cache (leaves stacked (nb, B, ...)): the serving engine's prefill-into-
    slot step. ``slot`` may be a traced scalar."""
    def one(c, c1):
        return jax.lax.dynamic_update_slice(
            c, c1.astype(c.dtype), (0, slot) + (0,) * (c.ndim - 2))
    return jax.tree_util.tree_map(one, caches, req_caches)


def decode_step(base, cfg: ModelConfig, spec, broadcast, per_layer, token,
                caches, cache_pos, *, enc_out=None, task=None, policy=None,
                all_logits=False):
    """One decode step: token (B, T) -> (logits (B, V), new caches).

    cache_pos: scalar, or a (B,) vector of per-row positions (continuous-
    batching slots — see repro/serving/engine.py); token column j lands at
    cache_pos + j (T == 1 everywhere except the speculative verifier's
    multi-token pass — attention handles T > 1 per column, bit-identical
    to T sequential single-token steps). ``all_logits`` returns (B, T, V)
    — one distribution per column — instead of the last column's (B, V).
    ``policy`` routes the adapted matmuls / attention through the fused
    Pallas kernels."""
    h = embed_tokens(token, base["embed"]["tok"], cfg.compute_dtype)
    h = maybe_shard(h, BATCH, None, None)
    t = token.shape[1]
    if jnp.ndim(cache_pos) == 0:
        positions = cache_pos[None] + jnp.arange(t)[None, :]
    elif jnp.ndim(cache_pos) == 1:
        positions = cache_pos[:, None] + jnp.arange(t)[None, :]
    else:
        positions = cache_pos
    layer_offset = cfg.encoder_layers if cfg.is_encdec else 0
    h, new_caches, _ = run_blocks(
        h, base["blocks"], cfg.block_pattern, spec, broadcast, per_layer,
        cfg, causal=True, positions=positions, caches=caches,
        cache_pos=cache_pos, enc_out=enc_out, layer_offset=layer_offset,
        task=task, policy=policy)
    h = norm(h, jax.tree_util.tree_map(lambda a: a[0], base["final_norm"]),
             cfg.norm_eps)
    if all_logits:
        return _serve_logits(h, base["embed"]["tok"]), new_caches
    logits = _serve_logits(h[:, 0], base["embed"]["tok"])
    return logits, new_caches
