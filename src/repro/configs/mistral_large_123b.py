"""mistral-large-123b [hf:mistralai/Mistral-Large-Instruct-2407].

88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768, swiglu.
"""
import dataclasses

import jax.numpy as jnp

from repro.config.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    num_layers=88,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=32768,
).validate()


def smoke_config(name: str = "") -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name=CONFIG.name + "-smoke", num_layers=4, d_model=64,
        num_heads=8, num_kv_heads=2, d_ff=128, vocab_size=128,
        param_dtype=jnp.float32, compute_dtype=jnp.float32).validate()
