"""stablelm-1.6b [hf:stabilityai/stablelm-2-1_6b].

24L d_model=2048 32H (GQA kv=32, i.e. MHA) d_ff=5632 vocab=100352, swiglu.
"""
import dataclasses

import jax.numpy as jnp

from repro.config.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
).validate()


def smoke_config(name: str = "") -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name=CONFIG.name + "-smoke", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=128,
        param_dtype=jnp.float32, compute_dtype=jnp.float32).validate()
