"""granite-34b [arXiv:2405.04324] — llama-arch code model, MQA.

88L d_model=6144 48H (GQA kv=1, MQA) d_ff=24576 vocab=49152, gelu MLP.
"""
import dataclasses

import jax.numpy as jnp

from repro.config.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    mlp="gelu",
).validate()


def smoke_config(name: str = "") -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name=CONFIG.name + "-smoke", num_layers=4, d_model=64,
        num_heads=4, num_kv_heads=1, d_ff=128, vocab_size=128,
        param_dtype=jnp.float32, compute_dtype=jnp.float32).validate()
