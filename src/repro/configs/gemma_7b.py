"""gemma-7b [arXiv:2403.08295].

28L d_model=3072 16H (GQA kv=16) d_ff=24576 vocab=256000, GeGLU,
head_dim=256 (q_dim 4096 != d_model — exercises MetaTT's boundary slicing).
"""
import dataclasses

import jax.numpy as jnp

from repro.config.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    mlp="geglu",
).validate()


def smoke_config(name: str = "") -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name=CONFIG.name + "-smoke", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, head_dim=32, d_ff=128, vocab_size=128,
        param_dtype=jnp.float32, compute_dtype=jnp.float32).validate()
