"""The paper's own fine-tuning targets: RoBERTa-base / RoBERTa-large
(Liu et al. 2019) — used by the paper-reproduction benchmarks (Tables 1, 2,
Fig. 2) and the examples.

NOTE: RoBERTa is a bidirectional *encoder*; this framework's zoo is
decoder-LM shaped, so the reproduction uses a causal LM of identical
dimensions with last-token classification (synthetic GLUE-like tasks —
DESIGN.md §6). Every *parameter-count* claim (what Table 1 ranks methods by)
depends only on (D, L, M, H, r) and transfers exactly; adapter param counts
are asserted against the paper's numbers in tests/test_param_counts.py.
"""
import dataclasses

import jax.numpy as jnp

from repro.config.base import ModelConfig

CONFIG_BASE = ModelConfig(
    name="roberta-base",
    family="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=50265,
    mlp="gelu",
    norm_kind="layernorm",
    param_dtype=jnp.float32,
    compute_dtype=jnp.float32,
).validate()

CONFIG_LARGE = ModelConfig(
    name="roberta-large",
    family="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=50265,
    mlp="gelu",
    norm_kind="layernorm",
    param_dtype=jnp.float32,
    compute_dtype=jnp.float32,
).validate()

CONFIG = CONFIG_BASE


def smoke_config(name: str = "") -> ModelConfig:
    return dataclasses.replace(
        CONFIG_BASE, name="roberta-smoke", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=128).validate()
