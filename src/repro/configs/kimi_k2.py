"""kimi-k2-1t-a32b [arXiv:2501.kimi2; paper-table, unverified tier].

61L d_model=7168 64H (GQA kv=8) d_ff=2048/expert vocab=163840,
MoE 384 experts top-8 + 1 shared expert — ~1T total, ~32B active.

The flagship PEFT showcase: with MetaTT the base is frozen bf16 (no grads /
optimizer state / master copy), which is what makes 1T parameters fit the
512-chip mesh at all (see the dry-run memory_analysis in EXPERIMENTS.md).
"""
import dataclasses

import jax.numpy as jnp

from repro.config.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=163840,
    block_pattern=(("attn", "moe"),),
    num_experts=384,
    experts_per_token=8,
    num_shared_experts=1,
    # §Perf iteration K5: top-8-of-384 routing concentrates mass; cf=1.25
    # cuts expert GEMM flops + dispatch buffers 37.5% vs the 2.0 default
    moe_capacity_factor=1.25,
).validate()


def smoke_config(name: str = "") -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name=CONFIG.name + "-smoke", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=32, vocab_size=128, num_experts=8,
        experts_per_token=2, num_shared_experts=1, param_dtype=jnp.float32,
        compute_dtype=jnp.float32).validate()
