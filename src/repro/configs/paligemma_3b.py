"""paligemma-3b [arXiv:2407.07726] — SigLIP + gemma backbone.

18L d_model=2048 8H (GQA kv=1, MQA) d_ff=16384 vocab=257216; gemma-style
GeGLU, head_dim=256. The SigLIP vision tower is a STUB per the assignment:
``input_specs()`` provides 256 precomputed patch embeddings which are
prepended to the text sequence.
"""
import dataclasses

import jax.numpy as jnp

from repro.config.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    mlp="geglu",
    frontend="patch_stub",
    frontend_seq=256,
).validate()


def smoke_config(name: str = "") -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name=CONFIG.name + "-smoke", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=1, head_dim=16, d_ff=128, vocab_size=128,
        frontend_seq=8, param_dtype=jnp.float32,
        compute_dtype=jnp.float32).validate()
