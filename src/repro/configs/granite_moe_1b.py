"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) d_ff=512/expert vocab=49155,
MoE 32 experts top-8, swiglu.
"""
import dataclasses

import jax.numpy as jnp

from repro.config.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    block_pattern=(("attn", "moe"),),
    num_experts=32,
    experts_per_token=8,
).validate()


def smoke_config(name: str = "") -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name=CONFIG.name + "-smoke", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=32, vocab_size=128, num_experts=4,
        experts_per_token=2, param_dtype=jnp.float32,
        compute_dtype=jnp.float32).validate()
