"""jamba-v0.1-52b [arXiv:2403.19887] — Mamba+attention 1:7, MoE 16e top-2.

32L d_model=4096 32H (GQA kv=8) d_ff=14336/expert vocab=65536.
Super-block of 8 layers: 1 attention + 7 mamba, MoE every 2nd layer
(positions 1,3,5,7) — scanned 4x. Hybrid decode: only the 4 attention
layers carry a KV cache, so long_500k runs (memory dominated by those four
524k-long caches; mamba state is O(1)).
"""
import dataclasses

import jax.numpy as jnp

from repro.config.base import ModelConfig

_PATTERN = (
    ("mamba", "dense"), ("mamba", "moe"),
    ("mamba", "dense"), ("mamba", "moe"),
    ("attn", "dense"), ("mamba", "moe"),
    ("mamba", "dense"), ("mamba", "moe"),
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    block_pattern=_PATTERN,
    num_experts=16,
    experts_per_token=2,
    mamba_d_state=16,
    mamba_expand=2,
).validate()


def smoke_config(name: str = "") -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name=CONFIG.name + "-smoke", num_layers=8, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=128, num_experts=4,
        experts_per_token=2, param_dtype=jnp.float32,
        compute_dtype=jnp.float32).validate()
