"""xlstm-125m [arXiv:2405.04517] — sLSTM + mLSTM blocks, no FFN (d_ff=0).

12L d_model=768 4H vocab=50304, alternating mLSTM/sLSTM blocks.
Linear-time recurrent decode -> runs the long_500k cell the attention archs
skip.
"""
import dataclasses

import jax.numpy as jnp

from repro.config.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=(("mlstm", "none"), ("slstm", "none")),
).validate()


def smoke_config(name: str = "") -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name=CONFIG.name + "-smoke", num_layers=4, d_model=64,
        num_heads=4, num_kv_heads=4, vocab_size=128,
        param_dtype=jnp.float32, compute_dtype=jnp.float32).validate()
