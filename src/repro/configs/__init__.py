"""Architecture registry: one module per assigned architecture (+ the
paper's own RoBERTa targets). ``get_config("<arch-id>")`` returns the exact
assignment config; ``get_smoke_config`` returns the reduced same-family
config used by CPU smoke tests."""
from __future__ import annotations

import importlib

_MODULES = {
    "granite-moe-1b-a400m": "granite_moe_1b",
    "kimi-k2-1t-a32b": "kimi_k2",
    "paligemma-3b": "paligemma_3b",
    "mistral-large-123b": "mistral_large_123b",
    "stablelm-1.6b": "stablelm_1_6b",
    "gemma-7b": "gemma_7b",
    "granite-34b": "granite_34b",
    "whisper-large-v3": "whisper_large_v3",
    "xlstm-125m": "xlstm_125m",
    "jamba-v0.1-52b": "jamba_52b",
    "roberta-base": "roberta",
    "roberta-large": "roberta",
}

ARCH_IDS = tuple(k for k in _MODULES if not k.startswith("roberta"))
ALL_IDS = tuple(_MODULES)


def _mod(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str):
    mod = _mod(name)
    if name == "roberta-large":
        return mod.CONFIG_LARGE
    if name == "roberta-base":
        return mod.CONFIG_BASE
    return mod.CONFIG


def get_smoke_config(name: str):
    return _mod(name).smoke_config(name)


def supports_shape(cfg, shape_name: str) -> bool:
    """Assignment skip rules: long_500k only for sub-quadratic-decode archs
    (SSM / hybrid / linear-attention); decode shapes skipped for
    encoder-only archs (none assigned)."""
    if shape_name == "long_500k":
        return cfg.family in ("ssm", "hybrid")
    return True
