"""whisper-large-v3 [arXiv:2212.04356] — encoder-decoder, audio.

32L encoder + 32L decoder, d_model=1280 20H (kv=20) d_ff=5120 vocab=51866,
layernorm + gelu. The conv audio frontend is a STUB per the assignment:
``input_specs()`` provides 1500 precomputed frame embeddings for the encoder.
Adapter L axis spans enc+dec (64); M axis includes cross-attention q/v
(DESIGN.md §4).
"""
import dataclasses

import jax.numpy as jnp

from repro.config.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    mlp="gelu",
    norm_kind="layernorm",
    encoder_layers=32,
    # 1500 mel frames padded to 1536 = 16*96 so the encoder sequence is
    # shardable over the 16-way mesh axes (stub frontend pads with zeros).
    encoder_seq=1536,
    frontend="audio_stub",
).validate()


def smoke_config(name: str = "") -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name=CONFIG.name + "-smoke", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=128,
        encoder_layers=2, encoder_seq=16, param_dtype=jnp.float32,
        compute_dtype=jnp.float32).validate()
