"""Version-compat shim for the shard_map API drift.

Newer JAX exposes ``jax.shard_map(..., check_vma=)``; the installed version
only has ``jax.experimental.shard_map.shard_map(..., check_rep=)`` (same
knob, renamed). Callers import ``shard_map`` from here.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    if hasattr(jax, "shard_map"):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)
