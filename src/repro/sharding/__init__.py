from repro.sharding.rules import (  # noqa: F401
    BATCH,
    SEQ,
    get_seq_axis,
    set_seq_axis,
    batch_axes,
    current_mesh,
    maybe_shard,
    params_pspec,
    params_sharding,
    spec_for_param,
)
