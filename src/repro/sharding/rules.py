"""Logical→physical sharding rules.

Parallelism map (DESIGN.md §4):
  * batch            -> ("pod", "data")     DP across pods, DP/FSDP within
  * weight d_model / d_ff "other" dim -> "data"   (FSDP storage sharding)
  * heads / d_ff compute dim          -> "model"  (TP)
  * MoE expert dim                    -> "model"  (EP, via shard_map)
  * KV-cache sequence dim             -> "model"  (sequence-sharded decode
                                         attention — GQA kv-heads are often
                                         < |model|, so we shard S instead)
  * long-context activations          -> sequence over "data" when
                                         global_batch < |data|

``maybe_shard`` degrades gracefully: axes missing from the ambient mesh are
dropped, and any dim not divisible by its axis-size product falls back to
replicated — so e.g. paligemma's 8 heads on a 16-way model axis simply stay
replicated instead of erroring (documented trade-off; the dry-run output
shows the real placement).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.interpreters import pxla
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def current_mesh() -> Mesh | None:
    mesh = pxla.thread_resources.env.physical_mesh
    if mesh is None or mesh.empty:
        return None
    return mesh


# Sequence-parallel sentinel: ``SEQ`` in a spec resolves to the configured
# sequence axis (default: none -> replicated). The dry-run / launcher enables
# SP for train/prefill shapes via ``set_seq_axis("model")`` — activations'
# T dim is then sharded on the residual stream and gathered inside
# attention/FFN (Korthikanti-style SP, expressed purely as constraints).
SEQ = "__seq__"
_seq_axis: list = [None]


def set_seq_axis(axis: str | None) -> None:
    _seq_axis[0] = axis


def get_seq_axis() -> str | None:
    return _seq_axis[0]


# --------------------------------------------------------------------------
# Serve-time tensor parallelism (DESIGN.md §9).
#
# The serving engine wraps its jitted step graphs in ``shard_map`` over a
# ("data", "model") mesh. Inside that manual-mesh region the ambient-mesh
# machinery above is inert (``current_mesh()`` is None, so ``maybe_shard``
# no-ops) and the per-shard call sites — attention head slicing, the
# vocab-striped readout — consult this TRACE-TIME context instead: it is
# set by the engine around tracing a sharded step and cleared after, the
# same pattern as the SEQ sentinel. ``None`` means "no serve TP" (the
# default for training, dry-runs and the single-device engine).
# --------------------------------------------------------------------------
_serve_tp: list = [None]


def set_serve_tp(axis: str | None, size: int = 0) -> None:
    """Install (or clear, with ``axis=None``) the serve-TP trace context:
    ``axis`` is the shard_map mesh axis name, ``size`` its length."""
    _serve_tp[0] = (axis, size) if axis is not None else None


def get_serve_tp() -> tuple | None:
    """Current serve-TP context as ``(axis_name, size)``, or None when no
    sharded serving step is being traced."""
    return _serve_tp[0]


def serve_tp_slice(x, axis: int):
    """This shard's contiguous chunk of dim ``axis`` under serve TP.

    x: any array whose dim ``axis`` divides the TP size (the engine
    validates heads / kv-heads / padded vocab up front). Returns the
    ``x.shape[axis] // tp``-wide slice owned by this shard — identity
    when no serve-TP context is active, so call sites can be
    unconditional. Slicing a dim that is NOT a contraction input is
    bitwise-safe: every output element's reduction order is unchanged.
    """
    tp = get_serve_tp()
    if tp is None:
        return x
    name, size = tp
    assert x.shape[axis] % size == 0, \
        f"dim {axis} of {x.shape} does not split {size} ways"
    n = x.shape[axis] // size
    return jax.lax.dynamic_slice_in_dim(
        x, jax.lax.axis_index(name) * n, n, axis)


def serve_tp_gather(x, axis: int):
    """All-gather shard chunks back into the full dim ``axis`` (tiled),
    inverse of ``serve_tp_slice``. Identity when no serve-TP context is
    active."""
    tp = get_serve_tp()
    if tp is None:
        return x
    return jax.lax.all_gather(x, tp[0], axis=axis, tiled=True)


# Row-parallel serve TP (DESIGN.md §11): when on, the second matmul of
# each attention / FFN pair keeps its input SHARDED (local head group /
# local d_ff stripe), row-slices the weight, and all-reduces the partial
# outputs — one psum of (B, d) instead of an all-gather of the (B, h·hd)
# activations. Partial sums change the reduction order, so this mode is
# near-parity (~1e-3), not bit-exact; the column-only default stays the
# parity oracle. Same trace-time lifecycle as the serve-TP context.
_serve_rp: list = [False]


def set_serve_rp(on: bool) -> None:
    """Enable/disable the row-parallel serve-TP variant for the step
    graph currently being traced (engine sets it alongside serve_tp)."""
    _serve_rp[0] = bool(on)


def get_serve_rp() -> bool:
    """True when the row-parallel serve-TP variant is being traced (only
    meaningful while a serve-TP context is installed)."""
    return _serve_rp[0] and _serve_tp[0] is not None


def serve_psum(x):
    """All-reduce partial outputs over the serve-TP axis (row-parallel
    epilogue). Identity when no serve-TP context is active."""
    tp = get_serve_tp()
    if tp is None:
        return x
    return jax.lax.psum(x, tp[0])


# --------------------------------------------------------------------------
# Serve-time data parallelism (DESIGN.md §11): the engine stripes decode
# SLOTS and paged-pool BLOCKS across the "data" mesh axis — each data
# shard owns max_batch/|data| slots and num_blocks/|data| pool blocks
# with LOCAL ids, so the whole per-replica step body runs unchanged on
# local shapes. The context mirrors the serve-TP one: installed around
# tracing a dp-sharded step, cleared after.
# --------------------------------------------------------------------------
_serve_dp: list = [None]


def set_serve_dp(axis: str | None, size: int = 0) -> None:
    """Install (or clear, with ``axis=None``) the serve-DP trace context:
    ``axis`` is the shard_map data-axis name, ``size`` its length."""
    _serve_dp[0] = (axis, size) if axis is not None else None


def get_serve_dp() -> tuple | None:
    """Current serve-DP context as ``(axis_name, size)``, or None when no
    data-striped serving step is being traced."""
    return _serve_dp[0]


def serve_dp_index():
    """This replica's index on the serve-DP axis (0 without a context) —
    the host addresses per-replica work by global slot/replica id and the
    step graphs gate on this to act only on their own stripe."""
    dp = get_serve_dp()
    if dp is None:
        return jnp.int32(0)
    return jax.lax.axis_index(dp[0])


def serve_mesh(shape, axes: tuple = ("data", "model")) -> Mesh:
    """Serving mesh over the local devices: ``shape`` is (data, model) —
    "model" is the tensor-parallel axis the engine shards kv-heads /
    vocab on, "data" is reserved for replica DP (state is replicated
    across it today). Raises with the XLA_FLAGS hint when the host does
    not expose enough devices (CPU tests force fake devices via
    ``--xla_force_host_platform_device_count=N``)."""
    shape = tuple(int(s) for s in shape)
    if len(shape) != len(axes):
        raise ValueError(
            f"serve mesh shape {shape} must have one entry per axis "
            f"{axes}")
    need = int(np.prod(shape))
    have = jax.device_count()
    if need > have:
        raise ValueError(
            f"serve mesh {dict(zip(axes, shape))} needs {need} devices "
            f"but only {have} are visible (on CPU force fake devices "
            f"with XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{need})")
    return jax.make_mesh(shape, axes)


def serve_cache_pspec(caches, axis: str = "model",
                      dp_axis: str | None = None):
    """PartitionSpec pytree sharding serving KV caches on the KV-HEAD
    axis — axis 3 of every leaf in both cache layouts:

      paged pools   (nb, num_blocks, page, KV, hd)  k / v
      scale pools   (nb, num_blocks, page, KV)      k_s / v_s (int8 KV)
      dense caches  (nb, B, S, KV, hd)              k / v

    Page/block/sequence dims stay whole, so one host-side block id
    indexes every shard's pool identically (the BlockManager never needs
    to know about the mesh). ``dp_axis`` additionally stripes the BLOCKS
    axis (axis 1, paged pools only) across data replicas (DESIGN.md
    §11): each replica then owns a private num_blocks/|data| pool whose
    LOCAL block ids its per-replica BlockManager hands out."""
    def one(leaf):
        spec = [None] * leaf.ndim
        spec[3] = axis
        if dp_axis is not None:
            spec[1] = dp_axis
        return P(*spec)
    return jax.tree_util.tree_map(one, caches)


def serve_cache_sharding(caches, mesh: Mesh, axis: str = "model",
                         dp_axis: str | None = None):
    """NamedSharding pytree for ``device_put``-placing serving KV caches
    kv-head-sharded on ``axis`` (and block-striped on ``dp_axis``, see
    serve_cache_pspec)."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        serve_cache_pspec(caches, axis, dp_axis))


def _resolve(entry):
    if entry == SEQ:
        return _seq_axis[0]
    if isinstance(entry, tuple):
        resolved = tuple(_seq_axis[0] if e == SEQ else e for e in entry)
        return tuple(e for e in resolved if e is not None) or None
    return entry


def _filter_spec(mesh: Mesh, spec: P, shape) -> P:
    """Drop mesh axes that don't exist / don't divide the dim."""
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        entry = _resolve(entry)
        if entry is None:
            out.append(None)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        names = tuple(n for n in names if n in mesh.axis_names)
        size = int(np.prod([mesh.shape[n] for n in names])) if names else 1
        if not names or size == 1 or dim % size != 0:
            out.append(None)
        else:
            out.append(names if len(names) > 1 else names[0])
    return P(*out)


def maybe_shard(x, *spec_entries) -> jax.Array:
    """with_sharding_constraint that no-ops without an ambient mesh and
    auto-filters invalid axes. Usable identically in CPU unit tests and in
    the 512-device dry-run."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = _filter_spec(mesh, P(*spec_entries), x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def batch_axes(mesh: Mesh | None = None) -> tuple:
    mesh = mesh or current_mesh()
    if mesh is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


BATCH = ("pod", "data")  # logical batch axes, filtered per-mesh by maybe_shard


# --------------------------------------------------------------------------
# parameter placement: pytree of PartitionSpec mirroring the params pytree.
# Conventions (leaf shapes, nb = stacked super-block dim first where present):
#   embed        (V, d)            -> P("model", "data")
#   in-proj      (nb, d_in, d_out) -> P(None, "data", "model")
#   out-proj     (nb, d_in, d_out) -> P(None, "model", "data")
#   experts      (nb, E, d, ff)    -> P(None, "model", None, "data")
#   vectors      (..., d)          -> replicated
# --------------------------------------------------------------------------

_IN_PROJ = {"wq", "wk", "wv", "wg", "wu", "w_in", "w_qkv", "w_up",
            "s_wg", "s_wu"}
_OUT_PROJ = {"wo", "wd", "w_out", "w_down", "s_wd"}
_EXPERT_IN = {"e_wg", "e_wu"}
_EXPERT_OUT = {"e_wd"}


def spec_for_param(path: str, shape) -> P:
    """Sharding spec from the parameter's name + rank (see conventions)."""
    leaf = path.split("/")[-1]
    nd = len(shape)
    if leaf in ("tok", "embed", "lm_head"):
        return P("model", "data") if nd == 2 else P()
    if leaf in _EXPERT_IN:
        return P(None, "model", None, "data") if nd == 4 else P("model", None, "data")
    if leaf in _EXPERT_OUT:
        return P(None, "model", "data", None) if nd == 4 else P("model", "data", None)
    if leaf in _IN_PROJ:
        return P(*( [None] * (nd - 2) + ["data", "model"] ))
    if leaf in _OUT_PROJ:
        return P(*( [None] * (nd - 2) + ["model", "data"] ))
    # norms, biases, conv kernels, gates, adapter cores: replicated
    return P()


def params_pspec(params) -> dict:
    """PartitionSpec pytree for a params pytree (path-based rules)."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]

    def name(kp):
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
        return "/".join(parts)

    specs = {name(kp): spec_for_param(name(kp), leaf.shape)
             for kp, leaf in flat}
    # rebuild as pytree
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(params),
        [spec_for_param(name(kp), leaf.shape) for kp, leaf in flat])


def cache_spec_for(path: str, shape) -> P:
    """Decode-cache placement: KV caches are sequence-sharded over "model"
    (kv-heads are often < |model|) and batch-sharded over ("pod","data");
    mamba state shards d_inner over "model"; recurrent xlstm scalars are
    tiny and replicate (see DESIGN.md §4).

    Cache leaves are stacked over super-blocks: shapes carry a leading nb
    dim (transformer.init_caches), hence the leading None below.
    """
    leaf = path.split("/")[-1]
    nd = len(shape)
    if leaf in ("k", "v") and nd == 5:       # (nb, B, S, KV, hd)
        return P(None, BATCH, "model", None, None)
    if leaf == "h" and nd == 4:              # (nb, B, di, ds) mamba state
        return P(None, BATCH, "model", None)
    if leaf == "conv" and nd == 4:           # (nb, B, K-1, di)
        return P(None, BATCH, None, "model")
    if nd >= 2:
        return P(None, BATCH)
    return P()


def _paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for kp, leaf in flat:
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        out.append(("/".join(parts), leaf))
    return out


def tree_sharding(tree, mesh: Mesh, spec_fn):
    """NamedSharding pytree from a (path, shape) -> PartitionSpec rule."""
    leaves = [NamedSharding(mesh, _filter_spec(mesh, spec_fn(p, leaf.shape),
                                               leaf.shape))
              for p, leaf in _paths(tree)]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(tree), leaves)


def params_sharding(params, mesh: Mesh):
    """NamedSharding pytree (filtered for divisibility) for device_put /
    in_shardings."""
    def one(path_spec, leaf):
        return NamedSharding(mesh, _filter_spec(mesh, path_spec, leaf.shape))
    return jax.tree_util.tree_map(one, params_pspec(params), params)


def reshard_after_reshape(tree, mesh: Mesh | None = None):
    """device_put a host-reshaped pytree back onto the ambient GSPMD mesh.

    Built for the mid-training DMRG sweep: the sweep runs host-side and
    returns cores / transported moments with NEW bond shapes, so their old
    shardings are stale. This re-places every leaf under the standard
    parameter rules (``spec_for_param`` — adapter cores and moments
    replicate), ensuring each device holds the rank-changed arrays before
    the next jitted train step retraces against them. No-op without an
    ambient mesh (single-device training and unit tests)."""
    mesh = mesh or current_mesh()
    if mesh is None:
        return tree
    return jax.device_put(tree, tree_sharding(tree, mesh, spec_for_param))
