"""Logical→physical sharding rules.

Parallelism map (DESIGN.md §4):
  * batch            -> ("pod", "data")     DP across pods, DP/FSDP within
  * weight d_model / d_ff "other" dim -> "data"   (FSDP storage sharding)
  * heads / d_ff compute dim          -> "model"  (TP)
  * MoE expert dim                    -> "model"  (EP, via shard_map)
  * KV-cache sequence dim             -> "model"  (sequence-sharded decode
                                         attention — GQA kv-heads are often
                                         < |model|, so we shard S instead)
  * long-context activations          -> sequence over "data" when
                                         global_batch < |data|

``maybe_shard`` degrades gracefully: axes missing from the ambient mesh are
dropped, and any dim not divisible by its axis-size product falls back to
replicated — so e.g. paligemma's 8 heads on a 16-way model axis simply stay
replicated instead of erroring (documented trade-off; the dry-run output
shows the real placement).
"""
from __future__ import annotations

import math

import jax
import numpy as np
from jax.interpreters import pxla
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def current_mesh() -> Mesh | None:
    mesh = pxla.thread_resources.env.physical_mesh
    if mesh is None or mesh.empty:
        return None
    return mesh


# Sequence-parallel sentinel: ``SEQ`` in a spec resolves to the configured
# sequence axis (default: none -> replicated). The dry-run / launcher enables
# SP for train/prefill shapes via ``set_seq_axis("model")`` — activations'
# T dim is then sharded on the residual stream and gathered inside
# attention/FFN (Korthikanti-style SP, expressed purely as constraints).
SEQ = "__seq__"
_seq_axis: list = [None]


def set_seq_axis(axis: str | None) -> None:
    _seq_axis[0] = axis


def get_seq_axis() -> str | None:
    return _seq_axis[0]


def _resolve(entry):
    if entry == SEQ:
        return _seq_axis[0]
    if isinstance(entry, tuple):
        resolved = tuple(_seq_axis[0] if e == SEQ else e for e in entry)
        return tuple(e for e in resolved if e is not None) or None
    return entry


def _filter_spec(mesh: Mesh, spec: P, shape) -> P:
    """Drop mesh axes that don't exist / don't divide the dim."""
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        entry = _resolve(entry)
        if entry is None:
            out.append(None)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        names = tuple(n for n in names if n in mesh.axis_names)
        size = int(np.prod([mesh.shape[n] for n in names])) if names else 1
        if not names or size == 1 or dim % size != 0:
            out.append(None)
        else:
            out.append(names if len(names) > 1 else names[0])
    return P(*out)


def maybe_shard(x, *spec_entries) -> jax.Array:
    """with_sharding_constraint that no-ops without an ambient mesh and
    auto-filters invalid axes. Usable identically in CPU unit tests and in
    the 512-device dry-run."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = _filter_spec(mesh, P(*spec_entries), x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def batch_axes(mesh: Mesh | None = None) -> tuple:
    mesh = mesh or current_mesh()
    if mesh is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


BATCH = ("pod", "data")  # logical batch axes, filtered per-mesh by maybe_shard


# --------------------------------------------------------------------------
# parameter placement: pytree of PartitionSpec mirroring the params pytree.
# Conventions (leaf shapes, nb = stacked super-block dim first where present):
#   embed        (V, d)            -> P("model", "data")
#   in-proj      (nb, d_in, d_out) -> P(None, "data", "model")
#   out-proj     (nb, d_in, d_out) -> P(None, "model", "data")
#   experts      (nb, E, d, ff)    -> P(None, "model", None, "data")
#   vectors      (..., d)          -> replicated
# --------------------------------------------------------------------------

_IN_PROJ = {"wq", "wk", "wv", "wg", "wu", "w_in", "w_qkv", "w_up",
            "s_wg", "s_wu"}
_OUT_PROJ = {"wo", "wd", "w_out", "w_down", "s_wd"}
_EXPERT_IN = {"e_wg", "e_wu"}
_EXPERT_OUT = {"e_wd"}


def spec_for_param(path: str, shape) -> P:
    """Sharding spec from the parameter's name + rank (see conventions)."""
    leaf = path.split("/")[-1]
    nd = len(shape)
    if leaf in ("tok", "embed", "lm_head"):
        return P("model", "data") if nd == 2 else P()
    if leaf in _EXPERT_IN:
        return P(None, "model", None, "data") if nd == 4 else P("model", None, "data")
    if leaf in _EXPERT_OUT:
        return P(None, "model", "data", None) if nd == 4 else P("model", "data", None)
    if leaf in _IN_PROJ:
        return P(*( [None] * (nd - 2) + ["data", "model"] ))
    if leaf in _OUT_PROJ:
        return P(*( [None] * (nd - 2) + ["model", "data"] ))
    # norms, biases, conv kernels, gates, adapter cores: replicated
    return P()


def params_pspec(params) -> dict:
    """PartitionSpec pytree for a params pytree (path-based rules)."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]

    def name(kp):
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
        return "/".join(parts)

    specs = {name(kp): spec_for_param(name(kp), leaf.shape)
             for kp, leaf in flat}
    # rebuild as pytree
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(params),
        [spec_for_param(name(kp), leaf.shape) for kp, leaf in flat])


def cache_spec_for(path: str, shape) -> P:
    """Decode-cache placement: KV caches are sequence-sharded over "model"
    (kv-heads are often < |model|) and batch-sharded over ("pod","data");
    mamba state shards d_inner over "model"; recurrent xlstm scalars are
    tiny and replicate (see DESIGN.md §4).

    Cache leaves are stacked over super-blocks: shapes carry a leading nb
    dim (transformer.init_caches), hence the leading None below.
    """
    leaf = path.split("/")[-1]
    nd = len(shape)
    if leaf in ("k", "v") and nd == 5:       # (nb, B, S, KV, hd)
        return P(None, BATCH, "model", None, None)
    if leaf == "h" and nd == 4:              # (nb, B, di, ds) mamba state
        return P(None, BATCH, "model", None)
    if leaf == "conv" and nd == 4:           # (nb, B, K-1, di)
        return P(None, BATCH, None, "model")
    if nd >= 2:
        return P(None, BATCH)
    return P()


def _paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for kp, leaf in flat:
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        out.append(("/".join(parts), leaf))
    return out


def tree_sharding(tree, mesh: Mesh, spec_fn):
    """NamedSharding pytree from a (path, shape) -> PartitionSpec rule."""
    leaves = [NamedSharding(mesh, _filter_spec(mesh, spec_fn(p, leaf.shape),
                                               leaf.shape))
              for p, leaf in _paths(tree)]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(tree), leaves)


def params_sharding(params, mesh: Mesh):
    """NamedSharding pytree (filtered for divisibility) for device_put /
    in_shardings."""
    def one(path_spec, leaf):
        return NamedSharding(mesh, _filter_spec(mesh, path_spec, leaf.shape))
    return jax.tree_util.tree_map(one, params_pspec(params), params)
