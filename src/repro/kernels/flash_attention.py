"""Flash attention (forward AND backward) as Pallas TPU kernels.

Blockwise online-softmax attention: the (T, S) score matrix never
materializes in HBM — each (bq, bkv) tile lives in VMEM with running
(row-max m, row-sum l, output acc) scratch carried across the innermost
(sequential) KV grid dimension. This is the TPU-native replacement for the
pure-XLA chunked path in models/attention.py (same math; the XLA path is
what the CPU dry-run lowers, this kernel is the TPU fast path).

The backward is the standard FlashAttention two-pass recompute: the
forward stashes one per-row statistic (the log-sum-exp ``lse = m +
log(l)``), and two kernels rebuild each (bq, bkv) probability tile from it
on the fly — ``p = exp(s − lse)`` — so the backward never holds more than
one tile of scores either. ``_bwd_dq_kernel`` accumulates dq over KV
blocks; ``_bwd_dkv_kernel`` accumulates dk/dv over query blocks, with the
per-row correction term ``D = rowsum(dO ⊙ O)`` precomputed outside (an
O(T·d) contraction). Gradient tiles strictly above the causal diagonal are
skipped in both, mirroring the forward.

Strictly-above-diagonal tiles are skipped under causal masking (the
``pl.when`` guard), halving work for training/prefill.

Layout: (B·H, T, d) per head — GQA callers broadcast kv heads before the
call and reduce dk/dv over the head group after it (ops.py). d is kept
whole per tile (d ≤ 256 across the zoo). Validated in interpret mode
against kernels/ref.py::flash_attention_ref / flash_attention_bwd_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *rest, scale: float, causal: bool,
            bq: int, bkv: int, kv_steps: int, kv_len: int,
            with_stats: bool = False):
    if with_stats:
        lse_ref, m_ref, l_ref, acc_ref = rest
    else:
        lse_ref, (m_ref, l_ref, acc_ref) = None, rest
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = (j * bkv <= i * bq + bq - 1) if causal else True

    @pl.when(run)
    def _block():
        q = q_ref[0]                                   # (bq, d)
        k = k_ref[0]                                   # (bkv, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bkv)
        ki = j * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        valid = ki < kv_len                            # kv tile padding
        if causal:
            qi = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            valid &= qi >= ki
        s = jnp.where(valid, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(valid, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p.astype(v_ref.dtype), v_ref[0],
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == kv_steps - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        if with_stats:
            # per-row log-sum-exp: the one statistic the blockwise backward
            # needs to rebuild probability tiles as p = exp(s - lse)
            lse_ref[0] = m_ref[...] + jnp.log(l)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bkv",
                                             "interpret", "kv_len"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, bq: int = 256, bkv: int = 256,
                    interpret: bool = True, kv_len: int = 0) -> jnp.ndarray:
    """q: (BH, T, d); k, v: (BH, S, d) -> (BH, T, d).

    kv_len: number of *real* key/value rows (0 -> S). Callers that pad S up
    to a bkv multiple pass the unpadded length so the tail keys are masked
    out of the softmax (zero-padded keys would otherwise contribute
    exp(0) mass under non-causal attention).
    """
    bh, t, d = q.shape
    s_len = k.shape[1]
    assert t % bq == 0 and s_len % bkv == 0, (t, s_len, bq, bkv)
    grid = (bh, t // bq, s_len // bkv)
    kernel = functools.partial(
        _kernel, scale=d ** -0.5, causal=causal, bq=bq, bkv=bkv,
        kv_steps=grid[2], kv_len=kv_len or s_len)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bkv",
                                             "interpret", "kv_len"))
def flash_attention_fwd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, bq: int = 256, bkv: int = 256,
                        interpret: bool = True, kv_len: int = 0):
    """Stats-emitting forward for training: same kernel as
    ``flash_attention`` plus a second output carrying the per-row
    log-sum-exp — the residual the blockwise backward rebuilds probability
    tiles from. Returns ``(out (BH, T, d), lse (BH, T) f32)``."""
    bh, t, d = q.shape
    s_len = k.shape[1]
    assert t % bq == 0 and s_len % bkv == 0, (t, s_len, bq, bkv)
    grid = (bh, t // bq, s_len // bkv)
    kernel = functools.partial(
        _kernel, scale=d ** -0.5, causal=causal, bq=bq, bkv=bkv,
        kv_steps=grid[2], kv_len=kv_len or s_len, with_stats=True)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, d), q.dtype),
            jax.ShapeDtypeStruct((bh, t), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# blockwise backward (standard FlashAttention two-pass recompute): each
# kernel rebuilds its (bq, bkv) probability tile from the stashed lse —
#   p  = exp(q·kᵀ·scale − lse)
#   dv = Σ_i pᵀ·dO            dp = dO·vᵀ
#   ds = p ⊙ (dp − D)·scale   with D = rowsum(dO ⊙ O)  (precomputed)
#   dq = Σ_j ds·k             dk = Σ_i dsᵀ·q
# so no (T, S) tensor ever exists: dq accumulates across KV blocks
# (innermost grid dim), dk/dv accumulate across query blocks.
# ---------------------------------------------------------------------------


def _bwd_dq_kernel(q_ref, k_ref, v_ref, g_ref, lse_ref, delta_ref, dq_ref,
                   acc_ref, *, scale: float, causal: bool, bq: int,
                   bkv: int, kv_steps: int, kv_len: int):
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = (j * bkv <= i * bq + bq - 1) if causal else True

    @pl.when(run)
    def _block():
        q = q_ref[0]                                   # (bq, d)
        k = k_ref[0]                                   # (bkv, d)
        g = g_ref[0]                                   # (bq, d) = dO tile
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bkv)
        ki = j * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        valid = ki < kv_len
        if causal:
            qi = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            valid &= qi >= ki
        p = jnp.where(valid, jnp.exp(s - lse_ref[0][:, None]), 0.0)
        dp = jax.lax.dot_general(
            g, v_ref[0], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)          # (bq, bkv)
        ds = p * (dp - delta_ref[0][:, None]) * scale
        acc_ref[...] += jax.lax.dot(
            ds.astype(k.dtype), k, preferred_element_type=jnp.float32)

    @pl.when(j == kv_steps - 1)
    def _finish():
        dq_ref[0] = acc_ref[...].astype(dq_ref.dtype)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, g_ref, lse_ref, delta_ref, dk_ref,
                    dv_ref, dk_acc, dv_acc, *, scale: float, causal: bool,
                    bq: int, bkv: int, q_steps: int, kv_len: int):
    j, i = pl.program_id(1), pl.program_id(2)   # j: kv tile, i: q tile

    @pl.when(i == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    run = (i * bq + bq - 1 >= j * bkv) if causal else True

    @pl.when(run)
    def _block():
        q = q_ref[0]                                   # (bq, d)
        k = k_ref[0]                                   # (bkv, d)
        g = g_ref[0]                                   # (bq, d) = dO tile
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bkv)
        ki = j * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        valid = ki < kv_len
        if causal:
            qi = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            valid &= qi >= ki
        p = jnp.where(valid, jnp.exp(s - lse_ref[0][:, None]), 0.0)
        dv_acc[...] += jax.lax.dot_general(
            p.astype(g.dtype), g, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)          # (bkv, d)
        dp = jax.lax.dot_general(
            g, v_ref[0], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)          # (bq, bkv)
        ds = p * (dp - delta_ref[0][:, None]) * scale
        dk_acc[...] += jax.lax.dot_general(
            ds.astype(q.dtype), q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)          # (bkv, d)

    @pl.when(i == q_steps - 1)
    def _finish():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bkv",
                                             "interpret", "kv_len"))
def flash_attention_bwd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        o: jnp.ndarray, lse: jnp.ndarray, g: jnp.ndarray, *,
                        causal: bool = True, bq: int = 256, bkv: int = 256,
                        interpret: bool = True, kv_len: int = 0):
    """Blockwise dq/dk/dv. q, k, v as in ``flash_attention``; o/lse are the
    stashed forward output + per-row log-sum-exp; g is the output
    cotangent (BH, T, d). Returns (dq, dk, dv) in the input dtypes.

    Zero-padded query rows (callers pad T up to a bq multiple) carry zero
    cotangents, so they contribute nothing to dk/dv; keys at ``ki >=
    kv_len`` are masked out of every probability tile, so their dk/dv rows
    come out exactly zero.
    """
    bh, t, d = q.shape
    s_len = k.shape[1]
    assert t % bq == 0 and s_len % bkv == 0, (t, s_len, bq, bkv)
    kv_len = kv_len or s_len
    # per-row correction D = rowsum(dO ⊙ O): O(T·d), stays out of kernels
    delta = jnp.sum(g.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
    lse = lse.astype(jnp.float32)
    common = dict(scale=d ** -0.5, causal=causal, bq=bq, bkv=bkv,
                  kv_len=kv_len)
    qspec = pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0))
    kspec = pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0))
    rowspec = pl.BlockSpec((1, bq), lambda b, i, j: (b, i))
    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, kv_steps=s_len // bkv, **common),
        grid=(bh, t // bq, s_len // bkv),
        in_specs=[qspec, kspec, kspec, qspec, rowspec, rowspec],
        out_specs=qspec,
        out_shape=jax.ShapeDtypeStruct((bh, t, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, g, lse, delta)
    # dk/dv: kv tiles on the parallel dim, q tiles innermost (sequential)
    qspec2 = pl.BlockSpec((1, bq, d), lambda b, j, i: (b, i, 0))
    kspec2 = pl.BlockSpec((1, bkv, d), lambda b, j, i: (b, j, 0))
    rowspec2 = pl.BlockSpec((1, bq), lambda b, j, i: (b, i))
    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, q_steps=t // bq, **common),
        grid=(bh, s_len // bkv, t // bq),
        in_specs=[qspec2, kspec2, kspec2, qspec2, rowspec2, rowspec2],
        out_specs=[kspec2, kspec2],
        out_shape=[jax.ShapeDtypeStruct((bh, s_len, d), k.dtype),
                   jax.ShapeDtypeStruct((bh, s_len, d), v.dtype)],
        scratch_shapes=[pltpu.VMEM((bkv, d), jnp.float32),
                        pltpu.VMEM((bkv, d), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, g, lse, delta)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# decode-shaped variant: one query token per (batch·head) row against a
# fixed-width KV cache, masked by a per-row position (the serving engine's
# continuous-batching slots each sit at their own cache position).
# ---------------------------------------------------------------------------


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale: float, bkv: int, kv_steps: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[0]                                   # this slot's position
    run = j * bkv <= pos                               # skip future kv tiles

    @pl.when(run)
    def _block():
        q = q_ref[0]                                   # (1, d)
        k = k_ref[0, :, 0]                             # (bkv, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (1, bkv)
        ki = j * bkv + jax.lax.broadcasted_iota(jnp.int32, (1, bkv), 1)
        valid = ki <= pos                              # cache cells written
        s = jnp.where(valid, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p.astype(v_ref.dtype), v_ref[0, :, 0],
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == kv_steps - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bkv", "interpret"))
def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     pos: jnp.ndarray, *, bkv: int = 256,
                     interpret: bool = True) -> jnp.ndarray:
    """q: (B, H, d); k, v: (B, S, KV, d) NATIVE cache layout; pos: (B,)
    int32 -> (B, H, d).

    Row b attends key/value cells [0, pos[b]] of its cache (pos is the cell
    the current token was just written to); KV tiles strictly beyond a
    slot's position are skipped entirely. The cache is read in its stored
    (B, S, KV, d) layout — the GQA broadcast happens in the index map
    (query head h reads kv head h // G), so the decode loop never
    materializes a transposed or head-repeated copy of the cache.
    """
    b, h, d = q.shape
    s_len, kv = k.shape[1], k.shape[2]
    g = h // kv
    assert s_len % bkv == 0, (s_len, bkv)
    grid = (b, h, s_len // bkv)
    kernel = functools.partial(_decode_kernel, scale=d ** -0.5, bkv=bkv,
                               kv_steps=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bi, hi, j: (bi,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, d), lambda bi, hi, j: (bi, hi, 0)),
            pl.BlockSpec((1, bkv, 1, d),
                         lambda bi, hi, j: (bi, j, hi // g, 0)),
            pl.BlockSpec((1, bkv, 1, d),
                         lambda bi, hi, j: (bi, j, hi // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda bi, hi, j: (bi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(pos.astype(jnp.int32), q, k, v)
