"""Flash attention (forward) as a Pallas TPU kernel.

Blockwise online-softmax attention: the (T, S) score matrix never
materializes in HBM — each (bq, bkv) tile lives in VMEM with running
(row-max m, row-sum l, output acc) scratch carried across the innermost
(sequential) KV grid dimension. This is the TPU-native replacement for the
pure-XLA chunked path in models/attention.py (same math; the XLA path is
what the CPU dry-run lowers, this kernel is the TPU fast path).

Strictly-above-diagonal tiles are skipped under causal masking (the
``pl.when`` guard), halving work for training/prefill.

Layout: (B·H, T, d) per head — GQA callers broadcast kv heads before the
call (ops.py). d is kept whole per tile (d ≤ 256 across the zoo).
Validated in interpret mode against kernels/ref.py::flash_attention_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, bq: int, bkv: int, kv_steps: int,
            kv_len: int):
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = (j * bkv <= i * bq + bq - 1) if causal else True

    @pl.when(run)
    def _block():
        q = q_ref[0]                                   # (bq, d)
        k = k_ref[0]                                   # (bkv, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bkv)
        ki = j * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        valid = ki < kv_len                            # kv tile padding
        if causal:
            qi = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            valid &= qi >= ki
        s = jnp.where(valid, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(valid, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p.astype(v_ref.dtype), v_ref[0],
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == kv_steps - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bkv",
                                             "interpret", "kv_len"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, bq: int = 256, bkv: int = 256,
                    interpret: bool = True, kv_len: int = 0) -> jnp.ndarray:
    """q: (BH, T, d); k, v: (BH, S, d) -> (BH, T, d).

    kv_len: number of *real* key/value rows (0 -> S). Callers that pad S up
    to a bkv multiple pass the unpadded length so the tail keys are masked
    out of the softmax (zero-padded keys would otherwise contribute
    exp(0) mass under non-causal attention).
    """
    bh, t, d = q.shape
    s_len = k.shape[1]
    assert t % bq == 0 and s_len % bkv == 0, (t, s_len, bq, bkv)
    grid = (bh, t // bq, s_len // bkv)
    kernel = functools.partial(
        _kernel, scale=d ** -0.5, causal=causal, bq=bq, bkv=bkv,
        kv_steps=grid[2], kv_len=kv_len or s_len)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# decode-shaped variant: one query token per (batch·head) row against a
# fixed-width KV cache, masked by a per-row position (the serving engine's
# continuous-batching slots each sit at their own cache position).
# ---------------------------------------------------------------------------


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale: float, bkv: int, kv_steps: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[0]                                   # this slot's position
    run = j * bkv <= pos                               # skip future kv tiles

    @pl.when(run)
    def _block():
        q = q_ref[0]                                   # (1, d)
        k = k_ref[0, :, 0]                             # (bkv, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (1, bkv)
        ki = j * bkv + jax.lax.broadcasted_iota(jnp.int32, (1, bkv), 1)
        valid = ki <= pos                              # cache cells written
        s = jnp.where(valid, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p.astype(v_ref.dtype), v_ref[0, :, 0],
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == kv_steps - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bkv", "interpret"))
def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     pos: jnp.ndarray, *, bkv: int = 256,
                     interpret: bool = True) -> jnp.ndarray:
    """q: (B, H, d); k, v: (B, S, KV, d) NATIVE cache layout; pos: (B,)
    int32 -> (B, H, d).

    Row b attends key/value cells [0, pos[b]] of its cache (pos is the cell
    the current token was just written to); KV tiles strictly beyond a
    slot's position are skipped entirely. The cache is read in its stored
    (B, S, KV, d) layout — the GQA broadcast happens in the index map
    (query head h reads kv head h // G), so the decode loop never
    materializes a transposed or head-repeated copy of the cache.
    """
    b, h, d = q.shape
    s_len, kv = k.shape[1], k.shape[2]
    g = h // kv
    assert s_len % bkv == 0, (s_len, bkv)
    grid = (b, h, s_len // bkv)
    kernel = functools.partial(_decode_kernel, scale=d ** -0.5, bkv=bkv,
                               kv_steps=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bi, hi, j: (bi,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, d), lambda bi, hi, j: (bi, hi, 0)),
            pl.BlockSpec((1, bkv, 1, d),
                         lambda bi, hi, j: (bi, j, hi // g, 0)),
            pl.BlockSpec((1, bkv, 1, d),
                         lambda bi, hi, j: (bi, j, hi // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda bi, hi, j: (bi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(pos.astype(jnp.int32), q, k, v)
