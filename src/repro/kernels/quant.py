"""Symmetric int8 quantization of the frozen serving state (DESIGN.md §8).

MetaTT freezes the base transformer by construction — only the tiny shared
TT is trained — so in the decode hot path the base weight matrices and the
KV cache are pure *read-only bandwidth*, and paged decode (DESIGN.md §7)
is bandwidth-bound. This module quantizes exactly that frozen half:

  * ``quantize_int8`` / ``dequantize_int8`` — symmetric per-output-channel
    (optionally K-group-wise) int8 of a weight matrix ``(..., K, N)``.
    One f32 scale per output channel (``group_size=0``) or per
    ``group_size``-row K group: ``scale = amax / 127`` over the group,
    ``q = clip(round(w / scale), ±127)``. Max dequant error is scale/2
    per element (tests/test_quant.py pins the bound).
  * ``quantize_linear`` / ``is_quantized`` / ``dequantize`` — the packed
    ``{"q8": int8, "scale": f32}`` container that replaces a raw weight
    leaf in the base pytree. The container is a plain pytree (jit-able,
    scan-sliceable: the transformer scan slices its leading ``nb`` axis
    exactly like a raw weight) and the group size is derived from shapes,
    so no static metadata rides along.
  * ``quantize_base`` — walks a transformer base pytree and packs the
    matmul hot-path leaves (attention wq/wk/wv/wo, dense-FFN wu/wd/wg);
    embeddings, norms, routers and MoE expert banks stay full precision.
    The serving engine calls this ONCE at construction.
  * ``quantize_kv`` — per-cell (token × kv-head) activation quantization
    for the int8 paged KV cache: amax/127 over head_dim at write time.
    Per-cell (not per-whole-page) scales are deliberate: pages fill
    incrementally inside the jitted decode loop, so a page-wide scale
    would have to re-scale already-written cells — per-cell scales make
    every write independent, and they live in the SAME paged block layout
    as the cells, so prefix sharing and copy-on-write round-trip the
    quantized representation exactly (serving/block_manager.py owns the
    blocks either way).

The trained adapter factors are NEVER quantized — the fused w8a16 kernels
(kernels/tt_linear.py) dequantize the int8 base tile in-register and apply
the full-precision rank-r TT epilogue while the tile is still in VMEM.
"""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp

#: container marker key — a dict leaf carrying this key is a packed weight
QKEY = "q8"

#: weight-dict keys eligible for base quantization (the dense matmul hot
#: path). MoE expert banks (e_*/s_*), routers, norms, embeddings, mamba /
#: xlstm state mixers stay fp — they are either not (K, N) matmuls or not
#: servable by the paged engine anyway.
_QUANT_KEYS = frozenset({"wq", "wk", "wv", "wo", "wu", "wd", "wg"})

_EPS = 1e-8


def quantize_int8(w: jnp.ndarray, group_size: int = 0):
    """w: (..., K, N) -> (q int8 (..., K, N), scale f32 (..., G, N)).

    ``group_size=0`` is per-output-channel (G = 1, amax over all of K);
    otherwise K splits into G = K // group_size groups with one scale row
    each (``group_size`` must divide K — callers fall back to per-channel
    when it does not).
    """
    *lead, k, n = w.shape
    if group_size:
        if k % group_size:
            raise ValueError(
                f"group_size={group_size} does not divide K={k}")
        g = k // group_size
    else:
        g = 1
    wf = w.astype(jnp.float32).reshape(*lead, g, k // g, n)
    amax = jnp.max(jnp.abs(wf), axis=-2)                    # (..., G, N)
    scale = jnp.maximum(amax, _EPS) / 127.0
    q = jnp.clip(jnp.round(wf / scale[..., :, None, :]), -127, 127)
    return q.astype(jnp.int8).reshape(*lead, k, n), scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Inverse of ``quantize_int8`` (up to the rounding error): f32 out."""
    *lead, k, n = q.shape
    g = scale.shape[-2]
    qf = q.astype(jnp.float32).reshape(*lead, g, k // g, n)
    return (qf * scale[..., :, None, :]).reshape(*lead, k, n)


def quantize_linear(w: jnp.ndarray, group_size: int = 0) -> dict:
    """Pack one weight leaf into the ``{"q8", "scale"}`` container."""
    q, scale = quantize_int8(w, group_size)
    return {QKEY: q, "scale": scale}


def is_quantized(w: Any) -> bool:
    return isinstance(w, dict) and QKEY in w


def dequantize(w: dict, dtype=jnp.float32) -> jnp.ndarray:
    """Unpack a ``{"q8", "scale"}`` container to a dense matrix."""
    return dequantize_int8(w[QKEY], w["scale"]).astype(dtype)


def quantize_base(base: dict, *, group_size: int = 0) -> dict:
    """Pack every matmul hot-path leaf of a transformer base pytree.

    Returns a NEW pytree (the input is not mutated) in which attention
    wq/wk/wv/wo and dense-FFN wu/wd/wg leaves — shaped ``(nb, K, N)``,
    stacked over super-blocks — are replaced by ``{"q8", "scale"}``
    containers; everything else (embeddings, norms, final norm, MoE
    banks) passes through untouched. Matrices whose K the group size
    does not divide quantize per-output-channel instead.
    """
    def qdict(d: dict) -> dict:
        out = {}
        for key, v in d.items():
            if key in _QUANT_KEYS and hasattr(v, "ndim") and v.ndim == 3:
                gs = group_size if (group_size
                                    and v.shape[-2] % group_size == 0) else 0
                out[key] = quantize_linear(v, group_size=gs)
            else:
                out[key] = v
        return out

    def qblocks(blocks: list) -> list:
        out = []
        for blk in blocks:
            nb = {}
            for name, sub in blk.items():
                nb[name] = (qdict(sub) if name in ("mixer", "ffn", "xattn")
                            else sub)
            out.append(nb)
        return out

    out = dict(base)
    out["blocks"] = qblocks(base["blocks"])
    if "enc_blocks" in base:
        out["enc_blocks"] = qblocks(base["enc_blocks"])
    return out


def quantize_kv(x: jnp.ndarray):
    """Per-cell KV quantization: x (..., d) -> (int8 (..., d), f32 (...)).

    One scale per cache cell per kv head (amax over head_dim). All-zero
    vectors quantize to q=0 with the epsilon scale — they dequantize back
    to exact zero.
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.maximum(amax, _EPS) / 127.0
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale
