"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.quant import dequantize_int8


def tt_linear_ref(x: jnp.ndarray, w: jnp.ndarray, a: jnp.ndarray,
                  b: jnp.ndarray, alpha: float = 1.0) -> jnp.ndarray:
    """y = x·W + α·(x·A)·B  — the adapted linear layer (paper Eq. (5) with
    the middle cores pre-merged into A = G1·G2[l]·G3[m], B = G4)."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    p = jnp.dot(x, a, preferred_element_type=jnp.float32)
    y = y + alpha * jnp.dot(p, b.astype(p.dtype),
                            preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


def tt_linear_q_ref(x: jnp.ndarray, wq: jnp.ndarray, scale: jnp.ndarray,
                    a: jnp.ndarray, b: jnp.ndarray,
                    alpha: float = 1.0) -> jnp.ndarray:
    """w8a16 oracle: dequantize the int8 base (per-channel or group-wise
    scales — quant.py owns the layout rule) then run the fp adapted
    linear. The Pallas twin dequantizes the W tile in-register; same
    math, same f32 accumulation."""
    return tt_linear_ref(x, dequantize_int8(wq, scale), a, b, alpha)


def tt_linear_batched_a_q_ref(x: jnp.ndarray, wq: jnp.ndarray,
                              scale: jnp.ndarray, a: jnp.ndarray,
                              b: jnp.ndarray,
                              alpha: float = 1.0) -> jnp.ndarray:
    """Per-row-A (slot-task-routed) w8a16 oracle. x: (S, K); a: (S, K, r)."""
    w = dequantize_int8(wq, scale)
    p = jnp.einsum("sk,skr->sr", x, a.astype(x.dtype),
                   preferred_element_type=jnp.float32)
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    y = y + alpha * jnp.dot(p, b.astype(p.dtype),
                            preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True) -> jnp.ndarray:
    """q,k,v: (B, H, T, d) -> (B, H, T, d), softmax in f32."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        t, s_len = q.shape[2], k.shape[2]
        mask = jnp.arange(t)[:, None] >= jnp.arange(s_len)[None, :]
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)


def flash_attention_bwd_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                            o: jnp.ndarray, lse: jnp.ndarray,
                            g: jnp.ndarray, causal: bool = True):
    """Recompute-from-lse twin of the blockwise flash backward.

    q, o, g: (B, H, T, d); k, v: (B, H, S, d); lse: (B, H, T) f32 per-row
    log-sum-exp stashed by the forward. Returns (dq, dk, dv) via the same
    math the Pallas kernels run — p = exp(s − lse), ds = p·(dp − D)·scale
    with D = rowsum(g ⊙ o) — including the dtype casts of p/ds back to the
    operand dtype before each contraction, so bf16 parity with the kernel
    is exact rather than merely close.
    """
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        t, s_len = q.shape[2], k.shape[2]
        mask = jnp.arange(t)[:, None] >= jnp.arange(s_len)[None, :]
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jnp.exp(s - lse.astype(jnp.float32)[..., None])      # (B,H,T,S)
    delta = jnp.sum(g.astype(jnp.float32) * o.astype(jnp.float32),
                    axis=-1)                                  # (B,H,T)
    dv = jnp.einsum("bhqk,bhqd->bhkd", p.astype(g.dtype), g,
                    preferred_element_type=jnp.float32)
    dp = jnp.einsum("bhqd,bhkd->bhqk", g, v,
                    preferred_element_type=jnp.float32)
    ds = p * (dp - delta[..., None]) * scale
    dq = jnp.einsum("bhqk,bhkd->bhqd", ds.astype(k.dtype), k,
                    preferred_element_type=jnp.float32)
    dk = jnp.einsum("bhqk,bhqd->bhkd", ds.astype(q.dtype), q,
                    preferred_element_type=jnp.float32)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         pos: jnp.ndarray) -> jnp.ndarray:
    """Single-token cached decode. q: (BH, d); k, v: (BH, S, d);
    pos: (BH,) — each row attends cache cells [0, pos[row]]."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bd,bsd->bs", q, k,
                   preferred_element_type=jnp.float32) * scale
    mask = jnp.arange(k.shape[1])[None, :] <= pos[:, None]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bs,bsd->bd", p.astype(v.dtype), v)


def paged_decode_attention_ref(q: jnp.ndarray, k_cache: jnp.ndarray,
                               v_cache: jnp.ndarray, tables: jnp.ndarray,
                               pos: jnp.ndarray) -> jnp.ndarray:
    """Block-table attention over a paged KV cache (the Pallas twin's
    allclose target).

    q: (B, C, H, d) — C co-batched query tokens per slot, slot b's query c
    at absolute position pos[b] + c; k_cache, v_cache: (N, page, KV, d)
    flat block pools; tables: (B, P) int32 logical-page -> physical-block
    map (entries may be an out-of-range sentinel: the gather clamps and
    the position mask hides whatever it reads); pos: (B,) base positions.
    Returns (B, C, H, d): query c attends cache cells [0, pos[b] + c].
    """
    b, c, h, d = q.shape
    n, page, kv, _ = k_cache.shape
    g = h // kv
    tbl = jnp.clip(tables, 0, n - 1)
    # (B, P, page, KV, d) -> (B, S, KV, d) with S = P * page cells in
    # logical-position order — same valid set, same order as a dense cache
    kg = k_cache[tbl].reshape(b, -1, kv, d)
    vg = v_cache[tbl].reshape(b, -1, kv, d)
    if g > 1:
        kg = jnp.repeat(kg, g, axis=2)
        vg = jnp.repeat(vg, g, axis=2)
    scale = d ** -0.5
    s = jnp.einsum("bchd,bshd->bhcs", q, kg,
                   preferred_element_type=jnp.float32) * scale
    ki = jnp.arange(kg.shape[1])
    qpos = pos[:, None] + jnp.arange(c)[None, :]            # (B, C)
    mask = ki[None, None, :] <= qpos[:, :, None]            # (B, C, S)
    s = jnp.where(mask[:, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhcs,bshd->bchd", p.astype(vg.dtype), vg)
