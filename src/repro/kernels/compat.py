"""Version-compat shims for Pallas TPU API drift.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` upstream;
depending on the installed JAX only one of the two exists. Kernels import
``CompilerParams`` from here so they compile against either version.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")
