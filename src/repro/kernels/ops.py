"""Public jit'd wrappers for the Pallas kernels.

These handle the gap between model-land and kernel-land: leading batch dims,
tile padding on EVERY dim (M, N, K, r for the linear kernels; T, S for the
attention kernels — GeGLU d_ff, odd vocab slices and non-128-multiple
sequence lengths all pad up and slice back down), GQA head broadcast, dtype
policy, and backend dispatch — ``backend="auto"`` uses the Pallas kernel on
TPU and the pure-jnp oracle elsewhere (the CPU container runs kernels only
under interpret=True, which is for correctness tests, not speed).

Model code should not call this module directly: ``kernels/dispatch.py``
wraps these entry points behind a ``KernelPolicy`` (DESIGN.md §5) and is the
single seam the model/serving stack routes through.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.flash_attention import decode_attention as _decode_attn
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.flash_attention import flash_attention_bwd as _flash_bwd
from repro.kernels.flash_attention import flash_attention_fwd as _flash_fwd
from repro.kernels.paged_attention import (
    paged_decode_attention as _paged_attn)
from repro.kernels.tt_linear import tt_linear as _tt_linear
from repro.kernels.tt_linear import tt_linear_batched_a as _tt_linear_ba
from repro.kernels.tt_linear import tt_linear_batched_a_w8 as _tt_ba_w8
from repro.kernels.tt_linear import tt_linear_w8 as _tt_linear_w8


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _use_ref(backend: str) -> bool:
    return backend == "ref" or (backend == "auto" and not _on_tpu())


def _interp(interpret: bool | None) -> bool:
    return (not _on_tpu()) if interpret is None else interpret


def _pick_tile(size: int, override: int, prefer: tuple) -> int:
    """Largest preferred tile that divides ``size``; otherwise the smallest
    preferred tile (the caller pads up to a multiple of it)."""
    if override:
        return override
    for t in prefer:
        if size % t == 0:
            return t
    return prefer[-1]


def _pad_to(x, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def tt_linear(x, w, a, b, *, alpha: float = 1.0, backend: str = "auto",
              interpret: bool | None = None, bm: int = 0, bn: int = 0,
              bk: int = 0):
    """Adapted linear layer y = x·W + α·(x·A)·B with arbitrary leading dims.

    x: (..., K); w: (K, N); a: (K, r); b: (r, N). No dim needs to be a tile
    multiple: M/N/K pad with zeros (exact — zero rows/cols contribute
    nothing) and the output slices back to (..., N).
    """
    if _use_ref(backend):
        return _ref.tt_linear_ref(x, w, a, b, alpha)
    lead = x.shape[:-1]
    k_dim = x.shape[-1]
    n_dim = w.shape[1]
    xf = x.reshape(-1, k_dim)
    bm = _pick_tile(xf.shape[0], bm, (256, 128))
    bn = _pick_tile(n_dim, bn, (256, 128))
    bk = _pick_tile(k_dim, bk, (512, 256, 128))
    xf, m0 = _pad_to(xf, 0, bm)
    xf, _ = _pad_to(xf, 1, bk)
    w, _ = _pad_to(w, 0, bk)
    w, n0 = _pad_to(w, 1, bn)
    a, _ = _pad_to(a, 0, bk)
    a, _ = _pad_to(a, 1, 128)            # r is kept whole per tile
    b, _ = _pad_to(b, 0, 128)
    b, _ = _pad_to(b, 1, bn)
    y = _tt_linear(xf, w, a, b, alpha=alpha, bm=bm, bn=bn, bk=bk,
                   interpret=_interp(interpret))
    return y[:m0, :n0].reshape(*lead, n0)


def _quant_tiles(k_dim: int, n_dim: int, scale, bn: int, bk: int):
    """Resolve (bn, bk, per_channel) for a w8 call: group-wise scales pin
    bk to the group size (one scale row per K tile; quantize_base
    guarantees the group divides K)."""
    groups = scale.shape[0]
    per_channel = groups == 1
    bn = _pick_tile(n_dim, bn, (256, 128))
    if per_channel:
        bk = _pick_tile(k_dim, bk, (512, 256, 128))
    else:
        bk = k_dim // groups
    return bn, bk, per_channel


def tt_linear_q(x, wq, scale, a, b, *, alpha: float = 1.0,
                backend: str = "auto", interpret: bool | None = None,
                bm: int = 0, bn: int = 0, bk: int = 0):
    """w8a16 adapted linear: int8 base W + f32 scales (kernels/quant.py),
    fp adapter factors. Same padding contract as ``tt_linear`` (padded K
    rows of the int8 W are zero, so they contribute nothing under any
    scale; padded scale columns are sliced off with the output).
    """
    if _use_ref(backend):
        return _ref.tt_linear_q_ref(x, wq, scale, a, b, alpha)
    lead = x.shape[:-1]
    k_dim = x.shape[-1]
    n_dim = wq.shape[1]
    xf = x.reshape(-1, k_dim)
    bm = _pick_tile(xf.shape[0], bm, (256, 128))
    bn, bk, _ = _quant_tiles(k_dim, n_dim, scale, bn, bk)
    xf, m0 = _pad_to(xf, 0, bm)
    xf, _ = _pad_to(xf, 1, bk)
    wq, _ = _pad_to(wq, 0, bk)
    wq, n0 = _pad_to(wq, 1, bn)
    scale, _ = _pad_to(scale, 1, bn)
    a, _ = _pad_to(a, 0, bk)
    a, _ = _pad_to(a, 1, 128)            # r is kept whole per tile
    b, _ = _pad_to(b, 0, 128)
    b, _ = _pad_to(b, 1, bn)
    y = _tt_linear_w8(xf, wq, scale, a, b, alpha=alpha, bm=bm, bn=bn,
                      bk=bk, interpret=_interp(interpret))
    return y[:m0, :n0].reshape(*lead, n0)


def tt_linear_batched_a_q(x, wq, scale, a, b, *, alpha: float = 1.0,
                          backend: str = "auto",
                          interpret: bool | None = None, bm: int = 0,
                          bn: int = 0, bk: int = 0):
    """w8a16 per-row-A adapted linear (the decode-slot task-routing form
    of ``tt_linear_batched_a`` over an int8 base)."""
    squeeze = x.ndim == 3
    if squeeze:
        assert x.shape[1] == 1, ("batched-A fusion is decode-shaped "
                                 "(one token per slot)", x.shape)
        x = x[:, 0]
    if _use_ref(backend):
        y = _ref.tt_linear_batched_a_q_ref(x, wq, scale, a, b, alpha)
        return y[:, None] if squeeze else y
    k_dim, n_dim = wq.shape
    bm = _pick_tile(x.shape[0], bm, (8,))
    bn, bk, _ = _quant_tiles(k_dim, n_dim, scale, bn, bk)
    x, m0 = _pad_to(x, 0, bm)
    x, _ = _pad_to(x, 1, bk)
    wq, _ = _pad_to(wq, 0, bk)
    wq, n0 = _pad_to(wq, 1, bn)
    scale, _ = _pad_to(scale, 1, bn)
    a, _ = _pad_to(a, 0, bm)
    a, _ = _pad_to(a, 1, bk)
    a, _ = _pad_to(a, 2, 128)
    b, _ = _pad_to(b, 0, 128)
    b, _ = _pad_to(b, 1, bn)
    y = _tt_ba_w8(x, wq, scale, a, b, alpha=alpha, bm=bm, bn=bn, bk=bk,
                  interpret=_interp(interpret))
    y = y[:m0, :n0]
    return y[:, None] if squeeze else y


def tt_linear_batched_a(x, w, a, b, *, alpha: float = 1.0,
                        backend: str = "auto",
                        interpret: bool | None = None, bm: int = 0,
                        bn: int = 0, bk: int = 0):
    """Per-row-A adapted linear: y[s] = x[s]·W + α·(x[s]·A[s])·B.

    x: (S, K) or (S, 1, K); w: (K, N); a: (S, K, r); b: (r, N). The leading
    S axis is the serving engine's slot axis — A[s] was gathered from the
    (4+1)d task axis by slot s's task id, so a mixed-task decode batch runs
    as ONE fused kernel call.
    """
    squeeze = x.ndim == 3
    if squeeze:
        assert x.shape[1] == 1, ("batched-A fusion is decode-shaped "
                                 "(one token per slot)", x.shape)
        x = x[:, 0]
    if _use_ref(backend):
        p = jnp.einsum("sk,skr->sr", x, a.astype(x.dtype),
                       preferred_element_type=jnp.float32)
        y = jnp.dot(x, w, preferred_element_type=jnp.float32)
        y = (y + alpha * jnp.dot(p, b.astype(p.dtype),
                                 preferred_element_type=jnp.float32)
             ).astype(x.dtype)
        return y[:, None] if squeeze else y
    k_dim, n_dim = w.shape
    bm = _pick_tile(x.shape[0], bm, (8,))
    bn = _pick_tile(n_dim, bn, (256, 128))
    bk = _pick_tile(k_dim, bk, (512, 256, 128))
    x, m0 = _pad_to(x, 0, bm)
    x, _ = _pad_to(x, 1, bk)
    w, _ = _pad_to(w, 0, bk)
    w, n0 = _pad_to(w, 1, bn)
    a, _ = _pad_to(a, 0, bm)
    a, _ = _pad_to(a, 1, bk)
    a, _ = _pad_to(a, 2, 128)
    b, _ = _pad_to(b, 0, 128)
    b, _ = _pad_to(b, 1, bn)
    y = _tt_linear_ba(x, w, a, b, alpha=alpha, bm=bm, bn=bn, bk=bk,
                      interpret=_interp(interpret))
    y = y[:m0, :n0]
    return y[:, None] if squeeze else y


def flash_attention(q, k, v, *, causal: bool = True, backend: str = "auto",
                    interpret: bool | None = None, bq: int = 0,
                    bkv: int = 0):
    """GQA flash attention. q: (B, T, H, d); k, v: (B, S, KV, d).

    KV heads are broadcast to the query-head count before the per-head
    kernel call (zero-copy under XLA when G == 1). T and S need not be tile
    multiples: both pad up and the padded keys are masked inside the kernel
    (``kv_len``), padded query rows are sliced off.
    """
    if _use_ref(backend):
        g = q.shape[2] // k.shape[2]
        kk = jnp.repeat(k, g, axis=2) if g > 1 else k
        vv = jnp.repeat(v, g, axis=2) if g > 1 else v
        out = _ref.flash_attention_ref(
            q.transpose(0, 2, 1, 3), kk.transpose(0, 2, 1, 3),
            vv.transpose(0, 2, 1, 3), causal=causal)
        return out.transpose(0, 2, 1, 3)
    b, t, h, d = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    qh = q.transpose(0, 2, 1, 3).reshape(b * h, t, d)
    kh = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, s, d)
    vh = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, s, d)
    bq = _pick_tile(t, bq, (256, 128))
    bkv = _pick_tile(s, bkv, (256, 128))
    qh, t0 = _pad_to(qh, 1, bq)
    kh, s0 = _pad_to(kh, 1, bkv)
    vh, _ = _pad_to(vh, 1, bkv)
    out = _flash(qh, kh, vh, causal=causal, bq=bq, bkv=bkv,
                 interpret=_interp(interpret), kv_len=s0)
    return out[:, :t0].reshape(b, h, t0, d).transpose(0, 2, 1, 3)


def flash_attention_fwd(q, k, v, *, causal: bool = True,
                        backend: str = "auto", interpret: bool | None = None,
                        bq: int = 0, bkv: int = 0):
    """Stats-emitting GQA flash forward for training.

    Same layout contract as ``flash_attention`` — q: (B, T, H, d); k, v:
    (B, S, KV, d) — but also returns the per-row log-sum-exp residual
    ``lse`` with shape (B, H, T) f32, which ``flash_attention_bwd`` needs
    to rebuild probability tiles without ever materializing (T, S).
    """
    if _use_ref(backend):
        g = q.shape[2] // k.shape[2]
        kk = jnp.repeat(k, g, axis=2) if g > 1 else k
        vv = jnp.repeat(v, g, axis=2) if g > 1 else v
        qh = q.transpose(0, 2, 1, 3)
        kh = kk.transpose(0, 2, 1, 3)
        vh = vv.transpose(0, 2, 1, 3)
        scale = q.shape[-1] ** -0.5
        s = jnp.einsum("bhqd,bhkd->bhqk", qh, kh,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            t, s_len = q.shape[1], k.shape[1]
            mask = jnp.arange(t)[:, None] >= jnp.arange(s_len)[None, :]
            s = jnp.where(mask[None, None], s, -1e30)
        lse = jax.nn.logsumexp(s, axis=-1)
        p = jnp.exp(s - lse[..., None])
        out = jnp.einsum("bhqk,bhkd->bhqd", p.astype(vh.dtype), vh)
        return out.transpose(0, 2, 1, 3).astype(q.dtype), lse
    b, t, h, d = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    qh = q.transpose(0, 2, 1, 3).reshape(b * h, t, d)
    kh = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, s, d)
    vh = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, s, d)
    bq = _pick_tile(t, bq, (256, 128))
    bkv = _pick_tile(s, bkv, (256, 128))
    qh, t0 = _pad_to(qh, 1, bq)
    kh, s0 = _pad_to(kh, 1, bkv)
    vh, _ = _pad_to(vh, 1, bkv)
    out, lse = _flash_fwd(qh, kh, vh, causal=causal, bq=bq, bkv=bkv,
                          interpret=_interp(interpret), kv_len=s0)
    out = out[:, :t0].reshape(b, h, t0, d).transpose(0, 2, 1, 3)
    return out, lse[:, :t0].reshape(b, h, t0)


def _group_sum_kv(dx, b: int, kv: int, grp: int, s: int, d: int, dtype):
    """(B·H, S, d) query-head grads -> (B, S, KV, d): sum each GQA group
    of ``grp`` query heads back onto its shared KV head (the adjoint of
    the jnp.repeat broadcast), accumulated in f32."""
    dx = dx.astype(jnp.float32).reshape(b, kv, grp, s, d).sum(axis=2)
    return dx.transpose(0, 2, 1, 3).astype(dtype)          # (B, S, KV, d)


def flash_attention_bwd(q, k, v, o, lse, g, *, causal: bool = True,
                        backend: str = "auto", interpret: bool | None = None,
                        bq: int = 0, bkv: int = 0):
    """Blockwise GQA flash backward: (dq, dk, dv) from stashed residuals.

    q, o, g: (B, T, H, d); k, v: (B, S, KV, d); lse: (B, H, T) f32 from
    ``flash_attention_fwd``. dk/dv come back in KV-head layout — the GQA
    broadcast's adjoint sums each group of query heads in f32. Padded
    query rows carry a +1e30 lse sentinel so their recomputed probability
    tiles are exactly zero (no inf·0 NaNs); padded keys are masked by
    ``kv_len`` inside the kernels.
    """
    b, t, h, d = q.shape
    s, kv = k.shape[1], k.shape[2]
    grp = h // kv
    if _use_ref(backend):
        kk = jnp.repeat(k, grp, axis=2) if grp > 1 else k
        vv = jnp.repeat(v, grp, axis=2) if grp > 1 else v
        dq, dk, dv = _ref.flash_attention_bwd_ref(
            q.transpose(0, 2, 1, 3), kk.transpose(0, 2, 1, 3),
            vv.transpose(0, 2, 1, 3), o.transpose(0, 2, 1, 3), lse,
            g.transpose(0, 2, 1, 3), causal=causal)
        dq = dq.transpose(0, 2, 1, 3)
        dk = _group_sum_kv(dk.reshape(b * h, s, d), b, kv, grp, s, d,
                           k.dtype)
        dv = _group_sum_kv(dv.reshape(b * h, s, d), b, kv, grp, s, d,
                           v.dtype)
        return dq, dk, dv
    qh = q.transpose(0, 2, 1, 3).reshape(b * h, t, d)
    kh = jnp.repeat(k.transpose(0, 2, 1, 3), grp, axis=1).reshape(b * h, s, d)
    vh = jnp.repeat(v.transpose(0, 2, 1, 3), grp, axis=1).reshape(b * h, s, d)
    oh = o.transpose(0, 2, 1, 3).reshape(b * h, t, d)
    gh = g.transpose(0, 2, 1, 3).reshape(b * h, t, d)
    lseh = lse.reshape(b * h, t)
    bq = _pick_tile(t, bq, (256, 128))
    bkv = _pick_tile(s, bkv, (256, 128))
    qh, t0 = _pad_to(qh, 1, bq)
    oh, _ = _pad_to(oh, 1, bq)
    gh, _ = _pad_to(gh, 1, bq)
    pad = (-t) % bq
    if pad:
        # sentinel, not zero: exp(s - 1e30) == 0 keeps padded rows inert
        lseh = jnp.pad(lseh, ((0, 0), (0, pad)), constant_values=1e30)
    kh, s0 = _pad_to(kh, 1, bkv)
    vh, _ = _pad_to(vh, 1, bkv)
    dq, dk, dv = _flash_bwd(qh, kh, vh, oh, lseh, gh, causal=causal,
                            bq=bq, bkv=bkv, interpret=_interp(interpret),
                            kv_len=s0)
    dq = dq[:, :t0].reshape(b, h, t0, d).transpose(0, 2, 1, 3)
    dk = _group_sum_kv(dk[:, :s0], b, kv, grp, s0, d, k.dtype)
    dv = _group_sum_kv(dv[:, :s0], b, kv, grp, s0, d, v.dtype)
    return dq, dk, dv


def decode_attention(q, k, v, pos, *, backend: str = "auto",
                     interpret: bool | None = None, bkv: int = 0):
    """Cached single-token decode attention with per-row positions.

    q: (B, 1, H, d); k, v: (B, S, KV, d) full-width caches; pos: (B,) — row
    b attends cache cells [0, pos[b]]. Returns (B, 1, H, d).
    """
    b, t, h, d = q.shape
    assert t == 1, "decode attention expects a single query token"
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    if _use_ref(backend):
        qh = q[:, 0].reshape(b * h, d)
        kh = (jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1)
              .reshape(b * h, s, d))
        vh = (jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1)
              .reshape(b * h, s, d))
        out = _ref.decode_attention_ref(qh, kh, vh,
                                        jnp.repeat(pos, h))
        return out.reshape(b, 1, h, d)
    bkv = _pick_tile(s, bkv, (256, 128))
    # the kernel reads the cache in its native (B, S, KV, d) layout (GQA
    # broadcast happens in its index map), so the decode hot loop never
    # materializes a transposed / head-repeated cache copy; padded tail
    # cells sit beyond every row's position -> masked by pos
    kp, _ = _pad_to(k, 1, bkv)
    vp, _ = _pad_to(v, 1, bkv)
    out = _decode_attn(q[:, 0], kp, vp, pos, bkv=bkv,
                       interpret=_interp(interpret))
    return out[:, None]


def paged_decode_attention(q, k_cache, v_cache, tables, pos, *,
                           k_scale=None, v_scale=None,
                           backend: str = "auto",
                           interpret: bool | None = None):
    """Block-table attention over a paged KV cache (serving engine decode
    + in-loop chunked prefill).

    q: (B, C, H, d) — C query tokens per slot, query c of slot b at
    absolute position pos[b] + c; k_cache, v_cache: (N, page, KV, d) flat
    block pools; tables: (B, P) int32 logical-page -> physical-block map
    (sentinel >= N marks unallocated pages); pos: (B,). Returns
    (B, C, H, d): query c attends cache cells [0, pos[b] + c]. The Pallas
    kernel gathers blocks in its index map (scalar-prefetched table) so
    the gathered cache never materializes; the reference path gathers
    explicitly — same valid set, same logical order.

    k_scale/v_scale: optional (N, page, KV) per-cell scale pools for the
    int8 KV mode — the kernel dequantizes pages in-register; the
    reference path dequantizes the pool up front (same math).
    """
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (q.shape[0],))
    if _use_ref(backend):
        if k_scale is not None:
            k_cache = k_cache.astype(jnp.float32) * k_scale[..., None]
            v_cache = v_cache.astype(jnp.float32) * v_scale[..., None]
            return _ref.paged_decode_attention_ref(
                q, k_cache, v_cache, tables, pos).astype(q.dtype)
        return _ref.paged_decode_attention_ref(q, k_cache, v_cache,
                                               tables, pos)
    return _paged_attn(q, k_cache, v_cache, tables, pos, k_scale, v_scale,
                       interpret=_interp(interpret))
