"""Public jit'd wrappers for the Pallas kernels.

These handle the gap between model-land and kernel-land: leading batch dims,
tile padding, GQA head broadcast, dtype policy, and backend dispatch —
``backend="auto"`` uses the Pallas kernel on TPU and the pure-jnp oracle
elsewhere (the CPU container runs kernels only under interpret=True, which
is for correctness tests, not speed).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.tt_linear import tt_linear as _tt_linear


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def tt_linear(x, w, a, b, *, alpha: float = 1.0, backend: str = "auto",
              interpret: bool | None = None):
    """Adapted linear layer y = x·W + α·(x·A)·B with arbitrary leading dims.

    x: (..., K); w: (K, N); a: (K, r); b: (r, N).
    """
    if backend == "ref" or (backend == "auto" and not _on_tpu()):
        return _ref.tt_linear_ref(x, w, a, b, alpha)
    interp = (not _on_tpu()) if interpret is None else interpret
    lead = x.shape[:-1]
    k_dim = x.shape[-1]
    xf = x.reshape(-1, k_dim)
    bm = 256 if xf.shape[0] % 256 == 0 else 128
    xf, m0 = _pad_to(xf, 0, bm)
    rpad = (-a.shape[1]) % 128
    if rpad:
        a = jnp.pad(a, ((0, 0), (0, rpad)))
        b = jnp.pad(b, ((0, rpad), (0, 0)))
    y = _tt_linear(xf, w, a, b, alpha=alpha, bm=bm,
                   bn=min(256, w.shape[1]), bk=min(512, k_dim),
                   interpret=interp)
    return y[:m0].reshape(*lead, w.shape[1])


def flash_attention(q, k, v, *, causal: bool = True, backend: str = "auto",
                    interpret: bool | None = None):
    """GQA flash attention. q: (B, T, H, d); k, v: (B, S, KV, d).

    KV heads are broadcast to the query-head count before the per-head
    kernel call (zero-copy under XLA when G == 1).
    """
    if backend == "ref" or (backend == "auto" and not _on_tpu()):
        g = q.shape[2] // k.shape[2]
        kk = jnp.repeat(k, g, axis=2) if g > 1 else k
        vv = jnp.repeat(v, g, axis=2) if g > 1 else v
        out = _ref.flash_attention_ref(
            q.transpose(0, 2, 1, 3), kk.transpose(0, 2, 1, 3),
            vv.transpose(0, 2, 1, 3), causal=causal)
        return out.transpose(0, 2, 1, 3)
    interp = (not _on_tpu()) if interpret is None else interpret
    b, t, h, d = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    qh = q.transpose(0, 2, 1, 3).reshape(b * h, t, d)
    kh = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, s, d)
    vh = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, s, d)
    bq = 256 if t % 256 == 0 else 128
    bkv = 256 if s % 256 == 0 else 128
    out = _flash(qh, kh, vh, causal=causal, bq=bq, bkv=bkv,
                 interpret=interp)
    return out.reshape(b, h, t, d).transpose(0, 2, 1, 3)
