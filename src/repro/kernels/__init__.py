"""Pallas TPU kernels for the perf-critical compute paths.

tt_linear           — fused base-matmul + rank-r TT epilogue (paper Eq. (5))
tt_linear_batched_a — same fusion with a per-slot A operand (the serving
                      engine's (4+1)d task-routed decode batches)
flash_attention     — blockwise online-softmax attention (train/prefill)
decode_attention    — decode-shaped variant (one query token per row
                      against a position-masked KV cache)

Model code reaches these through ``repro.kernels.dispatch`` (KernelPolicy —
DESIGN.md §5); ``ops`` holds the padding/broadcast wrappers. Each kernel
has a pure-jnp oracle in ref.py and a shape/dtype-sweeping allclose test in
tests/test_kernels.py (interpret=True on CPU; TPU is the target).
"""
from repro.kernels import dispatch  # noqa: F401
from repro.kernels.dispatch import KernelPolicy, resolve  # noqa: F401
from repro.kernels.ops import (decode_attention, flash_attention,  # noqa: F401
                               tt_linear, tt_linear_batched_a)
