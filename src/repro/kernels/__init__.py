"""Pallas TPU kernels for the perf-critical compute paths.

tt_linear           — fused base-matmul + rank-r TT epilogue (paper Eq. (5))
tt_linear_batched_a — same fusion with a per-slot A operand (the serving
                      engine's (4+1)d task-routed decode batches)
tt_linear[_batched_a]_w8 — w8a16 twins: int8 frozen base dequantized
                      in-register, fp TT epilogue (quant.py, DESIGN.md §8)
flash_attention     — blockwise online-softmax attention (train/prefill)
decode_attention    — decode-shaped variant (one query token per row
                      against a position-masked KV cache)
paged_attention     — block-table paged-cache attention (fp or int8 KV
                      with per-cell scale pools)

Model code reaches these through ``repro.kernels.dispatch`` (KernelPolicy —
DESIGN.md §5); ``ops`` holds the padding/broadcast wrappers. Each kernel
has a pure-jnp oracle in ref.py and a shape/dtype-sweeping allclose test in
tests/test_kernels.py (interpret=True on CPU; TPU is the target).
"""
from repro.kernels import dispatch  # noqa: F401
from repro.kernels import quant  # noqa: F401
from repro.kernels.dispatch import KernelPolicy, resolve  # noqa: F401
from repro.kernels.ops import (decode_attention, flash_attention,  # noqa: F401
                               paged_decode_attention, tt_linear,
                               tt_linear_batched_a, tt_linear_batched_a_q,
                               tt_linear_q)
