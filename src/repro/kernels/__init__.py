"""Pallas TPU kernels for the perf-critical compute paths.

tt_linear        — fused base-matmul + rank-r TT epilogue (paper Eq. (5))
flash_attention  — blockwise online-softmax attention (train/prefill path)

Each has a pure-jnp oracle in ref.py and a shape/dtype-sweeping allclose
test in tests/test_kernels.py (interpret=True on CPU; TPU is the target).
"""
from repro.kernels.ops import flash_attention, tt_linear  # noqa: F401
