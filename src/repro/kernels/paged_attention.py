"""Paged decode/chunked-prefill attention as a Pallas TPU kernel.

vLLM-style paged attention for the serving engine's block/paged KV cache:
k/v live in one flat pool of ``(page, kv_heads, d)`` blocks and each slot
owns a **block table** mapping its logical pages to physical blocks. The
kernel never materializes the gathered cache — the table is a
scalar-prefetch operand and the *index map* does the gather, DMA-ing each
physical block straight into VMEM (``pltpu.PrefetchScalarGridSpec``; see
the guide's scalar-prefetch section). The GQA broadcast also happens in
the index map (query head h reads kv head h // G), like the dense decode
kernel.

Queries are a (C,)-token chunk per slot — C = 1 is plain decode; C > 1 is
the engine's in-loop chunked prefill, where prefill chunks and decode
tokens co-batch in one fixed-shape graph. Query c of slot b sits at
absolute position ``pos[b] + c`` and attends cache cells ``[0, pos[b]+c]``
(per-slot, per-query masking); pages strictly beyond a slot's window are
skipped entirely, and sentinel table entries (>= num_blocks: unallocated
logical pages) are clamped by the index map and hidden by the same mask.

int8 KV mode (DESIGN.md §8): when per-cell scale pools ``(N, page, KV)``
ride along, the k/v page tiles arrive int8 and dequantize in-register
(``q8 * scale``) right before the score / value dots — the fp cache never
exists in HBM, halving KV read traffic per decoded token. Scales follow
the SAME block gather as the cells (one extra (1, page, 1) tile per page).

Layout: blocks of (1, C, 1, d) queries per (slot, head) against
(1, page, 1, d) cache tiles; online-softmax scratch (m, l, acc) carried
across the sequential page grid axis, exactly like flash_attention.py.
Validated in interpret mode against kernels/ref.py::
paged_decode_attention_ref (its quantized leg dequantizes explicitly).

Under tensor-parallel serving (DESIGN.md §9) the kernel is invoked once
PER SHARD inside the engine's shard_map region, with the shard's local
head group and local kv-head-striped pools — H and KV below are then
H/tp and KV/tp; the grid/indexing logic is unchanged because every
(slot, head) program is independent and the block table (replicated) and
positions are shard-agnostic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG = -1e30


def _kernel(tbl_ref, pos_ref, q_ref, k_ref, v_ref, *rest, scale: float,
            page: int, chunk: int, kv_steps: int, quantized: bool):
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    b, j = pl.program_id(0), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[b]                       # slot base position (first query)
    # pages beyond the last query's position hold nothing attendable —
    # skip them (their table entries may be sentinels)
    run = j * page <= pos + chunk - 1

    @pl.when(run)
    def _block():
        q = q_ref[0, :, 0]                                 # (C, d)
        k = k_ref[0, :, 0]                                 # (page, d)
        if quantized:
            # in-register dequant: int8 cells × per-cell (token, kv-head)
            # scale — the fp page never exists outside VMEM
            q = q.astype(jnp.float32)
            k = k.astype(jnp.float32) * ks_ref[0, :, 0][:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale    # (C, page)
        ki = j * page + jax.lax.broadcasted_iota(jnp.int32, (chunk, page), 1)
        qi = pos + jax.lax.broadcasted_iota(jnp.int32, (chunk, page), 0)
        valid = ki <= qi                  # query c attends cells <= pos + c
        s = jnp.where(valid, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        v = v_ref[0, :, 0]
        if quantized:
            v = v.astype(jnp.float32) * vs_ref[0, :, 0][:, None]
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == kv_steps - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                           v_cache: jnp.ndarray, tables: jnp.ndarray,
                           pos: jnp.ndarray, k_scale=None, v_scale=None, *,
                           interpret: bool = True) -> jnp.ndarray:
    """q: (B, C, H, d); k_cache, v_cache: (N, page, KV, d) flat block
    pools; tables: (B, P) int32 block table (sentinel >= N for
    unallocated pages); pos: (B,) base positions -> (B, C, H, d).
    k_scale/v_scale: optional (N, page, KV) per-cell scale pools — when
    given the cache pools are int8 and dequantize in-register.

    Grid (B, H, P): the page axis is sequential (online softmax); the
    block table is scalar-prefetched so each page's physical block is
    chosen in the index map — the gathered cache never exists in HBM.
    """
    b, c, h, d = q.shape
    n, page, kv, _ = k_cache.shape
    g = h // kv
    p_tab = tables.shape[1]
    quantized = k_scale is not None
    grid = (b, h, p_tab)
    kernel = functools.partial(_kernel, scale=d ** -0.5, page=page,
                               chunk=c, kv_steps=p_tab, quantized=quantized)

    def kv_map(bi, hi, j, tbl, _pos):
        return (jnp.minimum(tbl[bi, j], n - 1), 0, hi // g, 0)

    def s_map(bi, hi, j, tbl, _pos):
        return (jnp.minimum(tbl[bi, j], n - 1), 0, hi // g)

    in_specs = [
        pl.BlockSpec((1, c, 1, d),
                     lambda bi, hi, j, tbl, _pos: (bi, 0, hi, 0)),
        pl.BlockSpec((1, page, 1, d), kv_map),
        pl.BlockSpec((1, page, 1, d), kv_map),
    ]
    operands = [q, k_cache, v_cache]
    if quantized:
        in_specs += [pl.BlockSpec((1, page, 1), s_map),
                     pl.BlockSpec((1, page, 1), s_map)]
        operands += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, c, 1, d),
                               lambda bi, hi, j, tbl, _pos: (bi, 0, hi, 0)),
        scratch_shapes=[
            pltpu.VMEM((c,), jnp.float32),
            pltpu.VMEM((c,), jnp.float32),
            pltpu.VMEM((c, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, c, h, d), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(tables.astype(jnp.int32), pos.astype(jnp.int32), *operands)
