"""Fused TT-adapted linear layer: Y = X·W + α·(X·A)·B  (one Pallas kernel).

This is the paper's serving/training hot spot (Eq. (5)) with the middle TT
cores pre-merged (A = G1·G2[l]·G3[m] ∈ R^{K×r}, B = G4 ∈ R^{r×N},
DESIGN.md §3). The unfused XLA path writes Y_base to HBM, reads it back,
adds the rank-r delta — 3 extra HBM round-trips of the (M, N) output.
Here the rank-r epilogue is applied while the output tile is still in VMEM:

  grid (M/bm, N/bn, K/bk), K innermost ("arbitrary" = sequential):
    acc   (bm, bn) f32 VMEM scratch — base matmul accumulator
    acc_p (bm, r)  f32 VMEM scratch — P = X·A accumulator (r ≤ 256)
    k-step:  acc += X_tile @ W_tile ;  acc_p += X_tile @ A_tile
    last k:  OUT = acc + α · acc_p @ B_tile     (epilogue, in VMEM)

Tile choices: bm/bn/bk multiples of the MXU native (128×128; 8-sublane f32
scratch). VMEM footprint = bm·bk + bk·bn + bm·bn·4 + (bm+bn)·r·4 + bk·r
≈ 1.3 MB at (256, 256, 512, r=64) — comfortably inside the ~16 MB/core VMEM
budget, leaving room for double buffering.

Validated in interpret mode on CPU against kernels/ref.py::tt_linear_ref
(tests/test_kernels.py sweeps shapes/dtypes/ranks).

w8a16 variants (``tt_linear_w8`` / ``tt_linear_batched_a_w8``, DESIGN.md
§8): the frozen base W arrives int8 with f32 per-output-channel (or
K-group-wise) scales from kernels/quant.py — half the weight HBM traffic
on the bandwidth-bound decode path — while the rank-r TT epilogue stays
full precision (the trained adapter never quantizes). Oracles:
kernels/ref.py::tt_linear_q_ref / tt_linear_batched_a_q_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _base_dot(x, w_ref, s_ref, per_channel):
    """One K-step of the base matmul. fp (s_ref None): dot in the operand
    dtype. w8a16 per-channel (scale constant over K): dot the raw int8
    values cast to the activation dtype (|q| <= 127 is exact in bf16) —
    the scale is applied once to the f32 accumulator in the epilogue.
    w8a16 group-wise (scale row indexed by the K tile; ops.py pins
    bk == group_size): dequantize the tile in-register to f32 first."""
    if s_ref is None:
        return jax.lax.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    if per_channel:
        return jax.lax.dot(x, w_ref[...].astype(x.dtype),
                           preferred_element_type=jnp.float32)
    wf = w_ref[...].astype(jnp.float32) * s_ref[...]
    return jax.lax.dot(x.astype(jnp.float32), wf,
                       preferred_element_type=jnp.float32)


def _epilogue_out(acc_ref, accp_ref, b_ref, s_ref, out_ref, alpha,
                  per_channel):
    """Shared epilogue: the f32 P = X·A accumulator feeds the delta GEMM
    in f32 — casting it down to b's storage dtype first (bf16) would
    throw away the accumulated precision right before the last matmul.
    The w8a16 per-channel scale multiplies the f32 base accumulator here,
    so the int8 MXU passes never see it; the rank-r TT epilogue is full
    fp either way — the adapter delta never loses precision to the
    quantization."""
    delta = jax.lax.dot(accp_ref[...], b_ref[...].astype(jnp.float32),
                        preferred_element_type=jnp.float32)
    acc = acc_ref[...]
    if s_ref is not None and per_channel:
        acc = acc * s_ref[...]
    out_ref[...] = (acc + alpha * delta).astype(out_ref.dtype)


def _kernel(x_ref, w_ref, *rest, alpha: float, k_steps: int,
            per_channel: bool | None = None):
    """Fused adapted linear. ``per_channel=None`` is the fp form (no
    scale operand); True/False is the w8a16 form with a (1, bn) scale
    block riding after W (per-output-channel / group-wise)."""
    if per_channel is None:
        s_ref, (a_ref, b_ref, out_ref, acc_ref, accp_ref) = None, rest
    else:
        s_ref, a_ref, b_ref, out_ref, acc_ref, accp_ref = rest
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        accp_ref[...] = jnp.zeros_like(accp_ref)

    x = x_ref[...]
    acc_ref[...] += _base_dot(x, w_ref, s_ref, per_channel)
    accp_ref[...] += jax.lax.dot(
        x, a_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _epilogue():
        _epilogue_out(acc_ref, accp_ref, b_ref, s_ref, out_ref, alpha,
                      per_channel)


def _batched_a_kernel(x_ref, w_ref, *rest, alpha: float, k_steps: int,
                      per_channel: bool | None = None):
    """Per-slot-A variant (the slot-gathered 4+1d task routing); same
    fp / w8a16 operand convention as ``_kernel``."""
    if per_channel is None:
        s_ref, (a_ref, b_ref, out_ref, acc_ref, accp_ref) = None, rest
    else:
        s_ref, a_ref, b_ref, out_ref, acc_ref, accp_ref = rest
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        accp_ref[...] = jnp.zeros_like(accp_ref)

    x = x_ref[...]
    acc_ref[...] += _base_dot(x, w_ref, s_ref, per_channel)
    # per-row A: row m of the tile contracts against its own (bk, r) slice
    accp_ref[...] += jax.lax.dot_general(
        x, a_ref[...], (((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _epilogue():
        _epilogue_out(acc_ref, accp_ref, b_ref, s_ref, out_ref, alpha,
                      per_channel)


@functools.partial(jax.jit, static_argnames=("alpha", "bm", "bn", "bk",
                                             "interpret"))
def tt_linear_batched_a(x: jnp.ndarray, w: jnp.ndarray, a: jnp.ndarray,
                        b: jnp.ndarray, *, alpha: float = 1.0, bm: int = 8,
                        bn: int = 256, bk: int = 512,
                        interpret: bool = True) -> jnp.ndarray:
    """x: (M, K); w: (K, N); a: (M, K, r); b: (r, N) -> (M, N).

    Same fusion as ``tt_linear`` but the A operand carries a leading slot
    axis — one low-rank factor per output row. This is the serving engine's
    decode shape: M is the continuous-batching slot axis and A[m] was
    gathered from the (4+1)d task axis by the slot's task id, so per-request
    task routing stays inside the one fused kernel. bm defaults to the f32
    sublane (8): decode Ms are slot counts, not token counts.
    """
    m, k_dim = x.shape
    _, n = w.shape
    r = a.shape[2]
    assert a.shape[:2] == (m, k_dim), (a.shape, x.shape)
    assert m % bm == 0 and n % bn == 0 and k_dim % bk == 0, \
        (m, n, k_dim, bm, bn, bk)
    grid = (m // bm, n // bn, k_dim // bk)

    kernel = functools.partial(_batched_a_kernel, alpha=alpha,
                               k_steps=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, bk, r), lambda i, j, k: (i, k, 0)),
            pl.BlockSpec((r, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, r), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w, a, b)


@functools.partial(jax.jit, static_argnames=("alpha", "bm", "bn", "bk",
                                             "interpret"))
def tt_linear_w8(x: jnp.ndarray, wq: jnp.ndarray, scale: jnp.ndarray,
                 a: jnp.ndarray, b: jnp.ndarray, *, alpha: float = 1.0,
                 bm: int = 256, bn: int = 256, bk: int = 512,
                 interpret: bool = True) -> jnp.ndarray:
    """w8a16 fused adapted linear. x: (M, K); wq: (K, N) int8; scale:
    (G, N) f32 (G == 1: per-output-channel, applied at the epilogue;
    G > 1: group-wise with bk == K // G, dequantized in-register); a, b:
    fp adapter factors as in ``tt_linear``.
    """
    m, k_dim = x.shape
    _, n = wq.shape
    r = a.shape[1]
    g = scale.shape[0]
    per_channel = g == 1
    assert m % bm == 0 and n % bn == 0 and k_dim % bk == 0, \
        (m, n, k_dim, bm, bn, bk)
    assert per_channel or k_dim // g == bk, (k_dim, g, bk)
    grid = (m // bm, n // bn, k_dim // bk)

    def s_map(i, j, k):
        return (0 if per_channel else k, j)

    kernel = functools.partial(_kernel, alpha=alpha, k_steps=grid[2],
                               per_channel=per_channel)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), s_map),
            pl.BlockSpec((bk, r), lambda i, j, k: (k, 0)),
            pl.BlockSpec((r, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, r), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, wq, scale, a, b)


@functools.partial(jax.jit, static_argnames=("alpha", "bm", "bn", "bk",
                                             "interpret"))
def tt_linear_batched_a_w8(x: jnp.ndarray, wq: jnp.ndarray,
                           scale: jnp.ndarray, a: jnp.ndarray,
                           b: jnp.ndarray, *, alpha: float = 1.0,
                           bm: int = 8, bn: int = 256, bk: int = 512,
                           interpret: bool = True) -> jnp.ndarray:
    """w8a16 twin of ``tt_linear_batched_a`` (decode-slot per-row A).
    wq: (K, N) int8; scale: (G, N) f32 as in ``tt_linear_w8``."""
    m, k_dim = x.shape
    _, n = wq.shape
    r = a.shape[2]
    g = scale.shape[0]
    per_channel = g == 1
    assert a.shape[:2] == (m, k_dim), (a.shape, x.shape)
    assert m % bm == 0 and n % bn == 0 and k_dim % bk == 0, \
        (m, n, k_dim, bm, bn, bk)
    assert per_channel or k_dim // g == bk, (k_dim, g, bk)
    grid = (m // bm, n // bn, k_dim // bk)

    def s_map(i, j, k):
        return (0 if per_channel else k, j)

    kernel = functools.partial(_batched_a_kernel, alpha=alpha,
                               k_steps=grid[2], per_channel=per_channel)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), s_map),
            pl.BlockSpec((bm, bk, r), lambda i, j, k: (i, k, 0)),
            pl.BlockSpec((r, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, r), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, wq, scale, a, b)


@functools.partial(jax.jit, static_argnames=("alpha", "bm", "bn", "bk",
                                             "interpret"))
def tt_linear(x: jnp.ndarray, w: jnp.ndarray, a: jnp.ndarray,
              b: jnp.ndarray, *, alpha: float = 1.0, bm: int = 256,
              bn: int = 256, bk: int = 512,
              interpret: bool = True) -> jnp.ndarray:
    """x: (M, K); w: (K, N); a: (K, r); b: (r, N) -> (M, N).

    Dims must be multiples of the tile sizes (ops.py pads otherwise); r is
    kept whole per tile (r ≤ 256 in every paper configuration).
    """
    m, k_dim = x.shape
    _, n = w.shape
    r = a.shape[1]
    assert m % bm == 0 and n % bn == 0 and k_dim % bk == 0, \
        (m, n, k_dim, bm, bn, bk)
    grid = (m // bm, n // bn, k_dim // bk)

    kernel = functools.partial(_kernel, alpha=alpha, k_steps=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, r), lambda i, j, k: (k, 0)),
            pl.BlockSpec((r, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, r), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w, a, b)
