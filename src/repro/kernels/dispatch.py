"""Kernel-dispatch layer: the single seam between model code and kernels.

Every hot-path call site (``models/layers.py::adapted_linear``, the
attention paths in ``models/attention.py``, the serving engine's decode
loop) routes through this module instead of picking a backend ad hoc
(DESIGN.md §5). The flow is:

  KernelConfig (config/base.py, user-facing knobs on RunConfig / Engine)
      -> resolve() -> KernelPolicy (hashable, fully resolved: backend
         chosen, interpret decided, tile overrides pinned)
      -> AdapterCtx.policy -> layers / attention / engine call the
         dispatch functions below.

With ``use_pallas`` the fused Pallas kernels run (on TPU natively; on CPU
only under ``interpret=True`` — the correctness path the parity tests and
CI exercise). Otherwise the pure-XLA reference math runs from the SAME
entry points, so fused-vs-ref comparisons (tests, benchmarks) exercise
exactly the code the model executes — no benchmark-only kernel calls.

The fused linear is differentiable: a custom VJP whose dx GEMM is itself
the fused kernel with transposed operands (dx = g·Wᵀ + α·(g·Bᵀ)·Aᵀ has the
same base-matmul + rank-r-epilogue shape as the forward), so the *training*
hot path stays on the kernel in both directions. Flash attention is also
differentiable end-to-end on the blockwise path: the forward stashes the
per-row log-sum-exp and the backward runs the two-pass recompute kernels
(``kernels/flash_attention.py::flash_attention_bwd``), so neither direction
ever materializes the (T, S) score matrix — the flash memory win holds for
training as well as inference (DESIGN.md §14). Future backends (GPU Triton,
new TPU generations) plug in here: add a branch to resolve() and the whole
stack follows.

Sharded serving (DESIGN.md §9): these entry points are shard_map-safe —
under the engine's tensor-parallel mesh each shard calls them with its
LOCAL head group and LOCAL KV-pool shard (q (B, C, H/tp, d) against
(N, page, KV/tp, d) pools), which is just a smaller instance of the
single-device shapes documented below; no kernel knows about the mesh.
The ambient-GSPMD guard lives one level up (models/attention.py::
_flash_ok): kernels stand down under an ambient >1-chip mesh, but run
per-shard inside shard_map where no ambient mesh exists.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.config.base import KernelConfig
from repro.kernels import ops
from repro.kernels import quant as quant_lib
from repro.kernels import ref as _ref


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """Resolved dispatch decision. Hashable and static: it is closed over
    by jitted functions and passed through ``jax.custom_vjp`` nondiff args,
    so it must never carry tracers."""
    use_pallas: bool = False
    interpret: bool = True
    fuse_linear: bool = True
    flash: bool = True
    bm: int = 0
    bn: int = 0
    bk: int = 0
    bq: int = 0
    bkv: int = 0

    @property
    def fused_linear(self) -> bool:
        """adapted_linear routes through the fused TT-linear kernel."""
        return self.use_pallas and self.fuse_linear

    @property
    def flash_attn(self) -> bool:
        """attention routes through the Pallas flash/decode kernels."""
        return self.use_pallas and self.flash


#: Force-reference policy (dispatch entry points, XLA math) — the "ref" leg
#: of every fused-vs-ref parity comparison.
REF = KernelPolicy(use_pallas=False)

#: Interpret-mode Pallas policy — the CPU correctness path.
PALLAS_INTERPRET = KernelPolicy(use_pallas=True, interpret=True)


def resolve(cfg: Union[KernelConfig, KernelPolicy, None]
            ) -> Optional[KernelPolicy]:
    """KernelConfig -> KernelPolicy (None passes through: "no policy" keeps
    the legacy unfused path, bit-identical to the pre-dispatch stack)."""
    if cfg is None or isinstance(cfg, KernelPolicy):
        return cfg
    cfg = cfg.validate()
    if cfg.backend == "pallas":
        use = True
    elif cfg.backend == "ref":
        use = False
    else:                                   # auto: Pallas iff on TPU
        use = jax.default_backend() == "tpu"
    interp = ((jax.default_backend() != "tpu") if cfg.interpret is None
              else cfg.interpret)
    return KernelPolicy(use_pallas=use, interpret=interp,
                        fuse_linear=cfg.fuse_linear, flash=cfg.flash,
                        bm=cfg.bm, bn=cfg.bn, bk=cfg.bk, bq=cfg.bq,
                        bkv=cfg.bkv)


# ---------------------------------------------------------------------------
# fused adapted linear (differentiable)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _fused_tt_linear(pol: KernelPolicy, alpha: float, x, w, a, b):
    return ops.tt_linear(x, w, a, b, alpha=alpha, backend="pallas",
                         interpret=pol.interpret, bm=pol.bm, bn=pol.bn,
                         bk=pol.bk)


def _fused_tt_linear_fwd(pol, alpha, x, w, a, b):
    return _fused_tt_linear(pol, alpha, x, w, a, b), (x, w, a, b)


def _fused_tt_linear_bwd(pol, alpha, res, g):
    x, w, a, b = res
    # dx = g·Wᵀ + α·(g·Bᵀ)·Aᵀ — the SAME fused base-matmul + rank-r
    # epilogue, so the backward's big GEMM stays on the kernel. The N/K
    # roles swap under the transpose, so the tile overrides swap with them.
    dx = ops.tt_linear(g, w.T, b.T, a.T, alpha=alpha, backend="pallas",
                       interpret=pol.interpret, bm=pol.bm, bn=pol.bk,
                       bk=pol.bn)
    xf = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    gf = g.reshape(-1, g.shape[-1]).astype(jnp.float32)
    # dW = Xᵀ·G is dead code under PEFT (W frozen, cotangent dropped) and
    # XLA eliminates it; computed for custom_vjp completeness.
    dw = xf.T @ gf
    gb = gf @ b.astype(jnp.float32).T
    da = alpha * (xf.T @ gb)
    db = alpha * ((xf @ a.astype(jnp.float32)).T @ gf)
    return (dx.astype(x.dtype), dw.astype(w.dtype), da.astype(a.dtype),
            db.astype(b.dtype))


_fused_tt_linear.defvjp(_fused_tt_linear_fwd, _fused_tt_linear_bwd)


def tt_linear(x, w, a, b, *, alpha: float = 1.0,
              policy: Optional[KernelPolicy] = None):
    """y = x·W + α·(x·A)·B. x: (..., K); w: (K, N); a: (K, r); b: (r, N)."""
    if policy is not None and policy.fused_linear:
        return _fused_tt_linear(policy, float(alpha), x, w, a, b)
    return _ref.tt_linear_ref(x, w, a, b, float(alpha))


def tt_linear_q(x, wq, a, b, *, alpha: float = 1.0,
                policy: Optional[KernelPolicy] = None):
    """w8a16 adapted linear over a packed int8 base leaf (DESIGN.md §8).

    wq: ``{"q8": int8 (K, N), "scale": f32 (G, N)}`` (kernels/quant.py);
    x/a/b as in ``tt_linear``. Inference-only — the int8 base is frozen by
    construction, so no custom VJP is defined; differentiate the ref path
    (plain XLA dequant + matmul) if a gradient is ever needed.
    """
    if policy is not None and policy.fused_linear:
        return ops.tt_linear_q(x, wq["q8"], wq["scale"], a, b,
                               alpha=float(alpha), backend="pallas",
                               interpret=policy.interpret, bm=policy.bm,
                               bn=policy.bn, bk=policy.bk)
    return _ref.tt_linear_q_ref(x, wq["q8"], wq["scale"], a, b,
                                float(alpha))


def tt_linear_batched_a_q(x, wq, a, b, *, alpha: float = 1.0,
                          policy: Optional[KernelPolicy] = None):
    """w8a16 per-row-A adapted linear (slot-task routing over an int8
    base). Decode shapes run the fused w8 kernel; the (B, T>1, K) chunked-
    prefill generalization dequantizes once and runs the batched-einsum
    reference from the same seam (mirrors ``tt_linear_batched_a``)."""
    decode_shaped = x.ndim == 2 or (x.ndim == 3 and x.shape[1] == 1)
    if decode_shaped:
        fused = policy is not None and policy.fused_linear
        kw = dict(interpret=policy.interpret, bm=policy.bm, bn=policy.bn,
                  bk=policy.bk) if fused else {}
        return ops.tt_linear_batched_a_q(
            x, wq["q8"], wq["scale"], a, b, alpha=float(alpha),
            backend="pallas" if fused else "ref", **kw)
    w = quant_lib.dequantize(wq, x.dtype)
    p = jnp.einsum("b...k,bkr->b...r", x, a.astype(x.dtype),
                   preferred_element_type=jnp.float32)
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    y = y + float(alpha) * jnp.dot(p, b.astype(p.dtype),
                                   preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _fused_tt_linear_ba(pol: KernelPolicy, alpha: float, x, w, a, b):
    return ops.tt_linear_batched_a(x, w, a, b, alpha=alpha,
                                   backend="pallas", interpret=pol.interpret,
                                   bm=pol.bm, bn=pol.bn, bk=pol.bk)


def _fused_tt_linear_ba_fwd(pol, alpha, x, w, a, b):
    return _fused_tt_linear_ba(pol, alpha, x, w, a, b), (x, w, a, b)


def _fused_tt_linear_ba_bwd(pol, alpha, res, g):
    x, w, a, b = res
    # decode-shaped (one token per slot row): the backward contractions
    # are per-row rank-r epilogues, so plain XLA einsums in f32 suffice
    squeeze = x.ndim == 3
    xf = (x[:, 0] if squeeze else x).astype(jnp.float32)
    gf = (g[:, 0] if squeeze else g).astype(jnp.float32)
    af = a.astype(jnp.float32)
    gb = gf @ b.astype(jnp.float32).T                       # (S, r)
    dx = (gf @ w.astype(jnp.float32).T
          + alpha * jnp.einsum("sr,skr->sk", gb, af))
    dw = xf.T @ gf
    da = alpha * jnp.einsum("sk,sr->skr", xf, gb)
    p = jnp.einsum("sk,skr->sr", xf, af)
    db = alpha * (p.T @ gf)
    if squeeze:
        dx = dx[:, None]
    return (dx.astype(x.dtype), dw.astype(w.dtype), da.astype(a.dtype),
            db.astype(b.dtype))


_fused_tt_linear_ba.defvjp(_fused_tt_linear_ba_fwd, _fused_tt_linear_ba_bwd)


def tt_linear_batched_a(x, w, a, b, *, alpha: float = 1.0,
                        policy: Optional[KernelPolicy] = None):
    """Per-row-A adapted linear (the (4+1)d slot-task routing form).

    x: (S, [1,] K); w: (K, N); a: (S, K, r); b: (r, N). The Pallas kernel
    handles the decode shape (one token per slot row) through a custom VJP
    (differentiable like the plain fused linear); other shapes (e.g. a
    per-example task vector during training) run the batched-einsum
    reference from the same seam.
    """
    decode_shaped = x.ndim == 2 or (x.ndim == 3 and x.shape[1] == 1)
    if decode_shaped:
        fused = policy is not None and policy.fused_linear
        if fused:
            return _fused_tt_linear_ba(policy, float(alpha), x, w, a, b)
        return ops.tt_linear_batched_a(x, w, a, b, alpha=float(alpha),
                                       backend="ref")
    # (B, T>1, K) generalization (per-example task vectors during
    # training) — no kernel for this shape yet; batched-einsum reference
    p = jnp.einsum("b...k,bkr->b...r", x, a.astype(x.dtype),
                   preferred_element_type=jnp.float32)
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    y = y + float(alpha) * jnp.dot(p, b.astype(p.dtype),
                                   preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (flash forward, blockwise flash backward)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _fused_flash(pol: KernelPolicy, causal: bool, q, k, v):
    return ops.flash_attention(q, k, v, causal=causal, backend="pallas",
                               interpret=pol.interpret, bq=pol.bq,
                               bkv=pol.bkv)


def _fused_flash_fwd(pol, causal, q, k, v):
    # the stats-emitting forward: one extra (B, H, T) f32 residual (lse)
    # buys a backward that never builds (T, S)
    out, lse = ops.flash_attention_fwd(q, k, v, causal=causal,
                                       backend="pallas",
                                       interpret=pol.interpret, bq=pol.bq,
                                       bkv=pol.bkv)
    return out, (q, k, v, out, lse)


def _fused_flash_bwd(pol, causal, res, g):
    q, k, v, out, lse = res
    return ops.flash_attention_bwd(q, k, v, out, lse, g, causal=causal,
                                   backend="pallas",
                                   interpret=pol.interpret, bq=pol.bq,
                                   bkv=pol.bkv)


_fused_flash.defvjp(_fused_flash_fwd, _fused_flash_bwd)


def flash_attention(q, k, v, *, causal: bool = True,
                    policy: Optional[KernelPolicy] = None):
    """GQA attention. q: (B, T, H, d); k, v: (B, S, KV, d) -> (B, T, H, d)."""
    if policy is not None and policy.flash_attn:
        return _fused_flash(policy, causal, q, k, v)
    return ops.flash_attention(q, k, v, causal=causal, backend="ref")


def decode_attention(q, k, v, pos, *,
                     policy: Optional[KernelPolicy] = None):
    """Cached single-token decode. q: (B, 1, H, d); k, v: (B, S, KV, d);
    pos: scalar or (B,) per-slot positions -> (B, 1, H, d)."""
    if policy is not None and policy.flash_attn:
        return ops.decode_attention(q, k, v, pos, backend="pallas",
                                    interpret=policy.interpret,
                                    bkv=policy.bkv)
    return ops.decode_attention(q, k, v, pos, backend="ref")


def paged_decode_attention(q, k_cache, v_cache, tables, pos, *,
                           k_scale=None, v_scale=None,
                           policy: Optional[KernelPolicy] = None):
    """Paged-cache attention (decode and in-loop chunked prefill).
    q: (B, C, H, d); k_cache, v_cache: (N, page, KV, d); tables: (B, P)
    int32 block table; pos: (B,) base positions -> (B, C, H, d).
    k_scale/v_scale: (N, page, KV) per-cell scale pools when the cache is
    int8 (the kernel dequantizes pages in-register)."""
    if policy is not None and policy.flash_attn:
        return ops.paged_decode_attention(q, k_cache, v_cache, tables, pos,
                                          k_scale=k_scale, v_scale=v_scale,
                                          backend="pallas",
                                          interpret=policy.interpret)
    return ops.paged_decode_attention(q, k_cache, v_cache, tables, pos,
                                      k_scale=k_scale, v_scale=v_scale,
                                      backend="ref")
