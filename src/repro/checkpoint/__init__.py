from repro.checkpoint.ckpt import (CheckpointManager,  # noqa: F401
                                   load_base_snapshot, save_base_snapshot)
