"""Checkpointing: atomic, keep-k, async-capable, resumable.

Stores a full training snapshot — adapter params, optimizer moments, RNG,
step counter, data-iterator state — as a single ``.npz`` (pytree flattened
by path) plus a JSON sidecar for non-array state. Writes are atomic
(tmp file + rename), so a crash mid-save never corrupts the latest
checkpoint; ``latest_step`` + ``restore`` implement auto-resume.

The frozen base model is NOT checkpointed (it is deterministic from the
config seed / would be the pre-trained weights in production) — this is the
PEFT deployment story: checkpoints are KBs even for 1T-param models.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _to_savable(arr: np.ndarray) -> np.ndarray:
    """npz has no bfloat16: store as f32 (lossless upcast); the restore path
    casts back to the template dtype."""
    if arr.dtype.name == "bfloat16":
        return arr.astype(np.float32)
    return arr


def _flatten(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for kp, leaf in flat:
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        out["/".join(parts)] = _to_savable(np.asarray(leaf))
    return out


def _unflatten_into(template, arrays: dict):
    flat = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for kp, leaf in flat[0]:
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        key = "/".join(parts)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = arrays[key]
        if hasattr(leaf, "dtype"):
            # jnp handles bfloat16 casts numpy refuses
            import jax.numpy as jnp
            leaves.append(jnp.asarray(arr).astype(leaf.dtype))
        else:
            leaves.append(arr)
    return jax.tree_util.tree_unflatten(flat[1], leaves)


def save_base_snapshot(path: str, base: Any) -> str:
    """Atomic one-file snapshot of a serving base pytree.

    Built for the quantized serving path (DESIGN.md §8): the engine
    int8-quantizes the frozen base once at construction, and this snapshot
    lets a serving restart (or a fleet of replicas) load the packed
    ``{"q8", "scale"}`` leaves instead of re-reading + re-quantizing the
    fp base — int8 leaves store natively in npz, so the snapshot is ~4x
    smaller than an fp32 base dump. Works for any base pytree (folded /
    fp bases included). Returns the path written.
    """
    if not path.endswith(".npz"):
        path = path + ".npz"
    arrays = _flatten(jax.device_get(base))
    tmp = path + f".tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)
    return path


def load_base_snapshot(path: str, template: Any) -> Any:
    """Inverse of ``save_base_snapshot``: ``template`` supplies the pytree
    structure and leaf dtypes (int8 q8 leaves restore as int8)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    with np.load(path) as z:
        arrays = dict(z)
    return _unflatten_into(template, arrays)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = False):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:08d}")

    def all_steps(self) -> list:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("ckpt_") and name.endswith(".npz"):
                out.append(int(name[len("ckpt_"):-len(".npz")]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, meta: Optional[dict] = None) -> None:
        """Atomic save. ``tree`` is any pytree of arrays; ``meta`` is JSON-
        serializable (data-iterator state, config fingerprint, ...)."""
        self.wait()
        arrays = _flatten(jax.device_get(tree))

        def _write():
            base = self._path(step)
            tmp = base + f".tmp.{os.getpid()}"
            with open(tmp + ".npz", "wb") as f:
                np.savez(f, **arrays)
            # per-leaf shape manifest: DMRG sweeps change TT bond shapes
            # mid-run, so the sidecar records what was actually saved —
            # restore() is shape-flexible, and tools/tests can audit the
            # reshaped (params, opt-state, schedule-position) triple
            # without loading the npz
            manifest = {"step": step,
                        "shapes": {k: list(v.shape)
                                   for k, v in arrays.items()},
                        **(meta or {})}
            with open(tmp + ".json", "w") as f:
                json.dump(manifest, f)
            os.replace(tmp + ".json", base + ".json")
            os.replace(tmp + ".npz", base + ".npz")
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            for ext in (".npz", ".json"):
                try:
                    os.remove(self._path(s) + ext)
                except FileNotFoundError:
                    pass

    # ------------------------------------------------------------------
    def restore(self, step: int, template: Any) -> tuple:
        """Returns (tree, meta). ``template`` provides structure + dtypes.

        Shape-flexible for the DMRG case: saved arrays replace template
        leaves even when shapes differ (TT ranks may have changed)."""
        base = self._path(step)
        with np.load(base + ".npz") as z:
            arrays = dict(z)
        meta = {}
        if os.path.exists(base + ".json"):
            with open(base + ".json") as f:
                meta = json.load(f)
        return _unflatten_into(template, arrays), meta

    def restore_latest(self, template: Any) -> Optional[tuple]:
        step = self.latest_step()
        if step is None:
            return None
        tree, meta = self.restore(step, template)
        return step, tree, meta
