"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-7b \
        --adapter metatt --rank 8 --steps 100 --ckpt-dir /tmp/run1

On this CPU container the launcher trains the reduced (smoke) config; on a
real TPU slice it would be invoked once per host under the production mesh
(``--mesh single|multi`` selects it; the dry-run validates those programs —
repro.launch.dryrun). The trainer provides checkpoint/auto-resume, the
straggler watchdog, DMRG rank schedules and gradient compression.
"""
from __future__ import annotations

import argparse

from repro import configs as registry
from repro.config.base import OptimizerConfig, RunConfig, SHAPES, TrainConfig
from repro.core.dmrg import RankSchedule
from repro.data import LMStream
from repro.train.trainer import Trainer


def build_argparser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    choices=list(registry.ALL_IDS))
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--adapter", default="metatt",
                    choices=("metatt", "lora", "vera", "lotr", "none"))
    ap.add_argument("--variant", default="4d",
                    choices=("4d", "5d", "4+1d", "4+ed"))
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--alpha", type=float, default=4.0)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compression", default="none",
                    choices=("none", "int8", "topk"))
    ap.add_argument("--dmrg-start-rank", type=int, default=0,
                    help="enable DMRG schedule from this rank down to --rank")
    ap.add_argument("--steps-per-epoch", type=int, default=0)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full assigned config (TPU-scale) instead "
                         "of the reduced smoke config")
    return ap


def main() -> None:
    args = build_argparser().parse_args()
    cfg = (registry.get_config(args.arch) if args.full_config
           else registry.get_smoke_config(args.arch))
    start_rank = args.dmrg_start_rank or args.rank
    run = RunConfig(
        model=cfg, shape=SHAPES[args.shape], adapter_kind=args.adapter,
        adapter_variant=args.variant, adapter_rank=start_rank,
        adapter_alpha=args.alpha,
        optimizer=OptimizerConfig(lr=args.lr),
        train=TrainConfig(seed=args.seed, ckpt_dir=args.ckpt_dir,
                          ckpt_every=args.ckpt_every,
                          grad_compression=args.grad_compression,
                          remat="none" if not args.full_config else "block"))
    sched = None
    if args.dmrg_start_rank and args.dmrg_start_rank > args.rank:
        sched = RankSchedule.linear(args.dmrg_start_rank, args.rank,
                                    start_epoch=1, every=1, step=2)
    data = LMStream(vocab_size=cfg.vocab_size, seq_len=32, batch=8,
                    seed=args.seed, branching=2)
    tr = Trainer(run=run, data=data, total_steps=args.steps,
                 steps_per_epoch=args.steps_per_epoch,
                 rank_schedule=sched,
                 on_metrics=lambda s, m: (
                     s % 10 == 0 and print(
                         f"step {s:5d} loss {m['loss']:.4f} "
                         f"lr {m['lr']:.2e} {m['step_time_s']*1e3:.0f}ms")))
    tr.train()


if __name__ == "__main__":
    main()
