"""input_specs + lowerable step builders for the multi-pod dry-run.

Everything here is ShapeDtypeStruct-based (``jax.eval_shape``): no array is
ever allocated — a 1T-parameter base model "exists" only as shapes with
NamedShardings attached, and ``jit(fn).lower(*specs).compile()`` proves the
distributed program is coherent.

One builder per shape kind:
  train_*    -> the full PEFT train step (fwd + bwd + AdamW on the adapter)
  prefill_*  -> batched forward returning logits
  decode_* / long_* -> single-token serve_step against full-length caches
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs as config_registry
from repro.config.base import RunConfig, SHAPES, TrainConfig
from repro.distributed import GradCompressor
from repro.models import model as model_lib
from repro.models import transformer
from repro.peft import api as peft_api
from repro.serving import engine as serving_engine
from repro.sharding import rules
from repro.train import train_step as ts


def make_run_config(arch: str, shape_name: str, *, adapter_kind="metatt",
                    adapter_variant="4d", adapter_rank=16,
                    microbatch: Optional[int] = None) -> RunConfig:
    cfg = config_registry.get_config(arch)
    shape = SHAPES[shape_name]
    if microbatch is None:
        # big archs: keep per-chip live activations modest under the scan
        microbatch = 8 if (shape.is_train and cfg.d_model >= 1024) else 0
    variant = adapter_variant
    if variant == "4+ed" and not cfg.num_experts:
        variant = "4d"
    return RunConfig(
        model=cfg, shape=shape, adapter_kind=adapter_kind,
        adapter_variant=variant, adapter_rank=adapter_rank,
        train=TrainConfig(microbatch=microbatch, remat="block"),
    )


def _attach(sds_tree, mesh: Mesh, spec_fn) -> object:
    """Attach NamedShardings (filtered by divisibility) to an SDS pytree."""
    flat = rules._paths(sds_tree)
    leaves = [
        jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype,
            sharding=NamedSharding(
                mesh, rules._filter_spec(mesh, spec_fn(p, leaf.shape),
                                         leaf.shape)))
        for p, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(sds_tree), leaves)


def _repl_spec(path, shape) -> P:
    return P()


def _batch_first(path, shape) -> P:
    return P(rules.BATCH)


def input_specs(run: RunConfig, mesh: Mesh) -> dict:
    """ShapeDtypeStruct stand-ins (with shardings) for every input of this
    (arch x shape) cell, plus the jitted fn to lower.

    Returns {"fn": callable, "args": tuple, "kind": str, "spec": AdapterSpec}.
    """
    cfg, shape = run.model, run.shape
    spec = model_lib.build_adapter_spec(run)
    b, t = shape.global_batch, shape.seq_len
    key = jax.random.PRNGKey(0)

    base = _attach(
        jax.eval_shape(lambda: transformer.init_base_params(cfg, key)),
        mesh, rules.spec_for_param)
    adapter_raw, frozen_raw = jax.eval_shape(
        lambda: peft_api.init_adapter(spec, key))
    adapter = _attach(adapter_raw, mesh, _repl_spec)
    frozen = _attach(frozen_raw, mesh, _repl_spec)

    def batch_inputs(tokens_len: int) -> dict:
        raw = {"tokens": jax.ShapeDtypeStruct((b, tokens_len), jnp.int32)}
        if cfg.frontend == "patch_stub":
            raw["embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_seq, cfg.d_model), cfg.compute_dtype)
        if cfg.is_encdec:
            raw["enc_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), cfg.compute_dtype)
        return _attach(raw, mesh, _batch_first)

    if shape.kind == "train":
        text_len = t - (cfg.frontend_seq if cfg.frontend == "patch_stub"
                        else 0)
        batch = batch_inputs(text_len)
        state = _attach(
            jax.eval_shape(
                lambda a: ts.init_train_state(
                    a, GradCompressor(run.train.grad_compression)),
                adapter_raw),
            mesh, _repl_spec)
        step = ts.make_train_step(cfg, spec, run.optimizer, run.train,
                                  total_steps=1000, chunk=512, donate=False)
        return {"fn": step, "args": (state, base, frozen, batch),
                "kind": "train", "spec": spec}

    if shape.kind == "prefill":
        text_len = t - (cfg.frontend_seq if cfg.frontend == "patch_stub"
                        else 0)
        batch = batch_inputs(text_len)

        def prefill_fn(base, adapter, frozen, batch):
            bc, pl = peft_api.adapter_factors(spec, adapter, frozen)
            out = transformer.forward(
                base, cfg, spec, bc, pl, batch.get("tokens"),
                embeds=batch.get("embeds"),
                enc_embeds=batch.get("enc_embeds"), chunk=512)
            return out.logits

        return {"fn": jax.jit(prefill_fn),
                "args": (base, adapter, frozen, batch),
                "kind": "prefill", "spec": spec}

    # ---- decode: one token against a full-length cache -------------------
    caches = _attach(
        jax.eval_shape(
            lambda: transformer.init_caches(cfg, b, t, cfg.compute_dtype)),
        mesh, rules.cache_spec_for)
    token = _attach({"t": jax.ShapeDtypeStruct((b, 1), jnp.int32)},
                    mesh, _batch_first)["t"]
    pos = _attach({"p": jax.ShapeDtypeStruct((), jnp.int32)},
                  mesh, _repl_spec)["p"]
    serve = serving_engine.make_serve_step(cfg, spec)
    args = [base, adapter, frozen, token, caches, pos]
    if cfg.is_encdec:
        enc = _attach({"e": jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), cfg.compute_dtype)},
            mesh, _batch_first)["e"]
        args.append(enc)
    return {"fn": serve, "args": tuple(args), "kind": "decode", "spec": spec}
