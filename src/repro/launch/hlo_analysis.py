"""Trip-count-aware analysis of compiled (post-SPMD) HLO.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE, which
under-counts everything this framework puts inside ``lax.scan`` (layers,
microbatches, attention chunks) by the trip count. This module re-derives
the roofline inputs by walking the HLO text with loop multipliers:

  * flops — 2·(output elems)·K per ``dot`` (batch dims included via the
    output), scaled by the product of enclosing known_trip_counts.
    Elementwise flops are excluded: on the MXU roofline only contraction
    flops count, and elementwise work is bandwidth-bound (captured in
    ``bytes``).
  * bytes — HBM-traffic proxy: for every *top-level* instruction (fusion
    internals excluded — fused values never hit HBM), result bytes (one
    write) + operand bytes (one read per use), with loop multipliers.
  * collectives — per kind: count, payload bytes and a ring-model wire-byte
    estimate per chip (``_wire``), with loop multipliers.

Shapes in post-SPMD HLO are per-chip, so all outputs are per-chip
quantities; the roofline terms divide by per-chip peak rates directly.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "u4": 1, "s4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z]\w*)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUP_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

# call-site attrs that enter *control-flow* computations (bytes DO recurse)
_FLOW_CALLS = re.compile(r"(?:body|condition|to_apply"
                         r"|true_computation|false_computation"
                         r"|branch_computations=\{)[=]?(%[\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
# attrs that enter *fusion* computations (flops/collectives recurse; bytes
# do not — fused intermediates never materialize in HBM)
_FUSION_CALLS = re.compile(r"calls=(%[\w.\-]+)")


def _dims(s: str) -> List[int]:
    return [int(d) for d in s.split(",") if d]


def _shape_info(type_str: str):
    return [(_DTYPE_BYTES.get(dt, 0), _dims(ds))
            for dt, ds in _SHAPE_RE.findall(type_str)]


def _nbytes(type_str: str) -> int:
    total = 0
    for bpe, dims in _shape_info(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * bpe
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    attrs: str
    operands: List[str]


def _parse_instr(line: str) -> Optional[Instr]:
    m = _INSTR_RE.match(line)
    if m is None:
        return None
    name, rhs = m.groups()
    # tuple result types may contain /*index=N*/ comments but never nested
    # parens, so [^()]* is safe
    om = re.match(
        r"^((?:\([^()]*\)|[a-z]\w*\[[\d,]*\]\S*)?)\s*([a-z][\w\-]*)\(", rhs)
    if om is None:
        return None
    type_str, op = om.groups()
    rest = rhs[om.end():]
    depth, i = 1, 0
    while i < len(rest) and depth:
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
        i += 1
    args, attrs = rest[:i - 1], rest[i:]
    return Instr(name=name, type_str=type_str, op=op, attrs=attrs,
                 operands=re.findall(r"%[\w.\-]+", args))


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]


def parse_module(text: str):
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_RE.match(line.strip())
            if m:
                cur = Computation(name=m.group(1), instrs=[])
                if line.strip().startswith("ENTRY"):
                    entry = m.group(1)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        ins = _parse_instr(line)
        if ins is not None:
            cur.instrs.append(ins)
    return comps, entry


def _dot_flops(ins: Instr, shape_of) -> float:
    out_elems = 1
    for _, dims in _shape_info(ins.type_str):
        for d in dims:
            out_elems *= d
    lhs = shape_of.get(ins.operands[0]) if ins.operands else None
    cm = _CDIMS_RE.search(ins.attrs)
    if lhs is None or cm is None:
        return 2.0 * out_elems
    k = 1
    for ci in _dims(cm.group(1)):
        if ci < len(lhs):
            k *= lhs[ci]
    return 2.0 * out_elems * k


def _group_size(attrs: str, default: int = 2) -> int:
    m = _GROUP_IOTA_RE.search(attrs)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUP_LIST_RE.search(attrs)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return default


def _wire(kind: str, nbytes: float, g: int) -> float:
    g = max(g, 2)
    frac = (g - 1) / g
    if kind == "all-gather":
        return nbytes * frac
    if kind == "all-reduce":
        return 2 * nbytes * frac
    if kind == "reduce-scatter":
        return nbytes * (g - 1)
    if kind == "all-to-all":
        return nbytes * frac
    return float(nbytes)      # collective-permute


_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "call", "conditional", "after-all",
               "partition-id", "iota", "replica-id"}


def _zero_coll():
    return {k: {"count": 0.0, "payload_bytes": 0.0, "wire_bytes": 0.0,
                "wire_bytes_f32": 0.0}
            for k in COLLECTIVES}


def analyze(text: str) -> dict:
    """Returns per-chip {"flops", "bytes", "coll", "collective_wire_bytes",
    "collective_payload_bytes"} with loop multipliers applied."""
    comps, entry = parse_module(text)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    memo: Dict[str, dict] = {}

    def cost(name: str) -> dict:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        out = {"flops": 0.0, "bytes": 0.0, "coll": _zero_coll()}
        memo[name] = out
        if comp is None:
            return out
        shape_of = {}
        bytes_of = {}
        for i in comp.instrs:
            si = _shape_info(i.type_str)
            shape_of[i.name] = si[0][1] if si else []
            bytes_of[i.name] = _nbytes(i.type_str)
        for ins in comp.instrs:
            base = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if ins.op in ("dot", "convolution"):
                out["flops"] += _dot_flops(ins, shape_of)
            if base in COLLECTIVES and not ins.op.endswith("-done"):
                nb = float(_nbytes(ins.type_str))
                if ins.op.endswith("-start"):
                    nb /= 2          # (in, out) tuple result type
                g = _group_size(ins.attrs)
                c = out["coll"][base]
                c["count"] += 1
                c["payload_bytes"] += nb
                c["wire_bytes"] += _wire(base, nb, g)
                # f32-payload share: XLA:CPU legalizes bf16 GEMMs via f32
                # upcasts that get hoisted ABOVE collectives, so bf16 models
                # see 2x-inflated wire bytes vs native-bf16 TPU. dryrun.py
                # reports a TPU estimate halving this share for bf16 models.
                if ins.type_str.lstrip("(").startswith("f32"):
                        c["wire_bytes_f32"] += _wire(base, nb, g)
            if ins.op not in _SKIP_BYTES and not ins.op.endswith("-done"):
                out["bytes"] += bytes_of[ins.name]
                res_b = bytes_of[ins.name]
                for opnd in ins.operands:
                    ob = bytes_of.get(opnd, 0)
                    # operand-utilization model (§Perf iteration X2):
                    #  * dot/conv stream their operands in full;
                    #  * slice-like ops touch ~result bytes of the operand;
                    #  * fusions with tiny results reading huge closed-over
                    #    arrays (per-step slices of scan stacks) are capped —
                    #    charging the full array per loop iteration
                    #    overcounted xlstm's recurrent scan ~40x.
                    if ins.op in ("dot", "convolution"):
                        out["bytes"] += ob
                    elif ins.op in ("dynamic-slice", "gather", "slice"):
                        out["bytes"] += min(ob, res_b)
                    else:
                        out["bytes"] += min(ob, 8 * res_b)
            # ---- recurse -------------------------------------------------
            mult = 1.0
            if ins.op == "while":
                tm = _TRIP_RE.search(ins.attrs)
                mult = float(tm.group(1)) if tm else 1.0
            flow = _FLOW_CALLS.findall(ins.attrs)
            bm = _BRANCHES.search(ins.attrs)
            if bm:
                flow += re.findall(r"%[\w.\-]+", bm.group(1))
            for callee in flow:
                sub = cost(callee)
                out["flops"] += mult * sub["flops"]
                out["bytes"] += mult * sub["bytes"]
                for k, v in sub["coll"].items():
                    for f in v:
                        out["coll"][k][f] += mult * v[f]
            for callee in _FUSION_CALLS.findall(ins.attrs):
                sub = cost(callee)
                out["flops"] += sub["flops"]
                for k, v in sub["coll"].items():
                    for f in v:
                        out["coll"][k][f] += v[f]
        return out

    total = cost(entry)
    total["collective_wire_bytes"] = sum(
        v["wire_bytes"] for v in total["coll"].values())
    total["collective_payload_bytes"] = sum(
        v["payload_bytes"] for v in total["coll"].values())
    total["collective_wire_bytes_f32"] = sum(
        v["wire_bytes_f32"] for v in total["coll"].values())
    total["cpu_f32_upcast_bytes"] = entry_f32_upcast_bytes(comps, entry)
    return total


def entry_f32_upcast_bytes(comps, entry: str, min_bytes: int = 1 << 26) -> float:
    """CPU-backend artifact accounting: XLA:CPU legalizes bf16 GEMMs by
    upcasting operands to f32 and hoists loop-invariant weight upcasts into
    persistent entry-level buffers. These do not exist on TPU (native bf16
    MXU) — the dry-run reports temp minus this as the TPU estimate."""
    comp = comps.get(entry)
    if comp is None:
        return 0.0
    total = 0.0
    for ins in comp.instrs:
        if ins.op == "convert" or (ins.op == "fusion"
                                   and "wrapped_convert" in ins.attrs):
            if ins.type_str.startswith("f32"):
                nb = _nbytes(ins.type_str)
                if nb >= min_bytes:
                    total += nb
    return total
