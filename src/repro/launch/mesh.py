"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required because the dry-run
overrides the host device count via XLA_FLAGS before first jax init, while
unit tests / benches must see the single real CPU device.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods x 256 chips as (pod=2, data=16, model=16) — the "pod"
axis is pure DP (batch + gradient all-reduce only; base weights are
replicated per pod so no inter-pod weight traffic — DESIGN.md §4).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly fake) local devices exist —
    used by CPU tests that exercise the sharded code paths."""
    return jax.make_mesh((data, model), ("data", "model"))
