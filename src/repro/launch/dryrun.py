import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init). 512 fake host devices back the production meshes:
# single-pod (data=16, model=16) and multi-pod (pod=2, data=16, model=16).
"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell and extract the roofline terms from the compiled artifact.

Per cell it records to artifacts/dryrun/<arch>__<shape>__<mesh>.json:
  * memory_analysis (args/temp/output bytes per device — proves it fits),
  * cost_analysis flops + bytes accessed (per-device SPMD program),
  * per-collective wire bytes parsed from the optimized HLO,
  * the three roofline terms (v5e: 197 TFLOP/s bf16, 819 GB/s HBM,
    ~50 GB/s/link ICI) + MODEL_FLOPS and the useful-compute ratio.

Usage:
  python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both] [--force]
"""
import argparse
import json
import re
import time
import traceback

import jax
import numpy as np

# ---------------------------------------------------------------------------
# roofline constants (TPU v5e per chip)
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUP_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of all array shapes in an HLO result type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-chip wire-byte estimate per collective kind from optimized HLO.

    Shapes in the post-SPMD module are per-chip. Ring estimates:
      all-gather: out x (g-1)/g      all-reduce: 2 x out x (g-1)/g
      reduce-scatter: out x (g-1)    all-to-all: out x (g-1)/g
      collective-permute: out
    ``sum_output_bytes`` is the raw operand/result-size sum (the assignment's
    bookkeeping convention); ``wire_bytes`` is what the roofline term uses.
    """
    out = {k: {"count": 0, "output_bytes": 0, "wire_bytes": 0.0}
           for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+)$", stripped)
        if m is None:
            continue
        rhs = m.group(1)
        kind = None
        for k in _COLLECTIVES:
            if re.search(rf"\b{k}(-start)?\(", rhs):
                kind = k
                break
        if kind is None:
            continue
        # result type = everything before the op name
        type_str = rhs.split(kind)[0]
        nbytes = _shape_bytes(type_str)
        g = 1
        gm = _GROUP_IOTA_RE.search(rhs)
        if gm:
            g = int(gm.group(2))
        else:
            gm = _GROUP_LIST_RE.search(rhs)
            if gm:
                g = len(gm.group(1).split(","))
        if g <= 1:
            g_eff = 2  # degenerate parse; assume pairwise
        else:
            g_eff = g
        frac = (g_eff - 1) / g_eff
        if kind == "all-gather":
            wire = nbytes * frac
        elif kind == "all-reduce":
            wire = 2 * nbytes * frac
        elif kind == "reduce-scatter":
            wire = nbytes * (g_eff - 1)
        elif kind == "all-to-all":
            wire = nbytes * frac
        else:  # collective-permute
            wire = nbytes
        out[kind]["count"] += 1
        out[kind]["output_bytes"] += nbytes
        out[kind]["wire_bytes"] += wire
    out["total_wire_bytes"] = sum(
        v["wire_bytes"] for k, v in out.items() if isinstance(v, dict))
    out["total_output_bytes"] = sum(
        v["output_bytes"] for k, v in out.items() if isinstance(v, dict))
    return out


def model_flops(cfg, shape, spec) -> dict:
    """MODEL_FLOPS = 6·N_active·D for training, 2·N_active·D for inference
    (D = tokens processed; decode: D = global_batch x 1 token)."""
    from repro.models.model import count_params  # lazy; no jax init issues
    from repro.models import transformer

    base_sds = jax.eval_shape(
        lambda: transformer.init_base_params(cfg, jax.random.PRNGKey(0)))

    def tree_n(tree):
        return int(sum(np.prod(x.shape) for x in
                       jax.tree_util.tree_leaves(tree)))

    n_total = tree_n(base_sds)
    # active params: MoE uses top-k of num_experts experts
    n_active = n_total
    if cfg.num_experts:
        expert_leaves = 0
        for p, leaf in __import__("repro.sharding.rules",
                                  fromlist=["_paths"])._paths(base_sds):
            if p.split("/")[-1] in ("e_wg", "e_wu", "e_wd"):
                expert_leaves += int(np.prod(leaf.shape))
        active_frac = cfg.experts_per_token / cfg.num_experts
        n_active = n_total - expert_leaves + int(expert_leaves * active_frac)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        flops = 6 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        flops = 2 * n_active * tokens
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        flops = 2 * n_active * tokens
    return {"n_total": n_total, "n_active": n_active, "tokens": tokens,
            "model_flops": flops}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = "artifacts/dryrun", force: bool = False,
             run_kwargs: dict | None = None, tag: str = "") -> dict:
    from repro import configs as config_registry
    from repro.launch import mesh as mesh_lib
    from repro.launch import specs as specs_lib
    from repro.sharding import rules

    mesh_name = "multi" if multi_pod else "single"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(
        out_dir, f"{arch}__{shape_name}__{mesh_name}{tag}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = config_registry.get_config(arch)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag}
    if not config_registry.supports_shape(cfg, shape_name):
        rec["status"] = "SKIP"
        rec["reason"] = ("long_500k needs sub-quadratic decode; "
                         f"{arch} is full-attention (DESIGN.md §4)")
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
        return rec

    run = specs_lib.make_run_config(arch, shape_name, **(run_kwargs or {}))
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        with mesh:
            rules.set_seq_axis("model" if run.shape.kind != "decode"
                               else None)
            try:
                cell = specs_lib.input_specs(run, mesh)
                lowered = cell["fn"].lower(*cell["args"])
                t_lower = time.time() - t0
                compiled = lowered.compile()
                t_compile = time.time() - t0 - t_lower
            finally:
                rules.set_seq_axis(None)

        mem = compiled.memory_analysis()
        mem_rec = {}
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                mem_rec[k] = int(v)
        # raw XLA cost_analysis kept for reference only: it counts while-loop
        # bodies ONCE (wrong under scan) — see launch/hlo_analysis.py.
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        cost_rec = {k: float(v) for k, v in cost.items()
                    if isinstance(v, (int, float))
                    and k in ("flops", "bytes accessed")}
        hlo = compiled.as_text()
        from repro.launch import hlo_analysis
        hc = hlo_analysis.analyze(hlo)
        coll = {k: v for k, v in hc["coll"].items()}
        coll["total_wire_bytes"] = hc["collective_wire_bytes"]
        coll["total_payload_bytes"] = hc["collective_payload_bytes"]
        # TPU-native estimate: on CPU, bf16 data is often upcast to f32
        # BEFORE collectives (GEMM legalization); a bf16-native TPU moves
        # half those bytes. Conservatively halve only the f32 share.
        import jax.numpy as _jnp
        bf16_model = cfg.compute_dtype == _jnp.bfloat16
        coll["total_wire_bytes_tpu"] = (
            hc["collective_wire_bytes"]
            - (hc["collective_wire_bytes_f32"] / 2 if bf16_model else 0.0))
        mem_rec["cpu_f32_upcast_bytes"] = int(hc["cpu_f32_upcast_bytes"])
        if "temp_size_in_bytes" in mem_rec:
            # CPU legalizes bf16 GEMMs via hoisted f32 weight upcasts that
            # don't exist on TPU — subtract for the TPU estimate
            mem_rec["tpu_temp_estimate_bytes"] = (
                mem_rec["temp_size_in_bytes"]
                - mem_rec["cpu_f32_upcast_bytes"])
        mf = model_flops(cfg, run.shape, cell["spec"])

        chips = int(np.prod(mesh.devices.shape))
        flops_per_chip = hc["flops"]
        bytes_per_chip = hc["bytes"]
        cost_rec["hlo_flops_per_chip"] = flops_per_chip
        cost_rec["hlo_bytes_per_chip"] = bytes_per_chip
        compute_s = flops_per_chip / PEAK_FLOPS
        memory_s = bytes_per_chip / HBM_BW
        collective_s = coll["total_wire_bytes_tpu"] / LINK_BW
        terms = {"compute_s": compute_s, "memory_s": memory_s,
                 "collective_s": collective_s}
        bound = max(terms, key=terms.get)
        hlo_flops_global = flops_per_chip * chips
        rec.update({
            "status": "OK",
            "chips": chips,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory_analysis": mem_rec,
            "cost_analysis": cost_rec,
            "collectives": coll,
            "model_flops": mf,
            "roofline": {
                **{k: float(v) for k, v in terms.items()},
                "bound": bound.replace("_s", ""),
                "useful_compute_ratio": (
                    mf["model_flops"] / hlo_flops_global
                    if hlo_flops_global else None),
                "roofline_fraction": (
                    compute_s / max(terms.values())
                    if max(terms.values()) > 0 else None),
            },
        })
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["status"] = "FAIL"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--adapter", default="metatt")
    ap.add_argument("--rank", type=int, default=16)
    args = ap.parse_args()

    from repro import configs as config_registry
    from repro.config.base import SHAPES

    archs = config_registry.ARCH_IDS if (args.all or not args.arch) \
        else (args.arch,)
    shapes = tuple(SHAPES) if (args.all or not args.shape) else (args.shape,)
    meshes = {"single": (False,), "multi": (True,),
              "both": (False, True)}[args.mesh]

    run_kwargs = {"adapter_kind": args.adapter, "adapter_rank": args.rank}
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                t0 = time.time()
                rec = run_cell(arch, shape, mp, out_dir=args.out,
                               force=args.force, run_kwargs=run_kwargs)
                status = rec.get("status")
                extra = ""
                if status == "OK":
                    r = rec["roofline"]
                    extra = (f"bound={r['bound']} "
                             f"compute={r['compute_s']:.3e}s "
                             f"mem={r['memory_s']:.3e}s "
                             f"coll={r['collective_s']:.3e}s")
                elif status == "FAIL":
                    extra = rec.get("error", "")[:160]
                print(f"[{status}] {arch} x {shape} x "
                      f"{'multi' if mp else 'single'} "
                      f"({time.time()-t0:.0f}s) {extra}", flush=True)


if __name__ == "__main__":
    main()
