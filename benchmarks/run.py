"""Benchmark driver — one suite per paper table/figure + roofline summary.

Prints ``name,us_per_call,derived`` CSV rows. Roofline terms come from the
dry-run artifacts (benchmarks/roofline.py); run
``python -m repro.launch.dryrun --all`` first to refresh them.

``--smoke`` runs the CI subset: the kernel-dispatch benches and the serving
smoke benches — fused-vs-unfused parity from the same dispatch seam the
model uses, the paged-vs-dense engine comparison, and the fp-vs-int8
quantized serving comparison (token parity, prefix-cache hit rate and
peak-KV-memory assertions from the engine's own stats) — cheap enough to
gate every CI run against kernel regressions and benchmark bit-rot.

``--json`` additionally writes ``BENCH_kernels.json``, ``BENCH_serving.json``
and ``BENCH_train.json`` at the repo root — the same rows as the CSV (parsed
into objects) plus, for serving, the engines' own stats objects — so
future PRs can diff the perf trajectory machine-readably instead of
scraping stdout.
"""
from __future__ import annotations

import argparse
import json
import os

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _row_dicts(rows: list) -> list:
    """"name,us,derived" CSV strings -> dicts (derived may hold commas)."""
    out = []
    for r in rows:
        name, us, derived = r.split(",", 2)
        out.append({"name": name, "us_per_call": float(us),
                    "derived": derived})
    return out


def _write_json(path: str, payload: dict) -> None:
    full = os.path.join(REPO_ROOT, path)
    with open(full, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"# wrote {full}", flush=True)


def _emit_json(kernel_rows: list, serving_rows: list,
               train_rows: list) -> None:
    from benchmarks import bench_serving, bench_train
    _write_json("BENCH_kernels.json", {"rows": _row_dicts(kernel_rows)})
    # merge (replace same-name rows / same-label stats, keep the rest)
    # rather than overwrite, so rows written by other jobs — e.g. the
    # sharded-parity job's serving/tp4_vs_tp1 (`bench_serving --mesh`) —
    # survive this writer regardless of execution order
    bench_serving._merge_rows_into_json(serving_rows)
    bench_train._merge_rows_into_json(train_rows)


def main(*, smoke: bool = False, emit_json: bool = False) -> None:
    print("name,us_per_call,derived")
    from benchmarks import (bench_fig2_dmrg, bench_init_ablation,
                            bench_kernels, bench_serving, bench_table1,
                            bench_table2, bench_train, roofline)
    if smoke:
        kernel_rows = bench_kernels.run(smoke=True)
        serving_rows = bench_serving.run(smoke=True)
        train_rows = bench_train.run(smoke=True)
        if emit_json:
            _emit_json(kernel_rows, serving_rows, train_rows)
        return
    bench_table1.run()
    bench_table2.run()
    bench_fig2_dmrg.run()
    bench_init_ablation.run()
    serving_rows = bench_serving.run()
    kernel_rows = bench_kernels.run()
    train_rows = bench_train.run()
    if emit_json:
        _emit_json(kernel_rows, serving_rows, train_rows)
    # roofline summary rows (from dry-run artifacts, if present)
    for out_dir, label in (("artifacts/dryrun", "baseline"),
                           ("artifacts/dryrun_opt", "optimized")):
        if not os.path.isdir(out_dir):
            continue
        rows = roofline.load(out_dir)
        for r in rows:
            if r.get("status") != "OK" or r.get("mesh") != "single":
                continue
            ro = r["roofline"]
            print(f"roofline-{label}/{r['arch']}/{r['shape']},0.0,"
                  f"bound={ro['bound']} compute_s={ro['compute_s']:.3e} "
                  f"memory_s={ro['memory_s']:.3e} "
                  f"collective_s={ro['collective_s']:.3e} "
                  f"fraction={ro['roofline_fraction']:.4f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset: kernel-dispatch + serving smoke "
                         "benches (incl. paged-vs-dense and fp-vs-int8 "
                         "engine parity)")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_kernels.json / BENCH_serving.json / "
                         "BENCH_train.json at the repo root (rows + "
                         "engine stats)")
    args = ap.parse_args()
    main(smoke=args.smoke, emit_json=args.json)
