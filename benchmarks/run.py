"""Benchmark driver — one suite per paper table/figure + roofline summary.

Prints ``name,us_per_call,derived`` CSV rows. Roofline terms come from the
dry-run artifacts (benchmarks/roofline.py); run
``python -m repro.launch.dryrun --all`` first to refresh them.

``--smoke`` runs the CI subset: the kernel-dispatch benches and the serving
smoke benches — fused-vs-unfused parity from the same dispatch seam the
model uses, plus the paged-vs-dense engine comparison (token parity,
prefix-cache hit rate and peak-KV-memory assertions from the engine's own
stats) — cheap enough to gate every CI run against kernel regressions and
benchmark bit-rot.
"""
from __future__ import annotations

import argparse
import os


def main(*, smoke: bool = False) -> None:
    print("name,us_per_call,derived")
    from benchmarks import (bench_fig2_dmrg, bench_init_ablation,
                            bench_kernels, bench_serving, bench_table1,
                            bench_table2, roofline)
    if smoke:
        bench_kernels.run(smoke=True)
        bench_serving.run(smoke=True)
        return
    bench_table1.run()
    bench_table2.run()
    bench_fig2_dmrg.run()
    bench_init_ablation.run()
    bench_serving.run()
    bench_kernels.run()
    # roofline summary rows (from dry-run artifacts, if present)
    for out_dir, label in (("artifacts/dryrun", "baseline"),
                           ("artifacts/dryrun_opt", "optimized")):
        if not os.path.isdir(out_dir):
            continue
        rows = roofline.load(out_dir)
        for r in rows:
            if r.get("status") != "OK" or r.get("mesh") != "single":
                continue
            ro = r["roofline"]
            print(f"roofline-{label}/{r['arch']}/{r['shape']},0.0,"
                  f"bound={ro['bound']} compute_s={ro['compute_s']:.3e} "
                  f"memory_s={ro['memory_s']:.3e} "
                  f"collective_s={ro['collective_s']:.3e} "
                  f"fraction={ro['roofline_fraction']:.4f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset: kernel-dispatch + serving smoke "
                         "benches (incl. paged-vs-dense engine parity)")
    main(smoke=ap.parse_args().smoke)
