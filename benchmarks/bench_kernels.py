"""Pallas kernel microbenches (interpret mode on CPU — correctness-path
timing only; TPU is the performance target). Derived column reports the
kernel's VMEM working set and the HBM round-trips the fusion removes."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.kernels import ref
from repro.kernels.tt_linear import tt_linear


def run() -> list:
    rows = []
    key = jax.random.PRNGKey(0)
    M_, K, N, r = 256, 512, 512, 16
    x = jax.random.normal(key, (M_, K), jnp.float32)
    w = jax.random.normal(key, (K, N), jnp.float32) / 32
    a = jax.random.normal(key, (K, r), jnp.float32) / 32
    b = jax.random.normal(key, (r, N), jnp.float32) / 4

    us_ref = time_call(jax.jit(
        lambda *t: ref.tt_linear_ref(*t, 1.0)), x, w, a, b, iters=3)
    rows.append(emit("kernels/tt_linear_xla_ref", us_ref,
                     f"M={M_},K={K},N={N},r={r}"))
    us_k = time_call(lambda: tt_linear(x, w, a, b, bm=128, bn=128, bk=128,
                                       interpret=True), iters=3, warmup=1)
    # HBM savings of the fusion (the TPU story): unfused writes+reads the
    # (M, N) base output one extra time -> 2*M*N*2B saved per call
    saved = 2 * M_ * N * 2
    rows.append(emit("kernels/tt_linear_pallas_interpret", us_k,
                     f"hbm_roundtrip_saved_bytes={saved} "
                     f"vmem_tile_bytes={128*128*4 + 128*r*4}"))
    return rows


if __name__ == "__main__":
    run()
