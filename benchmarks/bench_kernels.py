"""Kernel benches through the SAME dispatch seam the model uses.

Every row calls ``repro.kernels.dispatch`` (or the model forward with a
KernelPolicy) — no benchmark-only kernel entry points — so fused-vs-unfused
numbers measure exactly what training/serving executes. On CPU the Pallas
rows run interpret mode (a correctness emulator, orders of magnitude slower
than the compiled kernel; TPU is the performance target) — the ref rows are
the meaningful CPU timings, the derived column carries the fusion's HBM
arithmetic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro import configs as registry
from repro.config.base import RunConfig, SHAPES
from repro.core import tt as ttlib
from repro.kernels import dispatch, quant
from repro.models import model as M
from repro.models import transformer as T
from repro.peft import api as peft_api

POLICIES = (("ref", dispatch.REF),
            ("pallas_interpret", dispatch.PALLAS_INTERPRET))


def _linear_rows(rows) -> None:
    key = jax.random.PRNGKey(0)
    m_, k, n, r = 256, 512, 512, 16
    x = jax.random.normal(key, (m_, k), jnp.float32)
    w = jax.random.normal(key, (k, n), jnp.float32) / 32
    a = jax.random.normal(key, (k, r), jnp.float32) / 32
    b = jax.random.normal(key, (r, n), jnp.float32) / 4
    # HBM savings of the fusion (the TPU story): unfused writes+reads the
    # (M, N) base output one extra time -> 2*M*N*2B saved per call
    saved = 2 * m_ * n * 2
    for name, pol in POLICIES:
        us = time_call(jax.jit(lambda *t, p=pol: dispatch.tt_linear(
            *t, alpha=1.0, policy=p)), x, w, a, b, iters=3, warmup=1)
        rows.append(emit(f"kernels/tt_linear_{name}", us,
                         f"M={m_},K={k},N={n},r={r},"
                         f"hbm_roundtrip_saved_bytes={saved}"))

    # w8a16: int8 base + f32 per-channel scales through the same seam —
    # the TPU story is the weight HBM read dropping from 4B (f32) / 2B
    # (bf16) to 1B per element (+ one f32 scale per output channel)
    wq = quant.quantize_linear(w)
    w_bytes_fp = k * n * 4
    w_bytes_q = k * n * 1 + n * 4
    for name, pol in POLICIES:
        us = time_call(jax.jit(lambda x_, a_, b_, p=pol: dispatch.tt_linear_q(
            x_, wq, a_, b_, alpha=1.0, policy=p)), x, a, b,
            iters=3, warmup=1)
        rows.append(emit(f"kernels/tt_linear_w8a16_{name}", us,
                         f"M={m_},K={k},N={n},r={r},"
                         f"w_bytes={w_bytes_q}vs{w_bytes_fp}"))

    s = 8                                 # decode slots
    xa = jax.random.normal(key, (s, k), jnp.float32)
    ab = jax.random.normal(key, (s, k, r), jnp.float32) / 32
    for name, pol in POLICIES:
        us = time_call(jax.jit(lambda *t, p=pol: dispatch.tt_linear_batched_a(
            *t, alpha=1.0, policy=p)), xa, w, ab, b, iters=3, warmup=1)
        rows.append(emit(f"kernels/tt_linear_batched_a_{name}", us,
                         f"slots={s},K={k},N={n},r={r}"))
    for name, pol in POLICIES:
        us = time_call(jax.jit(
            lambda x_, a_, b_, p=pol: dispatch.tt_linear_batched_a_q(
                x_, wq, a_, b_, alpha=1.0, policy=p)), xa, ab, b,
            iters=3, warmup=1)
        rows.append(emit(f"kernels/tt_linear_batched_a_w8a16_{name}", us,
                         f"slots={s},K={k},N={n},r={r}"))


def _attention_rows(rows) -> None:
    key = jax.random.PRNGKey(1)
    b, t, h, kv, d = 2, 256, 8, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, t, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, kv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, kv, d), jnp.float32)
    for name, pol in POLICIES:
        us = time_call(jax.jit(lambda *x_, p=pol: dispatch.flash_attention(
            *x_, causal=True, policy=p)), q, k, v, iters=3, warmup=1)
        rows.append(emit(f"kernels/flash_attention_{name}", us,
                         f"B={b},T={t},H={h},KV={kv},d={d}"))

    s_len = 128
    qd = jax.random.normal(ks[0], (b, 1, h, d), jnp.float32)
    kd = jax.random.normal(ks[1], (b, s_len, kv, d), jnp.float32)
    vd = jax.random.normal(ks[2], (b, s_len, kv, d), jnp.float32)
    pos = jnp.array([17, 103])
    for name, pol in POLICIES:
        us = time_call(jax.jit(lambda *x_, p=pol: dispatch.decode_attention(
            *x_, policy=p)), qd, kd, vd, pos, iters=3, warmup=1)
        rows.append(emit(f"kernels/decode_attention_{name}", us,
                         f"B={b},S={s_len},H={h},KV={kv},d={d}"))


def _model_rows(rows) -> None:
    """End-to-end: the full smoke-model forward, fused vs unfused, from the
    same AdapterCtx.policy seam the trainer/engine thread."""
    cfg = registry.get_smoke_config("stablelm-1.6b")
    run = RunConfig(model=cfg, shape=SHAPES["train_4k"],
                    adapter_kind="metatt", adapter_rank=8)
    spec = M.build_adapter_spec(run)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, spec, key)
    params["adapter"] = {"cores": ttlib.random_tt(key, spec.cfg.mode_sizes,
                                                  8, scale=0.1)}
    bc, pl = peft_api.adapter_factors(spec, params["adapter"],
                                      params["frozen"])
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    for name, pol in POLICIES:
        fn = jax.jit(lambda tok, p=pol: T.forward(
            params["base"], cfg, spec, bc, pl, tok, policy=p).logits)
        us = time_call(fn, tokens, iters=3, warmup=1)
        rows.append(emit(f"model/forward_{name}", us,
                         f"arch={cfg.name},adapter=metatt-r8"))


def run(*, smoke: bool = False) -> list:
    del smoke                       # shapes are already CI-sized
    rows = []
    _linear_rows(rows)
    _attention_rows(rows)
    _model_rows(rows)
    return rows


if __name__ == "__main__":
    run()
