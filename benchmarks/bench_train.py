"""Training-path benchmarks: the memory/step-time story of DESIGN.md §14.

Two comparisons, emitted as ``name,us_per_call,derived`` rows and merged
into ``BENCH_train.json``:

  * flash-backward vs reference backward — compile-time peak temp memory
    at T=2048 (the blockwise backward must NOT materialize the (T, T)
    score matrix; the ref path does) plus wall-clock step time at a small
    T (interpret mode on CPU is a correctness emulator, not a speed
    number; TPU is the target),
  * DMRG sweep-on vs sweep-off training — mean step time and final loss
    for a rank-annealed run against its fixed-rank baseline, with a
    non-divergence assertion (the sweep must not wreck optimization).

Usage:
    PYTHONPATH=src python benchmarks/bench_train.py [--smoke] [--json]
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro import configs as registry
from repro.config.base import (KernelConfig, OptimizerConfig, RunConfig,
                               SHAPES, TrainConfig)
from repro.core import tt as ttlib
from repro.core.dmrg import RankSchedule
from repro.data import LMStream
from repro.kernels import dispatch
from repro.train.trainer import Trainer

#: analytic size of the buffer the blockwise backward keeps out of HBM
_TT_BYTES = lambda t: t * t * 4


def _flash_grad_fn(policy, t):
    def loss(q, k, v):
        return jnp.sum(dispatch.flash_attention(q, k, v, causal=True,
                                                policy=policy))
    sds = jax.ShapeDtypeStruct((1, t, 1, 64), jnp.float32)
    return jax.jit(jax.grad(loss, argnums=(0, 1, 2))), sds


def _flash_bwd_rows(rows, *, smoke: bool = False) -> None:
    pallas = dispatch.resolve(KernelConfig(backend="pallas",
                                           interpret=True))

    # ---- peak temp memory, compile-only, at the acceptance shape T=2048
    t_mem = 2048
    temps = {}
    for label, pol in (("pallas", pallas), ("ref", None)):
        fn, sds = _flash_grad_fn(pol, t_mem)
        ma = fn.lower(sds, sds, sds).compile().memory_analysis()
        temps[label] = int(ma.temp_size_in_bytes)
        rows.append(emit(f"train/flash_bwd_peak_{label}", 0.0,
                         f"T={t_mem},temp_mb={temps[label] / 1e6:.1f},"
                         f"tt_buffer_mb={_TT_BYTES(t_mem) / 1e6:.1f}"))
    if temps["pallas"] >= temps["ref"]:
        raise AssertionError(
            f"flash backward lost the memory win: pallas temp "
            f"{temps['pallas']} >= ref temp {temps['ref']}")

    # ---- wall-clock step time at a small T (emulator numbers on CPU)
    t_time = 64 if smoke else 128
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, t_time, 1, 64), jnp.float32)
    for label, pol, iters, warmup in (("pallas_interpret", pallas, 3, 1),
                                      ("ref", None, 5, 2)):
        fn, _ = _flash_grad_fn(pol, t_time)
        us = time_call(fn, q, q, q, iters=iters, warmup=warmup)
        rows.append(emit(f"train/flash_bwd_step_{label}", us,
                         f"T={t_time},interpret={int(pol is not None)}"))


def _make_trainer(steps, steps_per_epoch, rank_schedule, seed=3):
    cfg = registry.get_smoke_config("stablelm-1.6b")
    run = RunConfig(
        model=cfg, shape=SHAPES["train_4k"], adapter_kind="metatt",
        adapter_rank=8, adapter_alpha=4.0,
        optimizer=OptimizerConfig(lr=2e-2, warmup_ratio=0.1),
        train=TrainConfig(seed=seed, remat="none"))
    data = LMStream(vocab_size=cfg.vocab_size, seq_len=32, batch=8,
                    seed=11, branching=2)
    return Trainer(run=run, data=data, total_steps=steps,
                   steps_per_epoch=steps_per_epoch,
                   rank_schedule=rank_schedule)


def _sweep_rows(rows, *, smoke: bool = False) -> None:
    steps = 12 if smoke else 30
    spe = 4 if smoke else 10
    sched = RankSchedule(milestones=((1, 6), (2, 4)))
    finals = {}
    for label, schedule in (("sweep_on", sched), ("sweep_off", None)):
        tr = _make_trainer(steps, spe, schedule)
        tr.train()
        losses = tr.losses()
        if not np.isfinite(losses).all():
            raise AssertionError(f"{label}: non-finite loss {losses}")
        finals[label] = float(np.mean(losses[-3:]))
        step_us = float(np.mean([m["step_time_s"]
                                 for _, m in tr.history])) * 1e6
        ranks = ttlib.ranks(tr.state.adapter["cores"])
        rows.append(emit(f"train/{label}", step_us,
                         f"steps={steps},final_loss={finals[label]:.4f},"
                         f"ranks={'-'.join(str(r) for r in ranks)}"))
    # rank annealing trades capacity for size; it must not diverge
    if finals["sweep_on"] > finals["sweep_off"] + 1.0:
        raise AssertionError(
            f"sweep-on diverged: {finals['sweep_on']:.4f} vs fixed-rank "
            f"{finals['sweep_off']:.4f}")


def _merge_rows_into_json(rows) -> None:
    """Same-name rows are replaced, everything else preserved — composes
    with other writers regardless of execution order (bench_serving
    idiom)."""
    import json
    import os
    from benchmarks.run import REPO_ROOT, _row_dicts
    path = os.path.join(REPO_ROOT, "BENCH_train.json")
    payload = {"rows": []}
    if os.path.exists(path):
        with open(path) as f:
            payload = json.load(f)
    new = _row_dicts(rows)
    names = {r["name"] for r in new}
    payload["rows"] = [r for r in payload.get("rows", [])
                       if r["name"] not in names] + new
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"# merged {sorted(names)} into {path}", flush=True)


def run(*, smoke: bool = False) -> list:
    rows = []
    _flash_bwd_rows(rows, smoke=smoke)
    _sweep_rows(rows, smoke=smoke)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes/steps for CI")
    ap.add_argument("--json", action="store_true",
                    help="merge rows into BENCH_train.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    out = run(smoke=args.smoke)
    if args.json:
        _merge_rows_into_json(out)
