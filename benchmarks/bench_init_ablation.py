"""Paper Fig. 3 (App. A.1) — init-scheme ablation: short training runs per
init scheme on the synthetic LM task; reports final-loss ranking (the paper
picks ze-id-id-id)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro import configs as registry
from repro.config.base import OptimizerConfig, RunConfig, SHAPES, TrainConfig
from repro.data import LMStream
from repro.train.trainer import Trainer

SCHEMES = ("ze-id-id-id", "ze-no-no-no", "no-ze-id-id", "id-id-id-ze")


def run(steps: int = 25) -> list:
    rows = []
    cfg = registry.get_smoke_config("roberta-base")
    for scheme in SCHEMES:
        run_cfg = RunConfig(
            model=cfg, shape=SHAPES["train_4k"], adapter_kind="metatt",
            adapter_rank=4, adapter_alpha=4.0,
            optimizer=OptimizerConfig(lr=2e-2, warmup_ratio=0.1),
            train=TrainConfig(remat="none", seed=42))
        data = LMStream(vocab_size=cfg.vocab_size, seq_len=32, batch=8,
                        seed=5, branching=2)
        tr = Trainer(run=run_cfg, data=data, total_steps=steps)
        # override the init scheme
        import dataclasses
        from repro.core import metatt as mtt
        import jax
        acfg = dataclasses.replace(tr.spec.cfg, init=scheme)
        tr.spec = dataclasses.replace(tr.spec, cfg=acfg)
        from repro.train import train_step as ts
        tr.state = ts.init_train_state(
            mtt.init_params(acfg, jax.random.PRNGKey(0)), tr.compressor)
        tr.step_fn = ts.make_train_step(cfg, tr.spec, run_cfg.optimizer,
                                        run_cfg.train, steps)
        tr.train()
        losses = tr.losses()
        rows.append(emit(f"fig3/init/{scheme}", 0.0,
                         f"final_loss={np.mean(losses[-5:]):.4f} "
                         f"drop={np.mean(losses[:5])-np.mean(losses[-5:]):.4f}"))
    return rows


if __name__ == "__main__":
    run()
