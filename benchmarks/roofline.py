"""Roofline table generator (deliverable g).

Reads artifacts/dryrun/*.json (written by repro.launch.dryrun) and emits the
EXPERIMENTS.md §Roofline markdown table: three terms per (arch × shape ×
mesh), dominant bound, MODEL_FLOPS/HLO_FLOPS ratio, and the per-cell
improvement note.

Hardware constants (TPU v5e): 197 TFLOP/s bf16 · 819 GB/s HBM ·
~50 GB/s/link ICI — defined in repro/launch/dryrun.py.
"""
from __future__ import annotations

import glob
import json
import os

NOTES = {
    "compute": ("raise MXU utilization: bigger per-chip microbatch or fewer "
                "remat recomputes"),
    "memory": ("cut HBM traffic: fuse elementwise chains, shrink f32 "
               "buffers, avoid re-gathering FSDP weights per microbatch"),
    "collective": ("cut wire bytes: fewer FSDP weight all-gathers "
                   "(microbatch count), SP only where activations dominate, "
                   "bf16 collectives"),
}


def load(out_dir: str = "artifacts/dryrun", tag: str = "") -> list:
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, f"*{tag}.json"))):
        with open(path) as f:
            rec = json.load(f)
        if tag == "" and rec.get("tag"):
            continue
        rows.append(rec)
    return rows


def fmt_seconds(s: float) -> str:
    if s >= 1:
        return f"{s:8.2f}s"
    if s >= 1e-3:
        return f"{s*1e3:6.1f}ms"
    return f"{s*1e6:6.0f}us"


def table(rows: list, mesh: str = "single") -> str:
    out = ["| arch | shape | compute | memory | collective | bound | "
           "frac | useful | args/chip | temp/chip(TPU est) |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    shapes_order = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    rows = [r for r in rows if r.get("mesh") == mesh]
    rows.sort(key=lambda r: (r["arch"], shapes_order.index(r["shape"])))
    for r in rows:
        if r["status"] == "SKIP":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | SKIP | "
                       f"— | — | — | — |")
            continue
        if r["status"] != "OK":
            out.append(f"| {r['arch']} | {r['shape']} | FAIL: "
                       f"{r.get('error','')[:60]} | | | | | | | |")
            continue
        ro = r["roofline"]
        m = r["memory_analysis"]
        args = m.get("argument_size_in_bytes", 0) / 2**30
        temp = m.get("tpu_temp_estimate_bytes",
                     m.get("temp_size_in_bytes", 0)) / 2**30
        useful = ro.get("useful_compute_ratio")
        out.append(
            f"| {r['arch']} | {r['shape']} |"
            f" {fmt_seconds(ro['compute_s'])} |"
            f" {fmt_seconds(ro['memory_s'])} |"
            f" {fmt_seconds(ro['collective_s'])} |"
            f" **{ro['bound']}** |"
            f" {ro['roofline_fraction']:.3f} |"
            f" {useful:.2f} |"
            f" {args:.2f}GB | {temp:.2f}GB |")
    return "\n".join(out)


def summary(rows: list) -> dict:
    ok = [r for r in rows if r["status"] == "OK"]
    skip = [r for r in rows if r["status"] == "SKIP"]
    fail = [r for r in rows if r["status"] == "FAIL"]
    worst = sorted((r for r in ok if r["mesh"] == "single"),
                   key=lambda r: r["roofline"]["roofline_fraction"])[:5]
    most_coll = sorted(
        (r for r in ok if r["mesh"] == "single"),
        key=lambda r: -(r["roofline"]["collective_s"]
                        / max(sum((r["roofline"]["compute_s"],
                                   r["roofline"]["memory_s"],
                                   r["roofline"]["collective_s"])),
                              1e-30)))[:5]
    return {"ok": len(ok), "skip": len(skip), "fail": len(fail),
            "worst_fraction": [(r["arch"], r["shape"],
                                round(r["roofline"]["roofline_fraction"], 4))
                               for r in worst],
            "most_collective_bound": [
                (r["arch"], r["shape"],
                 round(r["roofline"]["collective_s"], 3)) for r in most_coll]}


def main() -> None:
    rows = load()
    print("## single-pod (16x16 = 256 chips)\n")
    print(table(rows, "single"))
    print("\n## multi-pod (2x16x16 = 512 chips)\n")
    print(table(rows, "multi"))
    print("\n## summary\n")
    print(json.dumps(summary(rows), indent=2))


if __name__ == "__main__":
    main()
