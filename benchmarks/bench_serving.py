"""Serving benchmarks.

1. Paper §2.4 decode-step latency: live TT contraction vs pre-merged
   (fold-into-dense) weights vs the bare base model — the paper's claim is
   merged MetaTT == LoRA == base.
2. Engine throughput: the jitted-while-loop continuous-batching engine
   (repro/serving/) serving a MIXED-TASK batch (>= 2 distinct task ids per
   decode batch, one shared 4+1d TT) vs the seed's one-request-shape
   per-token Python loop, in tokens/sec.
3. Paged vs dense KV cache on a shared-prefix workload: token parity is
   asserted and throughput / peak KV memory / prefix-cache hit rate come
   from the engine's OWN stats object (engine.last_stats — the numbers a
   deployment would scrape), not benchmark-side re-derivation.
4. fp vs int8 (w8a16 weights + int8 KV) paged serving: greedy-token match
   fraction (>= TOKEN_MATCH_MIN asserted) and peak KV bytes (int8 must
   come in below fp at the same num_blocks budget) — again from
   engine.last_stats.
5. ``--mesh``: tensor-parallel vs single-device serving (DESIGN.md §9) —
   the TP=4 engine must be token-identical to TP=1 and report per-shard
   peak KV bytes of global/4; the ``serving/tp4_vs_tp1`` row (plus both
   engines' stats) is merged into ``BENCH_serving.json`` in place.
   Needs 4 devices: run under
   ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the
   scripts/ci.sh sharded-parity job does).
6. ``--fleet``: data-striped (dp2 x tp4) vs single-replica (dp1 x tp4)
   serving (DESIGN.md §11) — token identity and per-replica block
   accounting are asserted and the ``serving/dp2_vs_dp1`` row is merged
   into ``BENCH_serving.json``. Needs 8 devices (the scripts/ci.sh
   fleet-parity job forces them).

Throughput figures always come from a SECOND ``generate`` call — the
first, traced call pays jit compilation and is excluded from every
``tokens_per_s`` wall. Paged rows also report the latency phase split
(``ttft_ms`` time-to-first-token vs ``tpot_ms`` per-token decode
latency) straight from engine.last_stats.

Engine stats of every engine run land in ``ENGINE_STATS`` (reset per
``run()``) so ``benchmarks/run.py --json`` can emit them machine-readably.

    PYTHONPATH=src python benchmarks/bench_serving.py [--smoke] [--mesh]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro import configs as registry
from repro.config.base import (KernelConfig, QuantConfig, RegistryConfig,
                               RunConfig, SHAPES, ServeConfig)
from repro.core import tt as ttlib
from repro.core.merge import fold_transformer
from repro.kernels import dispatch
from repro.models import model as M, transformer as T
from repro.peft import api as peft_api
from repro.serving import AdapterRuntime, Engine, Request
from repro.serving import engine as se

#: engine stats (dataclasses.asdict + derived rates) of every timed engine
#: run in the latest run() call, labeled — consumed by run.py --json
ENGINE_STATS: list = []

#: documented int8-vs-fp greedy-parity floor (argmax near-ties flip under
#: quantization noise on a random-weight smoke model)
TOKEN_MATCH_MIN = 0.9


def _record_stats(label: str, st) -> None:
    d = dataclasses.asdict(st)
    d.update(label=label, tokens_per_s=st.tokens_per_s,
             prefix_hit_rate=st.prefix_hit_rate,
             kv_bytes_peak=st.kv_bytes_peak)
    ENGINE_STATS.append(d)


def _decode_step_rows(rows) -> None:
    cfg = registry.get_smoke_config("stablelm-1.6b")
    run_cfg = RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                        adapter_kind="metatt", adapter_rank=8)
    spec = M.build_adapter_spec(run_cfg)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, spec, key)
    params["adapter"] = {"cores": ttlib.random_tt(key, spec.cfg.mode_sizes,
                                                  8, scale=0.1)}
    bc, pl = peft_api.adapter_factors(spec, params["adapter"],
                                      params["frozen"])
    B, S = 4, 64
    token = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
    caches = T.init_caches(cfg, B, S, jnp.float32)
    pos = jnp.int32(3)

    live = jax.jit(lambda tok, c: T.decode_step(
        params["base"], cfg, spec, bc, pl, tok, c, pos)[0])
    us_live = time_call(live, token, caches)
    rows.append(emit("serving/decode_live_tt", us_live, "adapter=metatt-r8"))

    # same decode step through the fused dispatch seam (interpret mode on
    # CPU is a correctness emulator, not a speed number; TPU is the target)
    fused = jax.jit(lambda tok, c: T.decode_step(
        params["base"], cfg, spec, bc, pl, tok, c, pos,
        policy=dispatch.PALLAS_INTERPRET)[0])
    us_fused = time_call(fused, token, caches, iters=3, warmup=1)
    rows.append(emit("serving/decode_live_fused_interpret", us_fused,
                     "adapter=metatt-r8,interpret=1"))

    # merged: fold ΔW into every adapted weight, run with NO adapter
    folded = fold_transformer(params["adapter"], spec.cfg, params["base"],
                              cfg)
    merged_fn = jax.jit(lambda tok, c: T.decode_step(
        folded, cfg, peft_api.NONE, {}, None, tok, c, pos)[0])
    us_merged = time_call(merged_fn, token, caches)
    rows.append(emit("serving/decode_merged", us_merged,
                     f"overhead_removed={us_live-us_merged:.0f}us"))

    base_fn = jax.jit(lambda tok, c: T.decode_step(
        params["base"], cfg, peft_api.NONE, {}, None, tok, c, pos)[0])
    us_base = time_call(base_fn, token, caches)
    rows.append(emit("serving/decode_base_no_adapter", us_base,
                     f"merged_vs_base_ratio={us_merged/us_base:.3f}"))


def _engine_rows(rows, *, smoke: bool) -> None:
    """Mixed-task continuous batching vs the seed per-token Python loop."""
    n_req, n_new, slots, n_tasks = (4, 8, 2, 2) if smoke else (12, 24, 4, 3)
    cfg = registry.get_smoke_config("stablelm-1.6b")
    run_cfg = RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                        adapter_kind="metatt", adapter_variant="4+1d",
                        num_tasks=n_tasks, adapter_rank=8)
    spec = M.build_adapter_spec(run_cfg)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, spec, key)
    params["adapter"] = {"cores": ttlib.random_tt(key, spec.cfg.mode_sizes,
                                                  8, scale=0.5)}
    keys = jax.random.split(key, n_req)
    prompts = [jax.random.randint(keys[i], (4 + i % 4,), 0, cfg.vocab_size)
               for i in range(n_req)]
    # >= 2 distinct task ids in every decode batch, one shared 4+1d TT
    reqs = [Request(p, n_new, task=i % n_tasks)
            for i, p in enumerate(prompts)]
    cache_len = 8 + n_new

    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    eng = Engine(cfg, rt, max_batch=slots, cache_len=cache_len,
                 out_cap=n_new)
    eng.generate(reqs)                       # compile
    t0 = time.perf_counter()
    outs = eng.generate(reqs)
    dt_eng = time.perf_counter() - t0
    toks = sum(len(o) for o in outs)
    tasks_served = len({r.task for r in reqs})
    rows.append(emit("serving/engine_mixed_task_continuous",
                     dt_eng / toks * 1e6,
                     f"tok_per_s={toks/dt_eng:.1f},slots={slots},"
                     f"tasks={tasks_served}"))

    # seed path: per-token Python loop, one request shape at a time
    prefill = se.make_prefill(cfg, spec, cache_len)
    step = se.make_serve_step(cfg, spec)

    def one_shot(prompt, task):
        lg, caches, _ = prefill(params["base"], params["adapter"],
                                params["frozen"], prompt[None], None, None,
                                task)
        tok = jnp.argmax(lg[:, -1], axis=-1)[:, None]
        n = 1
        for i in range(n_new - 1):
            lg, caches = step(params["base"], params["adapter"],
                              params["frozen"], tok, caches,
                              jnp.int32(prompt.shape[0] + i), None, task)
            tok = jnp.argmax(lg, axis=-1)[:, None]
            n += 1
        jax.block_until_ready(tok)
        return n

    for p in {int(p.shape[0]): p for p in prompts}.values():
        one_shot(p, jnp.int32(0))            # compile every prompt shape
    t0 = time.perf_counter()
    toks_py = sum(one_shot(p, jnp.int32(r.task))
                  for p, r in zip(prompts, reqs))
    dt_py = time.perf_counter() - t0
    rows.append(emit("serving/python_loop_one_shot", dt_py / toks_py * 1e6,
                     f"tok_per_s={toks_py/dt_py:.1f},"
                     f"speedup_engine={dt_py/toks_py*toks/dt_eng:.2f}x"))


def _fused_engine_rows(rows, *, smoke: bool) -> None:
    """Engine fused-vs-unfused from the SAME dispatch seam: identical
    requests, identical runtime, only ``kernels=`` differs. The fused
    engine's decode loop runs ``tt_linear_batched_a`` (slot-gathered A)
    and the decode-shaped flash kernel; on CPU the Pallas leg runs under
    interpret (correctness emulator), so the derived column also asserts
    token parity — the number that matters off-TPU."""
    n_req, n_new, slots, n_tasks = (3, 5, 2, 2) if smoke else (6, 8, 3, 3)
    cfg = registry.get_smoke_config("stablelm-1.6b")
    run_cfg = RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                        adapter_kind="metatt", adapter_variant="4+1d",
                        num_tasks=n_tasks, adapter_rank=8)
    spec = M.build_adapter_spec(run_cfg)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, spec, key)
    params["adapter"] = {"cores": ttlib.random_tt(key, spec.cfg.mode_sizes,
                                                  8, scale=0.5)}
    keys = jax.random.split(key, n_req)
    reqs = [Request(jax.random.randint(keys[i], (4 + i % 3,), 0,
                                       cfg.vocab_size), n_new,
                    task=i % n_tasks) for i in range(n_req)]
    rt = AdapterRuntime.build("lora", params["base"], spec,
                              params["adapter"], params["frozen"])
    outs = {}
    for name, kcfg in (("unfused", None),
                       ("fused_interpret", KernelConfig(backend="pallas",
                                                        interpret=True))):
        # dense mode: the single-token decode path is what the batched-A
        # and decode-flash kernels fuse (the paged path is benchmarked in
        # _paged_rows)
        eng = Engine(cfg, rt, serve=ServeConfig(
            max_batch=slots, cache_len=8 + n_new, out_cap=n_new,
            cache_mode="dense"), kernels=kcfg)
        eng.generate(reqs)               # compile
        t0 = time.perf_counter()
        outs[name] = eng.generate(reqs)
        dt = time.perf_counter() - t0
        toks = sum(len(o) for o in outs[name])
        rows.append(emit(f"serving/engine_{name}", dt / toks * 1e6,
                         f"tok_per_s={toks/dt:.1f},slots={slots},"
                         f"tasks={n_tasks},runtime=lora"))
    parity = all(a.tolist() == b.tolist() for a, b in
                 zip(outs["unfused"], outs["fused_interpret"]))
    rows.append(emit("serving/engine_fused_token_parity", 0.0,
                     f"identical_tokens={parity}"))
    if not parity:
        raise AssertionError(
            "fused engine decode diverged from the unfused path")


def _paged_rows(rows, *, smoke: bool) -> None:
    """Paged vs dense KV cache on a shared-prefix mixed-task workload.

    Half the requests share a common prompt prefix (the multi-task
    deployment shape: one system prompt, many tasks — sharable across
    tasks precisely because ONE MetaTT tensor train serves them all).
    The dense engine reserves max_batch × cache_len up front; the paged
    engine allocates per request and reuses prefix blocks, so its peak
    KV memory (engine.last_stats.kv_bytes_peak) must come in lower and
    its prefix hit rate nonzero. Token parity dense-vs-paged is asserted.
    """
    n_req, n_new, slots = (6, 6, 3) if smoke else (16, 16, 4)
    cfg = registry.get_smoke_config("stablelm-1.6b")
    run_cfg = RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                        adapter_kind="metatt", adapter_variant="4+1d",
                        num_tasks=2, adapter_rank=8)
    spec = M.build_adapter_spec(run_cfg)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, spec, key)
    params["adapter"] = {"cores": ttlib.random_tt(key, spec.cfg.mode_sizes,
                                                  8, scale=0.5)}
    rt = AdapterRuntime.build("lora", params["base"], spec,
                              params["adapter"], params["frozen"])
    cache_len = 32 + n_new
    sys_prompt = np.asarray(jax.random.randint(key, (18,), 0,
                                               cfg.vocab_size))
    keys = jax.random.split(key, n_req)
    reqs = []
    for i in range(n_req):
        tail = np.asarray(jax.random.randint(keys[i], (2 + i % 4,), 0,
                                             cfg.vocab_size))
        prompt = (np.concatenate([sys_prompt, tail])
                  if i % 2 == 0 else tail)      # half share the prefix
        reqs.append(Request(prompt, n_new, task=i % 2))

    outs = {}
    for mode in ("dense", "paged"):
        eng = Engine(cfg, rt, serve=ServeConfig(
            max_batch=slots, cache_len=cache_len, out_cap=n_new,
            cache_mode=mode, page_size=8, prefill_chunk=8))
        eng.generate(reqs)                      # compile + warm the cache
        t0 = time.perf_counter()
        outs[mode] = eng.generate(reqs)
        dt = time.perf_counter() - t0
        st = eng.last_stats                     # the engine's own numbers
        rows.append(emit(
            f"serving/engine_{mode}_shared_prefix", dt / max(
                st.tokens_generated, 1) * 1e6,
            f"tok_per_s={st.tokens_per_s:.1f},"
            f"ttft_ms={st.ttft_s * 1e3:.1f},"
            f"tpot_ms={st.tpot_s * 1e3:.2f},"
            f"kv_bytes_peak={st.kv_bytes_peak},"
            f"kv_blocks_peak={st.kv_blocks_peak}/{st.num_blocks},"
            f"prefix_hit_rate={st.prefix_hit_rate:.2f},"
            f"cow={st.cow_copies},waits={st.backpressure_waits},"
            f"decode_traces={st.decode_traces},"
            f"prefill_traces={st.prefill_traces}"))
        _record_stats(f"engine_{mode}_shared_prefix", st)
        print(f"# engine stats [{mode}]: {st.summary()}")
        if mode == "dense":
            dense_bytes = st.kv_bytes_peak   # the engine's own number
        if mode == "paged":
            parity = all(a.tolist() == b.tolist() for a, b in
                         zip(outs["dense"], outs["paged"]))
            rows.append(emit(
                "serving/paged_vs_dense", 0.0,
                f"identical_tokens={parity},"
                f"kv_bytes_paged={st.kv_bytes_peak},"
                f"kv_bytes_dense={dense_bytes},"
                f"prefix_hit_rate={st.prefix_hit_rate:.2f}"))
            if not parity:
                raise AssertionError("paged engine diverged from dense")
            if not st.prefix_hit_rate > 0:
                raise AssertionError("shared-prefix workload missed the "
                                     "prefix cache")
            if not st.kv_bytes_peak < dense_bytes:
                raise AssertionError(
                    f"paged peak KV {st.kv_bytes_peak} not below dense "
                    f"reservation {dense_bytes}")


def _quant_rows(rows, *, smoke: bool) -> None:
    """fp vs int8 (weights=int8 w8a16 + kv=int8) paged serving on the
    shared-prefix mixed-task workload (DESIGN.md §8).

    Both engines run the same requests at the same ``num_blocks`` budget;
    the int8 run must (a) track the fp run's greedy tokens within the
    documented TOKEN_MATCH_MIN tolerance and (b) report lower peak KV
    bytes (its blocks are int8 cells + per-cell scales — roughly half of
    bf16, a quarter of f32) — both read from engine.last_stats.
    """
    n_req, n_new, slots = (6, 6, 3) if smoke else (16, 16, 4)
    cfg = registry.get_smoke_config("stablelm-1.6b")
    run_cfg = RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                        adapter_kind="metatt", adapter_variant="4+1d",
                        num_tasks=2, adapter_rank=8)
    spec = M.build_adapter_spec(run_cfg)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, spec, key)
    params["adapter"] = {"cores": ttlib.random_tt(key, spec.cfg.mode_sizes,
                                                  8, scale=0.5)}
    rt = AdapterRuntime.build("lora", params["base"], spec,
                              params["adapter"], params["frozen"])
    cache_len = 32 + n_new
    sys_prompt = np.asarray(jax.random.randint(key, (18,), 0,
                                               cfg.vocab_size))
    keys = jax.random.split(key, n_req)
    reqs = []
    for i in range(n_req):
        tail = np.asarray(jax.random.randint(keys[i], (2 + i % 4,), 0,
                                             cfg.vocab_size))
        prompt = (np.concatenate([sys_prompt, tail])
                  if i % 2 == 0 else tail)
        reqs.append(Request(prompt, n_new, task=i % 2))

    outs, stats = {}, {}
    for name, qc in (("fp", QuantConfig()),
                     ("int8", QuantConfig(weights="int8", kv="int8"))):
        eng = Engine(cfg, rt, serve=ServeConfig(
            max_batch=slots, cache_len=cache_len, out_cap=n_new,
            page_size=8, prefill_chunk=8, quant=qc))
        eng.generate(reqs)                      # compile + warm the cache
        t0 = time.perf_counter()
        outs[name] = eng.generate(reqs)
        dt = time.perf_counter() - t0
        st = eng.last_stats
        stats[name] = st
        rows.append(emit(
            f"serving/engine_paged_{name}",
            dt / max(st.tokens_generated, 1) * 1e6,
            f"tok_per_s={st.tokens_per_s:.1f},w={st.weights_dtype},"
            f"kv={st.kv_dtype},kv_bytes_peak={st.kv_bytes_peak},"
            f"kv_blocks_peak={st.kv_blocks_peak}/{st.num_blocks},"
            f"block_bytes={st.block_bytes},"
            f"prefix_hit_rate={st.prefix_hit_rate:.2f}"))
        _record_stats(f"engine_paged_{name}", st)
        print(f"# engine stats [{name}]: {st.summary()}")
    total = sum(len(o) for o in outs["fp"])
    same = sum(int(a == b) for f, q in zip(outs["fp"], outs["int8"])
               for a, b in zip(f.tolist(), q.tolist()))
    match = same / total
    rows.append(emit(
        "serving/int8_vs_fp", 0.0,
        f"token_match={match:.3f},"
        f"kv_bytes_int8={stats['int8'].kv_bytes_peak},"
        f"kv_bytes_fp={stats['fp'].kv_bytes_peak},"
        f"block_bytes_int8={stats['int8'].block_bytes},"
        f"block_bytes_fp={stats['fp'].block_bytes}"))
    if match < TOKEN_MATCH_MIN:
        raise AssertionError(
            f"int8 engine greedy tokens match fp at {match:.3f} < "
            f"{TOKEN_MATCH_MIN} tolerance")
    if not stats["int8"].kv_bytes_peak < stats["fp"].kv_bytes_peak:
        raise AssertionError(
            f"int8 peak KV bytes {stats['int8'].kv_bytes_peak} not below "
            f"fp {stats['fp'].kv_bytes_peak} at equal num_blocks")


def _mesh_rows(rows, *, smoke: bool, mesh_shape=(1, 4)) -> None:
    """Tensor-parallel vs single-device paged serving (DESIGN.md §9) on
    the shared-prefix mixed-task workload.

    Both engines serve identical requests; the TP engine shards the KV
    pools on the kv-head axis over the "model" mesh axis. Asserted from
    the engines' own stats: token identity (greedy decode is bitwise
    deterministic under the head/vocab-stripe sharding), global KV
    accounting unchanged, and per-shard peak KV bytes == global / tp.
    """
    tp = int(np.prod(mesh_shape))
    if jax.device_count() < tp:
        raise SystemExit(
            f"--mesh needs {tp} devices; on CPU run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={tp}")
    n_req, n_new, slots = (6, 6, 3) if smoke else (16, 16, 4)
    cfg = registry.get_smoke_config("stablelm-1.6b")
    run_cfg = RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                        adapter_kind="metatt", adapter_variant="4+1d",
                        num_tasks=2, adapter_rank=8)
    spec = M.build_adapter_spec(run_cfg)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, spec, key)
    params["adapter"] = {"cores": ttlib.random_tt(key, spec.cfg.mode_sizes,
                                                  8, scale=0.5)}
    rt = AdapterRuntime.build("lora", params["base"], spec,
                              params["adapter"], params["frozen"])
    cache_len = 32 + n_new
    sys_prompt = np.asarray(jax.random.randint(key, (18,), 0,
                                               cfg.vocab_size))
    keys = jax.random.split(key, n_req)
    reqs = []
    for i in range(n_req):
        tail = np.asarray(jax.random.randint(keys[i], (2 + i % 4,), 0,
                                             cfg.vocab_size))
        prompt = (np.concatenate([sys_prompt, tail])
                  if i % 2 == 0 else tail)
        reqs.append(Request(prompt, n_new, task=i % 2))

    outs, stats = {}, {}
    for label, mesh in (("tp1", ()), (f"tp{tp}", tuple(mesh_shape))):
        eng = Engine(cfg, rt, serve=ServeConfig(
            max_batch=slots, cache_len=cache_len, out_cap=n_new,
            page_size=8, prefill_chunk=8, mesh_shape=mesh))
        eng.generate(reqs)                      # compile + warm the cache
        t0 = time.perf_counter()
        outs[label] = eng.generate(reqs)
        dt = time.perf_counter() - t0
        st = eng.last_stats
        stats[label] = st
        rows.append(emit(
            f"serving/engine_{label}",
            dt / max(st.tokens_generated, 1) * 1e6,
            f"tok_per_s={st.tokens_per_s:.1f},shards={st.shards},"
            f"kv_bytes_peak={st.kv_bytes_peak},"
            f"kv_bytes_peak_per_shard={st.kv_bytes_peak_per_shard},"
            f"prefix_hit_rate={st.prefix_hit_rate:.2f}"))
        _record_stats(f"engine_{label}", st)
        print(f"# engine stats [{label}]: {st.summary()}")
    t1, t4 = stats["tp1"], stats[f"tp{tp}"]
    parity = all(a.tolist() == b.tolist() for a, b in
                 zip(outs["tp1"], outs[f"tp{tp}"]))
    rows.append(emit(
        f"serving/tp{tp}_vs_tp1", 0.0,
        f"identical_tokens={parity},shards={t4.shards},"
        f"kv_bytes_peak={t4.kv_bytes_peak},"
        f"kv_bytes_peak_per_shard={t4.kv_bytes_peak_per_shard},"
        f"tok_per_s_tp1={t1.tokens_per_s:.1f},"
        f"tok_per_s_tp{tp}={t4.tokens_per_s:.1f}"))
    if not parity:
        raise AssertionError("sharded engine diverged from single-device")
    if t4.kv_bytes_peak != t1.kv_bytes_peak:
        raise AssertionError("global KV accounting changed under TP")
    if t4.kv_bytes_peak_per_shard * t4.shards != t4.kv_bytes_peak:
        raise AssertionError("per-shard KV bytes do not sum to global")


def _fleet_rows(rows, *, smoke: bool, mesh_shape=(2, 4)) -> None:
    """Data-striped vs single-replica paged serving at fixed TP width
    (DESIGN.md §11) on the shared-prefix mixed-task workload.

    Both engines serve identical requests through the same tp-wide
    shard groups; the dp2 engine stripes requests over two decode
    replicas (deterministic least-loaded routing), each owning a
    private stripe of the slots and the block pool. Asserted from the
    engines' own stats: token identity, per-replica block accounting
    (every replica's peak stays inside its private ``num_blocks / dp``
    budget and the striped pool leaves physically hold 1/dp of the
    global blocks per data shard), and unchanged global KV accounting.
    The compile (first traced) call is excluded from every wall.
    """
    dp, tp = int(mesh_shape[0]), int(mesh_shape[1])
    if jax.device_count() < dp * tp:
        raise SystemExit(
            f"--fleet needs {dp * tp} devices; on CPU run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={dp * tp}")
    n_req, n_new, slots = (6, 6, 3) if smoke else (16, 16, 4)
    cfg = registry.get_smoke_config("stablelm-1.6b")
    run_cfg = RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                        adapter_kind="metatt", adapter_variant="4+1d",
                        num_tasks=2, adapter_rank=8)
    spec = M.build_adapter_spec(run_cfg)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, spec, key)
    params["adapter"] = {"cores": ttlib.random_tt(key, spec.cfg.mode_sizes,
                                                  8, scale=0.5)}
    rt = AdapterRuntime.build("lora", params["base"], spec,
                              params["adapter"], params["frozen"])
    cache_len = 32 + n_new
    sys_prompt = np.asarray(jax.random.randint(key, (18,), 0,
                                               cfg.vocab_size))
    keys = jax.random.split(key, n_req)
    reqs = []
    for i in range(n_req):
        tail = np.asarray(jax.random.randint(keys[i], (2 + i % 4,), 0,
                                             cfg.vocab_size))
        prompt = (np.concatenate([sys_prompt, tail])
                  if i % 2 == 0 else tail)
        reqs.append(Request(prompt, n_new, task=i % 2))

    outs, stats, pools = {}, {}, {}
    for label, mesh in (("dp1", (1, tp)), (f"dp{dp}", (dp, tp))):
        eng = Engine(cfg, rt, serve=ServeConfig(
            max_batch=slots, cache_len=cache_len, out_cap=n_new,
            page_size=8, prefill_chunk=8, mesh_shape=mesh))
        eng.generate(reqs)      # compile — excluded from the timed wall
        t0 = time.perf_counter()
        outs[label] = eng.generate(reqs)
        dt = time.perf_counter() - t0
        st = eng.last_stats
        stats[label] = st
        pools[label] = eng._paged_caches
        rows.append(emit(
            f"serving/engine_fleet_{label}",
            dt / max(st.tokens_generated, 1) * 1e6,
            f"tok_per_s={st.tokens_per_s:.1f},dp={st.data_shards},"
            f"shards={st.shards},ttft_ms={st.ttft_s * 1e3:.1f},"
            f"tpot_ms={st.tpot_s * 1e3:.2f},"
            f"kv_bytes_peak={st.kv_bytes_peak},"
            f"kv_blocks_peak={st.kv_blocks_peak}/{st.num_blocks}"))
        _record_stats(f"engine_fleet_{label}", st)
        print(f"# engine stats [{label}]: {st.summary()}")
    d1, d2 = stats["dp1"], stats[f"dp{dp}"]
    parity = all(a.tolist() == b.tolist() for a, b in
                 zip(outs["dp1"], outs[f"dp{dp}"]))
    reps = [r for r in d2.replica_stats if r["replica"] >= 0]
    per_replica_blocks = d2.num_blocks // dp
    rep_peak_bytes = [r["kv_blocks_peak"] * d2.block_bytes for r in reps]
    rows.append(emit(
        f"serving/dp{dp}_vs_dp1", 0.0,
        f"identical_tokens={parity},dp={d2.data_shards},"
        f"replica_kv_bytes_peak={'|'.join(map(str, rep_peak_bytes))},"
        f"replica_block_budget={per_replica_blocks},"
        f"tok_per_s_dp1={d1.tokens_per_s:.1f},"
        f"tok_per_s_dp{dp}={d2.tokens_per_s:.1f},"
        f"ttft_ms_dp{dp}={d2.ttft_s * 1e3:.1f},"
        f"tpot_ms_dp{dp}={d2.tpot_s * 1e3:.2f}"))
    if not parity:
        raise AssertionError("dp-striped engine diverged from dp1")
    if len(reps) != dp or sorted(r["replica"] for r in reps) != list(
            range(dp)):
        raise AssertionError(f"expected {dp} replica stats, got {reps}")
    if sum(r["admitted"] for r in reps) != len(reqs):
        raise AssertionError("replica admissions do not cover the batch")
    for r in reps:
        if not 0 < r["kv_blocks_peak"] <= per_replica_blocks:
            raise AssertionError(
                f"replica {r['replica']} peak {r['kv_blocks_peak']} "
                f"outside its private budget {per_replica_blocks}")
    if d2.block_bytes != d1.block_bytes:
        raise AssertionError("per-block bytes changed under dp striping")
    for leaf in jax.tree_util.tree_leaves(pools[f"dp{dp}"]):
        if leaf.addressable_shards[0].data.shape[1] * dp != leaf.shape[1]:
            raise AssertionError(
                "pool leaves are not physically striped 1/dp per data "
                f"shard: {leaf.addressable_shards[0].data.shape} of "
                f"{leaf.shape}")


def _multitask_rows(rows, *, smoke: bool) -> None:
    """Paged adapter registry (DESIGN.md §12): a zipf(1.1) stream over
    256 distinct tasks served through an 8-slot device pool vs the
    all-resident engine.

    The workload is the registry's design point — a long-tailed task
    popularity where a handful of hot tasks cover most admissions (high
    hit rate) while the cold tail still faults through the pool. Token
    identity against the all-resident engine is asserted outright;
    ``decode_traces`` must stay 1 (fault-ins are one pre-jitted donated
    scatter, never a retrace). Throughput of the pooled engine must stay
    within 15% of all-resident (asserted in the full run only — smoke
    shapes on CPU are timing noise).
    """
    n_tasks, n_slots = 256, 8
    n_req, n_new = (48, 4) if smoke else (192, 8)
    cfg = registry.get_smoke_config("stablelm-1.6b")
    run_cfg = RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                        adapter_kind="metatt", adapter_variant="4+1d",
                        num_tasks=n_tasks, adapter_rank=8)
    spec = M.build_adapter_spec(run_cfg)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, spec, key)
    params["adapter"] = {"cores": ttlib.random_tt(key, spec.cfg.mode_sizes,
                                                  8, scale=0.5)}
    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    # zipf(1.1) by explicit rank probabilities (bounded support, unlike
    # rng.zipf): task id == popularity rank
    rng = np.random.default_rng(0)
    p = 1.0 / np.arange(1, n_tasks + 1) ** 1.1
    tasks = rng.choice(n_tasks, size=n_req, p=p / p.sum())
    keys = jax.random.split(key, n_req)
    reqs = [Request(np.asarray(jax.random.randint(
        keys[i], (4 + i % 4,), 0, cfg.vocab_size)), n_new,
        task=int(tasks[i])) for i in range(n_req)]

    outs, stats = {}, {}
    for label, reg in (("all_resident", RegistryConfig()),
                       (f"pool{n_slots}",
                        RegistryConfig(max_resident_tasks=n_slots))):
        eng = Engine(cfg, rt, serve=ServeConfig(
            max_batch=4, cache_len=16 + n_new, out_cap=n_new, page_size=8,
            prefill_chunk=8, registry=reg))
        eng.generate(reqs)      # compile — excluded from the timed wall
        t0 = time.perf_counter()
        outs[label] = eng.generate(reqs)
        dt = time.perf_counter() - t0
        st = eng.last_stats
        stats[label] = st
        rows.append(emit(
            f"serving/engine_multitask_{label}",
            dt / max(st.tokens_generated, 1) * 1e6,
            f"tok_per_s={st.tokens_per_s:.1f},"
            f"tasks={n_tasks},slots={st.max_resident_tasks},"
            f"adapter_hit_rate={st.adapter_hit_rate:.2f},"
            f"adapter_faults={st.adapter_faults},"
            f"adapter_evictions={st.adapter_evictions},"
            f"decode_traces={st.decode_traces}"))
        _record_stats(f"engine_multitask_{label}", st)
        print(f"# engine stats [{label}]: {st.summary()}")
    full, pool = stats["all_resident"], stats[f"pool{n_slots}"]
    parity = all(a.tolist() == b.tolist() for a, b in
                 zip(outs["all_resident"], outs[f"pool{n_slots}"]))
    ratio = pool.tokens_per_s / max(full.tokens_per_s, 1e-9)
    rows.append(emit(
        "serving/zipf_256tasks", 0.0,
        f"identical_tokens={parity},tasks={n_tasks},slots={n_slots},"
        f"zipf_a=1.1,requests={n_req},"
        f"adapter_hit_rate={pool.adapter_hit_rate:.2f},"
        f"adapter_faults={pool.adapter_faults},"
        f"adapter_evictions={pool.adapter_evictions},"
        f"adapter_waits={pool.adapter_waits},"
        f"tok_per_s_all={full.tokens_per_s:.1f},"
        f"tok_per_s_pool={pool.tokens_per_s:.1f},"
        f"tok_per_s_ratio={ratio:.2f}"))
    if not parity:
        raise AssertionError(
            "pooled-registry engine diverged from all-resident")
    if pool.decode_traces != 1:
        raise AssertionError(
            f"adapter fault-ins retraced the decode graph: "
            f"decode_traces={pool.decode_traces}")
    if pool.adapter_faults == 0 or pool.adapter_hits == 0:
        raise AssertionError(
            "zipf workload should both fault (cold tail) and hit (hot "
            f"head): faults={pool.adapter_faults} hits={pool.adapter_hits}")
    if not smoke and ratio < 0.85:
        raise AssertionError(
            f"pooled throughput {ratio:.2f}x all-resident — outside the "
            "15% budget")


def _decaying_tt(key, mode_sizes, rank, scale, decay):
    """Random TT whose bond strength decays geometrically — the spectrum
    shape DMRG rank adaptation produces on trained adapters (and the
    regime where rank-truncated drafters track the target; a flat random
    spectrum makes truncation a valid but useless approximation)."""
    cores = ttlib.random_tt(key, mode_sizes, rank, scale=scale)
    w = decay ** jnp.arange(rank)
    out = []
    for i, c in enumerate(cores):
        if i == 0:
            out.append(c * w[None, :])
        else:
            shape = [1] * c.ndim
            shape[0] = c.shape[0]
            out.append(c * w[: c.shape[0]].reshape(shape))
    return out


def _spec_rows(rows, *, smoke: bool) -> None:
    """Speculative decode (rank-truncated + layer-strided TT self-drafter,
    DESIGN.md §10) vs the plain paged engine on the shared-prefix
    workload.

    The random-weight smoke model is made REPRESENTATIVE of the regime
    speculation targets: the adapter's TT cores get a geometrically
    decaying bond spectrum (what DMRG rank adaptation yields on trained
    adapters — so the rank-truncated drafter tracks the target) and the
    base's block output projections are damped so each block is a small
    residual perturbation (trained-network shape — so the layer-strided
    drafter stays close). Asserted, all from engine.last_stats + outputs:
    greedy token IDENTITY with speculation on (the accept rule only
    commits verifier-argmax prefixes), acceptance_rate > 0.5, and
    tokens/sec strictly above the non-speculative baseline (best-of-3
    walls — the drafter runs half the layers, so k drafts + 1 verify
    cost less than k+1 target passes)."""
    import jax.tree_util as jtu
    n_req, n_new, slots = (6, 16, 3) if smoke else (8, 24, 4)
    cfg = registry.get_smoke_config("stablelm-1.6b")
    run_cfg = RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                        adapter_kind="metatt", adapter_variant="4+1d",
                        num_tasks=2, adapter_rank=8)
    spec = M.build_adapter_spec(run_cfg)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, spec, key)
    params["adapter"] = {"cores": _decaying_tt(key, spec.cfg.mode_sizes,
                                               8, 0.5, 0.35)}
    blocks = jtu.tree_map_with_path(
        lambda p, a: a * 0.05 if any(getattr(k, "key", None) in
                                     ("wo", "wd") for k in p) else a,
        params["base"]["blocks"])
    base = dict(params["base"])
    base["blocks"] = blocks
    rt = AdapterRuntime.build("live", base, spec, params["adapter"],
                              params["frozen"])
    cache_len = 32 + n_new
    sys_prompt = np.asarray(jax.random.randint(key, (18,), 0,
                                               cfg.vocab_size))
    keys = jax.random.split(key, n_req)
    reqs = []
    for i in range(n_req):
        tail = np.asarray(jax.random.randint(keys[i], (2 + i % 4,), 0,
                                             cfg.vocab_size))
        prompt = (np.concatenate([sys_prompt, tail])
                  if i % 2 == 0 else tail)
        reqs.append(Request(prompt, n_new, task=i % 2))

    from repro.config.base import SpecConfig
    outs, walls, stats = {}, {}, {}
    for label, sc in (("base", SpecConfig()),
                      ("spec", SpecConfig(spec_k=3, draft_rank=4,
                                          draft_layer_stride=2))):
        eng = Engine(cfg, rt, serve=ServeConfig(
            max_batch=slots, cache_len=cache_len, out_cap=n_new,
            page_size=8, prefill_chunk=8, spec=sc))
        eng.generate(reqs)                      # compile + warm the cache
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            outs[label] = eng.generate(reqs)
            best = min(best, time.perf_counter() - t0)
        walls[label] = best
        st = eng.last_stats
        stats[label] = st
        rows.append(emit(
            f"serving/engine_{label}_speculative"
            if label == "spec" else "serving/engine_no_spec",
            best / max(st.tokens_generated, 1) * 1e6,
            f"tok_per_s={st.tokens_generated/best:.1f},"
            f"spec_k={st.spec_k},accept={st.acceptance_rate:.3f},"
            f"tok_per_step={st.tokens_per_step:.2f},"
            f"decode_traces={st.decode_traces}"))
        _record_stats(f"engine_{label}_spec_workload", st)
        print(f"# engine stats [{label}]: {st.summary()}")
    parity = all(a.tolist() == b.tolist() for a, b in
                 zip(outs["base"], outs["spec"]))
    accept = stats["spec"].acceptance_rate
    speedup = walls["base"] / walls["spec"]
    rows.append(emit(
        "serving/spec_vs_base", 0.0,
        f"identical_tokens={parity},accept={accept:.3f},"
        f"speedup={speedup:.2f}x,spec_k=3,draft_rank=4,"
        f"draft_layer_stride=2,"
        f"tok_per_step={stats['spec'].tokens_per_step:.2f}"))
    if not parity:
        raise AssertionError(
            "speculative greedy decode diverged from the baseline engine")
    if not accept > 0.5:
        raise AssertionError(
            f"drafter acceptance {accept:.3f} <= 0.5 on the decaying-"
            "spectrum workload")
    if not speedup > 1.0:
        raise AssertionError(
            f"speculative engine not faster: {speedup:.2f}x <= 1.0")


def _chaos_rows(rows, *, smoke: bool) -> None:
    """Serving resilience smoke (DESIGN.md §13): the shared-prefix
    workload under a seeded chaos schedule — forced allocation
    failures, one scripted cancel, one NaN-logit injection — vs the
    fault-free run.

    Asserted: every SURVIVOR (request not deliberately killed) is
    token-identical to the fault-free run, the cancelled / failed
    requests carry the right terminal status, ``decode_traces`` stays
    1 (aborts and the NaN guard ride the one compiled graph), and the
    per-step pool audits (run by the injector on every host-loop
    iteration) plus the at-rest audit hold — zero leaked blocks, zero
    leaked adapter pins. The ``serving/chaos_survivors`` row records
    what fired and what survived.
    """
    from repro.serving import FINISHED, ChaosInjector, audit
    n_req, n_new, slots = (6, 6, 3) if smoke else (12, 12, 4)
    cfg = registry.get_smoke_config("stablelm-1.6b")
    run_cfg = RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                        adapter_kind="metatt", adapter_variant="4+1d",
                        num_tasks=2, adapter_rank=8)
    spec = M.build_adapter_spec(run_cfg)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, spec, key)
    params["adapter"] = {"cores": ttlib.random_tt(key, spec.cfg.mode_sizes,
                                                  8, scale=0.5)}
    rt = AdapterRuntime.build("lora", params["base"], spec,
                              params["adapter"], params["frozen"])
    cache_len = 32 + n_new
    keys = jax.random.split(key, n_req)
    reqs = [Request(np.asarray(jax.random.randint(
        keys[i], (4 + i % 4,), 0, cfg.vocab_size)), n_new, task=i % 2,
        request_id=f"r{i}") for i in range(n_req)]

    sv = ServeConfig(max_batch=slots, cache_len=cache_len, out_cap=n_new,
                     page_size=8, prefill_chunk=8)
    eng = Engine(cfg, rt, serve=sv)
    baseline = eng.generate(reqs)           # compile + fault-free tokens
    chaos = ChaosInjector(seed=0, alloc_fail_steps=(0, 1),
                          alloc_fail_rate=0.2,
                          cancel_at={1: ["r1"]},
                          nan_after={"r3": 1})
    t0 = time.perf_counter()
    out = eng.generate(reqs, chaos=chaos)
    dt = time.perf_counter() - t0
    st = eng.last_stats
    audit(eng)                              # at rest: drained, zero pins
    victims = {"r1", "r3"}
    survivors = [i for i in range(n_req)
                 if reqs[i].request_id not in victims]
    identical = all(out[i].tolist() == baseline[i].tolist()
                    for i in survivors)
    statuses = [r.status for r in eng.last_results]
    rows.append(emit(
        "serving/chaos_survivors",
        dt / max(st.tokens_generated, 1) * 1e6,
        f"survivors_identical={identical},"
        f"survivors={len(survivors)}/{n_req},"
        f"alloc_faults={chaos.alloc_faults},"
        f"cancelled={st.cancelled},nan_faults={st.numerics_faults},"
        f"failed={st.failed_requests},waits={st.backpressure_waits},"
        f"decode_traces={st.decode_traces}"))
    _record_stats("engine_chaos_survivors", st)
    print(f"# engine stats [chaos]: {st.summary()}")
    if not identical:
        raise AssertionError(
            "chaos perturbed a survivor's tokens — scheduling faults "
            "must never change math")
    if statuses[1] != "CANCELLED" or statuses[3] != "FAILED":
        raise AssertionError(
            f"victim statuses wrong: r1={statuses[1]} r3={statuses[3]}")
    if any(statuses[i] != FINISHED for i in survivors):
        raise AssertionError(f"survivor not FINISHED: {statuses}")
    if chaos.alloc_faults == 0:
        raise AssertionError("chaos schedule never fired an alloc fault")
    if st.decode_traces != 1:
        raise AssertionError(
            f"chaos retraced the decode graph: {st.decode_traces}")


def _merge_rows_into_json(rows) -> None:
    """Merge freshly produced CSV rows (+ ENGINE_STATS) into
    BENCH_serving.json in place — rows with the same name are replaced,
    everything else is preserved, so the ``--mesh`` job composes with
    ``run.py --json`` regardless of execution order."""
    import json
    import os
    from benchmarks.run import REPO_ROOT, _row_dicts
    path = os.path.join(REPO_ROOT, "BENCH_serving.json")
    payload = {"rows": [], "engine_stats": []}
    if os.path.exists(path):
        with open(path) as f:
            payload = json.load(f)
    new = _row_dicts(rows)
    names = {r["name"] for r in new}
    payload["rows"] = [r for r in payload.get("rows", [])
                       if r["name"] not in names] + new
    labels = {s["label"] for s in ENGINE_STATS}
    payload["engine_stats"] = [s for s in payload.get("engine_stats", [])
                               if s.get("label") not in labels]
    payload["engine_stats"] += ENGINE_STATS
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"# merged {sorted(names)} into {path}", flush=True)


def run_mesh(*, smoke: bool = False) -> list:
    """The ``--mesh`` entry point: only the TP-vs-single-device rows
    (CI runs this as its own job, with --smoke, under forced fake
    devices)."""
    ENGINE_STATS.clear()
    rows = []
    _mesh_rows(rows, smoke=smoke)
    _merge_rows_into_json(rows)
    return rows


def run_fleet(*, smoke: bool = False) -> list:
    """The ``--fleet`` entry point: only the dp2-vs-dp1 rows (the
    scripts/ci.sh fleet-parity job runs this with --smoke under 8
    forced fake devices)."""
    ENGINE_STATS.clear()
    rows = []
    _fleet_rows(rows, smoke=smoke)
    _merge_rows_into_json(rows)
    return rows


def run_spec(*, smoke: bool = False) -> list:
    """The ``--spec`` entry point: only the speculative-vs-baseline rows,
    merged into BENCH_serving.json (the scripts/ci.sh spec-parity job)."""
    ENGINE_STATS.clear()
    rows = []
    _spec_rows(rows, smoke=smoke)
    _merge_rows_into_json(rows)
    return rows


def run_multitask(*, smoke: bool = False) -> list:
    """The ``--multitask`` entry point: zipf-over-256-tasks adapter
    paging rows only (the scripts/ci.sh adapter-paging job runs this
    with --smoke; merges serving/zipf_256tasks into
    BENCH_serving.json)."""
    ENGINE_STATS.clear()
    rows = []
    _multitask_rows(rows, smoke=smoke)
    _merge_rows_into_json(rows)
    return rows


def run_chaos(*, smoke: bool = False) -> list:
    """The ``--chaos`` entry point: the seeded-chaos survivor row only
    (the scripts/ci.sh chaos-parity job runs this with --smoke; merges
    serving/chaos_survivors into BENCH_serving.json)."""
    ENGINE_STATS.clear()
    rows = []
    _chaos_rows(rows, smoke=smoke)
    _merge_rows_into_json(rows)
    return rows


def run(*, smoke: bool = False) -> list:
    ENGINE_STATS.clear()
    rows = []
    _decode_step_rows(rows)
    _engine_rows(rows, smoke=smoke)
    _fused_engine_rows(rows, smoke=smoke)
    _paged_rows(rows, smoke=smoke)
    _quant_rows(rows, smoke=smoke)
    _spec_rows(rows, smoke=smoke)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes for CI")
    ap.add_argument("--mesh", action="store_true",
                    help="tensor-parallel vs single-device rows only "
                         "(needs 4 devices; merges serving/tp4_vs_tp1 "
                         "into BENCH_serving.json; honors --smoke)")
    ap.add_argument("--spec", action="store_true",
                    help="speculative-vs-baseline rows only (merges "
                         "serving/spec_vs_base into BENCH_serving.json; "
                         "honors --smoke)")
    ap.add_argument("--fleet", action="store_true",
                    help="data-striped dp2 vs dp1 rows only (needs 8 "
                         "devices; merges serving/dp2_vs_dp1 into "
                         "BENCH_serving.json; honors --smoke)")
    ap.add_argument("--multitask", action="store_true",
                    help="zipf(1.1) over 256 tasks through an 8-slot "
                         "adapter pool vs all-resident (merges "
                         "serving/zipf_256tasks into BENCH_serving.json; "
                         "honors --smoke)")
    ap.add_argument("--chaos", action="store_true",
                    help="seeded chaos survivor row only (merges "
                         "serving/chaos_survivors into "
                         "BENCH_serving.json; honors --smoke)")
    args = ap.parse_args()
    if args.mesh:
        print("name,us_per_call,derived")
        run_mesh(smoke=args.smoke)
    elif args.fleet:
        print("name,us_per_call,derived")
        run_fleet(smoke=args.smoke)
    elif args.multitask:
        print("name,us_per_call,derived")
        run_multitask(smoke=args.smoke)
    elif args.chaos:
        print("name,us_per_call,derived")
        run_chaos(smoke=args.smoke)
    elif args.spec:
        print("name,us_per_call,derived")
        run_spec(smoke=args.smoke)
    else:
        run(smoke=args.smoke)
