"""Paper §2.4 — inference-time merging: decode-step latency with the live TT
contraction vs the pre-merged (fold-into-dense) weights. The paper's claim:
after merging, MetaTT serving cost == LoRA == base model."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro import configs as registry
from repro.config.base import RunConfig, SHAPES
from repro.core import tt as ttlib
from repro.core.merge import fold_into_dense
from repro.models import model as M, transformer as T
from repro.peft import api as peft_api


def run() -> list:
    rows = []
    cfg = registry.get_smoke_config("stablelm-1.6b")
    run_cfg = RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                        adapter_kind="metatt", adapter_rank=8)
    spec = M.build_adapter_spec(run_cfg)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, spec, key)
    params["adapter"] = {"cores": ttlib.random_tt(key, spec.cfg.mode_sizes,
                                                  8, scale=0.1)}
    bc, pl = peft_api.adapter_factors(spec, params["adapter"],
                                      params["frozen"])
    B, S = 4, 64
    token = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
    caches = T.init_caches(cfg, B, S, jnp.float32)
    pos = jnp.int32(3)

    live = jax.jit(lambda tok, c: T.decode_step(
        params["base"], cfg, spec, bc, pl, tok, c, pos)[0])
    us_live = time_call(live, token, caches)
    rows.append(emit("serving/decode_live_tt", us_live, "adapter=metatt-r8"))

    # merged: fold ΔW into q/v, run with NO adapter (paper's pre-compute)
    folded = dict(params["base"])
    blk = dict(folded["blocks"][0])
    mixer = dict(blk["mixer"])
    merged = fold_into_dense(params["adapter"], spec.cfg,
                             {"attn_q": mixer["wq"], "attn_v": mixer["wv"]})
    mixer["wq"], mixer["wv"] = merged["attn_q"], merged["attn_v"]
    blk["mixer"] = mixer
    folded["blocks"] = [blk]
    merged_fn = jax.jit(lambda tok, c: T.decode_step(
        folded, cfg, peft_api.NONE, {}, None, tok, c, pos)[0])
    us_merged = time_call(merged_fn, token, caches)
    rows.append(emit("serving/decode_merged", us_merged,
                     f"overhead_removed={us_live-us_merged:.0f}us"))

    base_fn = jax.jit(lambda tok, c: T.decode_step(
        params["base"], cfg, peft_api.NONE, {}, None, tok, c, pos)[0])
    us_base = time_call(base_fn, token, caches)
    rows.append(emit("serving/decode_base_no_adapter", us_base,
                     f"merged_vs_base_ratio={us_merged/us_base:.3f}"))
    return rows


if __name__ == "__main__":
    run()
