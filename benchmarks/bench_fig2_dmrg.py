"""Paper Fig. 2 / Algorithm 1 — DMRG-inspired rank-adaptive sweeps.

Measures: sweep wall time at paper-scale core sizes, the rank trajectory of
the paper's 10 -> 4 schedule, and the per-sweep truncation error (the "dip"
visible in Fig. 2 right after each sweep)."""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_call
from repro.core import dmrg, metatt, tt


def run() -> list:
    rows = []
    # paper-scale MetaTT-5D on RoBERTa-large dims: (1024, 24, 2, 16, 64)
    cfg = metatt.MetaTTConfig(num_layers=24, matrix_types=("q", "v"),
                              d_in=(1024, 1024), d_out=(1024, 1024),
                              rank=10, variant="5d", num_heads=16,
                              head_dim=64)
    key = jax.random.PRNGKey(0)
    params = {"cores": tt.random_tt(key, cfg.mode_sizes, 10)}
    us = time_call(lambda: dmrg.dmrg_sweep(params, target_rank=8).params,
                   iters=3, warmup=1)
    rows.append(emit("fig2/dmrg_sweep_time_5d_r10to8", us,
                     f"params={tt.num_params(params['cores'])}"))
    # the paper's schedule 10 -> 4 (Fig. 2 arrows)
    p = params
    for target in (8, 6, 5, 4):
        res = dmrg.dmrg_sweep(p, target_rank=target)
        err = dmrg.reconstruction_error(p, res.params)
        p = res.params
        rows.append(emit(f"fig2/sweep_to_r{target}", 0.0,
                         f"ranks={res.ranks} trunc_err={err:.4f} "
                         f"params={tt.num_params(p['cores'])}"))
    return rows


if __name__ == "__main__":
    run()
