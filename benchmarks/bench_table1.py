"""Paper Table 1 — single-task fine-tuning: adapter parameter counts (exact
paper parity) + train-step wall time per PEFT method on RoBERTa-base/large
dims (smoke-scale step timing: CPU container; the parameter counts are the
paper's actual Table 1 column and are exact at full scale)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro import configs as registry
from repro.config.base import RunConfig, SHAPES, TrainConfig
from repro.core import metatt
from repro.distributed import GradCompressor
from repro.models import model as M
from repro.peft import api as peft_api, lora, lotr, vera
from repro.train import train_step as ts

# (method, rank) rows of Table 1 with the paper's published param counts
TABLE1_BASE = [
    ("lora", 8, lora.paper_count(768, 12, 2, 8), 295),
    ("vera", 1024, vera.paper_count(768, 12, 2, 1024), 43),
    ("lotr", 40, lotr.paper_count(768, 12, 2, 40), 100),
    ("lotr", 80, lotr.paper_count(768, 12, 2, 80), 276),
    ("metatt-4d", 8, metatt.paper_count_4d(768, 12, 2, 8), 13),
    ("metatt-4d", 24, metatt.paper_count_4d(768, 12, 2, 24), 45),
    ("metatt-4d", 64, metatt.paper_count_4d(768, 12, 2, 64), 156),
    ("metatt-5d", 16, metatt.paper_count_5d(768, 12, 12, 2, 16), 20),
    ("metatt-5d", 64, metatt.paper_count_5d(768, 12, 12, 2, 64), 160),
]
TABLE1_LARGE = [
    ("lora", 8, lora.paper_count(1024, 24, 2, 8), 786),
    ("vera", 256, vera.paper_count(1024, 24, 2, 256), 61),
    ("lotr", 64, lotr.paper_count(1024, 24, 2, 64), 328),
    ("metatt-4d", 16, metatt.paper_count_4d(1024, 24, 2, 16), 39),
    ("metatt-4d", 32, metatt.paper_count_4d(1024, 24, 2, 32), 92),
    ("metatt-5d", 32, metatt.paper_count_5d(1024, 16, 24, 2, 32), 78),
    ("metatt-5d", 64, metatt.paper_count_5d(1024, 16, 24, 2, 64), 242),
]


def run() -> list:
    rows = []
    for model_name, table in (("roberta-base", TABLE1_BASE),
                              ("roberta-large", TABLE1_LARGE)):
        for method, rank, count, paper_k in table:
            ok = abs(count / 1000 - paper_k) < 1.0
            rows.append(emit(
                f"table1/{model_name}/{method}-r{rank}/params", 0.0,
                f"params={count} paper={paper_k}k match={ok}"))
    # step-time comparison at matched rank (smoke dims, CPU)
    cfg = registry.get_smoke_config("roberta-base")
    key = jax.random.PRNGKey(0)
    toks = jax.random.randint(key, (8, 64), 0, cfg.vocab_size)
    for kind, variant in [("metatt", "4d"), ("metatt", "5d"),
                          ("lora", "4d"), ("vera", "4d"), ("lotr", "4d")]:
        run_cfg = RunConfig(model=cfg, shape=SHAPES["train_4k"],
                            adapter_kind=kind, adapter_variant=variant,
                            adapter_rank=8, train=TrainConfig(remat="none"))
        spec = M.build_adapter_spec(run_cfg)
        params = M.init_params(cfg, spec, key)
        state = ts.init_train_state(params["adapter"], GradCompressor("none"))
        step = ts.make_train_step(cfg, spec, run_cfg.optimizer,
                                  run_cfg.train, 100, donate=False)
        us = time_call(lambda s=state: step(s, params["base"],
                                            params["frozen"],
                                            {"tokens": toks})[0].adapter)
        n = peft_api.count_trainable(spec, params["adapter"])
        label = f"{kind}-{variant}" if kind == "metatt" else kind
        rows.append(emit(f"table1/step_time/{label}", us,
                         f"trainable={n}"))
    return rows


if __name__ == "__main__":
    run()
