"""Paper Table 2 — multi-task learning: parameter overhead of the task core
(MetaTT-(4+1)D vs MetaTT-4D vs one shared LoRA) + per-step time of joint
training with task cycling."""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_call
from repro import configs as registry
from repro.config.base import RunConfig, SHAPES, TrainConfig
from repro.core import metatt
from repro.data import ClassificationTasks
from repro.distributed import GradCompressor
from repro.models import model as M
from repro.peft import api as peft_api, lora
from repro.train import train_step as ts


def run() -> list:
    rows = []
    # exact Table 2 param columns (RoBERTa-base/large, q+v, r=8, T=3)
    for D, L, name in ((768, 12, "roberta-base"), (1024, 24, "roberta-large")):
        n4 = metatt.paper_count_4d(D, L, 2, 8)
        n41 = n4 + 3 * 64          # one extra (T, r, r) core
        nl = lora.paper_count(D, L, 2, 8)
        rows.append(emit(f"table2/{name}/params", 0.0,
                         f"lora={nl} metatt4d={n4} metatt4+1d={n41} "
                         f"ratio_lora_over_4+1d={nl/n41:.1f}"))
    # joint-training step time with the task core (smoke dims)
    cfg = registry.get_smoke_config("roberta-base")
    key = jax.random.PRNGKey(0)
    tasks = ClassificationTasks(vocab_size=cfg.vocab_size, seq_len=16,
                                batch=8, num_tasks=3)
    for variant in ("4d", "4+1d"):
        run_cfg = RunConfig(model=cfg, shape=SHAPES["train_4k"],
                            adapter_kind="metatt", adapter_variant=variant,
                            adapter_rank=8, num_tasks=3,
                            train=TrainConfig(remat="none"))
        spec = M.build_adapter_spec(run_cfg)
        params = M.init_params(cfg, spec, key)
        state = ts.init_train_state(params["adapter"], GradCompressor("none"))
        step = ts.make_train_step(cfg, spec, run_cfg.optimizer,
                                  run_cfg.train, 100, donate=False)
        b = tasks.sample(0)
        import jax.numpy as jnp
        batch = {"tokens": jnp.asarray(b["tokens"]),
                 "mask": jnp.asarray(b["mask"])}
        if variant == "4+1d":
            batch["task"] = jnp.int32(0)
        us = time_call(lambda s=state: step(s, params["base"],
                                            params["frozen"],
                                            batch)[0].adapter)
        n = peft_api.count_trainable(spec, params["adapter"])
        rows.append(emit(f"table2/step_time/metatt-{variant}", us,
                         f"trainable={n}"))
    return rows


if __name__ == "__main__":
    run()
