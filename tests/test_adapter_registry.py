"""Paged adapter registry: thousands of tasks through a fixed K-slot
device pool (DESIGN.md §12).

Acceptance criteria:

  * an engine with ``RegistryConfig(max_resident_tasks=8)`` serving 256
    DISTINCT tasks emits greedy tokens identical to the all-resident
    engine, with ``decode_traces == 1`` (fault-ins never retrace) and
    zero pinned slots after the drain,
  * admission backpressures when every slot is pinned by an in-flight
    request (``adapter_waits`` counted, tokens still exact),
  * the loaded-flag is transactional: a slot mapped by a rolled-back
    admission faults again on retry, never decodes a stale/zero column,
  * prefix caching keys on the TASK ID, not the pool slot — a task
    evicted from the adapter pool and re-admitted later still warm-hits
    its cached prompt prefixes,
  * bad task ids (negative or >= num_tasks) are rejected host-side at
    submission with a clear message,
  * the registry composes with dense KV, speculative decode, TP meshes
    and dp replicas (mesh cases need 4 fake devices — scripts/ci.sh
    ``adapter-paging`` job; they skip on one device).
"""
import jax
import numpy as np
import pytest

from repro import configs as registry
from repro.config.base import (RunConfig, SHAPES, RegistryConfig,
                               ServeConfig, SpecConfig)
from repro.core import tt as ttlib
from repro.models import model as M
from repro.serving import (AdapterRegistry, AdapterRuntime, Engine,
                           LRUClock, Request)

KEY = jax.random.PRNGKey(0)

needs4 = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs 4 (fake) devices: run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=4 "
           "(scripts/ci.sh adapter-paging job)")


# ---------------------------------------------------------------------------
# LRUClock units (shared with PrefixCache)
# ---------------------------------------------------------------------------

def test_lru_clock_orders_by_recency():
    c = LRUClock()
    for k in ("a", "b", "c"):
        c.touch(k)
    assert c.oldest(["a", "b", "c"]) == "a"
    c.touch("a")                      # refresh -> b is now oldest
    assert c.oldest(["a", "b", "c"]) == "b"
    assert len(c) == 3 and "b" in c


def test_lru_clock_never_touched_is_infinitely_old():
    c = LRUClock()
    c.touch("x")
    # a never-touched candidate always loses to any touched one
    assert c.oldest(["x", "y"]) == "y"
    # deterministic tie-break among never-touched: first in iteration
    assert c.oldest(["z", "y"]) == "z"
    assert c.oldest([]) is None


def test_lru_clock_forget():
    c = LRUClock()
    c.touch("a")
    c.touch("b")
    c.forget("a")
    assert "a" not in c and len(c) == 1
    c.forget("a")                     # idempotent
    assert c.oldest(["a", "b"]) == "a"   # forgotten == never touched


# ---------------------------------------------------------------------------
# AdapterRegistry units (pure host-side)
# ---------------------------------------------------------------------------

def test_registry_validation():
    with pytest.raises(ValueError):
        AdapterRegistry(0)
    with pytest.raises(ValueError):
        AdapterRegistry(2, policy="random")


def test_acquire_miss_fill_hit_evict():
    r = AdapterRegistry(2)
    a = r.acquire(10)
    assert a.slot == 0 and a.fault and a.evicted is None
    r.mark_loaded(10)
    b = r.acquire(11)
    assert b.slot == 1 and b.fault
    r.mark_loaded(11)
    # hit: same slot, no fault, no device work
    h = r.acquire(10)
    assert h.slot == 0 and not h.fault
    assert len(r) == 2 and r.resident_tasks == [10, 11]
    # all pins dropped -> a third task evicts the LRU resident (11:
    # task 10 was re-touched by its hit)
    for t in (10, 10, 11):
        r.release(t)
    e = r.acquire(12)
    assert e.fault and e.evicted == 11 and e.slot == 1
    assert r.slot_of(11) is None and r.slot_of(10) == 0


def test_pins_block_eviction_then_backpressure():
    r = AdapterRegistry(2)
    r.acquire(1), r.acquire(2)
    r.mark_loaded(1), r.mark_loaded(2)
    # both slots pinned by in-flight requests -> a third task must wait
    assert r.acquire(3) is None
    assert r.pinned_slots == 2
    r.release(2)
    got = r.acquire(3)                # now evicts idle task 2
    assert got is not None and got.evicted == 2
    # pins are counted, not boolean
    r.acquire(1)
    assert r.pin_count(1) == 2
    r.release(1)
    assert r.pin_count(1) == 1


def test_loaded_flag_is_transactional():
    """An admission that acquires a slot but rolls back before the
    device scatter leaves the slot mapped-but-unloaded: the retry MUST
    fault again (decoding the stale/zero column would corrupt output)."""
    r = AdapterRegistry(2)
    a = r.acquire(7)
    assert a.fault
    r.release(7)                      # rollback WITHOUT mark_loaded
    b = r.acquire(7)
    assert b.slot == a.slot and b.fault   # same mapping, still faults
    r.mark_loaded(7)
    assert not r.acquire(7).fault


def test_release_and_mark_loaded_errors():
    r = AdapterRegistry(2)
    with pytest.raises(ValueError):
        r.release(5)                  # never acquired
    with pytest.raises(ValueError):
        r.mark_loaded(5)              # unmapped
    r.acquire(5)
    r.release(5)
    with pytest.raises(ValueError):
        r.release(5)                  # pin already dropped


def test_fifo_policy_ignores_hits():
    """fifo ranks by LOAD order: a hit on the oldest resident does not
    save it from eviction (lru would refresh it)."""
    for policy, victim in (("lru", 2), ("fifo", 1)):
        r = AdapterRegistry(2, policy=policy)
        for t in (1, 2):
            r.acquire(t)
            r.mark_loaded(t)
            r.release(t)
        r.acquire(1)                  # touch the older resident
        r.release(1)
        got = r.acquire(3)
        assert got.evicted == victim, policy


def test_clear_resets_everything():
    r = AdapterRegistry(2)
    r.acquire(1)
    r.mark_loaded(1)
    r.clear()
    assert len(r) == 0 and r.pinned_slots == 0
    a = r.acquire(9)
    assert a.slot == 0 and a.fault


# ---------------------------------------------------------------------------
# config plumbing
# ---------------------------------------------------------------------------

def test_registry_config_validation():
    with pytest.raises(ValueError):
        ServeConfig(registry=RegistryConfig(max_resident_tasks=-1)
                    ).validate()
    with pytest.raises(ValueError):
        ServeConfig(registry=RegistryConfig(max_resident_tasks=4,
                                            eviction="random")).validate()
    assert not RegistryConfig().enabled
    assert RegistryConfig(max_resident_tasks=4).enabled


def test_registry_requires_tasked_runtime():
    """Paging pools the TASK axis — a runtime without one (4d variant
    collapses tasks into the layer mode) must be rejected up front."""
    cfg = registry.get_smoke_config("stablelm-1.6b")
    run = RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                    adapter_kind="metatt", adapter_variant="4d",
                    num_tasks=1, adapter_rank=4)
    spec = M.build_adapter_spec(run)
    params = M.init_params(cfg, spec, KEY)
    params["adapter"] = {"cores": ttlib.random_tt(
        KEY, spec.cfg.mode_sizes, 4, scale=0.8)}
    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    with pytest.raises(ValueError, match="task"):
        Engine(cfg, rt, serve=ServeConfig(
            max_batch=2, cache_len=32, out_cap=8,
            registry=RegistryConfig(max_resident_tasks=2)))


# ---------------------------------------------------------------------------
# engine parity
# ---------------------------------------------------------------------------

def _setup(num_tasks=16, mode="live"):
    cfg = registry.get_smoke_config("stablelm-1.6b")
    run = RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                    adapter_kind="metatt", adapter_variant="4+1d",
                    num_tasks=num_tasks, adapter_rank=4)
    spec = M.build_adapter_spec(run)
    params = M.init_params(cfg, spec, KEY)
    params["adapter"] = {"cores": ttlib.random_tt(
        KEY, spec.cfg.mode_sizes, 4, scale=0.8)}
    rt = AdapterRuntime.build(mode, params["base"], spec,
                              params["adapter"], params["frozen"])
    return cfg, rt


def _mixed_requests(cfg, n=10, tasks=16):
    prompts = [jax.random.randint(jax.random.PRNGKey(i), (4 + i % 3,), 0,
                                  cfg.vocab_size) for i in range(n)]
    return [Request(p, 3 + (i % 3), task=(7 * i) % tasks)
            for i, p in enumerate(prompts)]


def _serve(cfg, rt, reqs, *, slots=0, **kw):
    base = dict(max_batch=2, cache_len=32, out_cap=8, page_size=8,
                prefill_chunk=4)
    base.update(kw)
    if slots:
        base["registry"] = RegistryConfig(max_resident_tasks=slots)
    eng = Engine(cfg, rt, serve=ServeConfig(**base))
    return [o.tolist() for o in eng.generate(reqs)], eng


def _assert_drained(eng):
    assert eng.registries, "registry engine expected"
    for r in eng.registries:
        assert r.pinned_slots == 0, "leaked adapter-slot pins"


def test_pool_of_8_serves_256_distinct_tasks_token_identical():
    """The headline: 256 distinct tasks stream through an 8-slot pool
    with exact tokens, one decode trace, and no leaked pins."""
    cfg, rt = _setup(num_tasks=256)
    reqs = [Request([1 + t % 7, 2, 3 + t % 5], 2, task=t)
            for t in range(256)]
    sv = dict(max_batch=4, cache_len=16, out_cap=4, prefill_chunk=8)
    ref, _ = _serve(cfg, rt, reqs, **sv)
    got, eng = _serve(cfg, rt, reqs, slots=8, **sv)
    assert got == ref
    st = eng.last_stats
    assert st.decode_traces == 1
    assert st.adapter_faults == 256           # every task distinct
    assert st.adapter_hits == 0
    assert st.adapter_evictions == 256 - 8    # pool filled once, then churn
    assert st.max_resident_tasks == 8
    _assert_drained(eng)
    # the pool holds the LAST 8 tasks (LRU churn through slots)
    assert len(eng.registries[0]) == 8


def test_task_reuse_hits_without_refault():
    """Zipf-ish reuse: repeated tasks hit their resident slot — faults
    count DISTINCT task loads, not admissions."""
    cfg, rt = _setup(num_tasks=16)
    reqs = _mixed_requests(cfg, n=12, tasks=4)   # 4 distinct tasks
    ref, _ = _serve(cfg, rt, reqs)
    got, eng = _serve(cfg, rt, reqs, slots=4)
    assert got == ref
    st = eng.last_stats
    assert st.adapter_faults == 4
    assert st.adapter_hits == len(reqs) - 4
    assert st.adapter_evictions == 0
    assert st.adapter_hit_rate == pytest.approx((len(reqs) - 4) / len(reqs))
    _assert_drained(eng)


def test_backpressure_when_all_slots_pinned():
    """More distinct in-flight tasks than slots: admission defers
    (adapter_waits) instead of evicting a pinned resident, and the
    output is still exact."""
    cfg, rt = _setup(num_tasks=16)
    reqs = _mixed_requests(cfg, n=8, tasks=8)    # all-distinct tasks
    sv = dict(max_batch=4)
    ref, _ = _serve(cfg, rt, reqs, **sv)
    got, eng = _serve(cfg, rt, reqs, slots=2, **sv)   # batch 4 > 2 slots
    assert got == ref
    st = eng.last_stats
    assert st.adapter_waits > 0
    assert st.backpressure_waits >= st.adapter_waits
    _assert_drained(eng)


def test_prefix_cache_survives_adapter_eviction():
    """Prefix namespaces key on the TASK ID, not the pool slot: a task
    evicted from the adapter pool between passes still warm-hits its
    cached prompt pages on re-admission — and the hit is not poisoned
    by another task having occupied the same slot meanwhile."""
    cfg, rt = _setup(num_tasks=16)
    reqs = _mixed_requests(cfg, n=6, tasks=6)
    ref, _ = _serve(cfg, rt, reqs)
    _, eng = _serve(cfg, rt, reqs, slots=2)      # K=2 -> heavy churn
    warm = [o.tolist() for o in eng.generate(reqs)]
    assert warm == ref
    st = eng.last_stats
    assert st.prefix_hit_rate > 0.0
    assert st.decode_traces == 1                 # no retrace across passes
    _assert_drained(eng)


def test_dense_mode_registry_token_identical():
    cfg, rt = _setup(num_tasks=16)
    reqs = _mixed_requests(cfg, n=8, tasks=8)
    ref, _ = _serve(cfg, rt, reqs, cache_mode="dense")
    got, eng = _serve(cfg, rt, reqs, slots=3, cache_mode="dense")
    assert got == ref
    st = eng.last_stats
    assert st.adapter_faults == 8
    _assert_drained(eng)


def test_lora_form_runtime_pages_identically():
    """The lora-form runtime pools its per-task A factor (task axis 1)
    through the same registry path."""
    cfg, rt = _setup(num_tasks=16, mode="lora")
    reqs = _mixed_requests(cfg, n=8, tasks=8)
    ref, _ = _serve(cfg, rt, reqs)
    got, eng = _serve(cfg, rt, reqs, slots=3)
    assert got == ref
    assert eng.last_stats.adapter_faults == 8
    _assert_drained(eng)


def test_speculative_drafter_pages_with_target():
    """Spec decode composes: the rank-truncated drafter's task column
    faults in at the same slot in the same scatter, tokens exact."""
    cfg, rt = _setup(num_tasks=16)
    reqs = _mixed_requests(cfg, n=6, tasks=6)
    sc = SpecConfig(spec_k=2, draft_rank=2)
    ref, _ = _serve(cfg, rt, reqs, spec=sc)
    got, eng = _serve(cfg, rt, reqs, slots=3, spec=sc)
    assert got == ref
    st = eng.last_stats
    assert st.decode_traces == 1 and st.adapter_faults == 6
    _assert_drained(eng)


# ---------------------------------------------------------------------------
# task-id validation at submission (host-side, both cache modes)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cache_mode", ["paged", "dense"])
@pytest.mark.parametrize("bad", [-1, 16, 99])
def test_bad_task_id_rejected_at_submission(cache_mode, bad):
    cfg, rt = _setup(num_tasks=16)
    reqs = _mixed_requests(cfg, n=2, tasks=2)
    reqs.append(Request([1, 2, 3], 2, task=bad))
    eng = Engine(cfg, rt, serve=ServeConfig(
        max_batch=2, cache_len=32, out_cap=8, cache_mode=cache_mode))
    with pytest.raises(ValueError, match="out of range"):
        eng.generate(reqs)


# ---------------------------------------------------------------------------
# 4-device mesh cases
# ---------------------------------------------------------------------------

@needs4
def test_tp4_registry_token_identical():
    """The pool is replicated over the TP mesh; the fault-in scatter
    runs OUTSIDE shard_map and the sharded step consumes its output
    without a retrace."""
    cfg, rt = _setup(num_tasks=16)
    reqs = _mixed_requests(cfg, n=8, tasks=8)
    ref, _ = _serve(cfg, rt, reqs)
    got, eng = _serve(cfg, rt, reqs, slots=3, mesh_shape=(1, 4))
    assert got == ref
    st = eng.last_stats
    assert st.shards == 4 and st.decode_traces == 1
    assert st.adapter_faults == 8
    _assert_drained(eng)


@needs4
def test_dp2_per_replica_registries_token_identical():
    """dp replicas each own a private registry over their own pool
    stripe; global slot = replica * K + local slot."""
    cfg, rt = _setup(num_tasks=16)
    reqs = _mixed_requests(cfg, n=8, tasks=8)
    ref, _ = _serve(cfg, rt, reqs)
    got, eng = _serve(cfg, rt, reqs, slots=3, mesh_shape=(2, 2))
    assert got == ref
    assert len(eng.registries) == 2
    _assert_drained(eng)


@needs4
def test_dp2_disagg_shared_registry_token_identical():
    """Disaggregation: the prefill scheduler takes the pin, the decode
    scheduler's harvest drops it — one registry per replica, shared by
    both, drains to zero pins."""
    cfg, rt = _setup(num_tasks=16)
    reqs = _mixed_requests(cfg, n=8, tasks=8)
    ref, _ = _serve(cfg, rt, reqs)
    got, eng = _serve(cfg, rt, reqs, slots=3, mesh_shape=(2, 2),
                      disagg=True)
    assert got == ref
    assert eng.last_stats.decode_traces == 1
    _assert_drained(eng)
