"""Quantized serving path (kernels/quant.py, w8a16 fused kernels, int8
paged KV cache — DESIGN.md §8). Acceptance criteria:

  * quantize -> dequantize round-trips within the symmetric-int8 bound
    (half a scale step per element), per-channel and group-wise,
  * the w8a16 fused kernels match their dequantize-then-fp oracles in
    interpret mode across adapter kinds and odd (non-tile-multiple)
    shapes — the SAME quantized numbers through two execution paths,
  * the int8 paged KV cache (per-cell scale pools, in-register dequant)
    matches its explicit-dequant reference twin, and the int8 engine's
    pallas leg is token-identical to its ref leg,
  * the int8 engine tracks the fp engine's greedy tokens on the smoke
    config (documented tolerance: the quantization error can flip
    near-tie argmaxes on a random-weight model; >= 90% positional match
    is asserted, and in practice the first tokens of every request
    agree),
  * prefix cache + COW round-trip the quantized representation (warm ==
    cold on an int8 engine),
  * the quantized-base snapshot round-trips through checkpoint/ckpt.py
    with int8 dtypes preserved.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as registry
from repro.checkpoint import ckpt as ckpt_lib
from repro.config.base import (KernelConfig, QuantConfig, RunConfig, SHAPES,
                               ServeConfig)
from repro.core import tt as ttlib
from repro.kernels import dispatch, ops, quant, ref
from repro.models import model as M
from repro.models import transformer as T
from repro.peft import api as peft_api
from repro.serving import AdapterRuntime, Engine, Request

KEY = jax.random.PRNGKey(0)
PALLAS = dispatch.resolve(KernelConfig(backend="pallas", interpret=True))
REF = dispatch.resolve(KernelConfig(backend="ref"))

#: documented greedy-parity tolerance of the int8 engine vs the fp engine
#: (argmax near-ties under quantization noise; see module docstring)
TOKEN_MATCH_MIN = 0.9


# ---------------------------------------------------------------------------
# quantize / dequantize
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("group", [0, 128])
def test_quantize_dequantize_error_bound(group):
    w = jax.random.normal(KEY, (256, 130), jnp.float32)
    q, scale = quant.quantize_int8(w, group_size=group)
    assert q.dtype == jnp.int8
    assert scale.shape == ((1, 130) if group == 0 else (2, 130))
    dq = quant.dequantize_int8(q, scale)
    # symmetric rounding: at most half a scale step per element, with the
    # scale taken over that element's K group
    g = scale.shape[0]
    bound = jnp.repeat(scale, 256 // g, axis=0) * 0.5 + 1e-7
    assert bool(jnp.all(jnp.abs(dq - w) <= bound))
    # group-wise scales are no coarser than per-channel ones
    if group:
        _, sc_pc = quant.quantize_int8(w)
        assert float(jnp.max(scale)) <= float(jnp.max(sc_pc)) + 1e-12


def test_quantize_rejects_indivisible_group():
    w = jnp.ones((100, 8))
    with pytest.raises(ValueError):
        quant.quantize_int8(w, group_size=64)


def test_quantize_base_packs_hot_leaves_only():
    cfg = registry.get_smoke_config("stablelm-1.6b")
    base = T.init_base_params(cfg, KEY)
    qbase = quant.quantize_base(base, group_size=0)
    blk = qbase["blocks"][0]
    for key in ("wq", "wk", "wv", "wo"):
        assert quant.is_quantized(blk["mixer"][key])
        assert blk["mixer"][key]["q8"].dtype == jnp.int8
    for key in ("wu", "wd"):
        assert quant.is_quantized(blk["ffn"][key])
    # embeddings / norms stay fp; the input tree is not mutated
    assert not quant.is_quantized(qbase["embed"]["tok"])
    assert qbase["final_norm"] is base["final_norm"]
    assert not quant.is_quantized(base["blocks"][0]["mixer"]["wq"])
    # a group size that does not divide some K falls back per-channel
    qb2 = quant.quantize_base(base, group_size=1024)
    for blk2 in qb2["blocks"]:
        for w8 in blk2["mixer"].values():
            if quant.is_quantized(w8):
                k = w8["q8"].shape[-2]
                want_g = k // 1024 if k % 1024 == 0 and k >= 1024 else 1
                assert w8["scale"].shape[-2] == max(want_g, 1)


def test_quant_config_validation():
    with pytest.raises(ValueError):
        QuantConfig(weights="int4").validate()
    with pytest.raises(ValueError):
        QuantConfig(group_size=100).validate()
    with pytest.raises(ValueError):
        ServeConfig(cache_mode="dense",
                    quant=QuantConfig(kv="int8")).validate()
    ServeConfig(quant=QuantConfig(kv="int8")).validate()   # paged: fine


# ---------------------------------------------------------------------------
# w8a16 fused kernels vs oracles (interpret mode)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n,r,group", [
    (128, 256, 256, 8, 0),
    (12, 200, 391, 9, 0),       # odd everything -> pad-and-slice path
    (8, 256, 384, 16, 128),     # group-wise: one scale row per K tile
])
def test_w8_tt_linear_matches_ref_twin(m, k, n, r, group):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (m, k), jnp.float32)
    w = jax.random.normal(ks[1], (k, n), jnp.float32) / np.sqrt(k)
    a = jax.random.normal(ks[2], (k, r), jnp.float32) / np.sqrt(k)
    b = jax.random.normal(ks[3], (r, n), jnp.float32) / np.sqrt(r)
    wq, scale = quant.quantize_int8(w, group_size=group)
    y = ops.tt_linear_q(x, wq, scale, a, b, alpha=1.3, backend="pallas",
                        interpret=True)
    want = ref.tt_linear_q_ref(x, wq, scale, a, b, alpha=1.3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               atol=1e-4, rtol=1e-4)
    # and the quantized result tracks the fp one at int8 resolution
    fp = ref.tt_linear_ref(x, w, a, b, alpha=1.3)
    assert float(jnp.max(jnp.abs(y - fp))) < 0.1


@pytest.mark.parametrize("group", [0, 128])
def test_w8_tt_linear_batched_a_matches_ref_twin(group):
    s, k, n, r = 5, 256, 130, 6
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (s, k), jnp.float32)
    w = jax.random.normal(ks[1], (k, n), jnp.float32) / np.sqrt(k)
    a = jax.random.normal(ks[2], (s, k, r), jnp.float32) / np.sqrt(k)
    b = jax.random.normal(ks[3], (r, n), jnp.float32) / np.sqrt(r)
    wq, scale = quant.quantize_int8(w, group_size=group)
    y = ops.tt_linear_batched_a_q(x, wq, scale, a, b, alpha=0.7,
                                  backend="pallas", interpret=True)
    want = ref.tt_linear_batched_a_q_ref(x, wq, scale, a, b, alpha=0.7)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               atol=1e-4, rtol=1e-4)
    # decode layout (S, 1, K) round-trips
    y3 = ops.tt_linear_batched_a_q(x[:, None], wq, scale, a, b, alpha=0.7,
                                   backend="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(y3[:, 0]), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_w8_zero_adapter_equals_quantized_base_matmul():
    x = jax.random.normal(KEY, (128, 256), jnp.float32)
    w = jax.random.normal(KEY, (256, 128), jnp.float32) / 16
    wq, scale = quant.quantize_int8(w)
    a = jnp.zeros((256, 16))
    b = jax.random.normal(KEY, (16, 128), jnp.float32)
    y = ops.tt_linear_q(x, wq, scale, a, b, alpha=4.0, backend="pallas",
                        interpret=True)
    want = x @ quant.dequantize_int8(wq, scale)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-4)


# ---------------------------------------------------------------------------
# full-model forward over a quantized base: pallas vs ref, adapter kinds
# ---------------------------------------------------------------------------


def _setup(kind="metatt", variant="4d", num_tasks=0, rank=4, scale=0.5):
    cfg = registry.get_smoke_config("stablelm-1.6b")
    run = RunConfig(model=cfg, shape=SHAPES["train_4k"], adapter_kind=kind,
                    adapter_variant=variant, num_tasks=num_tasks,
                    adapter_rank=rank)
    spec = M.build_adapter_spec(run)
    params = M.init_params(cfg, spec, KEY)
    if kind == "metatt":
        params["adapter"] = {"cores": ttlib.random_tt(
            KEY, spec.cfg.mode_sizes, rank, scale=scale)}
    else:
        params["adapter"] = jax.tree_util.tree_map(
            lambda a: scale * jax.random.normal(KEY, a.shape, a.dtype),
            params["adapter"])
    return cfg, spec, params


@pytest.mark.parametrize("kind,variant,num_tasks", [
    ("metatt", "4d", 0),
    ("metatt", "4+1d", 2),
    ("lora", "4d", 0),
    ("vera", "4d", 0),
    ("lotr", "4d", 0),
])
def test_w8_forward_parity_across_adapter_kinds(kind, variant, num_tasks):
    """Quantized base, fused w8a16 kernels vs the ref dequant path — the
    SAME int8 numbers through both execution paths, so the comparison is
    tight (no quantization error in the diff)."""
    cfg, spec, params = _setup(kind, variant, num_tasks)
    qbase = quant.quantize_base(params["base"])
    bc, pl = peft_api.adapter_factors(spec, params["adapter"],
                                      params["frozen"])
    tokens = jax.random.randint(KEY, (2, 9), 0, cfg.vocab_size)
    task = jnp.int32(1) if variant == "4+1d" else None
    out_p = T.forward(qbase, cfg, spec, bc, pl, tokens, task=task,
                      policy=PALLAS)
    out_r = T.forward(qbase, cfg, spec, bc, pl, tokens, task=task,
                      policy=REF)
    np.testing.assert_allclose(out_p.logits, out_r.logits,
                               atol=5e-5, rtol=5e-5)


# ---------------------------------------------------------------------------
# int8 paged KV cache
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("c,heads", [(1, (4, 4)), (4, (4, 2))])
def test_int8_kv_paged_attention_kernel_matches_ref(c, heads):
    """Per-cell-scale int8 pools through the ops seam: kernel in-register
    dequant vs explicit reference dequant, incl. GQA + sentinel pages."""
    h, kv = heads
    b, d, n, page, p_tab = 3, 16, 12, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(c), 3)
    q = jax.random.normal(ks[0], (b, c, h, d), jnp.float32)
    kc = jax.random.normal(ks[1], (n, page, kv, d), jnp.float32)
    vc = jax.random.normal(ks[2], (n, page, kv, d), jnp.float32)
    kq, k_s = quant.quantize_kv(kc)
    vq, v_s = quant.quantize_kv(vc)
    tables = np.full((b, p_tab), n, np.int32)     # sentinel everywhere
    tables[0, :3] = [2, 7, 1]
    tables[1, :2] = [4, 9]
    tables[2, :1] = [11]
    tables = jnp.asarray(tables)
    pos = jnp.asarray([17, 9, 3], jnp.int32)
    want = ops.paged_decode_attention(q, kq, vq, tables, pos, k_scale=k_s,
                                      v_scale=v_s, backend="ref")
    got = ops.paged_decode_attention(q, kq, vq, tables, pos, k_scale=k_s,
                                     v_scale=v_s, backend="pallas",
                                     interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    # int8 attention tracks fp attention at quantization resolution
    fp = ops.paged_decode_attention(q, kc, vc, tables, pos, backend="ref")
    assert float(jnp.max(jnp.abs(want - fp))) < 0.1


def test_quantize_kv_zero_rows_roundtrip_to_zero():
    x = jnp.zeros((3, 4, 8))
    q, s = quant.quantize_kv(x)
    assert bool(jnp.all(q == 0)) and bool(jnp.all(s > 0))


# ---------------------------------------------------------------------------
# engine: int8 serving path
# ---------------------------------------------------------------------------


def _engine_setup():
    cfg = registry.get_smoke_config("stablelm-1.6b")
    run = RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                    adapter_kind="metatt", adapter_variant="4+1d",
                    num_tasks=2, adapter_rank=4)
    spec = M.build_adapter_spec(run)
    params = M.init_params(cfg, spec, KEY)
    params["adapter"] = {"cores": ttlib.random_tt(
        KEY, spec.cfg.mode_sizes, 4, scale=0.8)}
    rt = AdapterRuntime.build("lora", params["base"], spec,
                              params["adapter"], params["frozen"])
    prompts = [jax.random.randint(jax.random.PRNGKey(i), (4 + i,), 0,
                                  cfg.vocab_size) for i in range(5)]
    reqs = [Request(p, 6, task=i % 2) for i, p in enumerate(prompts)]
    return cfg, rt, reqs


def _serve(cfg, rt, reqs, qc, kernels=None):
    sv = ServeConfig(max_batch=2, cache_len=32, out_cap=8, page_size=8,
                     prefill_chunk=4, quant=qc)
    eng = Engine(cfg, rt, serve=sv, kernels=kernels)
    return [o.tolist() for o in eng.generate(reqs)], eng


def _match_fraction(a, b):
    tot = sum(len(x) for x in a)
    same = sum(int(p == q) for x, y in zip(a, b) for p, q in zip(x, y))
    return same / tot


def test_int8_engine_greedy_parity_and_kv_bytes():
    cfg, rt, reqs = _engine_setup()
    fp, fp_eng = _serve(cfg, rt, reqs, QuantConfig())
    for qc in (QuantConfig(kv="int8"),
               QuantConfig(weights="int8"),
               QuantConfig(weights="int8", kv="int8"),
               QuantConfig(weights="int8", kv="int8", group_size=128)):
        out, eng = _serve(cfg, rt, reqs, qc)
        assert _match_fraction(out, fp) >= TOKEN_MATCH_MIN, qc
        st = eng.last_stats
        assert st.weights_dtype == ("int8" if qc.weights == "int8"
                                    else "fp")
        assert st.kv_dtype == ("int8" if qc.kv == "int8" else "fp")
        if qc.kv == "int8":
            # same num_blocks budget, same blocks peak -> fewer bytes
            assert st.num_blocks == fp_eng.last_stats.num_blocks
            assert st.block_bytes < fp_eng.last_stats.block_bytes
            assert st.kv_bytes_peak < fp_eng.last_stats.kv_bytes_peak


def test_int8_engine_pallas_interpret_matches_ref_backend():
    """Same quantized numbers through the fused w8a16 + int8 paged-
    attention kernels and through the ref path: token-IDENTICAL."""
    cfg, rt, reqs = _engine_setup()
    qc = QuantConfig(weights="int8", kv="int8")
    ref_out, _ = _serve(cfg, rt, reqs, qc)
    pal_out, _ = _serve(cfg, rt, reqs, qc,
                        kernels=KernelConfig(backend="pallas",
                                             interpret=True))
    assert pal_out == ref_out


def test_int8_engine_warm_prefix_cache_token_identical():
    """Prefix cache + COW round-trip THROUGH the quantized representation:
    a warm rerun reuses int8 blocks + scale pools and must reproduce the
    cold run exactly."""
    cfg, rt, reqs = _engine_setup()
    qc = QuantConfig(weights="int8", kv="int8")
    sv = ServeConfig(max_batch=2, cache_len=32, out_cap=8, page_size=8,
                     prefill_chunk=4, quant=qc)
    eng = Engine(cfg, rt, serve=sv)
    cold = [o.tolist() for o in eng.generate(reqs)]
    warm = [o.tolist() for o in eng.generate(reqs)]
    assert warm == cold
    assert eng.last_stats.prefix_hit_rate > 0
    assert eng.last_stats.cow_copies > 0


def test_int8_kv_requires_paged_mode():
    cfg, rt, reqs = _engine_setup()
    with pytest.raises(ValueError):
        Engine(cfg, rt, serve=ServeConfig(
            max_batch=2, cache_len=32, out_cap=8, cache_mode="dense",
            quant=QuantConfig(kv="int8")))
    # weights quant via KernelConfig.quant merges in (dense mode is fine
    # for weights — only the KV side needs the paged layout)
    eng = Engine(cfg, rt, serve=ServeConfig(
        max_batch=2, cache_len=32, out_cap=8, cache_mode="dense"),
        kernels=KernelConfig(quant=QuantConfig(weights="int8")))
    assert eng.quant.weights == "int8"
    assert quant.is_quantized(eng.base_weights["blocks"][0]["mixer"]["wq"])


# ---------------------------------------------------------------------------
# quantized-base snapshot (checkpoint/ckpt.py)
# ---------------------------------------------------------------------------


def test_quantized_base_snapshot_roundtrip(tmp_path):
    cfg = registry.get_smoke_config("stablelm-1.6b")
    base = T.init_base_params(cfg, KEY)
    qbase = quant.quantize_base(base, group_size=0)
    path = ckpt_lib.save_base_snapshot(str(tmp_path / "qbase"), qbase)
    template = jax.tree_util.tree_map(jnp.zeros_like, qbase)
    loaded = ckpt_lib.load_base_snapshot(path, template)
    for got, want in zip(jax.tree_util.tree_leaves(loaded),
                         jax.tree_util.tree_leaves(qbase)):
        assert got.dtype == want.dtype          # int8 stays int8
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_engine_snapshot_roundtrip_same_tokens(tmp_path):
    cfg, rt, reqs = _engine_setup()
    qc = QuantConfig(weights="int8", kv="int8")
    out1, eng1 = _serve(cfg, rt, reqs, qc)
    path = eng1.save_base_snapshot(str(tmp_path / "snap"))
    _, eng2 = _serve(cfg, rt, reqs, qc)
    eng2.load_base_snapshot(path)
    out2 = [o.tolist() for o in eng2.generate(reqs)]
    assert out2 == out1
