"""Serving resilience (DESIGN.md §13): request lifecycle
(cancel / deadline / preemption), replica failover, and the seeded
chaos harness with per-step invariant audits.

Acceptance criteria:

  * survivors of any injected fault are TOKEN-IDENTICAL to a fault-free
    greedy run (chaos perturbs scheduling, never math),
  * every chaos run keeps ``decode_traces == 1`` — aborts, NaN guards
    and preemptions ride the one compiled decode graph,
  * the pool invariants (block conservation, refcount == live holders,
    pinned => loaded, router load == outstanding cost) hold after EVERY
    host-loop iteration under chaos and at rest ("drains to empty"),
  * dp2 with one replica killed mid-flight finishes every request with
    the same greedy tokens as unfaulted dp1.

The dp2 failover case needs fake host devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m pytest -q tests/test_chaos.py

(the scripts/ci.sh ``chaos-parity`` job runs it that way; on a single
device it skips and everything else still runs in the tier-1 suite).
"""
import jax
import numpy as np
import pytest

from repro import configs as registry
from repro.config.base import (RegistryConfig, RunConfig, SHAPES,
                               ServeConfig)
from repro.core import tt as ttlib
from repro.models import model as M
from repro.serving import (CANCELLED, FAILED, FINISHED, TIMEOUT,
                           AdapterRegistry, AdapterRuntime, BlockManager,
                           ChaosInjector, Engine, PrefixCache, Request,
                           Scheduler, audit, audit_pools)

KEY = jax.random.PRNGKey(0)

needs2 = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs 2 (fake) devices: run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(scripts/ci.sh chaos-parity job)")


def _runtime(variant="4+1d", num_tasks=3):
    cfg = registry.get_smoke_config("stablelm-1.6b")
    run = RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                    adapter_kind="metatt", adapter_variant=variant,
                    num_tasks=num_tasks, adapter_rank=4)
    spec = M.build_adapter_spec(run)
    params = M.init_params(cfg, spec, KEY)
    params["adapter"] = {"cores": ttlib.random_tt(
        KEY, spec.cfg.mode_sizes, 4, scale=0.8)}
    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    return cfg, rt


def _requests(cfg, n=4, tasks=3, max_new=6):
    prompts = [jax.random.randint(jax.random.PRNGKey(i), (4 + i,), 0,
                                  cfg.vocab_size) for i in range(n)]
    return [Request(p, max_new, task=i % tasks, request_id=f"r{i}")
            for i, p in enumerate(prompts)]


def _engine(cfg, rt, **kw):
    base = dict(max_batch=2, cache_len=32, out_cap=8, page_size=8,
                prefill_chunk=4)
    base.update(kw)
    return Engine(cfg, rt, serve=ServeConfig(**base))


def _statuses(eng):
    return [r.status for r in eng.last_results]


# ---------------------------------------------------------------------------
# request lifecycle
# ---------------------------------------------------------------------------


def test_cancel_scripted_spares_survivors():
    """Cancel one in-flight request mid-decode (via the chaos schedule,
    which calls Engine.cancel between jitted steps): it ends CANCELLED
    with a partial output; every survivor is token-identical to the
    fault-free run; the pool drains to empty.

    The host regains control exactly when some slot finishes, so the
    cancel step is scheduled one completion in: r0 (short) finishes in
    host-step 0, and step 1's sweep catches r1 (long) mid-decode."""
    cfg, rt = _runtime()
    lens, news = (4, 5, 6, 7), (3, 8, 6, 6)
    reqs = [Request(jax.random.randint(jax.random.PRNGKey(i), (lens[i],),
                                       0, cfg.vocab_size),
                    news[i], task=i % 3, request_id=f"r{i}")
            for i in range(4)]
    baseline = [o.tolist() for o in _engine(cfg, rt).generate(reqs)]
    eng = _engine(cfg, rt)
    out = eng.generate(reqs, chaos=ChaosInjector(cancel_at={1: ["r1"]}))
    res = eng.last_results
    assert res[1].status == CANCELLED
    assert res[1].n_generated < reqs[1].max_new_tokens
    assert out[1].tolist() == baseline[1][:res[1].n_generated]
    for i in (0, 2, 3):
        assert res[i].status == FINISHED
        assert out[i].tolist() == baseline[i], i
    assert eng.last_stats.cancelled == 1
    assert eng.last_stats.decode_traces == 1
    audit(eng)                                  # drained, zero pins


def test_cancel_before_generate_kills_queued_request():
    cfg, rt = _runtime()
    reqs = _requests(cfg, n=3)
    eng = _engine(cfg, rt)
    eng.cancel("r2")
    out = eng.generate(reqs)
    assert _statuses(eng) == [FINISHED, FINISHED, CANCELLED]
    assert out[2].tolist() == []
    assert eng.last_stats.cancelled == 1
    audit(eng)


def test_deadline_timeout_status_and_partial_tokens():
    cfg, rt = _runtime()
    reqs = _requests(cfg, n=3)
    reqs[0] = Request(reqs[0].prompt, reqs[0].max_new_tokens,
                      task=reqs[0].task, request_id="r0",
                      deadline_s=0.0)       # expired on entry
    baseline = [o.tolist()
                for o in _engine(cfg, rt).generate(_requests(cfg, n=3))]
    eng = _engine(cfg, rt)
    out = eng.generate(reqs)
    assert _statuses(eng) == [TIMEOUT, FINISHED, FINISHED]
    assert out[0].tolist() == []
    assert out[1].tolist() == baseline[1]
    assert out[2].tolist() == baseline[2]
    assert eng.last_stats.timeouts == 1
    audit(eng)


def test_lifecycle_on_dense_engine_too():
    """cancel / deadline / NaN guard are not paged-only: the dense
    engine shares the Request/RequestResult contract."""
    cfg, rt = _runtime()
    lens, news = (4, 5, 6), (3, 8, 6)
    mk = lambda i, **kw: Request(
        jax.random.randint(jax.random.PRNGKey(i), (lens[i],), 0,
                           cfg.vocab_size), news[i], task=i % 3,
        request_id=f"r{i}", **kw)
    baseline = [o.tolist()
                for o in _engine(cfg, rt, cache_mode="dense")
                .generate([mk(i) for i in range(3)])]
    reqs = [mk(0), mk(1), mk(2, deadline_s=0.0)]
    eng = _engine(cfg, rt, cache_mode="dense")
    out = eng.generate(reqs, chaos=ChaosInjector(cancel_at={1: ["r1"]},
                                                 audit_every_step=False))
    res = eng.last_results
    assert res[2].status == TIMEOUT and out[2].tolist() == []
    assert res[1].status == CANCELLED
    assert res[1].n_generated < news[1]
    assert out[1].tolist() == baseline[1][:res[1].n_generated]
    assert res[0].status == FINISHED and out[0].tolist() == baseline[0]


# ---------------------------------------------------------------------------
# numerics faults (in-graph NaN guard)
# ---------------------------------------------------------------------------


def test_nan_injection_fails_request_in_graph():
    cfg, rt = _runtime()
    reqs = _requests(cfg)
    baseline = [o.tolist() for o in _engine(cfg, rt).generate(reqs)]
    eng = _engine(cfg, rt)
    out = eng.generate(reqs, chaos=ChaosInjector(nan_after={"r2": 2}))
    res = eng.last_results
    assert res[2].status == FAILED
    assert res[2].n_generated == 2          # tokens emitted BEFORE the fault
    assert out[2].tolist() == baseline[2][:2]
    for i in (0, 1, 3):
        assert res[i].status == FINISHED and out[i].tolist() == baseline[i]
    st = eng.last_stats
    assert st.numerics_faults == 1 and st.failed_requests == 1
    assert st.decode_traces == 1            # the guard rides the one trace
    audit(eng)


def test_nan_at_zero_fails_before_any_output():
    cfg, rt = _runtime()
    reqs = _requests(cfg, n=2)
    eng = _engine(cfg, rt)
    out = eng.generate(reqs, chaos=ChaosInjector(nan_after={"r0": 0}))
    assert _statuses(eng) == [FAILED, FINISHED]
    assert out[0].tolist() == []
    audit(eng)


# ---------------------------------------------------------------------------
# allocation / scatter chaos
# ---------------------------------------------------------------------------


def test_alloc_chaos_only_delays_never_corrupts():
    cfg, rt = _runtime()
    reqs = _requests(cfg, n=5)
    baseline = [o.tolist() for o in _engine(cfg, rt).generate(reqs)]
    eng = _engine(cfg, rt)
    chaos = ChaosInjector(seed=7, alloc_fail_steps=(0, 1, 2),
                          alloc_fail_rate=0.3)
    out = eng.generate(reqs, chaos=chaos)
    assert chaos.alloc_faults > 0
    assert [o.tolist() for o in out] == baseline
    assert all(s == FINISHED for s in _statuses(eng))
    assert eng.last_stats.decode_traces == 1
    audit(eng)


def test_scatter_chaos_leaves_slot_mapped_but_unloaded_then_retries():
    """A failed adapter fault-in scatter unwinds the whole admission
    (blocks deref'd, pin dropped) and the task slot stays
    mapped-but-UNLOADED; the retry faults the slice in again. Output
    must match the fault-free registry run exactly."""
    cfg, rt = _runtime()
    reqs = _requests(cfg, n=4, tasks=3)
    reg = RegistryConfig(max_resident_tasks=2)
    baseline = [o.tolist()
                for o in _engine(cfg, rt, registry=reg).generate(reqs)]
    eng = _engine(cfg, rt, registry=reg)
    chaos = ChaosInjector(scatter_failures=2)
    out = eng.generate(reqs, chaos=chaos)
    assert chaos.scatter_faults == 2
    assert [o.tolist() for o in out] == baseline
    assert all(s == FINISHED for s in _statuses(eng))
    audit(eng)                              # zero pins, pinned => loaded


# ---------------------------------------------------------------------------
# recompute preemption
# ---------------------------------------------------------------------------


def test_preemption_recomputes_victim_token_identically():
    """Pool sized so two requests can never be resident together: with
    preempt_after set, the blocked head eventually preempts the running
    (youngest) request, which re-enters the queue with its generated
    prefix and still produces exactly the fault-free tokens."""
    cfg, rt = _runtime(variant="4d", num_tasks=0)
    # r0: 1 page, finishes first. r1: 2 pages, long — the running
    # request when r2's admission blocks. r2: 4 pages, can never fit
    # beside r1 in a 5-block pool -> r1 is the preemption victim.
    lens, news = (4, 9, 25), (4, 7, 7)
    reqs = [Request(jax.random.randint(jax.random.PRNGKey(i), (lens[i],),
                                       0, cfg.vocab_size),
                    news[i], request_id=f"r{i}")
            for i in range(3)]
    kw = dict(max_batch=2, num_blocks=5)
    baseline = [o.tolist() for o in _engine(cfg, rt, **kw).generate(reqs)]
    eng = _engine(cfg, rt, preempt_after=1, **kw)
    out = eng.generate(reqs, chaos=ChaosInjector())  # audits every step
    res = eng.last_results
    assert eng.last_stats.preemptions >= 1
    assert res[1].preemptions >= 1          # the in-flight long request
    assert all(s == FINISHED for s in _statuses(eng))
    assert [o.tolist() for o in out] == baseline
    assert eng.last_stats.decode_traces == 1
    audit(eng)


# ---------------------------------------------------------------------------
# replica failover
# ---------------------------------------------------------------------------


@needs2
def test_dp2_replica_kill_matches_unfaulted_dp1():
    cfg, rt = _runtime()
    reqs = _requests(cfg, n=5, max_new=8)   # long enough to be in flight
    dp1 = [o.tolist() for o in _engine(cfg, rt).generate(reqs)]
    eng = _engine(cfg, rt, mesh_shape=(2, 1))
    chaos = ChaosInjector(kill_replica_at=(1, 1))
    out = eng.generate(reqs, chaos=chaos)
    st = eng.last_stats
    assert chaos.killed == [1]
    assert st.replicas_lost == 1
    assert st.failover_requests > 0
    assert all(s == FINISHED for s in _statuses(eng))
    assert [o.tolist() for o in out] == dp1
    assert st.decode_traces == 1
    audit(eng)
    assert not eng.router.is_up(1) and eng.router.is_up(0)


@needs2
def test_dp2_kill_then_next_generate_still_serves():
    """After a failover generate, the engine keeps serving on the
    surviving replicas (the dead one stays out of the rotation)."""
    cfg, rt = _runtime()
    reqs = _requests(cfg, n=3)
    dp1 = [o.tolist() for o in _engine(cfg, rt).generate(reqs)]
    eng = _engine(cfg, rt, mesh_shape=(2, 1))
    eng.generate(reqs, chaos=ChaosInjector(kill_replica_at=(1, 0)))
    again = [o.tolist() for o in eng.generate(reqs)]
    assert again == dp1
    assert all(s == FINISHED for s in _statuses(eng))
    audit(eng)


# ---------------------------------------------------------------------------
# pool-invariant property test (host-side only, no model)
# ---------------------------------------------------------------------------


def _drive_pools(seed, n_ops=150):
    """Random interleaving of plan / release / cancel / evict over a
    Scheduler(BlockManager + PrefixCache + AdapterRegistry), auditing
    the pool invariants after every operation and draining to empty."""
    rng = np.random.default_rng(seed)
    bm = BlockManager(8, 4)
    prefix = PrefixCache(bm)
    reg = AdapterRegistry(2)
    sched = Scheduler(bm, prefix, registry=reg)
    live = []                   # (prompt, blocks, task) per admitted req

    def check():
        audit_pools(bm, prefix, [b for _, b, _ in live],
                    registry=reg, pinned_tasks=[t for _, _, t in live])

    for _ in range(n_ops):
        op = rng.integers(0, 4)
        if op == 0:             # plan (admission attempt)
            plen = int(rng.integers(1, 9))
            prompt = rng.integers(0, 50, plen).tolist()
            task = int(rng.integers(0, 5))
            plan = sched.plan(prompt, int(rng.integers(0, 6)), task=task)
            if plan is not None:
                if plan.adapter_fault:
                    reg.mark_loaded(task)   # the engine's scatter step
                live.append((prompt, plan.blocks, task))
        elif op == 1 and live:  # release (normal finish, registers)
            prompt, blocks, task = live.pop(rng.integers(0, len(live)))
            sched.release(prompt, blocks, task=task)
        elif op == 2 and live:  # cancel-style release (no registration)
            prompt, blocks, task = live.pop(rng.integers(0, len(live)))
            sched.release(prompt, blocks, register=False, task=task)
        elif op == 3:           # pressure-evict cached prefix blocks
            prefix.evict_lru(int(rng.integers(1, 3)))
        check()
    while live:                 # drain
        prompt, blocks, task = live.pop()
        sched.release(prompt, blocks, task=task)
        check()
    prefix.evict_lru(bm.num_blocks)
    check()
    assert bm.free_blocks == bm.num_blocks      # drained to empty
    assert all(p == 0 for p in reg._pins)


def test_pool_invariants_random_interleaving_seeded():
    for seed in range(10):
        _drive_pools(seed)


try:
    from hypothesis import given, settings
    from hypothesis import strategies as hst
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(seed=hst.integers(min_value=0, max_value=2**32 - 1))
    def test_pool_invariants_random_interleaving_hypothesis(seed):
        _drive_pools(seed, n_ops=80)
else:
    def test_pool_invariants_random_interleaving_hypothesis():
        pytest.importorskip("hypothesis")
