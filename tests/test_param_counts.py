"""Pin adapter parameter counts to the paper's published numbers.

Every row below is a "Param ×10³" entry from Table 1 / Table 2 of the paper
(MetaTT, LoRA, VeRA, LoTR on RoBERTa-base/large with M=2 adapted matrices
q,v). This is the paper's central claim — the compression ranking — and it
must hold *exactly*.
"""
import pytest

from repro.core import metatt
from repro.peft import lora, lotr, vera

BASE = dict(D=768, L=12, H=12, M=2)      # RoBERTa-base
LARGE = dict(D=1024, L=24, H=16, M=2)    # RoBERTa-large


@pytest.mark.parametrize("D,L,M,r,expected", [
    (768, 12, 2, 8, 13184),      # Table 1: MetaTT-4D base r=8  -> 13k
    (768, 12, 2, 24, 44928),     # Table 1: r=24 -> 45k
    (768, 12, 2, 64, 155648),    # Table 1: r=64 -> 156k
    (1024, 24, 2, 16, 39424),    # Table 1: large r=16 -> 39k
    (1024, 24, 2, 32, 92160),    # Table 1: large r=32 -> 92k
    (768, 12, 2, 8, 13184),      # Table 2 (MTL): 13.2k row
])
def test_metatt_4d_counts(D, L, M, r, expected):
    assert metatt.paper_count_4d(D, L, M, r) == expected
    cfg = metatt.MetaTTConfig(num_layers=L, matrix_types=("q", "v"),
                              d_in=(D, D), d_out=(D, D), rank=r)
    assert cfg.num_params() == expected


@pytest.mark.parametrize("D,H,L,M,r,expected", [
    (768, 12, 12, 2, 16, 19968),     # Table 1: MetaTT-5D base r=16 -> 20k
    (768, 12, 12, 2, 64, 159744),    # Table 1: base r=64 -> 160k
    (1024, 16, 24, 2, 32, 77824),    # Table 1: large r=32 -> 78k
    (1024, 16, 24, 2, 64, 241664),   # Table 1: large r=64 -> 242k
])
def test_metatt_5d_counts(D, H, L, M, r, expected):
    assert metatt.paper_count_5d(D, H, L, M, r) == expected
    cfg = metatt.MetaTTConfig(num_layers=L, matrix_types=("q", "v"),
                              d_in=(D, D), d_out=(D, D), rank=r,
                              variant="5d", num_heads=H, head_dim=D // H)
    assert cfg.num_params() == expected


@pytest.mark.parametrize("D,L,M,r,expected", [
    (768, 12, 2, 8, 294912),     # Table 1: LoRA base r=8 -> 295k
    (1024, 24, 2, 8, 786432),    # Table 1: LoRA large r=8 -> 786k
])
def test_lora_counts(D, L, M, r, expected):
    assert lora.paper_count(D, L, M, r) == expected
    cfg = lora.LoRAConfig(num_layers=L, matrix_types=("q", "v"),
                          d_in=(D, D), d_out=(D, D), rank=r)
    assert cfg.num_params() == expected


@pytest.mark.parametrize("D,L,M,r,expected", [
    (768, 12, 2, 1024, 43008),   # Table 1: VeRA base r=1024 -> 43k
    (1024, 24, 2, 256, 61440),   # Table 1: VeRA large r=256 -> 61k
])
def test_vera_counts(D, L, M, r, expected):
    assert vera.paper_count(D, L, M, r) == expected
    cfg = vera.VeRAConfig(num_layers=L, matrix_types=("q", "v"),
                          d_in=(D, D), d_out=(D, D), rank=r)
    assert cfg.num_params() == expected


@pytest.mark.parametrize("D,L,M,r,expected", [
    (768, 12, 2, 40, 99840),     # Table 1: LoTR base r=40 -> 100k
    (768, 12, 2, 80, 276480),    # Table 1: LoTR base r=80 -> 276k
    (768, 12, 2, 88, 321024),    # Table 1: LoTR base r=88 -> 321k
    (1024, 24, 2, 64, 327680),   # Table 1: LoTR large r=64 -> 328k
])
def test_lotr_counts(D, L, M, r, expected):
    assert lotr.paper_count(D, L, M, r) == expected
    cfg = lotr.LoTRConfig(num_layers=L, matrix_types=("q", "v"),
                          d_in=(D, D), d_out=(D, D), rank=r)
    assert cfg.num_params() == expected


def test_compression_ranking_matches_paper():
    """§2.4: MetaTT grows with the SUM across modes, LoRA with the PRODUCT.
    At matched rank, MetaTT-4D < LoTR < LoRA for the paper's configs."""
    for D, L in ((768, 12), (1024, 24)):
        for r in (8, 16, 32):
            m4 = metatt.paper_count_4d(D, L, 2, r)
            lt = lotr.paper_count(D, L, 2, r)
            lr = lora.paper_count(D, L, 2, r)
            assert m4 < lt < lr


def test_mtl_task_core_overhead():
    """Table 2: MetaTT-(4+1)D adds ~200 params over MetaTT-4D at r=8, T=3
    (one extra r×r core per task = T·r² = 192)."""
    cfg4 = metatt.MetaTTConfig(num_layers=12, matrix_types=("q", "v"),
                               d_in=(768, 768), d_out=(768, 768), rank=8)
    cfg41 = metatt.MetaTTConfig(num_layers=12, matrix_types=("q", "v"),
                                d_in=(768, 768), d_out=(768, 768), rank=8,
                                variant="4+1d", num_tasks=3)
    assert cfg41.num_params() - cfg4.num_params() == 3 * 64  # 192 ≈ "200"
