"""Sharded-execution tests (subprocess with 8 fake host devices so the main
pytest process keeps the single real CPU device)."""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow

_ENV = dict(os.environ,
            XLA_FLAGS="--xla_force_host_platform_device_count=8",
            PYTHONPATH="src")


def _run(code: str):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=_ENV,
                       cwd=os.path.dirname(os.path.dirname(__file__)),
                       timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_sharded_train_step_runs_and_matches_single_device():
    """The (data=4, model=2) sharded train step produces the same loss as
    the unsharded one — GSPMD + shard_map EP are numerically transparent."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.mesh import make_host_mesh
        from repro.configs import get_smoke_config
        from repro.config.base import RunConfig, SHAPES, TrainConfig
        from repro.models import model as M
        from repro.train import train_step as ts
        from repro.distributed import GradCompressor
        cfg = dataclasses.replace(get_smoke_config('granite-moe-1b-a400m'),
                                  num_experts=4, experts_per_token=2,
                                  moe_capacity_factor=8.0)
        run = RunConfig(model=cfg, shape=SHAPES['train_4k'],
                        adapter_kind='metatt', adapter_rank=4,
                        train=TrainConfig(remat='none'))
        spec = M.build_adapter_spec(run)
        key = jax.random.PRNGKey(0)
        params = M.init_params(cfg, spec, key)
        toks = jax.random.randint(key, (8, 32), 0, cfg.vocab_size)
        # unsharded reference (compare CE to CE — total loss adds aux)
        _, m_ref = M.loss_fn(params['adapter'], params['base'],
                             params['frozen'], {'tokens': toks}, cfg, spec)
        l_ref = m_ref['ce']
        mesh = make_host_mesh(4, 2)
        with mesh:
            state = ts.init_train_state(params['adapter'],
                                        GradCompressor('none'))
            step = ts.make_train_step(cfg, spec, run.optimizer, run.train,
                                      100)
            b = {'tokens': jax.device_put(
                toks, NamedSharding(mesh, P('data', None)))}
            state, mets = step(state, params['base'], params['frozen'], b)
        l_sh = float(mets['ce'])
        assert abs(l_sh - float(l_ref)) / float(l_ref) < 1e-2, (l_sh, float(l_ref))
        print('OK', l_sh, float(l_ref))
    """)


def test_moe_ep_matches_local_path():
    """shard_map expert parallelism == the no-mesh local path, exactly."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.mesh import make_host_mesh
        from repro.config.base import ModelConfig
        from repro.models import moe as MO
        from repro.models.layers import NO_ADAPTER
        key = jax.random.PRNGKey(0)
        cfg = ModelConfig(name='t', family='moe', num_layers=1, d_model=16,
                          num_heads=2, num_kv_heads=2, d_ff=8, vocab_size=32,
                          block_pattern=(('attn','moe'),), num_experts=4,
                          experts_per_token=2, moe_capacity_factor=8.0,
                          param_dtype=jnp.float32, compute_dtype=jnp.float32)
        x = jax.random.normal(key, (4, 8, 16))
        w = {'router': jax.random.normal(key, (16, 4)),
             'e_wg': jax.random.normal(key, (4, 16, 8)),
             'e_wu': jax.random.normal(jax.random.PRNGKey(1), (4, 16, 8)),
             'e_wd': jax.random.normal(jax.random.PRNGKey(2), (4, 8, 16))}
        y_local, _ = MO.moe_ffn(x, w, NO_ADAPTER, cfg)
        mesh = make_host_mesh(2, 4)   # model axis 4 -> 1 expert per shard
        with mesh:
            y_ep, _ = jax.jit(lambda x, w: MO.moe_ffn(x, w, NO_ADAPTER,
                                                      cfg))(x, w)
        err = float(jnp.abs(y_local - y_ep).max())
        assert err < 1e-4, err
        print('OK', err)
    """)


def test_elastic_remesh():
    """Reshard params from a (4,2) mesh to a (2,4) mesh (elastic resize)."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_host_mesh
        from repro.configs import get_smoke_config
        from repro.models import transformer
        from repro.distributed import remesh
        from repro.sharding import params_sharding
        cfg = get_smoke_config('gemma-7b')
        key = jax.random.PRNGKey(0)
        base = transformer.init_base_params(cfg, key)
        m1 = make_host_mesh(4, 2)
        base1 = jax.device_put(base, params_sharding(base, m1))
        m2 = make_host_mesh(2, 4)      # lost half the data axis, grew model
        base2 = remesh(base1, m2)
        for a, b in zip(jax.tree_util.tree_leaves(base1),
                        jax.tree_util.tree_leaves(base2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))
        print('OK')
    """)


def test_compressed_psum_shard_map():
    """int8-on-the-wire psum approximates the exact psum."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_host_mesh
        from repro.distributed import compressed_psum
        from repro.sharding.compat import shard_map
        mesh = make_host_mesh(8, 1)
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
        def f(xs):
            exact = jax.lax.psum(xs, 'data')
            approx = compressed_psum(xs, 'data', kind='int8')
            return exact, approx
        with mesh:
            ex, ap = jax.jit(shard_map(
                f, mesh=mesh, in_specs=P('data', None),
                out_specs=(P(None, None), P(None, None)),
                check_vma=False))(x)
        rel = float(jnp.abs(ex - ap).max() / jnp.abs(ex).max())
        assert rel < 0.05, rel
        print('OK', rel)
    """)
