# NOTE: deliberately NO XLA_FLAGS / device-count override here — smoke tests
# and benches must see the single real CPU device. Sharded tests spawn
# subprocesses with their own XLA_FLAGS (tests/test_sharding.py).
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(42)
