"""Continuous-batching serving engine (repro/serving/).

Coverage pinned by the serving refactor:
  * jitted while_loop decode is token-identical to the seed per-step
    Python loop,
  * a mixed-task batch equals per-task single-request serving (4+1d
    routing from ONE shared TT),
  * slot eviction/admission preserves in-flight sequences,
  * live / lora / merged adapter runtimes agree,
  * fold_transformer folds EVERY layer (the blocks[0]-only fold bug).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as registry
from repro.config.base import RunConfig, SHAPES
from repro.core import tt as ttlib
from repro.core.merge import fold_transformer
from repro.models import model as M, transformer as T
from repro.peft import api as peft_api
from repro.serving import (AdapterRuntime, Engine, Request, SamplingConfig,
                           engine as se)

KEY = jax.random.PRNGKey(0)


def _setup(variant="4d", num_tasks=0, scale=0.8, arch="stablelm-1.6b",
           model_cfg=None):
    cfg = model_cfg or registry.get_smoke_config(arch)
    run = RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                    adapter_kind="metatt", adapter_variant=variant,
                    num_tasks=num_tasks, adapter_rank=4)
    spec = M.build_adapter_spec(run)
    params = M.init_params(cfg, spec, KEY)
    params["adapter"] = {"cores": ttlib.random_tt(
        KEY, spec.cfg.mode_sizes, 4, scale=scale)}
    return cfg, spec, params


def _python_loop(cfg, spec, params, prompt, n_new, cache_len, task=None):
    """The seed's per-token Python decode loop (greedy)."""
    prefill = se.make_prefill(cfg, spec, cache_len)
    logits, caches, _ = prefill(params["base"], params["adapter"],
                                params["frozen"], prompt[None], None, None,
                                task)
    step = se.make_serve_step(cfg, spec)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    out = [int(tok[0, 0])]
    pos = prompt.shape[0]
    for i in range(n_new - 1):
        lg, caches = step(params["base"], params["adapter"],
                          params["frozen"], tok, caches, jnp.int32(pos + i),
                          None, task)
        tok = jnp.argmax(lg, axis=-1)[:, None]
        out.append(int(tok[0, 0]))
    return out


def test_jitted_loop_matches_python_loop():
    cfg, spec, params = _setup()
    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    eng = Engine(cfg, rt, max_batch=2, cache_len=32, out_cap=8)
    prompts = [jax.random.randint(jax.random.PRNGKey(i), (5 + i,), 0,
                                  cfg.vocab_size) for i in range(3)]
    outs = eng.generate([Request(p, 6) for p in prompts])
    for p, got in zip(prompts, outs):
        ref = _python_loop(cfg, spec, params, p, 6, 32)
        assert got.tolist() == ref


def test_mixed_task_batch_matches_single_task_serving():
    cfg, spec, params = _setup(variant="4+1d", num_tasks=3)
    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    assert rt.tasked
    prompt = jax.random.randint(KEY, (6,), 0, cfg.vocab_size)
    reqs = [Request(prompt, 5, task=t) for t in range(3)]
    mixed = Engine(cfg, rt, max_batch=3, cache_len=32,
                   out_cap=8).generate(reqs)
    # the task axis must actually route: identical prompts, different output
    assert len({tuple(o.tolist()) for o in mixed}) > 1
    solo_eng = Engine(cfg, rt, max_batch=1, cache_len=32, out_cap=8)
    for t in range(3):
        solo = solo_eng.generate([Request(prompt, 5, task=t)])[0]
        assert solo.tolist() == mixed[t].tolist(), t
        ref = _python_loop(cfg, spec, params, prompt, 5, 32,
                           task=jnp.int32(t))
        assert mixed[t].tolist() == ref, t


def test_slot_eviction_admission_preserves_in_flight_sequences():
    """5 requests through 2 slots with staggered budgets: every admission
    into a freed slot happens while the other slot is mid-generation."""
    cfg, spec, params = _setup()
    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    eng = Engine(cfg, rt, max_batch=2, cache_len=32, out_cap=16)
    prompts = [jax.random.randint(jax.random.PRNGKey(10 + i), (4 + i,), 0,
                                  cfg.vocab_size) for i in range(5)]
    budgets = [3, 11, 1, 7, 5]
    outs = eng.generate([Request(p, n) for p, n in zip(prompts, budgets)])
    for p, n, got in zip(prompts, budgets, outs):
        assert len(got) == n
        assert got.tolist() == _python_loop(cfg, spec, params, p, n, 32)


def test_merged_and_lora_runtimes_agree_with_live():
    cfg, spec, params = _setup(variant="4+1d", num_tasks=2)
    base, adapter, frozen = (params["base"], params["adapter"],
                             params["frozen"])
    prompt = jax.random.randint(KEY, (6,), 0, cfg.vocab_size)
    outs = {}
    for mode, kw in (("live", {}), ("lora", {}),
                     ("merged", dict(model_cfg=cfg, task=1))):
        rt = AdapterRuntime.build(mode, base, spec, adapter, frozen, **kw)
        eng = Engine(cfg, rt, max_batch=1, cache_len=32, out_cap=8)
        outs[mode] = eng.generate([Request(prompt, 5, task=1)])[0].tolist()
    assert outs["lora"] == outs["live"]
    assert outs["merged"] == outs["live"]
    # merged froze task 1; a task-0 request must be rejected, not mis-served
    rt = AdapterRuntime.build("merged", base, spec, adapter, frozen,
                              model_cfg=cfg, task=1)
    eng = Engine(cfg, rt, max_batch=1, cache_len=32, out_cap=8)
    with pytest.raises(ValueError):
        eng.generate([Request(prompt, 5, task=0)])


def test_fold_transformer_folds_all_layers_and_positions():
    """The seed fold kept only blocks[0] — wrong for every pattern with >1
    position. fold_transformer must match the live forward on a 2-position
    (4-layer) pattern, and folding with a zeroed adapter must be a no-op."""
    base_cfg = registry.get_smoke_config("stablelm-1.6b")
    cfg = dataclasses.replace(
        base_cfg, name="stablelm-2pos", num_layers=4,
        block_pattern=(("attn", "dense"), ("attn", "dense")))
    cfg2, spec, params = _setup(model_cfg=cfg)
    bc, pl = peft_api.adapter_factors(spec, params["adapter"],
                                      params["frozen"])
    tokens = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    live = T.forward(params["base"], cfg, spec, bc, pl, tokens)
    folded = fold_transformer(params["adapter"], spec.cfg, params["base"],
                              cfg)
    merged = T.forward(folded, cfg, peft_api.NONE, {}, None, tokens)
    rel = (float(jnp.max(jnp.abs(merged.logits - live.logits)))
           / float(jnp.max(jnp.abs(live.logits))))
    assert rel < 2e-2, rel
    # blocks[0]-only fold (the old bug) must NOT match on this config
    buggy = dict(params["base"])
    buggy["blocks"] = [folded["blocks"][0], params["base"]["blocks"][1]]
    out_buggy = T.forward(buggy, cfg, peft_api.NONE, {}, None, tokens)
    rel_buggy = (float(jnp.max(jnp.abs(out_buggy.logits - live.logits)))
                 / float(jnp.max(jnp.abs(live.logits))))
    assert rel_buggy > rel


def test_temperature_zero_seedless_greedy_and_sampling_shapes():
    """Non-greedy samplers stay in-graph and produce per-slot tokens."""
    cfg, spec, params = _setup()
    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    eng = Engine(cfg, rt, max_batch=2, cache_len=32, out_cap=8,
                 sampling=SamplingConfig(method="top_k", temperature=0.8,
                                         top_k=5))
    prompt = jax.random.randint(KEY, (5,), 0, cfg.vocab_size)
    outs = eng.generate([Request(prompt, 6), Request(prompt, 6)],
                        key=jax.random.PRNGKey(7))
    assert all(len(o) == 6 for o in outs)
    assert all(0 <= int(t) < cfg.padded_vocab for o in outs for t in o)
