"""Checkpoint manager: atomicity, keep-k GC, async, shape-flexible restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager


def _tree(v):
    return {"a": jnp.full((3, 4), v, jnp.float32),
            "b": [jnp.full((2,), v + 1, jnp.bfloat16),
                  jnp.asarray(v, jnp.int32)]}


def test_save_restore_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    cm.save(5, _tree(1.0), meta={"data_state": {"step": 5, "seed": 11}})
    tree, meta = cm.restore(5, _tree(0.0))
    np.testing.assert_allclose(tree["a"], 1.0)
    assert tree["b"][0].dtype == jnp.bfloat16
    assert meta["data_state"]["step"] == 5


def test_keep_k_gc(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        cm.save(s, _tree(float(s)))
    assert cm.all_steps() == [3, 4]
    assert cm.latest_step() == 4


def test_restore_latest(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=3)
    assert cm.restore_latest(_tree(0.0)) is None
    cm.save(7, _tree(2.0))
    step, tree, _ = cm.restore_latest(_tree(0.0))
    assert step == 7
    np.testing.assert_allclose(tree["a"], 2.0)


def test_async_save(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    cm.save(1, _tree(9.0))
    cm.wait()
    assert cm.latest_step() == 1


def test_no_partial_files_on_disk(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=3)
    cm.save(1, _tree(1.0))
    names = os.listdir(tmp_path)
    assert all(not n.startswith("ckpt_") or n.endswith((".npz", ".json"))
               for n in names)
    assert not any(".tmp." in n for n in names)


def test_shape_flexible_restore_for_dmrg(tmp_path):
    """After a DMRG sweep TT core shapes change; restore must accept a
    template whose leaf shapes differ from the saved arrays."""
    cm = CheckpointManager(str(tmp_path), keep=3)
    saved = {"cores": [jnp.ones((1, 8, 4)), jnp.ones((4, 8, 1))]}
    cm.save(3, saved)
    template = {"cores": [jnp.zeros((1, 8, 2)), jnp.zeros((2, 8, 1))]}
    tree, _ = cm.restore(3, template)
    assert tree["cores"][0].shape == (1, 8, 4)   # saved shapes win
