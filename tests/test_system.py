"""End-to-end behaviour tests for the paper's system.

The headline reproduction claims, executable on CPU:
 1. MetaTT fine-tunes a frozen model to a *better-than-chance* synthetic
    GLUE-like task with far fewer trainable params than LoRA.
 2. The DMRG-interspersed run ends at the target rank and still trains.
 3. Multi-task (4+1)D: one adapter, per-task cores, all tasks learn.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as registry
from repro.config.base import OptimizerConfig, RunConfig, SHAPES, TrainConfig
from repro.data import ClassificationTasks, LMStream
from repro.models import model as M
from repro.peft import api as peft_api
from repro.train.trainer import Trainer

pytestmark = pytest.mark.slow

CFG = registry.get_smoke_config("roberta-base")


def _train(adapter_kind, steps=60, rank=4, variant="4d", ntasks=0,
           task_cycle=(), data=None, lr=2e-2):
    run = RunConfig(model=CFG, shape=SHAPES["train_4k"],
                    adapter_kind=adapter_kind, adapter_variant=variant,
                    adapter_rank=rank, adapter_alpha=4.0, num_tasks=ntasks,
                    optimizer=OptimizerConfig(lr=lr, warmup_ratio=0.1),
                    train=TrainConfig(remat="none", seed=42))
    data = data or LMStream(vocab_size=CFG.vocab_size, seq_len=32, batch=8,
                            seed=5, branching=2)
    tr = Trainer(run=run, data=data, total_steps=steps,
                 task_cycle=task_cycle)
    tr.train()
    return tr


def test_metatt_learns_with_far_fewer_params_than_lora():
    tr_tt = _train("metatt")
    tr_lora = _train("lora")
    n_tt = peft_api.count_trainable(tr_tt.spec, tr_tt.state.adapter)
    n_lora = peft_api.count_trainable(tr_lora.spec, tr_lora.state.adapter)
    assert n_lora / n_tt > 3, (n_tt, n_lora)   # smoke dims; paper: 20x
    # both reduce loss substantially; MetaTT within ~2x of LoRA's drop
    def drop(tr):
        l = tr.losses()
        return float(np.mean(l[:5]) - np.mean(l[-5:]))
    d_tt, d_lora = drop(tr_tt), drop(tr_lora)
    assert d_tt > 0.1 and d_lora > 0.1, (d_tt, d_lora)
    assert d_tt > 0.5 * d_lora, (d_tt, d_lora)


def test_multitask_4p1d_all_tasks_learn():
    """Paper §3.2 shape: pre-train the base on the MIXED task distribution
    (the tasks' rules conflict, so no single frozen model can solve all
    three), then freeze it and joint-train one MetaTT-(4+1)D adapter whose
    task core disambiguates. Expect near-perfect per-task accuracy."""
    from repro.models import transformer as T
    from repro.optim import adamw
    from repro.train import train_step as ts
    key = jax.random.PRNGKey(0)
    tasks = ClassificationTasks(vocab_size=CFG.vocab_size, seq_len=8,
                                batch=32, num_tasks=3, seed=9)
    # stage 1: "pre-training" stand-in (full FT on mixed tasks)
    base = T.init_base_params(CFG, key)
    ft = ts.make_full_ft_step(CFG, OptimizerConfig(lr=3e-3,
                                                   warmup_ratio=0.05),
                              TrainConfig(remat="none"), 200)
    opt = adamw.init_state(base)
    for i in range(150):
        b = tasks.sample(i % 3)
        base, opt, _ = ft(base, opt,
                          {"tokens": jnp.asarray(b["tokens"]),
                           "mask": jnp.asarray(b["mask"])})
    # stage 2: frozen base + MetaTT-(4+1)D, adapter-only joint training
    run = RunConfig(model=CFG, shape=SHAPES["train_4k"],
                    adapter_kind="metatt", adapter_variant="4+1d",
                    adapter_rank=8, adapter_alpha=4.0, num_tasks=3,
                    optimizer=OptimizerConfig(lr=2e-2, warmup_ratio=0.05),
                    train=TrainConfig(remat="none", seed=42))
    tr = Trainer(run=run, data=tasks, total_steps=240, task_cycle=(0, 1, 2))
    tr.base = base
    tr.train()
    bc, pl = peft_api.adapter_factors(tr.spec, tr.state.adapter, tr.frozen)
    accs = []
    for t in range(3):
        b = tasks.sample(t, split="eval")
        out = T.forward(base, CFG, tr.spec, bc, pl,
                        jnp.asarray(b["tokens"]), task=jnp.int32(t))
        accs.append(tasks.accuracy(np.asarray(out.logits[:, -2]),
                                   b["labels"], tasks.class_token_base,
                                   tasks.n_classes))
    assert np.mean(accs) > 0.8, accs


def test_dmrg_interspersed_training_reaches_target_rank():
    from repro.core.dmrg import RankSchedule
    from repro.core import tt
    run = RunConfig(model=CFG, shape=SHAPES["train_4k"],
                    adapter_kind="metatt", adapter_rank=8,
                    adapter_alpha=4.0,
                    optimizer=OptimizerConfig(lr=2e-2, warmup_ratio=0.1),
                    train=TrainConfig(remat="none", seed=42))
    data = LMStream(vocab_size=CFG.vocab_size, seq_len=32, batch=8, seed=5,
                    branching=2)
    tr = Trainer(run=run, data=data, total_steps=60, steps_per_epoch=15,
                 rank_schedule=RankSchedule(milestones=((1, 6), (2, 4))))
    tr.train()
    assert max(tt.ranks(tr.state.adapter["cores"])) <= 4
    losses = tr.losses()
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
