"""Integration tests: the trainer loop end-to-end on CPU.

Covers: loss decreases, checkpoint/restart resume equivalence, simulated
node failure + auto-resume, DMRG rank-adaptive training, gradient
compression, microbatch accumulation equivalence, full-FT baseline.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as registry
from repro.config.base import (OptimizerConfig, RunConfig, SHAPES,
                               TrainConfig)
from repro.core.dmrg import RankSchedule
from repro.data import LMStream
from repro.distributed import FailureInjector, SimulatedFailure
from repro.train.trainer import Trainer

CFG = registry.get_smoke_config("stablelm-1.6b")


def _run(tmp, steps=24, seed=3, **kw):
    run = RunConfig(
        model=CFG, shape=SHAPES["train_4k"], adapter_kind="metatt",
        adapter_rank=kw.pop("adapter_rank", 4), adapter_alpha=4.0,
        optimizer=OptimizerConfig(lr=2e-2, warmup_ratio=0.1),
        train=TrainConfig(seed=seed, ckpt_every=kw.pop("ckpt_every", 0),
                          ckpt_dir=kw.pop("ckpt_dir", ""),
                          remat="none",
                          grad_compression=kw.pop("grad_compression",
                                                  "none"),
                          microbatch=kw.pop("microbatch", 0),
                          dmrg_warm_moments=kw.pop("dmrg_warm_moments",
                                                   True)))
    data = LMStream(vocab_size=CFG.vocab_size, seq_len=32, batch=8,
                    seed=11, branching=2)
    return Trainer(run=run, data=data, total_steps=steps, **kw)


def test_loss_decreases(tmp_path):
    tr = _run(tmp_path, steps=30)
    tr.train()
    losses = tr.losses()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses


def test_checkpoint_resume_is_equivalent(tmp_path):
    d = str(tmp_path / "ck")
    # uninterrupted run
    tr_full = _run(tmp_path, steps=20)
    tr_full.train()
    # interrupted at step 10 by a simulated node failure, then restarted
    tr_a = _run(tmp_path, steps=20, ckpt_dir=d, ckpt_every=5,
                failure_injector=FailureInjector(fail_at_step=10))
    with pytest.raises(SimulatedFailure):
        tr_a.train()
    tr_b = _run(tmp_path, steps=20, ckpt_dir=d, ckpt_every=5)
    assert int(tr_b.state.step) == 10  # auto-resumed from latest snapshot
    tr_b.train()
    # identical final adapter: deterministic data + restored opt state
    la = tr_full.state.adapter["cores"]
    lb = tr_b.state.adapter["cores"]
    for x, y in zip(la, lb):
        np.testing.assert_allclose(x, y, atol=1e-5)


def test_dmrg_rank_adaptive_training(tmp_path):
    sched = RankSchedule(milestones=((1, 6), (2, 4)))
    tr = _run(tmp_path, steps=30, steps_per_epoch=10, rank_schedule=sched)
    # starting rank 8 per run config? adapter_rank=4 -> start higher
    tr.run = dataclasses.replace(tr.run, adapter_rank=8)
    tr2 = Trainer(run=dataclasses.replace(tr.run), data=tr.data,
                  total_steps=30, steps_per_epoch=10, rank_schedule=sched)
    tr2.train()
    from repro.core import tt
    final_ranks = tt.ranks(tr2.state.adapter["cores"])
    assert max(final_ranks) <= 4, final_ranks
    # optimizer moments were rebuilt to the new shapes
    for m, p in zip(jax.tree_util.tree_leaves(tr2.state.opt.mu),
                    jax.tree_util.tree_leaves(tr2.state.adapter)):
        assert m.shape == p.shape
    losses = tr2.losses()
    assert np.isfinite(losses).all()


def test_dmrg_warm_moments_carry_over(tmp_path):
    """Regression for the stale-moment bug: a rank-changed core must get
    moments RESPLIT with the bond (warm, transported through the sweep)
    and keep the Adam step counter — the old reinit silently zeroed both."""
    sched = RankSchedule(milestones=((1, 6),))
    tr = _run(tmp_path, steps=10, adapter_rank=8, steps_per_epoch=10,
              rank_schedule=sched)
    tr.train()          # sweep fires at the step-10 epoch boundary
    from repro.core import tt
    assert max(tt.ranks(tr.state.adapter["cores"])) <= 6
    # moments match the NEW core shapes (no stale-shape crash on step 11)
    for m, p in zip(jax.tree_util.tree_leaves(tr.state.opt.mu),
                    jax.tree_util.tree_leaves(tr.state.adapter)):
        assert m.shape == p.shape
    # warm: the transported first moments are non-trivial, second moments
    # stay non-negative, and the bias-correction clock did NOT rewind
    assert int(tr.state.opt.step) == 10
    mu_norm = sum(float(jnp.abs(m).sum())
                  for m in jax.tree_util.tree_leaves(tr.state.opt.mu))
    assert mu_norm > 0
    for v in jax.tree_util.tree_leaves(tr.state.opt.nu):
        assert float(v.min()) >= 0
    # the next step runs against the resplit moments without retracing pain
    tr.train(steps=11)
    assert np.isfinite(tr.losses()).all()
    # cold fallback (paper §3.3): fresh zeros, clock restarted
    tr_cold = _run(tmp_path, steps=10, adapter_rank=8, steps_per_epoch=10,
                   rank_schedule=sched, dmrg_warm_moments=False)
    tr_cold.train()
    assert int(tr_cold.state.opt.step) == 0
    assert sum(float(jnp.abs(m).sum()) for m in
               jax.tree_util.tree_leaves(tr_cold.state.opt.mu)) == 0


def test_dmrg_resume_lands_on_post_sweep_triple(tmp_path):
    """A checkpoint at an epoch boundary must capture the POST-sweep
    (params, opt-state, schedule-position) triple: resuming from it
    continues with the reshaped cores + carried moments and never replays
    the sweep (the old save-then-sweep order silently lost the rank
    change on restart)."""
    sched = RankSchedule(milestones=((1, 6),))
    kw = dict(adapter_rank=8, steps_per_epoch=10, rank_schedule=sched)
    # uninterrupted run
    tr_full = _run(tmp_path, steps=20, **kw)
    tr_full.train()
    # interrupted right after the boundary checkpoint, then restarted
    d = str(tmp_path / "ck")
    tr_a = _run(tmp_path, steps=20, ckpt_dir=d, ckpt_every=10,
                failure_injector=FailureInjector(fail_at_step=15), **kw)
    with pytest.raises(SimulatedFailure):
        tr_a.train()
    tr_b = _run(tmp_path, steps=20, ckpt_dir=d, ckpt_every=10, **kw)
    from repro.core import tt
    assert int(tr_b.state.step) == 10
    # the restored triple is post-sweep: reshaped cores, carried moments,
    # schedule position recorded so epoch 1 is never re-applied
    assert max(tt.ranks(tr_b.state.adapter["cores"])) <= 6
    assert int(tr_b.state.opt.step) == 10
    assert tr_b._dmrg_applied == [1]
    tr_b.train()
    for x, y in zip(tr_full.state.adapter["cores"],
                    tr_b.state.adapter["cores"]):
        np.testing.assert_allclose(x, y, atol=1e-5)


def test_dmrg_training_under_forced_mesh(tmp_path):
    """Rank-adaptive training under an ambient 4-device GSPMD mesh: the
    host-side sweep reshapes cores + moments, and the trainer re-places
    them on the mesh (sharding/rules.py::reshard_after_reshape) before the
    retrace. Subprocess with fake host devices, like test_sharding.py."""
    import subprocess
    import sys
    import textwrap
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH="src")
    code = textwrap.dedent("""
        import jax, numpy as np
        from jax.sharding import Mesh
        from repro import configs as registry
        from repro.config.base import (OptimizerConfig, RunConfig, SHAPES,
                                       TrainConfig)
        from repro.core import tt
        from repro.core.dmrg import RankSchedule
        from repro.data import LMStream
        from repro.train.trainer import Trainer
        assert jax.device_count() == 4
        cfg = registry.get_smoke_config('stablelm-1.6b')
        run = RunConfig(model=cfg, shape=SHAPES['train_4k'],
                        adapter_kind='metatt', adapter_rank=8,
                        adapter_alpha=4.0,
                        optimizer=OptimizerConfig(lr=2e-2,
                                                  warmup_ratio=0.1),
                        train=TrainConfig(seed=3, remat='none'))
        data = LMStream(vocab_size=cfg.vocab_size, seq_len=32, batch=8,
                        seed=11, branching=2)
        mesh = Mesh(np.array(jax.devices()).reshape(2, 2),
                    ('data', 'model'))
        with mesh:
            tr = Trainer(run=run, data=data, total_steps=15,
                         steps_per_epoch=10,
                         rank_schedule=RankSchedule(milestones=((1, 6),)))
            tr.train()
        ranks = tt.ranks(tr.state.adapter['cores'])
        assert max(ranks) <= 6, ranks
        assert int(tr.state.opt.step) == 15
        # every rank-changed leaf actually lives on the 4-device mesh
        for leaf in jax.tree_util.tree_leaves(tr.state.adapter):
            assert len(leaf.devices()) == 4, leaf.sharding
        for leaf in jax.tree_util.tree_leaves(tr.state.opt.mu):
            assert len(leaf.devices()) == 4, leaf.sharding
        losses = np.array([m['loss'] for _, m in tr.history])
        assert np.isfinite(losses).all()
        print('OK', ranks, losses[-1])
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"


def test_grad_compression_trains(tmp_path):
    for kind in ("int8", "topk"):
        tr = _run(tmp_path, steps=20, grad_compression=kind)
        tr.train()
        losses = tr.losses()
        assert np.isfinite(losses).all()
        assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_microbatch_accumulation_matches_full_batch(tmp_path):
    """nmb=4 gradient accumulation == single big batch (same data/seed)."""
    tr1 = _run(tmp_path, steps=3, microbatch=0)
    tr1.train()
    tr2 = _run(tmp_path, steps=3, microbatch=4)
    tr2.train()
    for x, y in zip(tr1.state.adapter["cores"], tr2.state.adapter["cores"]):
        np.testing.assert_allclose(x, y, atol=2e-4)


def test_straggler_watchdog_fires():
    from repro.distributed import Watchdog
    events = []
    wd = Watchdog(threshold=2.0, min_steps=3,
                  on_straggler=lambda s, dt, ew: events.append(s))
    for i in range(10):
        wd.step(i, 0.1)
    assert not events
    wd.step(10, 1.0)   # 10x the EWMA -> flagged
    assert events == [10]


def test_full_ft_baseline_step():
    """Paper Table 1 "FT" row: full fine-tuning machinery works."""
    from repro.optim import adamw
    from repro.train import train_step as ts
    cfg = registry.get_smoke_config("roberta-base")
    key = jax.random.PRNGKey(0)
    from repro.models import transformer
    base = transformer.init_base_params(cfg, key)
    step = ts.make_full_ft_step(cfg, OptimizerConfig(lr=1e-3),
                                TrainConfig(remat="none"), 10)
    opt = adamw.init_state(base)
    batch = {"tokens": jax.random.randint(key, (4, 16), 0, cfg.vocab_size)}
    base_before = jax.tree_util.tree_map(jnp.copy, base)
    base2, opt2, m = step(base, opt, batch)
    assert np.isfinite(float(m["loss"]))
    # base weights actually moved (unlike the PEFT path, which freezes them)
    moved = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(base2),
        jax.tree_util.tree_leaves(base_before)))
    assert moved > 0
