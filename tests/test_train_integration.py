"""Integration tests: the trainer loop end-to-end on CPU.

Covers: loss decreases, checkpoint/restart resume equivalence, simulated
node failure + auto-resume, DMRG rank-adaptive training, gradient
compression, microbatch accumulation equivalence, full-FT baseline.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as registry
from repro.config.base import (OptimizerConfig, RunConfig, SHAPES,
                               TrainConfig)
from repro.core.dmrg import RankSchedule
from repro.data import LMStream
from repro.distributed import FailureInjector, SimulatedFailure
from repro.train.trainer import Trainer

CFG = registry.get_smoke_config("stablelm-1.6b")


def _run(tmp, steps=24, seed=3, **kw):
    run = RunConfig(
        model=CFG, shape=SHAPES["train_4k"], adapter_kind="metatt",
        adapter_rank=4, adapter_alpha=4.0,
        optimizer=OptimizerConfig(lr=2e-2, warmup_ratio=0.1),
        train=TrainConfig(seed=seed, ckpt_every=kw.pop("ckpt_every", 0),
                          ckpt_dir=kw.pop("ckpt_dir", ""),
                          remat="none",
                          grad_compression=kw.pop("grad_compression",
                                                  "none"),
                          microbatch=kw.pop("microbatch", 0)))
    data = LMStream(vocab_size=CFG.vocab_size, seq_len=32, batch=8,
                    seed=11, branching=2)
    return Trainer(run=run, data=data, total_steps=steps, **kw)


def test_loss_decreases(tmp_path):
    tr = _run(tmp_path, steps=30)
    tr.train()
    losses = tr.losses()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses


def test_checkpoint_resume_is_equivalent(tmp_path):
    d = str(tmp_path / "ck")
    # uninterrupted run
    tr_full = _run(tmp_path, steps=20)
    tr_full.train()
    # interrupted at step 10 by a simulated node failure, then restarted
    tr_a = _run(tmp_path, steps=20, ckpt_dir=d, ckpt_every=5,
                failure_injector=FailureInjector(fail_at_step=10))
    with pytest.raises(SimulatedFailure):
        tr_a.train()
    tr_b = _run(tmp_path, steps=20, ckpt_dir=d, ckpt_every=5)
    assert int(tr_b.state.step) == 10  # auto-resumed from latest snapshot
    tr_b.train()
    # identical final adapter: deterministic data + restored opt state
    la = tr_full.state.adapter["cores"]
    lb = tr_b.state.adapter["cores"]
    for x, y in zip(la, lb):
        np.testing.assert_allclose(x, y, atol=1e-5)


def test_dmrg_rank_adaptive_training(tmp_path):
    sched = RankSchedule(milestones=((1, 6), (2, 4)))
    tr = _run(tmp_path, steps=30, steps_per_epoch=10, rank_schedule=sched)
    # starting rank 8 per run config? adapter_rank=4 -> start higher
    tr.run = dataclasses.replace(tr.run, adapter_rank=8)
    tr2 = Trainer(run=dataclasses.replace(tr.run), data=tr.data,
                  total_steps=30, steps_per_epoch=10, rank_schedule=sched)
    tr2.train()
    from repro.core import tt
    final_ranks = tt.ranks(tr2.state.adapter["cores"])
    assert max(final_ranks) <= 4, final_ranks
    # optimizer moments were rebuilt to the new shapes
    for m, p in zip(jax.tree_util.tree_leaves(tr2.state.opt.mu),
                    jax.tree_util.tree_leaves(tr2.state.adapter)):
        assert m.shape == p.shape
    losses = tr2.losses()
    assert np.isfinite(losses).all()


def test_grad_compression_trains(tmp_path):
    for kind in ("int8", "topk"):
        tr = _run(tmp_path, steps=20, grad_compression=kind)
        tr.train()
        losses = tr.losses()
        assert np.isfinite(losses).all()
        assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_microbatch_accumulation_matches_full_batch(tmp_path):
    """nmb=4 gradient accumulation == single big batch (same data/seed)."""
    tr1 = _run(tmp_path, steps=3, microbatch=0)
    tr1.train()
    tr2 = _run(tmp_path, steps=3, microbatch=4)
    tr2.train()
    for x, y in zip(tr1.state.adapter["cores"], tr2.state.adapter["cores"]):
        np.testing.assert_allclose(x, y, atol=2e-4)


def test_straggler_watchdog_fires():
    from repro.distributed import Watchdog
    events = []
    wd = Watchdog(threshold=2.0, min_steps=3,
                  on_straggler=lambda s, dt, ew: events.append(s))
    for i in range(10):
        wd.step(i, 0.1)
    assert not events
    wd.step(10, 1.0)   # 10x the EWMA -> flagged
    assert events == [10]


def test_full_ft_baseline_step():
    """Paper Table 1 "FT" row: full fine-tuning machinery works."""
    from repro.optim import adamw
    from repro.train import train_step as ts
    cfg = registry.get_smoke_config("roberta-base")
    key = jax.random.PRNGKey(0)
    from repro.models import transformer
    base = transformer.init_base_params(cfg, key)
    step = ts.make_full_ft_step(cfg, OptimizerConfig(lr=1e-3),
                                TrainConfig(remat="none"), 10)
    opt = adamw.init_state(base)
    batch = {"tokens": jax.random.randint(key, (4, 16), 0, cfg.vocab_size)}
    base_before = jax.tree_util.tree_map(jnp.copy, base)
    base2, opt2, m = step(base, opt, batch)
    assert np.isfinite(float(m["loss"]))
    # base weights actually moved (unlike the PEFT path, which freezes them)
    moved = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(base2),
        jax.tree_util.tree_leaves(base_before)))
    assert moved > 0
