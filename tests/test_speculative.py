"""Speculative multi-token decode with the rank-truncated TT self-drafter
(serving/speculative.py, DESIGN.md §10) — acceptance criteria:

  * GREEDY TOKEN IDENTITY: speculative greedy decode emits bit-identical
    tokens to the non-speculative engine for EVERY drafter (rank
    truncation and layer stride included — the accept rule only commits
    verifier-argmax prefixes), across paged/dense caches, fp/int8 KV,
    ref/pallas-interpret backends and mesh(1,1)/tp4 sharding,
  * SINGLE TRACE: draft + verify + accept all live inside the one jitted
    while_loop — ``decode_traces == 1`` with speculation on,
  * STATS: draft/accept counters land on EngineStats; a full-rank
    unstrided drafter (drafter == target) accepts everything,
  * DISTRIBUTION: the Leviathan rejection-sampling accept preserves the
    output distribution (frequency test on a small categorical case),
  * BLOCK ACCOUNTING: the drafter's parallel pools ride the SAME block
    tables — no extra allocations, nothing leaked after generate.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as registry
from repro.config.base import (KernelConfig, QuantConfig, RunConfig,
                               SHAPES, ServeConfig, SpecConfig)
from repro.core import tt as ttlib
from repro.models import model as M
from repro.serving import (AdapterRuntime, Engine, Request,
                           SamplingConfig)
from repro.serving import speculative as spec_lib

KEY = jax.random.PRNGKey(0)
PALLAS = KernelConfig(backend="pallas", interpret=True)

needs4 = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs 4 (fake) devices: run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=4 "
           "(scripts/ci.sh spec-parity job)")


def _setup(variant="4+1d", num_tasks=3):
    cfg = registry.get_smoke_config("stablelm-1.6b")
    run = RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                    adapter_kind="metatt", adapter_variant=variant,
                    num_tasks=num_tasks, adapter_rank=4)
    spec = M.build_adapter_spec(run)
    params = M.init_params(cfg, spec, KEY)
    params["adapter"] = {"cores": ttlib.random_tt(
        KEY, spec.cfg.mode_sizes, 4, scale=0.8)}
    return cfg, spec, params


def _mixed_requests(cfg, n=4, tasks=3):
    prompts = [jax.random.randint(jax.random.PRNGKey(i), (4 + i,), 0,
                                  cfg.vocab_size) for i in range(n)]
    return [Request(p, 5 + (i % 3), task=i % tasks)
            for i, p in enumerate(prompts)]


def _serve(cfg, rt, reqs, *, spec=SpecConfig(), mode="paged",
           quant=QuantConfig(), kernels=None, sampling=SamplingConfig(),
           **kw):
    base = dict(max_batch=2, cache_len=32, out_cap=8, cache_mode=mode,
                page_size=8, prefill_chunk=4, quant=quant, spec=spec)
    base.update(kw)
    eng = Engine(cfg, rt, serve=ServeConfig(**base), kernels=kernels,
                 sampling=sampling)
    return [o.tolist() for o in eng.generate(reqs)], eng


# ---------------------------------------------------------------------------
# greedy token identity across the serving matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["paged", "dense"])
def test_spec_greedy_token_identical(mode):
    """Rank-truncated drafter, both cache modes: the committed stream is
    the non-speculative stream, with draft/accept stats populated."""
    cfg, _, params = _setup()
    rt = AdapterRuntime.build("live", params["base"], M.build_adapter_spec(
        RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                  adapter_kind="metatt", adapter_variant="4+1d",
                  num_tasks=3, adapter_rank=4)), params["adapter"],
        params["frozen"])
    reqs = _mixed_requests(cfg)
    base, _ = _serve(cfg, rt, reqs, mode=mode)
    out, eng = _serve(cfg, rt, reqs, mode=mode,
                      spec=SpecConfig(spec_k=3, draft_rank=2))
    assert out == base
    st = eng.last_stats
    assert st.spec_k == 3
    assert st.spec_steps > 0
    assert st.draft_tokens > 0
    assert 0.0 <= st.acceptance_rate <= 1.0


def test_spec_layer_stride_greedy_token_identical():
    """A layer-strided drafter is a WORSE approximation but greedy
    identity cannot depend on drafter quality."""
    cfg, spec, params = _setup()
    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    reqs = _mixed_requests(cfg)
    base, _ = _serve(cfg, rt, reqs)
    out, _ = _serve(cfg, rt, reqs, spec=SpecConfig(
        spec_k=2, draft_rank=2, draft_layer_stride=2))
    assert out == base


@pytest.mark.parametrize("mode", ["lora", "merged"])
def test_spec_greedy_across_runtimes(mode):
    cfg, spec, params = _setup()
    kw = dict(model_cfg=cfg, task=1) if mode == "merged" else {}
    rt = AdapterRuntime.build(mode, params["base"], spec,
                              params["adapter"], params["frozen"], **kw)
    reqs = _mixed_requests(cfg)
    if mode == "merged":
        reqs = [r for r in reqs if r.task == 1]
    base, _ = _serve(cfg, rt, reqs)
    out, _ = _serve(cfg, rt, reqs, spec=SpecConfig(spec_k=3, draft_rank=2))
    assert out == base


def test_spec_greedy_int8_kv_and_pallas_interpret():
    cfg, spec, params = _setup()
    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    reqs = _mixed_requests(cfg)
    q = QuantConfig(kv="int8")
    base, _ = _serve(cfg, rt, reqs, quant=q, kernels=PALLAS)
    out, _ = _serve(cfg, rt, reqs, quant=q, kernels=PALLAS,
                    spec=SpecConfig(spec_k=3, draft_rank=2))
    assert out == base


def test_spec_greedy_mesh_1x1():
    """The sharded step graphs (shard_map specs extended with drafter
    weights + dcaches) stay token-identical on a trivial mesh — runs in
    the tier-1 single-device suite."""
    cfg, spec, params = _setup()
    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    reqs = _mixed_requests(cfg)
    base, _ = _serve(cfg, rt, reqs)
    out, _ = _serve(cfg, rt, reqs, mesh_shape=(1, 1),
                    spec=SpecConfig(spec_k=3, draft_rank=2))
    assert out == base


@needs4
@pytest.mark.parametrize("mode", ["paged", "dense"])
def test_spec_greedy_tp4(mode):
    cfg, spec, params = _setup()
    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    reqs = _mixed_requests(cfg)
    base, _ = _serve(cfg, rt, reqs, mode=mode)
    out, _ = _serve(cfg, rt, reqs, mode=mode, mesh_shape=(1, 4),
                    spec=SpecConfig(spec_k=3, draft_rank=2))
    assert out == base


# ---------------------------------------------------------------------------
# single trace, full-rank acceptance, warm prefix reuse
# ---------------------------------------------------------------------------


def test_spec_single_decode_trace():
    """Draft, verify and accept all live inside the ONE jitted
    while_loop: heterogeneous prompt lengths + speculation still compile
    the decode graph exactly once."""
    cfg, spec, params = _setup()
    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    reqs = _mixed_requests(cfg, n=4)
    _, eng = _serve(cfg, rt, reqs, spec=SpecConfig(spec_k=3, draft_rank=2))
    assert eng.last_stats.decode_traces == 1


def test_spec_full_rank_drafter_accepts_everything():
    """draft_rank=0 / stride=1 makes the drafter THE target adapter: its
    argmax always matches the verifier's, so every draft is accepted and
    the engine commits spec_k+1 tokens per decode iteration."""
    cfg, spec, params = _setup()
    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    reqs = [Request(np.arange(4) % cfg.vocab_size, 8, task=0)]
    base, _ = _serve(cfg, rt, reqs)
    out, eng = _serve(cfg, rt, reqs, spec=SpecConfig(spec_k=3))
    assert out == base
    assert eng.last_stats.acceptance_rate == 1.0


def test_spec_warm_prefix_cache_token_identical():
    """Prefix hits reuse blocks whose cells carry BOTH the target's and
    the drafter's KV (same tables, parallel pools) — warm speculative
    output matches cold."""
    cfg, spec, params = _setup()
    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    reqs = _mixed_requests(cfg)
    cold, eng = _serve(cfg, rt, reqs,
                       spec=SpecConfig(spec_k=3, draft_rank=2))
    assert eng.last_stats.prefix_hit_rate == 0.0
    warm = [o.tolist() for o in eng.generate(reqs)]
    assert warm == cold
    assert eng.last_stats.prefix_hit_rate > 0.0


def test_spec_no_leaked_blocks_and_byte_accounting():
    """The drafter's pools ride the SAME block tables: generate allocates
    no extra blocks for drafts, and every block returns to the free list
    (prefix cache off so release is unconditional). block_bytes grows by
    the drafter region's share."""
    cfg, spec, params = _setup()
    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    reqs = _mixed_requests(cfg)
    _, base_eng = _serve(cfg, rt, reqs, prefix_cache=False)
    _, eng = _serve(cfg, rt, reqs, prefix_cache=False,
                    spec=SpecConfig(spec_k=3, draft_rank=2))
    assert eng.bm.free_blocks == eng._num_blocks
    st, bst = eng.last_stats, base_eng.last_stats
    assert st.kv_blocks_peak == bst.kv_blocks_peak
    assert st.block_bytes > bst.block_bytes       # drafter region counted
    # unstrided drafter: same layer count -> exactly double
    assert st.block_bytes == 2 * bst.block_bytes


def test_spec_temperature_engine_smoke():
    """Sampling methods run end-to-end through the rejection-sampling
    accept path (distribution-level checks live in
    test_rejection_sampling_preserves_distribution)."""
    cfg, spec, params = _setup()
    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    reqs = _mixed_requests(cfg, n=3)
    out, eng = _serve(
        cfg, rt, reqs, spec=SpecConfig(spec_k=2, draft_rank=2),
        sampling=SamplingConfig(method="top_k", top_k=8, temperature=0.9,
                                repetition_penalty=1.2))
    assert [len(o) for o in out] == [r.max_new_tokens for r in reqs]
    assert eng.last_stats.decode_traces == 1


# ---------------------------------------------------------------------------
# accept-rule unit tests (pure functions from serving/speculative.py)
# ---------------------------------------------------------------------------


def test_greedy_verify_prefix_rule():
    draft = jnp.array([[5, 7, 9], [5, 7, 9]])
    verify = jnp.array([[5, 7, 9, 2],     # all accepted + bonus
                        [5, 8, 9, 2]])    # mismatch at position 1
    emitted, n = spec_lib.greedy_verify(draft, verify)
    assert n.tolist() == [3, 1]
    assert emitted.tolist() == verify.tolist()


def test_rejection_sampling_preserves_distribution():
    """Empirical law of the FIRST committed token under a deliberately
    wrong drafter must match the target distribution p (Leviathan
    correctness), and a perfect drafter (q == p) must accept at a rate
    well above a broken one."""
    V, k, trials = 4, 1, 4000
    p = jnp.array([0.55, 0.25, 0.15, 0.05])
    q = jnp.array([0.10, 0.40, 0.30, 0.20])    # wrong on purpose

    def run(key, qv):
        kd, ka = jax.random.split(key)
        d = jax.random.categorical(kd, jnp.log(qv))[None, None]    # (1, 1)
        emitted, n = spec_lib.rejection_verify(
            ka, d, jnp.broadcast_to(qv, (1, k, V)),
            jnp.broadcast_to(p, (1, k + 1, V)))
        return emitted[0, 0], n[0]

    keys = jax.random.split(jax.random.PRNGKey(1), trials)
    toks, ns = jax.vmap(lambda kk: run(kk, q))(keys)
    freq = np.bincount(np.asarray(toks), minlength=V) / trials
    np.testing.assert_allclose(freq, np.asarray(p), atol=0.03)
    # perfect drafter: acceptance = sum_x min(p, q) = 1
    _, ns_perfect = jax.vmap(lambda kk: run(kk, p))(keys)
    assert float(np.mean(np.asarray(ns_perfect))) > \
        float(np.mean(np.asarray(ns))) + 0.2


def test_truncate_factors_rank_nesting():
    """metatt live-factor truncation keeps the LEADING bond columns —
    composing the truncated factors equals composing the full factors
    with the trailing columns zeroed (rank nesting)."""
    key = jax.random.PRNGKey(2)
    k1, k2, k3 = jax.random.split(key, 3)
    g1 = jax.random.normal(k1, (6, 4))
    c = jax.random.normal(k2, (3, 2, 4, 4))
    g4 = jax.random.normal(k3, (4, 5))
    bc, pl = spec_lib.truncate_factors(
        "metatt", {"g1": g1, "g4": g4}, {"c": c}, 2)
    assert bc["g1"].shape == (6, 2) and bc["g4"].shape == (2, 5)
    assert pl["c"].shape == (3, 2, 2, 2)
    full = jnp.einsum("dr,lmrs,se->lmde", g1.at[:, 2:].set(0),
                      c.at[..., 2:, :].set(0).at[..., :, 2:].set(0),
                      g4.at[2:, :].set(0))
    trunc = jnp.einsum("dr,lmrs,se->lmde", bc["g1"], pl["c"], bc["g4"])
    np.testing.assert_allclose(np.asarray(trunc), np.asarray(full),
                               rtol=1e-5, atol=1e-5)


def test_column_penalty_masks_compose_autoregressively():
    base = jnp.zeros((1, 6), bool).at[0, 1].set(True)
    draft = jnp.array([[3, 3, 5]])
    masks = spec_lib.column_penalty_masks(base, draft, 6)
    assert masks.shape == (1, 4, 6)
    assert masks[0, 0].tolist() == base[0].tolist()       # history only
    assert bool(masks[0, 1, 3]) and not bool(masks[0, 1, 5])
    assert bool(masks[0, 3, 3]) and bool(masks[0, 3, 5])
    assert spec_lib.column_penalty_masks(None, draft, 6) is None
