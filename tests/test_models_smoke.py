"""Per-architecture smoke tests (assignment deliverable f).

Every assigned architecture instantiates a REDUCED same-family config and
runs one forward + one train step on CPU, asserting output shapes and
finiteness. The FULL configs are exercised only via the dry-run
(ShapeDtypeStruct, no allocation) — see launch/dryrun.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as registry
from repro.config.base import SHAPES, RunConfig, TrainConfig
from repro.distributed import GradCompressor
from repro.models import model as model_lib
from repro.train import train_step as ts

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, b=2, t=16):
    batch = {"tokens": jax.random.randint(KEY, (b, t), 0, cfg.vocab_size)}
    if cfg.frontend == "patch_stub":
        batch["embeds"] = jax.random.normal(
            KEY, (b, cfg.frontend_seq, cfg.d_model), jnp.float32)
    if cfg.is_encdec:
        batch["enc_embeds"] = jax.random.normal(
            KEY, (b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = registry.get_smoke_config(arch)
    run = RunConfig(model=cfg, shape=SHAPES["train_4k"],
                    adapter_kind="metatt", adapter_rank=4,
                    train=TrainConfig(remat="none"))
    spec = model_lib.build_adapter_spec(run)
    params = model_lib.init_params(cfg, spec, KEY)
    batch = _batch_for(cfg)

    loss, metrics = model_lib.loss_fn(
        params["adapter"], params["base"], params["frozen"], batch, cfg,
        spec)
    assert np.isfinite(float(loss)), (arch, float(loss))
    assert float(loss) > 0

    step = ts.make_train_step(cfg, spec, run.optimizer, run.train,
                              total_steps=10)
    state = ts.init_train_state(params["adapter"], GradCompressor("none"))
    state, m = step(state, params["base"], params["frozen"], batch)
    assert np.isfinite(float(m["loss"]))
    assert float(m["grad_norm"]) > 0, f"{arch}: adapter got no gradient"
    # one more step with donated buffers
    state, m2 = step(state, params["base"], params["frozen"], batch)
    assert np.isfinite(float(m2["loss"]))


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The FULL config must carry the exact assigned hyperparameters."""
    cfg = registry.get_config(arch)
    expected = {
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155, 32, 8),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840, 384, 8),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216, 0, 0),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768, 0, 0),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352, 0, 0),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000, 0, 0),
        "granite-34b": (88, 6144, 48, 1, 24576, 49152, 0, 0),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866, 0, 0),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304, 0, 0),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536, 16, 2),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size, cfg.num_experts, cfg.experts_per_token)
    assert got == expected, (arch, got, expected)


def test_long_context_skip_rules():
    for arch in registry.ARCH_IDS:
        cfg = registry.get_config(arch)
        runs = registry.supports_shape(cfg, "long_500k")
        assert runs == (cfg.family in ("ssm", "hybrid")), arch
        assert registry.supports_shape(cfg, "decode_32k")


def test_adapter_variants_on_roberta():
    """The paper's own target model with every adapter method."""
    cfg = registry.get_smoke_config("roberta-base")
    batch = _batch_for(cfg)
    for kind in ("metatt", "lora", "vera", "lotr"):
        run = RunConfig(model=cfg, shape=SHAPES["train_4k"],
                        adapter_kind=kind, adapter_rank=4)
        spec = model_lib.build_adapter_spec(run)
        params = model_lib.init_params(cfg, spec, KEY)
        loss, _ = model_lib.loss_fn(params["adapter"], params["base"],
                                    params["frozen"], batch, cfg, spec)
        assert np.isfinite(float(loss)), kind


def test_kimi_param_count_is_about_1t():
    """The headline: the kimi config really is ~1T parameters (counted via
    eval_shape — never allocated)."""
    cfg = registry.get_config("kimi-k2-1t-a32b")
    from repro.models import transformer
    sds = jax.eval_shape(
        lambda: transformer.init_base_params(cfg, KEY))
    n = sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(sds))
    assert 0.9e12 < n < 1.3e12, n
