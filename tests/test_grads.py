"""Finite-difference gradient checks over the fused custom VJPs.

test_dispatch.py pins pallas-vs-ref agreement, which would pass trivially
if both legs shared a bug. Here ``jax.test_util.check_grads`` validates
every fused VJP against finite differences (order=1, reverse mode) on odd
(non-tile-multiple) shapes, plus the model loss across adapter kinds.

bf16 gradients are themselves bf16-quantized, so finite differences are
meaningless there; the bf16 acceptance is analytic instead — the pallas
blockwise backward vs its ref twin (kernels/ref.py::flash_attention_bwd_ref,
which mirrors the kernel's dtype casts) at <=1e-3, and a relative-error
sanity check against full-f32 autodiff.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import jax.test_util
import numpy as np
import pytest

from repro import configs as registry
from repro.config.base import KernelConfig, RunConfig, SHAPES
from repro.core import tt as ttlib
from repro.kernels import dispatch, ops
from repro.models import model as M

KEY = jax.random.PRNGKey(7)
PALLAS = dispatch.resolve(KernelConfig(backend="pallas", interpret=True))

check_grads = functools.partial(jax.test_util.check_grads, order=1,
                                modes=("rev",), atol=2e-2, rtol=2e-2)


# ---------------------------------------------------------------------------
# fused linear VJPs vs finite differences
# ---------------------------------------------------------------------------


def test_fd_tt_linear_fused_vjp():
    """Odd M/K/N/r: every dim exercises the pad-and-slice path and the
    dx-through-the-kernel backward."""
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (3, 5, 52), jnp.float32) * 0.5
    w = jax.random.normal(ks[1], (52, 39), jnp.float32) * 0.2
    a = jax.random.normal(ks[2], (52, 5), jnp.float32) * 0.2
    b = jax.random.normal(ks[3], (5, 39), jnp.float32) * 0.2

    def f(x, w, a, b):
        return dispatch.tt_linear(x, w, a, b, alpha=1.3, policy=PALLAS)

    check_grads(f, (x, w, a, b))


@pytest.mark.parametrize("decode_3d", [False, True])
def test_fd_tt_linear_batched_a_fused_vjp(decode_3d):
    """The slot-task-routed per-row-A kernel: its custom VJP must agree
    with finite differences in both decode layouts (S, K) and (S, 1, K)."""
    s, k, n, r = 5, 52, 39, 3
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (s, k), jnp.float32) * 0.5
    w = jax.random.normal(ks[1], (k, n), jnp.float32) * 0.2
    a = jax.random.normal(ks[2], (s, k, r), jnp.float32) * 0.2
    b = jax.random.normal(ks[3], (r, n), jnp.float32) * 0.2
    if decode_3d:
        x = x[:, None]

    def f(x, w, a, b):
        return dispatch.tt_linear_batched_a(x, w, a, b, alpha=0.7,
                                            policy=PALLAS)

    check_grads(f, (x, w, a, b))


# ---------------------------------------------------------------------------
# blockwise flash backward vs finite differences (f32, odd shapes + GQA)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("causal", [True, False])
def test_fd_flash_attention_fused_vjp(causal):
    """T=70, S=70, GQA 4:2 heads — nothing is a tile multiple, so the
    backward kernels run with padded tiles, the +1e30 lse sentinel and the
    kv_len mask, and must still match finite differences."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 70, 4, 16), jnp.float32) * 0.5
    k = jax.random.normal(ks[1], (2, 70, 2, 16), jnp.float32) * 0.5
    v = jax.random.normal(ks[2], (2, 70, 2, 16), jnp.float32) * 0.5

    def f(q, k, v):
        return dispatch.flash_attention(q, k, v, causal=causal,
                                        policy=PALLAS)

    check_grads(f, (q, k, v))


def test_fd_flash_attention_cross_lengths():
    """T != S (encoder-style, non-causal) with odd lengths on both sides."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 45, 2, 16), jnp.float32) * 0.5
    k = jax.random.normal(ks[1], (1, 130, 2, 16), jnp.float32) * 0.5
    v = jax.random.normal(ks[2], (1, 130, 2, 16), jnp.float32) * 0.5

    def f(q, k, v):
        return dispatch.flash_attention(q, k, v, causal=False,
                                        policy=PALLAS)

    check_grads(f, (q, k, v))


# ---------------------------------------------------------------------------
# flash backward acceptance tolerances: pallas vs ref twin, f32 / bf16
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.bfloat16, 1e-3)])
def test_flash_backward_matches_ref_twin(dtype, tol):
    """Same residuals into both backends: the blockwise kernels must match
    the recompute-from-lse twin to 1e-5 (f32) / 1e-3 (bf16) on odd GQA
    shapes (the twin mirrors the kernels' dtype casts, so bf16 agreement
    is not diluted by independent rounding)."""
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (2, 70, 4, 32), dtype)
    k = jax.random.normal(ks[1], (2, 91, 2, 32), dtype)
    v = jax.random.normal(ks[2], (2, 91, 2, 32), dtype)
    g = jax.random.normal(ks[3], (2, 70, 4, 32), dtype)
    o, lse = ops.flash_attention_fwd(q, k, v, causal=True, backend="pallas",
                                     interpret=True)
    got = ops.flash_attention_bwd(q, k, v, o, lse, g, causal=True,
                                  backend="pallas", interpret=True)
    want = ops.flash_attention_bwd(q, k, v, o, lse, g, causal=True,
                                   backend="ref")
    for name, x, y in zip(("dq", "dk", "dv"), got, want):
        err = float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                    - y.astype(jnp.float32))))
        assert err <= tol, (name, err)


def test_flash_backward_bf16_tracks_f32_autodiff():
    """bf16 end-to-end grads through the fused VJP stay within a couple of
    bf16 ulps (relative) of full-f32 reference autodiff."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 70, 4, 32), jnp.bfloat16)
    k = jax.random.normal(ks[1], (2, 91, 2, 32), jnp.bfloat16)
    v = jax.random.normal(ks[2], (2, 91, 2, 32), jnp.bfloat16)

    def loss(policy, cast):
        def f(q, k, v):
            o = dispatch.flash_attention(q.astype(cast), k.astype(cast),
                                         v.astype(cast), causal=True,
                                         policy=policy)
            return jnp.sum(jnp.sin(o.astype(jnp.float32)))
        return f

    gp = jax.grad(loss(PALLAS, jnp.bfloat16), argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss(None, jnp.float32), argnums=(0, 1, 2))(q, k, v)
    for name, x, y in zip("qkv", gp, gr):
        x = np.asarray(x, np.float32)
        y = np.asarray(y, np.float32)
        rel = np.max(np.abs(x - y)) / max(np.max(np.abs(y)), 1e-6)
        assert rel <= 2e-2, (name, rel)


# ---------------------------------------------------------------------------
# model loss across adapter kinds (fused path end-to-end)
# ---------------------------------------------------------------------------


def _odd_setup(kind):
    cfg = dataclasses.replace(
        registry.get_smoke_config("stablelm-1.6b"), name="odd-grads",
        d_model=40, num_heads=4, num_kv_heads=2, d_ff=72, vocab_size=77,
        mlp="geglu")
    run = RunConfig(model=cfg, shape=SHAPES["train_4k"], adapter_kind=kind,
                    adapter_rank=3,
                    adapter_matrices=("attn_q", "attn_v", "ffn_up",
                                      "ffn_down", "ffn_gate"))
    spec = M.build_adapter_spec(run)
    params = M.init_params(cfg, spec, KEY)
    if kind == "metatt":
        params["adapter"] = {"cores": ttlib.random_tt(
            KEY, spec.cfg.mode_sizes, 3, scale=0.5)}
    else:
        params["adapter"] = jax.tree_util.tree_map(
            lambda a: 0.5 * jax.random.normal(KEY, a.shape, a.dtype),
            params["adapter"])
    return cfg, spec, params


@pytest.mark.parametrize("kind", ["metatt", "lora", "vera"])
def test_fd_model_loss_grads_across_adapter_kinds(kind):
    """The full train objective through the fused kernels (tt_linear VJP +
    flash VJP inside attention) agrees with finite differences for every
    adapter kind on an odd-shape config."""
    cfg, spec, params = _odd_setup(kind)
    batch = {"tokens": jax.random.randint(KEY, (2, 9), 0, cfg.vocab_size)}

    def f(adapter):
        return M.loss_fn(adapter, params["base"], params["frozen"], batch,
                         cfg, spec, policy=PALLAS)[0]

    jax.test_util.check_grads(f, (params["adapter"],), order=1,
                              modes=("rev",), atol=5e-2, rtol=5e-2)


def test_bf16_model_grads_no_worse_than_ref_path():
    """Elementwise bf16 parity between two different-but-valid computation
    orders is not a meaningful target (rounding diverges through the depth
    of the model), so this pins what actually matters for training: at the
    SAME bf16 params, the fused VJPs' gradients (a) point in the f32-truth
    direction and (b) are no further from f32 truth than the reference
    path's bf16 autodiff — the custom VJPs accumulate in f32, so they tend
    to be strictly closer."""
    cfg32, spec, params = _odd_setup("metatt")
    cfg16 = dataclasses.replace(cfg32, param_dtype=jnp.bfloat16,
                                compute_dtype=jnp.bfloat16)
    params = jax.tree_util.tree_map(
        lambda a: a.astype(jnp.bfloat16)
        if jnp.issubdtype(a.dtype, jnp.floating) else a, params)
    batch = {"tokens": jax.random.randint(KEY, (2, 9), 0, cfg32.vocab_size)}

    def grads(cfg, policy, cast):
        p = jax.tree_util.tree_map(
            lambda a: a.astype(cast)
            if jnp.issubdtype(a.dtype, jnp.floating) else a, params)
        def f(adapter):
            return M.loss_fn(adapter, p["base"], p["frozen"], batch, cfg,
                             spec, policy=policy)[0]
        return jax.grad(f)(p["adapter"])

    ref = dispatch.resolve(KernelConfig(backend="ref"))
    truth = grads(cfg32, ref, jnp.float32)
    gp = grads(cfg16, PALLAS, jnp.bfloat16)
    gr = grads(cfg16, ref, jnp.bfloat16)
    for (kp, t), p, r in zip(
            jax.tree_util.tree_flatten_with_path(truth)[0],
            jax.tree_util.tree_leaves(gp), jax.tree_util.tree_leaves(gr)):
        t = np.asarray(t, np.float64)
        p = np.asarray(p, np.float64)
        r = np.asarray(r, np.float64)
        nt = np.linalg.norm(t)
        cos = float((p * t).sum() / (np.linalg.norm(p) * nt))
        err_p = float(np.linalg.norm(p - t) / nt)
        err_r = float(np.linalg.norm(r - t) / nt)
        name = jax.tree_util.keystr(kp)
        assert cos >= 0.9, (name, cos)
        assert err_p <= err_r + 0.1, (name, err_p, err_r)
