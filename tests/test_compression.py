"""Gradient compression (distributed/compression.py) — deterministic
tests that run without hypothesis (test_property.py holds the
property-based variants, skipped where hypothesis is absent)."""
import jax.numpy as jnp
import numpy as np

from repro.distributed import compression


def test_topk_error_feedback_carries_residual_across_steps():
    """The residual must CARRY across steps: a coordinate too small to
    make the top-k at step 1 accumulates in the residual until it wins a
    later step — so over N constant-gradient steps every coordinate's
    cumulative transmitted mass approaches N·g (bounded residual), while
    dropping the residual each step silently loses those coordinates."""
    g = {"g": jnp.array([1.0, 0.4, 0.3, 0.2])}
    comp = compression.GradCompressor("topk", topk_frac=0.25)   # k = 1
    n_steps = 12
    res = comp.init_residual(g)
    sent = jnp.zeros(4)
    for _ in range(n_steps):
        out, res = comp(g, res)
        sent = sent + out["g"]
    # error feedback: cumulative transmission == N·g minus the (bounded)
    # final residual — nothing is lost, only delayed
    np.testing.assert_allclose(np.asarray(sent + res["g"]),
                               n_steps * np.asarray(g["g"]), atol=1e-5)
    assert all(float(s) > 0 for s in sent)      # every coordinate got out
    # without feedback the small coordinates never transmit at all
    sent_nofb = jnp.zeros(4)
    for _ in range(n_steps):
        out, _ = comp(g, comp.init_residual(g))
        sent_nofb = sent_nofb + out["g"]
    assert float(sent_nofb[1]) == 0 and float(sent_nofb[3]) == 0


def test_topk_residual_dtype_and_structure_follow_grads():
    g = {"a": jnp.ones((4, 4), jnp.bfloat16), "b": jnp.ones((8,))}
    comp = compression.GradCompressor("topk", topk_frac=0.5)
    res = comp.init_residual(g)
    out, new_res = comp(g, res)
    assert out["a"].dtype == jnp.bfloat16       # roundtrip keeps dtype
    assert new_res["a"].dtype == jnp.float32    # residual accumulates f32
    assert out["b"].shape == (8,)
