"""Paged serving engine (block/paged KV cache, prefix sharing, in-loop
chunked prefill) — acceptance criteria of the paged-cache refactor:

  * paged engine is token-identical to the dense engine on a mixed-task,
    mixed-length greedy workload, across live/lora/merged runtimes, on
    the reference backend and in Pallas interpret mode,
  * warm (prefix-cache) requests produce token-identical output to
    cold-cache runs, including divergence after a shared partial page
    (copy-on-write),
  * heterogeneous prompt lengths compile the chunked-prefill decode
    graph exactly ONCE (no per-bucket prefill ladder),
  * out-of-blocks admission backpressure serves everything correctly,
  * the paged_decode_attention Pallas kernel matches its reference twin
    through the same ops entry point the model uses.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as registry
from repro.config.base import KernelConfig, RunConfig, SHAPES, ServeConfig
from repro.core import tt as ttlib
from repro.kernels import ops
from repro.models import model as M
from repro.serving import AdapterRuntime, Engine, Request

KEY = jax.random.PRNGKey(0)
PALLAS = KernelConfig(backend="pallas", interpret=True)


def _setup(variant="4+1d", num_tasks=3):
    cfg = registry.get_smoke_config("stablelm-1.6b")
    run = RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                    adapter_kind="metatt", adapter_variant=variant,
                    num_tasks=num_tasks, adapter_rank=4)
    spec = M.build_adapter_spec(run)
    params = M.init_params(cfg, spec, KEY)
    params["adapter"] = {"cores": ttlib.random_tt(
        KEY, spec.cfg.mode_sizes, 4, scale=0.8)}
    return cfg, spec, params


def _mixed_requests(cfg, n=5, tasks=3):
    prompts = [jax.random.randint(jax.random.PRNGKey(i), (4 + i,), 0,
                                  cfg.vocab_size) for i in range(n)]
    return [Request(p, 5 + (i % 3), task=i % tasks)
            for i, p in enumerate(prompts)]


def _serve(cfg, rt, reqs, mode, *, kernels=None, **kw):
    base = dict(max_batch=2, cache_len=32, out_cap=8, cache_mode=mode,
                page_size=8, prefill_chunk=4)
    base.update(kw)
    eng = Engine(cfg, rt, serve=ServeConfig(**base), kernels=kernels)
    return [o.tolist() for o in eng.generate(reqs)], eng


def test_paged_matches_dense_mixed_task_mixed_length_all_runtimes():
    cfg, spec, params = _setup()
    reqs = _mixed_requests(cfg)
    for mode_name, build_kw, rq in (
            ("live", {}, reqs),
            ("lora", {}, reqs),
            # merged freezes one task: single-task slice of the workload
            ("merged", dict(model_cfg=cfg, task=1),
             [r for r in reqs if r.task == 1])):
        rt = AdapterRuntime.build(mode_name, params["base"], spec,
                                  params["adapter"], params["frozen"],
                                  **build_kw)
        dense, _ = _serve(cfg, rt, rq, "dense")
        paged, _ = _serve(cfg, rt, rq, "paged")
        assert paged == dense, mode_name


@pytest.mark.parametrize("mode", ["live", "lora"])
def test_paged_matches_dense_in_pallas_interpret_mode(mode):
    cfg, spec, params = _setup()
    reqs = _mixed_requests(cfg, n=4)
    rt = AdapterRuntime.build(mode, params["base"], spec,
                              params["adapter"], params["frozen"])
    dense, _ = _serve(cfg, rt, reqs, "dense")
    paged, _ = _serve(cfg, rt, reqs, "paged", kernels=PALLAS)
    assert paged == dense


def test_warm_prefix_cache_token_identical_and_hits():
    cfg, spec, params = _setup()
    reqs = _mixed_requests(cfg)
    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    cold, eng = _serve(cfg, rt, reqs, "paged")
    assert eng.last_stats.prefix_hit_rate == 0.0
    warm = [o.tolist() for o in eng.generate(reqs)]
    assert warm == cold
    st = eng.last_stats
    assert st.prefix_hit_rate > 0
    assert st.cow_copies > 0          # partial last prompt pages reshared


def test_shared_prefix_divergence_copy_on_write_parity():
    """Two requests sharing a prefix that ends mid-page, then diverging:
    the second maps the cached partial page, COWs it, and must still be
    token-identical to a cold dense run — and the cached original must
    serve a third identical request unchanged."""
    cfg, spec, params = _setup()
    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    base_p = np.asarray(
        jax.random.randint(KEY, (10,), 0, cfg.vocab_size))  # 1 page + 2
    div = np.concatenate([base_p[:6], np.array([1, 2, 3], np.int32)])
    reqs = [Request(base_p, 6, task=1), Request(div, 6, task=1),
            Request(base_p, 6, task=1)]
    dense, _ = _serve(cfg, rt, reqs, "dense")
    # max_batch=1 serializes: req 0 registers its prefix, req 1 shares+COWs
    sv = ServeConfig(max_batch=1, cache_len=32, out_cap=8,
                     cache_mode="paged", page_size=8, prefill_chunk=4)
    eng = Engine(cfg, rt, serve=sv)
    paged = [o.tolist() for o in eng.generate(reqs)]
    assert paged == dense
    st = eng.last_stats
    assert st.cow_copies >= 1 and st.prefix_hit_tokens > 0


def test_heterogeneous_prompts_compile_decode_graph_once():
    """The in-loop chunked prefill replaces the dense _bucket ladder: one
    trace serves every prompt length (asserted via a trace counter that
    increments as a Python side effect inside the jitted impl)."""
    cfg, spec, params = _setup(variant="4d", num_tasks=0)
    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    sv = ServeConfig(max_batch=2, cache_len=32, out_cap=8,
                     page_size=8, prefill_chunk=4)
    eng = Engine(cfg, rt, serve=sv)
    reqs = [Request(jax.random.randint(jax.random.PRNGKey(i), (2 + 3 * i,),
                                       0, cfg.vocab_size), 4)
            for i in range(5)]          # prompt lengths 2, 5, 8, 11, 14
    eng.generate(reqs)
    assert eng.last_stats.decode_traces == 1
    assert eng.last_stats.prefill_traces == 0
    # the dense engine's bucket ladder, by contrast, compiles per bucket
    dense = Engine(cfg, rt, serve=ServeConfig(
        max_batch=2, cache_len=32, out_cap=8, cache_mode="dense"))
    dense.generate(reqs)
    assert dense.last_stats.prefill_traces > 1


def test_out_of_blocks_backpressure_still_serves_everything():
    cfg, spec, params = _setup()
    reqs = _mixed_requests(cfg)
    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    dense, _ = _serve(cfg, rt, reqs, "dense")
    # 4 blocks of 8 tokens: at most ~2 requests resident -> waits > 0
    paged, eng = _serve(cfg, rt, reqs, "paged", num_blocks=4,
                        max_batch=4)
    assert paged == dense
    assert eng.last_stats.backpressure_waits > 0
    assert eng.last_stats.kv_blocks_peak <= 4


def test_warm_request_in_tight_pool_falls_back_cold_not_deadlock():
    """A pool just big enough for one request, fully occupied by that
    request's cached prefix: the warm re-admission's own prefix match
    pins the cached blocks, so the COW block cannot be allocated — the
    scheduler must drop the match and admit cold instead of deadlocking."""
    cfg, spec, params = _setup(variant="4d", num_tasks=0)
    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    sv = ServeConfig(max_batch=1, cache_len=16, out_cap=8, page_size=8,
                     prefill_chunk=4)            # num_blocks == 2
    eng = Engine(cfg, rt, serve=sv)
    prompt = jax.random.randint(KEY, (9,), 0, cfg.vocab_size)
    cold = eng.generate([Request(prompt, 7)])[0].tolist()
    warm = eng.generate([Request(prompt, 7)])[0].tolist()
    assert warm == cold
    assert eng.last_stats.backpressure_waits == 0  # resolved in plan()


def test_prefix_chains_are_namespaced_per_task():
    """Task-adapted matrices make deep-layer KV task-dependent: an
    identical prompt under a DIFFERENT task must not reuse the cached
    prefix (and must still match the dense engine's output)."""
    cfg, spec, params = _setup()
    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    prompt = jax.random.randint(KEY, (9,), 0, cfg.vocab_size)
    dense, _ = _serve(cfg, rt, [Request(prompt, 5, task=1)], "dense")
    _, eng = _serve(cfg, rt, [Request(prompt, 5, task=0)], "paged")
    other = [o.tolist() for o in eng.generate([Request(prompt, 5, task=1)])]
    assert eng.last_stats.prefix_hit_tokens == 0   # no cross-task reuse
    assert other == dense
    same = [o.tolist() for o in eng.generate([Request(prompt, 5, task=1)])]
    assert eng.last_stats.prefix_hit_tokens > 0    # within-task reuse
    assert same == dense


def test_paged_engine_rejects_oversized_request():
    cfg, spec, params = _setup(variant="4d", num_tasks=0)
    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    eng = Engine(cfg, rt, serve=ServeConfig(max_batch=1, cache_len=16,
                                            out_cap=8, page_size=8))
    long_prompt = jnp.zeros((12,), jnp.int32)
    with pytest.raises(ValueError):
        eng.generate([Request(long_prompt, 8)])   # 12 + 8 > cache_len
    with pytest.raises(ValueError):
        ServeConfig(cache_len=64, page_size=8, num_blocks=4).validate()


@pytest.mark.parametrize("c,heads", [(1, (4, 4)), (4, (4, 2)),
                                     (8, (8, 2))])
def test_paged_attention_kernel_matches_ref(c, heads):
    """kernels/paged_attention.py vs kernels/ref.py twin through the ops
    entry point, including GQA broadcast and sentinel table entries."""
    h, kv = heads
    b, d, n, page, p_tab = 3, 16, 12, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(c), 3)
    q = jax.random.normal(ks[0], (b, c, h, d), jnp.float32)
    kc = jax.random.normal(ks[1], (n, page, kv, d), jnp.float32)
    vc = jax.random.normal(ks[2], (n, page, kv, d), jnp.float32)
    tables = np.full((b, p_tab), n, np.int32)     # sentinel everywhere
    tables[0, :3] = [2, 7, 1]
    tables[1, :2] = [4, 9]
    tables[2, :1] = [11]
    tables = jnp.asarray(tables)
    pos = jnp.asarray([17, 9, 3], jnp.int32)
    ref = ops.paged_decode_attention(q, kc, vc, tables, pos, backend="ref")
    pal = ops.paged_decode_attention(q, kc, vc, tables, pos,
                                     backend="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
