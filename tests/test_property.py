"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import dmrg, merge, metatt, tt
from repro.distributed import compression

jax.config.update("jax_platform_name", "cpu")

_dims = st.integers(min_value=2, max_value=7)
_rank = st.integers(min_value=1, max_value=5)
_seed = st.integers(min_value=0, max_value=2**31 - 1)


@settings(max_examples=25, deadline=None)
@given(shape=st.lists(_dims, min_size=2, max_size=5), rank=_rank, seed=_seed)
def test_tt_materialize_consistent_with_slices(shape, rank, seed):
    """Any slice of the materialized tensor equals the core-product slice."""
    cores = tt.random_tt(jax.random.PRNGKey(seed), shape, rank)
    full = tt.materialize(cores)
    assert full.shape == tuple(shape)
    idx = tuple(np.random.default_rng(seed).integers(0, s)
                for s in shape[1:-1])
    np.testing.assert_allclose(tt.slice_matrix(cores, idx),
                               full[(slice(None),) + idx], atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(rank=st.integers(min_value=1, max_value=8), seed=_seed)
def test_svd_truncation_error_is_eckart_young(rank, seed):
    cores = tt.random_tt(jax.random.PRNGKey(seed), (10, 8), 8)
    merged = tt.merge_pair(cores[0], cores[1])
    a, b, _ = tt.split_merged(merged, rank=rank)
    err = float(jnp.linalg.norm((tt.merge_pair(a, b) - merged).reshape(-1)))
    bound = float(tt.truncation_error(merged, rank))
    assert err <= bound + 1e-4
    assert err >= bound - 1e-4


@settings(max_examples=15, deadline=None)
@given(seed=_seed, rank=st.integers(min_value=2, max_value=6))
def test_dmrg_never_increases_ranks_beyond_target(seed, rank):
    p = {"cores": tt.random_tt(jax.random.PRNGKey(seed), (12, 5, 4, 12), 8)}
    res = dmrg.dmrg_sweep(p, target_rank=rank)
    assert all(r <= rank for r in res.ranks)
    tt.validate_cores(res.params["cores"])


@settings(max_examples=15, deadline=None)
@given(seed=_seed)
def test_dmrg_idempotent_at_same_rank(seed):
    """Sweeping twice at the same target changes nothing (projection)."""
    p = {"cores": tt.random_tt(jax.random.PRNGKey(seed), (12, 5, 12), 6)}
    once = dmrg.dmrg_sweep(p, target_rank=3).params
    twice = dmrg.dmrg_sweep(once, target_rank=3).params
    assert dmrg.reconstruction_error(once, twice) < 1e-5


@settings(max_examples=15, deadline=None)
@given(seed=_seed, alpha=st.floats(min_value=0.1, max_value=8.0))
def test_merge_preserves_adapter_function(seed, alpha):
    """Serving-form merge (paper §2.4) is exact for every (l, m)."""
    cfg = metatt.MetaTTConfig(num_layers=3, matrix_types=("q", "v"),
                              d_in=(12, 12), d_out=(12, 8), rank=3,
                              alpha=alpha)
    key = jax.random.PRNGKey(seed)
    p = {"cores": tt.random_tt(key, cfg.mode_sizes, 3)}
    lf = merge.to_lora_form(p, cfg)
    x = jax.random.normal(key, (4, 12))
    for l in range(3):
        for m in ("q", "v"):
            np.testing.assert_allclose(
                lf.delta(cfg, x, l, m), metatt.apply(p, cfg, x, l, m),
                atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(seed=_seed)
def test_zero_init_invariant_all_schemes(seed):
    """Any init scheme containing >=1 'ze' core yields ΔW == 0 everywhere
    (the paper's fine-tuning start condition, App. A.1)."""
    rng = np.random.default_rng(seed)
    toks = [rng.choice(["id", "no"]) for _ in range(4)]
    toks[rng.integers(0, 4)] = "ze"
    cfg = metatt.MetaTTConfig(num_layers=3, matrix_types=("q", "v"),
                              d_in=(12, 12), d_out=(12, 12), rank=3,
                              init="-".join(toks))
    p = metatt.init_params(cfg, jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (4, 12))
    for l in range(3):
        for m in ("q", "v"):
            assert float(jnp.abs(metatt.apply(p, cfg, x, l, m)).max()) == 0


@settings(max_examples=25, deadline=None)
@given(seed=_seed)
def test_int8_compression_error_bound(seed):
    """Per-tensor symmetric int8: |x - deq(q(x))| <= scale/2 elementwise."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * 10
    q, scale = compression.int8_encode(x)
    err = jnp.abs(compression.int8_decode(q, scale) - x)
    assert float(err.max()) <= float(scale) / 2 + 1e-6


@settings(max_examples=10, deadline=None)
@given(seed=_seed)
def test_topk_error_feedback_conserves_mass(seed):
    """Error feedback: compressed + residual == accumulated signal."""
    g = jax.random.normal(jax.random.PRNGKey(seed), (128,))
    comp = compression.GradCompressor("topk", topk_frac=0.25)
    grads = {"g": g}
    res = comp.init_residual(grads)
    out, new_res = comp(grads, res)
    np.testing.assert_allclose(out["g"] + new_res["g"], g, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=_seed, r_hi=st.integers(min_value=4, max_value=8))
def test_dmrg_preserves_function_within_truncation_bound(seed, r_hi):
    """After a sweep, the adapter's *function* moves by at most the sum of
    local truncation errors (triangle inequality over bonds)."""
    cfg = metatt.MetaTTConfig(num_layers=3, matrix_types=("q", "v"),
                              d_in=(12, 12), d_out=(12, 12), rank=r_hi)
    p = {"cores": tt.random_tt(jax.random.PRNGKey(seed), cfg.mode_sizes,
                               r_hi)}
    swept = dmrg.dmrg_sweep(p, target_rank=r_hi).params  # same rank: exact
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 12))
    for l in range(3):
        np.testing.assert_allclose(
            metatt.apply(p, cfg, x, l, "q"),
            metatt.apply(swept, cfg, x, l, "q"), atol=1e-3)


# ---------------------------------------------------------------------------
# LRU clock invariants (serving/lru.py — shared by PrefixCache and the
# adapter registry, DESIGN.md §12)
# ---------------------------------------------------------------------------

from repro.serving import LRUClock  # noqa: E402

_keys = st.integers(min_value=0, max_value=7)


@settings(max_examples=50, deadline=None)
@given(ops=st.lists(st.tuples(st.booleans(), _keys), max_size=40),
       cands=st.lists(_keys, min_size=1, max_size=8, unique=True))
def test_lru_oldest_is_least_recently_touched(ops, cands):
    """After any interleaving of touch/forget, ``oldest(candidates)``
    is the candidate whose last surviving touch is earliest — with
    never-touched (or forgotten) keys infinitely old, and ties broken
    toward the first candidate (deterministic eviction order)."""
    clock = LRUClock()
    last = {}                         # reference: key -> touch index
    for i, (is_touch, k) in enumerate(ops):
        if is_touch:
            clock.touch(k)
            last[k] = i + 1
        else:
            clock.forget(k)
            last.pop(k, None)
    expect = min(cands, key=lambda k: last.get(k, 0))
    assert clock.oldest(cands) == expect


@settings(max_examples=50, deadline=None)
@given(ops=st.lists(_keys, min_size=1, max_size=40))
def test_lru_eviction_order_matches_touch_order(ops):
    """Draining the clock by repeated oldest()+forget() yields keys in
    exactly last-touch order — the registry's eviction sequence among
    unpinned residents."""
    clock = LRUClock()
    last = {}
    for i, k in enumerate(ops):
        clock.touch(k)
        last[k] = i
    expect = sorted(last, key=last.get)
    drained = []
    alive = sorted(last)
    while alive:
        k = clock.oldest(alive)
        drained.append(k)
        clock.forget(k)
        alive.remove(k)
    assert drained == expect


# ---------------------------------------------------------------------------
# in-graph sampling invariants (serving/sampling.py)
# ---------------------------------------------------------------------------

from repro.serving import sampling as sampling_lib  # noqa: E402
from repro.serving.sampling import SamplingConfig  # noqa: E402

_vocab = st.integers(min_value=4, max_value=32)


@settings(max_examples=30, deadline=None)
@given(seed=_seed, vocab=_vocab, k=st.integers(min_value=1, max_value=8))
def test_top_k_never_selects_masked_token(seed, vocab, k):
    """A top-k draw always lands in the k highest logits."""
    key = jax.random.PRNGKey(seed)
    lg = jax.random.normal(key, (3, vocab)) * 5
    cfg = SamplingConfig(method="top_k", top_k=min(k, vocab),
                         temperature=0.7)
    tok = sampling_lib.sample(lg, jax.random.fold_in(key, 1), cfg)
    kth = jnp.sort(lg, axis=-1)[:, -min(k, vocab)]
    assert bool(jnp.all(jnp.take_along_axis(lg, tok[:, None], 1)[:, 0]
                        >= kth))


@settings(max_examples=30, deadline=None)
@given(seed=_seed, vocab=_vocab,
       p=st.floats(min_value=0.05, max_value=1.0))
def test_top_p_never_selects_masked_token_and_keeps_one(seed, vocab, p):
    """The nucleus never empties (>= 1 token survives at ANY p) and the
    draw always comes from inside it."""
    key = jax.random.PRNGKey(seed)
    lg = jax.random.normal(key, (2, vocab)) * 8
    cfg = SamplingConfig(method="top_p", top_p=p, temperature=1.0)
    masked = sampling_lib.process_logits(lg, cfg)
    nkeep = jnp.sum(jnp.isfinite(masked) & (masked > -1e30), axis=-1)
    assert bool(jnp.all(nkeep >= 1))
    tok = sampling_lib.sample(lg, jax.random.fold_in(key, 1), cfg)
    picked = jnp.take_along_axis(masked, tok[:, None], 1)[:, 0]
    assert bool(jnp.all(picked > -1e30))


@settings(max_examples=30, deadline=None)
@given(seed=_seed, vocab=_vocab)
def test_temperature_to_zero_recovers_greedy(seed, vocab):
    """As temperature -> 0 the temperature sampler concentrates on the
    argmax: a draw at T=1e-4 equals the greedy token."""
    key = jax.random.PRNGKey(seed)
    lg = jax.random.normal(key, (4, vocab)) * 3
    cold = SamplingConfig(method="temperature", temperature=1e-4)
    tok = sampling_lib.sample(lg, jax.random.fold_in(key, 1), cold)
    assert tok.tolist() == jnp.argmax(lg, axis=-1).tolist()


@settings(max_examples=30, deadline=None)
@given(seed=_seed, vocab=_vocab,
       rp=st.floats(min_value=1.01, max_value=3.0))
def test_repetition_penalty_only_demotes_emitted_ids(seed, vocab, rp):
    """With penalty > 1, masked (already-emitted) ids never gain logit
    mass and unmasked ids are untouched."""
    key = jax.random.PRNGKey(seed)
    lg = jax.random.normal(key, (2, vocab)) * 4
    mask = jax.random.bernoulli(jax.random.fold_in(key, 2), 0.4,
                                (2, vocab))
    cfg = SamplingConfig(method="greedy", repetition_penalty=rp)
    out = sampling_lib.process_logits(lg, cfg, penalty_mask=mask)
    lg32 = lg.astype(jnp.float32)
    assert bool(jnp.all(jnp.where(mask, out <= lg32 + 1e-6, out == lg32)))


# ---------------------------------------------------------------------------
# DMRG-in-training invariants (rank-adaptive sweeps as a training-loop move)
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=_seed, rank=st.integers(min_value=1, max_value=6))
def test_two_site_resplit_exact_at_full_rank(seed, rank):
    """The sweep's elementary move — merge two cores, SVD-resplit — is an
    exact factorization whenever the bond is not actually truncated."""
    cores = tt.random_tt(jax.random.PRNGKey(seed), (9, 7), rank)
    merged = tt.merge_pair(cores[0], cores[1])
    full = min(merged.shape[0] * merged.shape[1],
               merged.shape[2] * merged.shape[3])
    a, b, _ = tt.split_merged(merged, rank=full)
    np.testing.assert_allclose(np.asarray(tt.merge_pair(a, b)),
                               np.asarray(merged), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=_seed, r_lo=st.integers(min_value=1, max_value=5),
       dr=st.integers(min_value=0, max_value=3))
def test_sweep_truncation_error_monotone_in_target_rank(seed, r_lo, dr):
    """A larger target rank never reconstructs the adapter worse — the
    property that makes RankSchedule's shrink-over-epochs well-ordered."""
    p = {"cores": tt.random_tt(jax.random.PRNGKey(seed), (12, 5, 4, 12), 6)}
    full = tt.materialize(p["cores"])

    def err(r):
        out = dmrg.dmrg_sweep(p, target_rank=r).params["cores"]
        return float(jnp.linalg.norm(tt.materialize(out) - full))

    assert err(r_lo + dr) <= err(r_lo) + 1e-4


def _slice_bonds(cores, rd):
    out = []
    for i, c in enumerate(cores):
        if i > 0:
            c = c[:rd]
        if i < len(cores) - 1:
            c = c[..., :rd]
        out.append(c)
    return out


@settings(max_examples=10, deadline=None)
@given(seed=_seed, rd=st.integers(min_value=1, max_value=4))
def test_bond_nesting_sliced_swept_train_is_sweep_fixed_point(seed, rd):
    """Bond-dimension nesting, the identity the self-drafter relies on:
    slicing every bond of a swept (canonical) train down to rd yields a
    train the sweep itself cannot improve — re-sweeping the sliced cores
    at target rd preserves their function exactly, so truncate_factors'
    cheap slices behave like a genuine rank-rd sweep, not an arbitrary
    crop."""
    p = {"cores": tt.random_tt(jax.random.PRNGKey(seed), (10, 5, 4, 10), 6)}
    swept = dmrg.dmrg_sweep(p, target_rank=6).params["cores"]
    sliced = _slice_bonds(swept, rd)
    reswept = dmrg.dmrg_sweep({"cores": sliced},
                              target_rank=rd).params["cores"]
    np.testing.assert_allclose(np.asarray(tt.materialize(reswept)),
                               np.asarray(tt.materialize(sliced)),
                               atol=1e-5)


@settings(max_examples=5, deadline=None)
@given(seed=_seed, rd=st.integers(min_value=1, max_value=5))
def test_truncate_factors_commutes_with_outer_bond_slice(seed, rd):
    """The serving-layer half of the nesting identity: truncating the live
    factor bundle (speculative.truncate_factors) equals rebuilding the
    bundle from cores whose OUTER bonds were sliced — the drafter's crop
    is a real TT operation, not a layout hack."""
    from repro import configs as registry
    from repro.config.base import RunConfig, SHAPES
    from repro.models import model as M
    from repro.peft import api as peft_api
    from repro.serving import speculative

    key = jax.random.PRNGKey(seed)
    cfg = registry.get_smoke_config("stablelm-1.6b")
    run = RunConfig(model=cfg, shape=SHAPES["train_4k"],
                    adapter_kind="metatt", adapter_variant="4d",
                    adapter_rank=6)
    spec = M.build_adapter_spec(run)
    params = M.init_params(cfg, spec, key)
    cores = tt.random_tt(key, spec.cfg.mode_sizes, 6, scale=0.5)
    bc, pl = peft_api.adapter_factors(spec, {"cores": cores},
                                      params["frozen"])
    bct, plt = speculative.truncate_factors("metatt", bc, pl, rd)
    sl = list(cores)
    sl[0] = sl[0][..., :rd]
    sl[1] = sl[1][:rd]
    sl[-2] = sl[-2][..., :rd]
    sl[-1] = sl[-1][:rd]
    bcs, pls = peft_api.adapter_factors(spec, {"cores": sl},
                                        params["frozen"])
    for k in bct:
        np.testing.assert_allclose(np.asarray(bct[k]), np.asarray(bcs[k]),
                                   atol=1e-6)
    for k in plt:
        np.testing.assert_allclose(np.asarray(plt[k]), np.asarray(pls[k]),
                                   atol=1e-6)
