"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import dmrg, merge, metatt, tt
from repro.distributed import compression

jax.config.update("jax_platform_name", "cpu")

_dims = st.integers(min_value=2, max_value=7)
_rank = st.integers(min_value=1, max_value=5)
_seed = st.integers(min_value=0, max_value=2**31 - 1)


@settings(max_examples=25, deadline=None)
@given(shape=st.lists(_dims, min_size=2, max_size=5), rank=_rank, seed=_seed)
def test_tt_materialize_consistent_with_slices(shape, rank, seed):
    """Any slice of the materialized tensor equals the core-product slice."""
    cores = tt.random_tt(jax.random.PRNGKey(seed), shape, rank)
    full = tt.materialize(cores)
    assert full.shape == tuple(shape)
    idx = tuple(np.random.default_rng(seed).integers(0, s)
                for s in shape[1:-1])
    np.testing.assert_allclose(tt.slice_matrix(cores, idx),
                               full[(slice(None),) + idx], atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(rank=st.integers(min_value=1, max_value=8), seed=_seed)
def test_svd_truncation_error_is_eckart_young(rank, seed):
    cores = tt.random_tt(jax.random.PRNGKey(seed), (10, 8), 8)
    merged = tt.merge_pair(cores[0], cores[1])
    a, b, _ = tt.split_merged(merged, rank=rank)
    err = float(jnp.linalg.norm((tt.merge_pair(a, b) - merged).reshape(-1)))
    bound = float(tt.truncation_error(merged, rank))
    assert err <= bound + 1e-4
    assert err >= bound - 1e-4


@settings(max_examples=15, deadline=None)
@given(seed=_seed, rank=st.integers(min_value=2, max_value=6))
def test_dmrg_never_increases_ranks_beyond_target(seed, rank):
    p = {"cores": tt.random_tt(jax.random.PRNGKey(seed), (12, 5, 4, 12), 8)}
    res = dmrg.dmrg_sweep(p, target_rank=rank)
    assert all(r <= rank for r in res.ranks)
    tt.validate_cores(res.params["cores"])


@settings(max_examples=15, deadline=None)
@given(seed=_seed)
def test_dmrg_idempotent_at_same_rank(seed):
    """Sweeping twice at the same target changes nothing (projection)."""
    p = {"cores": tt.random_tt(jax.random.PRNGKey(seed), (12, 5, 12), 6)}
    once = dmrg.dmrg_sweep(p, target_rank=3).params
    twice = dmrg.dmrg_sweep(once, target_rank=3).params
    assert dmrg.reconstruction_error(once, twice) < 1e-5


@settings(max_examples=15, deadline=None)
@given(seed=_seed, alpha=st.floats(min_value=0.1, max_value=8.0))
def test_merge_preserves_adapter_function(seed, alpha):
    """Serving-form merge (paper §2.4) is exact for every (l, m)."""
    cfg = metatt.MetaTTConfig(num_layers=3, matrix_types=("q", "v"),
                              d_in=(12, 12), d_out=(12, 8), rank=3,
                              alpha=alpha)
    key = jax.random.PRNGKey(seed)
    p = {"cores": tt.random_tt(key, cfg.mode_sizes, 3)}
    lf = merge.to_lora_form(p, cfg)
    x = jax.random.normal(key, (4, 12))
    for l in range(3):
        for m in ("q", "v"):
            np.testing.assert_allclose(
                lf.delta(cfg, x, l, m), metatt.apply(p, cfg, x, l, m),
                atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(seed=_seed)
def test_zero_init_invariant_all_schemes(seed):
    """Any init scheme containing >=1 'ze' core yields ΔW == 0 everywhere
    (the paper's fine-tuning start condition, App. A.1)."""
    rng = np.random.default_rng(seed)
    toks = [rng.choice(["id", "no"]) for _ in range(4)]
    toks[rng.integers(0, 4)] = "ze"
    cfg = metatt.MetaTTConfig(num_layers=3, matrix_types=("q", "v"),
                              d_in=(12, 12), d_out=(12, 12), rank=3,
                              init="-".join(toks))
    p = metatt.init_params(cfg, jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (4, 12))
    for l in range(3):
        for m in ("q", "v"):
            assert float(jnp.abs(metatt.apply(p, cfg, x, l, m)).max()) == 0


@settings(max_examples=25, deadline=None)
@given(seed=_seed)
def test_int8_compression_error_bound(seed):
    """Per-tensor symmetric int8: |x - deq(q(x))| <= scale/2 elementwise."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * 10
    q, scale = compression.int8_encode(x)
    err = jnp.abs(compression.int8_decode(q, scale) - x)
    assert float(err.max()) <= float(scale) / 2 + 1e-6


@settings(max_examples=10, deadline=None)
@given(seed=_seed)
def test_topk_error_feedback_conserves_mass(seed):
    """Error feedback: compressed + residual == accumulated signal."""
    g = jax.random.normal(jax.random.PRNGKey(seed), (128,))
    comp = compression.GradCompressor("topk", topk_frac=0.25)
    grads = {"g": g}
    res = comp.init_residual(grads)
    out, new_res = comp(grads, res)
    np.testing.assert_allclose(out["g"] + new_res["g"], g, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=_seed, r_hi=st.integers(min_value=4, max_value=8))
def test_dmrg_preserves_function_within_truncation_bound(seed, r_hi):
    """After a sweep, the adapter's *function* moves by at most the sum of
    local truncation errors (triangle inequality over bonds)."""
    cfg = metatt.MetaTTConfig(num_layers=3, matrix_types=("q", "v"),
                              d_in=(12, 12), d_out=(12, 12), rank=r_hi)
    p = {"cores": tt.random_tt(jax.random.PRNGKey(seed), cfg.mode_sizes,
                               r_hi)}
    swept = dmrg.dmrg_sweep(p, target_rank=r_hi).params  # same rank: exact
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 12))
    for l in range(3):
        np.testing.assert_allclose(
            metatt.apply(p, cfg, x, l, "q"),
            metatt.apply(swept, cfg, x, l, "q"), atol=1e-3)


# ---------------------------------------------------------------------------
# LRU clock invariants (serving/lru.py — shared by PrefixCache and the
# adapter registry, DESIGN.md §12)
# ---------------------------------------------------------------------------

from repro.serving import LRUClock  # noqa: E402

_keys = st.integers(min_value=0, max_value=7)


@settings(max_examples=50, deadline=None)
@given(ops=st.lists(st.tuples(st.booleans(), _keys), max_size=40),
       cands=st.lists(_keys, min_size=1, max_size=8, unique=True))
def test_lru_oldest_is_least_recently_touched(ops, cands):
    """After any interleaving of touch/forget, ``oldest(candidates)``
    is the candidate whose last surviving touch is earliest — with
    never-touched (or forgotten) keys infinitely old, and ties broken
    toward the first candidate (deterministic eviction order)."""
    clock = LRUClock()
    last = {}                         # reference: key -> touch index
    for i, (is_touch, k) in enumerate(ops):
        if is_touch:
            clock.touch(k)
            last[k] = i + 1
        else:
            clock.forget(k)
            last.pop(k, None)
    expect = min(cands, key=lambda k: last.get(k, 0))
    assert clock.oldest(cands) == expect


@settings(max_examples=50, deadline=None)
@given(ops=st.lists(_keys, min_size=1, max_size=40))
def test_lru_eviction_order_matches_touch_order(ops):
    """Draining the clock by repeated oldest()+forget() yields keys in
    exactly last-touch order — the registry's eviction sequence among
    unpinned residents."""
    clock = LRUClock()
    last = {}
    for i, k in enumerate(ops):
        clock.touch(k)
        last[k] = i
    expect = sorted(last, key=last.get)
    drained = []
    alive = sorted(last)
    while alive:
        k = clock.oldest(alive)
        drained.append(k)
        clock.forget(k)
        alive.remove(k)
    assert drained == expect


# ---------------------------------------------------------------------------
# in-graph sampling invariants (serving/sampling.py)
# ---------------------------------------------------------------------------

from repro.serving import sampling as sampling_lib  # noqa: E402
from repro.serving.sampling import SamplingConfig  # noqa: E402

_vocab = st.integers(min_value=4, max_value=32)


@settings(max_examples=30, deadline=None)
@given(seed=_seed, vocab=_vocab, k=st.integers(min_value=1, max_value=8))
def test_top_k_never_selects_masked_token(seed, vocab, k):
    """A top-k draw always lands in the k highest logits."""
    key = jax.random.PRNGKey(seed)
    lg = jax.random.normal(key, (3, vocab)) * 5
    cfg = SamplingConfig(method="top_k", top_k=min(k, vocab),
                         temperature=0.7)
    tok = sampling_lib.sample(lg, jax.random.fold_in(key, 1), cfg)
    kth = jnp.sort(lg, axis=-1)[:, -min(k, vocab)]
    assert bool(jnp.all(jnp.take_along_axis(lg, tok[:, None], 1)[:, 0]
                        >= kth))


@settings(max_examples=30, deadline=None)
@given(seed=_seed, vocab=_vocab,
       p=st.floats(min_value=0.05, max_value=1.0))
def test_top_p_never_selects_masked_token_and_keeps_one(seed, vocab, p):
    """The nucleus never empties (>= 1 token survives at ANY p) and the
    draw always comes from inside it."""
    key = jax.random.PRNGKey(seed)
    lg = jax.random.normal(key, (2, vocab)) * 8
    cfg = SamplingConfig(method="top_p", top_p=p, temperature=1.0)
    masked = sampling_lib.process_logits(lg, cfg)
    nkeep = jnp.sum(jnp.isfinite(masked) & (masked > -1e30), axis=-1)
    assert bool(jnp.all(nkeep >= 1))
    tok = sampling_lib.sample(lg, jax.random.fold_in(key, 1), cfg)
    picked = jnp.take_along_axis(masked, tok[:, None], 1)[:, 0]
    assert bool(jnp.all(picked > -1e30))


@settings(max_examples=30, deadline=None)
@given(seed=_seed, vocab=_vocab)
def test_temperature_to_zero_recovers_greedy(seed, vocab):
    """As temperature -> 0 the temperature sampler concentrates on the
    argmax: a draw at T=1e-4 equals the greedy token."""
    key = jax.random.PRNGKey(seed)
    lg = jax.random.normal(key, (4, vocab)) * 3
    cold = SamplingConfig(method="temperature", temperature=1e-4)
    tok = sampling_lib.sample(lg, jax.random.fold_in(key, 1), cold)
    assert tok.tolist() == jnp.argmax(lg, axis=-1).tolist()


@settings(max_examples=30, deadline=None)
@given(seed=_seed, vocab=_vocab,
       rp=st.floats(min_value=1.01, max_value=3.0))
def test_repetition_penalty_only_demotes_emitted_ids(seed, vocab, rp):
    """With penalty > 1, masked (already-emitted) ids never gain logit
    mass and unmasked ids are untouched."""
    key = jax.random.PRNGKey(seed)
    lg = jax.random.normal(key, (2, vocab)) * 4
    mask = jax.random.bernoulli(jax.random.fold_in(key, 2), 0.4,
                                (2, vocab))
    cfg = SamplingConfig(method="greedy", repetition_penalty=rp)
    out = sampling_lib.process_logits(lg, cfg, penalty_mask=mask)
    lg32 = lg.astype(jnp.float32)
    assert bool(jnp.all(jnp.where(mask, out <= lg32 + 1e-6, out == lg32)))
