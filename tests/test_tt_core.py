"""Core TT algebra + MetaTT adapter unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dmrg, merge, metatt, tt

KEY = jax.random.PRNGKey(0)


class TestTTAlgebra:
    def test_materialize_matches_manual(self):
        cores = tt.random_tt(KEY, (6, 5, 4, 7), rank=3)
        full = tt.materialize(cores)
        assert full.shape == (6, 5, 4, 7)
        # slice == product of core slices
        got = tt.slice_matrix(cores, (2, 1))
        np.testing.assert_allclose(got, full[:, 2, 1, :], atol=1e-5)

    def test_tt_norm(self):
        cores = tt.random_tt(KEY, (6, 5, 4), rank=3)
        full = tt.materialize(cores)
        assert abs(float(tt.tt_norm(cores))
                   - float(jnp.linalg.norm(full))) < 1e-4

    def test_validate_rejects_bad_bonds(self):
        cores = tt.random_tt(KEY, (4, 4), rank=2)
        cores[1] = cores[1][:1]  # break the bond
        with pytest.raises(ValueError):
            tt.validate_cores(cores)

    def test_merge_split_roundtrip(self):
        cores = tt.random_tt(KEY, (8, 6, 8), rank=4)
        merged = tt.merge_pair(cores[0], cores[1])
        a, b, _ = tt.split_merged(merged, rank=64)  # full rank -> exact
        re_merged = tt.merge_pair(a, b)
        np.testing.assert_allclose(re_merged, merged, atol=1e-5)

    def test_truncation_error_eckart_young(self):
        cores = tt.random_tt(KEY, (8, 6), rank=6)
        merged = tt.merge_pair(cores[0], cores[1])
        a, b, s = tt.split_merged(merged, rank=3)
        approx = tt.merge_pair(a, b)
        err = float(jnp.linalg.norm((approx - merged).reshape(-1)))
        bound = float(tt.truncation_error(merged, 3))
        assert abs(err - bound) < 1e-4

    def test_left_canonicalize_preserves_tensor(self):
        cores = tt.random_tt(KEY, (6, 5, 4, 7), rank=3)
        canon = tt.left_canonicalize(list(cores))
        np.testing.assert_allclose(tt.materialize(canon),
                                   tt.materialize(cores), atol=1e-4)
        # every non-final core is a left isometry
        for c in canon[:-1]:
            m = c.reshape(-1, c.shape[-1])
            np.testing.assert_allclose(m.T @ m, np.eye(m.shape[1]),
                                       atol=1e-4)


class TestMetaTT:
    def _cfg(self, **kw):
        base = dict(num_layers=3, matrix_types=("q", "v"), d_in=(16, 16),
                    d_out=(16, 12), rank=4, alpha=2.0)
        base.update(kw)
        return metatt.MetaTTConfig(**base)

    def test_zero_at_init_all_variants(self):
        for variant, extra in [("4d", {}),
                               ("5d", dict(num_heads=4, head_dim=4,
                                           d_out=(16, 8))),
                               ("4+1d", dict(num_tasks=3)),
                               ("4+ed", dict(num_experts=4))]:
            cfg = self._cfg(variant=variant, **extra)
            p = metatt.init_params(cfg, KEY)
            assert metatt.zero_at_init(p, cfg), variant
            x = jax.random.normal(KEY, (5, 16))
            task = 0 if variant in ("4+1d", "4+ed") else None
            y = metatt.apply(p, cfg, x, layer=1, m="v", task=task)
            assert float(jnp.abs(y).max()) == 0.0

    def test_init_requires_a_zero_core(self):
        cfg = self._cfg(init="id-id-id-id")
        with pytest.raises(ValueError):
            metatt.init_params(cfg, KEY)

    def test_apply_matches_materialized_4d(self):
        cfg = self._cfg()
        p = {"cores": tt.random_tt(KEY, cfg.mode_sizes, 4)}
        x = jax.random.normal(KEY, (5, 16))
        for l, m in [(0, "q"), (2, "v")]:
            dw = metatt.materialize_delta(p, cfg, l, m)
            y = metatt.apply(p, cfg, x, layer=l, m=m)
            np.testing.assert_allclose(y, x @ dw, atol=1e-4)

    def test_apply_matches_full_tensor_5d(self):
        cfg = self._cfg(variant="5d", num_heads=4, head_dim=4,
                        d_out=(16, 8))
        p = {"cores": tt.random_tt(KEY, cfg.mode_sizes, 4)}
        full = tt.materialize(p["cores"])    # (16, 3, 2, 4, 4)
        x = jax.random.normal(KEY, (5, 16))
        y = metatt.apply(p, cfg, x, layer=1, m="v")
        dw = full[:, 1, 1].reshape(16, 16)[:, :8]
        np.testing.assert_allclose(y, cfg.alpha * x @ dw, atol=1e-4)

    def test_task_axis(self):
        cfg = self._cfg(variant="4+1d", num_tasks=3, d_out=(16, 16))
        p = {"cores": tt.random_tt(KEY, cfg.mode_sizes, 4)}
        x = jax.random.normal(KEY, (5, 16))
        ys = [metatt.apply(p, cfg, x, layer=1, m="q", task=t)
              for t in range(3)]
        # different tasks give different deltas
        assert not np.allclose(ys[0], ys[1])
        full = tt.materialize(p["cores"])
        np.testing.assert_allclose(
            ys[2], cfg.alpha * x @ full[:, 1, 2, 0, :], atol=1e-4)

    def test_boundary_slicing(self):
        """Heterogeneous out dims read leading columns of G4."""
        cfg = self._cfg()
        p = {"cores": tt.random_tt(KEY, cfg.mode_sizes, 4)}
        x = jax.random.normal(KEY, (5, 16))
        y_v = metatt.apply(p, cfg, x, layer=0, m="v")
        assert y_v.shape == (5, 12)
        y_q = metatt.apply(p, cfg, x, layer=0, m="q")
        assert y_q.shape == (5, 16)


class TestDMRG:
    def test_sweep_reaches_target_ranks(self):
        p = {"cores": tt.random_tt(KEY, (32, 6, 4, 32), 8)}
        res = dmrg.dmrg_sweep(p, target_rank=4)
        assert res.ranks == (4, 4, 4)
        assert len(res.spectra) == 3

    def test_exact_when_already_low_rank(self):
        p = {"cores": tt.random_tt(KEY, (32, 6, 4, 32), 4)}
        res = dmrg.dmrg_sweep(p, target_rank=4)
        assert dmrg.reconstruction_error(p, res.params) < 1e-5

    def test_adaptive_rtol(self):
        p = {"cores": tt.random_tt(KEY, (32, 6, 32), 4)}
        res = dmrg.dmrg_sweep(p, rtol=1e-6, max_rank=8)
        assert all(r <= 8 for r in res.ranks)

    def test_monotone_error_in_rank(self):
        p = {"cores": tt.random_tt(KEY, (32, 6, 4, 32), 8)}
        errs = [dmrg.reconstruction_error(
            p, dmrg.dmrg_sweep(p, target_rank=r).params)
            for r in (8, 6, 4, 2)]
        assert errs[0] < 1e-4
        assert all(errs[i] <= errs[i + 1] + 1e-6 for i in range(3))

    def test_rank_schedule(self):
        rs = dmrg.RankSchedule.linear(10, 4, start_epoch=2, every=2, step=2)
        assert rs.milestones == ((2, 8), (4, 6), (6, 4))
        assert rs.rank_after_epoch(4) == 6
        assert rs.rank_after_epoch(3) is None
        assert rs.final_rank == 4


class TestMerge:
    def test_lora_form_equals_apply(self):
        cfg = metatt.MetaTTConfig(num_layers=4, matrix_types=("q", "v"),
                                  d_in=(16, 16), d_out=(16, 12), rank=4,
                                  alpha=0.5)
        p = {"cores": tt.random_tt(KEY, cfg.mode_sizes, 4)}
        lf = merge.to_lora_form(p, cfg)
        x = jax.random.normal(KEY, (5, 16))
        for l, m in [(0, "q"), (3, "v")]:
            np.testing.assert_allclose(
                lf.delta(cfg, x, l, m), metatt.apply(p, cfg, x, l, m),
                atol=1e-4)

    def test_fold_into_dense(self):
        cfg = metatt.MetaTTConfig(num_layers=4, matrix_types=("q", "v"),
                                  d_in=(16, 16), d_out=(16, 12), rank=4,
                                  alpha=0.5)
        p = {"cores": tt.random_tt(KEY, cfg.mode_sizes, 4)}
        w = {"q": jax.random.normal(KEY, (4, 16, 16)),
             "v": jax.random.normal(KEY, (4, 16, 12))}
        wf = merge.fold_into_dense(p, cfg, w)
        x = jax.random.normal(KEY, (5, 16))
        np.testing.assert_allclose(
            x @ wf["q"][2],
            x @ w["q"][2] + metatt.apply(p, cfg, x, 2, "q"), atol=1e-4)


class TestTwoSiteDMRG:
    def test_two_site_beats_projection_sweep(self):
        """Paper App. C extension: local loss optimization inside the sweep
        reaches the target ranks at a LOWER loss than plain Algorithm 1."""
        import jax.numpy as jnp
        cfg = metatt.MetaTTConfig(num_layers=3, matrix_types=("q", "v"),
                                  d_in=(16, 16), d_out=(16, 16), rank=6)
        p = {"cores": tt.random_tt(KEY, cfg.mode_sizes, 6)}
        x = jax.random.normal(KEY, (12, 16))
        y = jax.random.normal(jax.random.PRNGKey(1), (12, 16))

        def loss_fn(params):
            pred = metatt.apply(params, cfg, x, layer=1, m="q")
            return jnp.mean((pred - y) ** 2)

        proj = dmrg.dmrg_sweep(p, target_rank=4)
        two = dmrg.two_site_sweep(p, loss_fn, target_rank=4,
                                  inner_steps=4, lr=5e-2)
        assert two.ranks == (4, 4, 4)
        assert float(loss_fn(two.params)) < float(loss_fn(proj.params))
