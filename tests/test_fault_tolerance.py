"""Unit tests for distributed/fault_tolerance.py: the straggler
Watchdog's EWMA baseline and the deterministic FailureInjector (the
primitive behind both the training restart tests and the serving
chaos harness's replica-kill trigger)."""
import pytest

from repro.distributed.fault_tolerance import (FailureInjector,
                                               SimulatedFailure, Watchdog)


def test_watchdog_flags_straggler_and_reports_baseline():
    seen = []
    wd = Watchdog(threshold=3.0, decay=0.9, min_steps=3,
                  on_straggler=lambda i, dt, ew: seen.append((i, dt, ew)))
    for i in range(5):
        assert not wd.step(i, 1.0)
    assert wd.step(5, 10.0)
    assert seen == [(5, 10.0, pytest.approx(1.0))]


def test_watchdog_warmup_never_flags():
    wd = Watchdog(threshold=3.0, min_steps=5)
    # huge spread during warm-up: no baseline yet, nothing fires
    assert not wd.step(0, 1.0)
    assert not wd.step(1, 100.0)


def test_watchdog_excludes_stragglers_from_ewma():
    """The regression this guards: folding a flagged duration into the
    EWMA inflates the baseline and masks the NEXT straggler. Two
    consecutive 5x-slow steps must BOTH fire."""
    wd = Watchdog(threshold=3.0, decay=0.9, min_steps=3)
    for i in range(4):
        wd.step(i, 1.0)
    base = wd._ewma
    assert wd.step(4, 5.0)
    # the 5.0 did not move the baseline ...
    assert wd._ewma == pytest.approx(base)
    # ... so an identical second straggler fires too (with the buggy
    # update the baseline would sit at ~1.4 and 5.0 > 3 * 1.4 barely
    # passes; at 2.5x it would already be masked — check that too)
    assert wd.step(5, 5.0)
    assert wd.step(6, 3.5 * base)
    assert wd._ewma == pytest.approx(base)


def test_watchdog_healthy_steps_still_update_ewma():
    wd = Watchdog(threshold=3.0, decay=0.5, min_steps=2)
    wd.step(0, 1.0)
    wd.step(1, 2.0)
    assert wd._ewma == pytest.approx(1.5)


def test_failure_injector_fires_exactly_at_step():
    inj = FailureInjector(fail_at_step=3)
    for s in range(3):
        inj.check(s)
    with pytest.raises(SimulatedFailure):
        inj.check(3)
    # disarmed injectors (the chaos harness one-shots them) stay quiet
    inj.fail_at_step = -1
    inj.check(3)


def test_failure_injector_default_never_fires():
    inj = FailureInjector()
    for s in range(100):
        inj.check(s)
