"""Serving path: decode==forward consistency, prefill+decode generation,
inference-time adapter merging (paper §2.4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as registry
from repro.config.base import RunConfig, SHAPES
from repro.core import tt as ttlib
from repro.core.merge import fold_transformer
from repro.models import model as M, transformer as T
from repro.peft import api as peft_api
from repro.serving import engine as se

KEY = jax.random.PRNGKey(0)


def _setup(arch, nonzero_adapter=True):
    cfg = registry.get_smoke_config(arch)
    run = RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                    adapter_kind="metatt", adapter_rank=4)
    spec = M.build_adapter_spec(run)
    params = M.init_params(cfg, spec, KEY)
    if nonzero_adapter:
        params["adapter"] = {"cores": ttlib.random_tt(
            KEY, spec.cfg.mode_sizes, 4, scale=0.1)}
    return cfg, spec, params


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "granite-moe-1b-a400m",
                                  "jamba-v0.1-52b", "xlstm-125m",
                                  "whisper-large-v3", "gemma-7b"])
def test_decode_matches_parallel_forward(arch):
    cfg, spec, params = _setup(arch)
    bc, pl = peft_api.adapter_factors(spec, params["adapter"],
                                      params["frozen"])
    B, S = 2, 8
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    kw = {}
    if cfg.is_encdec:
        kw["enc_embeds"] = jax.random.normal(KEY, (B, cfg.encoder_seq,
                                                   cfg.d_model))
    out = T.forward(params["base"], cfg, spec, bc, pl, tokens, **kw)
    caches = T.init_caches(cfg, B, S, jnp.float32)
    steps = []
    for t in range(S):
        lg, caches = T.decode_step(params["base"], cfg, spec, bc, pl,
                                   tokens[:, t:t + 1], caches, jnp.int32(t),
                                   enc_out=out.enc_out)
        steps.append(lg)
    dec = jnp.stack(steps, axis=1)
    rel = (float(jnp.max(jnp.abs(dec - out.logits)))
           / float(jnp.max(jnp.abs(out.logits))))
    assert rel < 2e-2, (arch, rel)


def test_prefill_then_decode_greedy_generation():
    cfg, spec, params = _setup("stablelm-1.6b")
    bc, pl = peft_api.adapter_factors(spec, params["adapter"],
                                      params["frozen"])
    B, P, G = 2, 6, 4
    cache_len = P + G
    prompt = jax.random.randint(KEY, (B, P), 0, cfg.vocab_size)
    prefill = se.make_prefill(cfg, spec, cache_len)
    logits, caches, _ = prefill(params["base"], params["adapter"],
                                params["frozen"], prompt)
    # reference: full forward over the eventually-generated sequence
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    gen = [tok]
    for i in range(G - 1):
        lg, caches = T.decode_step(params["base"], cfg, spec, bc, pl,
                                   tok, caches, jnp.int32(P + i))
        tok = jnp.argmax(lg, axis=-1)[:, None]
        gen.append(tok)
    seq = jnp.concatenate([prompt] + gen, axis=1)
    out = T.forward(params["base"], cfg, spec, bc, pl, seq)
    # greedy property: every generated token is argmax of the full-forward
    # logits at its position
    for i in range(G):
        want = jnp.argmax(out.logits[:, P + i - 1], axis=-1)
        np.testing.assert_array_equal(np.asarray(seq[:, P + i]),
                                      np.asarray(want))


def test_fold_into_dense_serving_is_zero_overhead_and_exact():
    cfg, spec, params = _setup("stablelm-1.6b")
    bc, pl = peft_api.adapter_factors(spec, params["adapter"],
                                      params["frozen"])
    tokens = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    out_adapted = T.forward(params["base"], cfg, spec, bc, pl, tokens)
    # fold ΔW into ALL adapted weights (every layer), run with NO adapter
    folded = fold_transformer(params["adapter"], spec.cfg, params["base"],
                              cfg)
    out_folded = T.forward(folded, cfg, peft_api.NONE, {}, None, tokens)
    rel = (float(jnp.max(jnp.abs(out_folded.logits - out_adapted.logits)))
           / float(jnp.max(jnp.abs(out_adapted.logits))))
    assert rel < 2e-2, rel
