"""Tensor-parallel sharded serving engine (DESIGN.md §9).

Acceptance criteria of the mesh-aware engine refactor:

  * the sharded engine (shard_map over a ("data", "model") mesh, KV
    caches kv-head-sharded, vocab-striped readout + logits all-gather)
    is TOKEN-IDENTICAL to the single-device engine for greedy decode —
    across runtimes (live / lora / merged), cache modes (paged / dense),
    kv dtypes (fp / int8) and kernel backends (ref / pallas-interpret),
  * warm (prefix-cache) requests stay token-identical under sharding —
    the host-side BlockManager / PrefixCache / COW machinery is
    shard-agnostic (one block id indexes every shard's pool),
  * the paged pools are PHYSICALLY sharded: each device holds a
    1/|model| kv-head stripe of every pool leaf,
  * EngineStats reports GLOBAL byte figures with a ``shards`` field
    whose per-shard projections sum back to the global numbers.

The 4-device cases need fake host devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        python -m pytest -q tests/test_sharded_engine.py

(the scripts/ci.sh ``sharded-parity`` job does exactly this). On a
single device they skip; the mesh(1,1) cases still run and exercise the
whole shard_map machinery in the tier-1 suite.
"""
import jax
import numpy as np
import pytest

from repro import configs as registry
from repro.config.base import (KernelConfig, QuantConfig, RunConfig,
                               SHAPES, ServeConfig)
from repro.core import tt as ttlib
from repro.models import model as M
from repro.serving import AdapterRuntime, Engine, Request

KEY = jax.random.PRNGKey(0)
PALLAS = KernelConfig(backend="pallas", interpret=True)

needs4 = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs 4 (fake) devices: run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=4 "
           "(scripts/ci.sh sharded-parity job)")


def _setup(variant="4+1d", num_tasks=3):
    cfg = registry.get_smoke_config("stablelm-1.6b")
    run = RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                    adapter_kind="metatt", adapter_variant=variant,
                    num_tasks=num_tasks, adapter_rank=4)
    spec = M.build_adapter_spec(run)
    params = M.init_params(cfg, spec, KEY)
    params["adapter"] = {"cores": ttlib.random_tt(
        KEY, spec.cfg.mode_sizes, 4, scale=0.8)}
    return cfg, spec, params


def _mixed_requests(cfg, n=5, tasks=3):
    prompts = [jax.random.randint(jax.random.PRNGKey(i), (4 + i,), 0,
                                  cfg.vocab_size) for i in range(n)]
    return [Request(p, 5 + (i % 3), task=i % tasks)
            for i, p in enumerate(prompts)]


def _serve(cfg, rt, reqs, *, mesh=(), mode="paged", quant=QuantConfig(),
           kernels=None, **kw):
    base = dict(max_batch=2, cache_len=32, out_cap=8, cache_mode=mode,
                page_size=8, prefill_chunk=4, quant=quant,
                mesh_shape=mesh)
    base.update(kw)
    eng = Engine(cfg, rt, serve=ServeConfig(**base), kernels=kernels)
    return [o.tolist() for o in eng.generate(reqs)], eng


def test_mesh_1x1_token_identical_to_unsharded():
    """The shard_map machinery itself (specs, tp context, collectives of
    size 1) must be transparent — runs in the tier-1 single-device
    suite."""
    cfg, spec, params = _setup()
    reqs = _mixed_requests(cfg)
    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    ref, _ = _serve(cfg, rt, reqs)
    for mode in ("paged", "dense"):
        got, eng = _serve(cfg, rt, reqs, mesh=(1, 1), mode=mode)
        assert got == ref, mode
        assert eng.last_stats.shards == 1


@needs4
def test_tp4_token_parity_all_runtimes():
    """mesh(1,4) vs mesh() greedy token parity for live / lora / merged
    runtimes on a mixed-task, mixed-length paged workload."""
    cfg, spec, params = _setup()
    reqs = _mixed_requests(cfg)
    for mode_name, build_kw, rq in (
            ("live", {}, reqs),
            ("lora", {}, reqs),
            ("merged", dict(model_cfg=cfg, task=1),
             [r for r in reqs if r.task == 1])):
        rt = AdapterRuntime.build(mode_name, params["base"], spec,
                                  params["adapter"], params["frozen"],
                                  **build_kw)
        ref, _ = _serve(cfg, rt, rq)
        tp4, eng = _serve(cfg, rt, rq, mesh=(1, 4))
        assert tp4 == ref, mode_name
        assert eng.last_stats.shards == 4


@needs4
@pytest.mark.parametrize("mode", ["paged", "dense"])
def test_tp4_token_parity_both_cache_modes(mode):
    cfg, spec, params = _setup()
    reqs = _mixed_requests(cfg)
    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    ref, _ = _serve(cfg, rt, reqs, mode=mode)
    tp4, _ = _serve(cfg, rt, reqs, mesh=(1, 4), mode=mode)
    assert tp4 == ref


@needs4
def test_tp4_int8_kv_and_weights_parity():
    """w8a16 + int8 paged KV under TP: the int8 scale pools shard with
    the cells through the same block tables; the sharded int8 engine
    must match the single-device int8 engine token for token."""
    cfg, spec, params = _setup()
    reqs = _mixed_requests(cfg)
    rt = AdapterRuntime.build("lora", params["base"], spec,
                              params["adapter"], params["frozen"])
    q8 = QuantConfig(weights="int8", kv="int8")
    ref, _ = _serve(cfg, rt, reqs, quant=q8)
    tp4, eng = _serve(cfg, rt, reqs, mesh=(1, 4), quant=q8)
    assert tp4 == ref
    # scale pools are physically sharded alongside the int8 cells
    ks = eng._paged_caches[0]["self"]["k_s"]
    local = ks.addressable_shards[0].data.shape
    assert local[3] == ks.shape[3] // 4


@needs4
def test_tp4_pallas_interpret_parity():
    """The Pallas paged-attention / fused-linear kernels run PER SHARD
    inside shard_map (local head group, local pool shard) — interpret
    mode on CPU must stay token-identical to the unsharded ref engine."""
    cfg, spec, params = _setup()
    reqs = _mixed_requests(cfg, n=4)
    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    ref, _ = _serve(cfg, rt, reqs)
    tp4, _ = _serve(cfg, rt, reqs, mesh=(1, 4), kernels=PALLAS)
    assert tp4 == ref


@needs4
def test_tp4_warm_prefix_cache_token_identical():
    """Prefix sharing under sharding: the host-side chain/COW decisions
    are shard-independent, so a warm second pass must reproduce the cold
    tokens exactly and actually hit the cache."""
    cfg, spec, params = _setup()
    reqs = _mixed_requests(cfg)
    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    cold, eng = _serve(cfg, rt, reqs, mesh=(1, 4))
    assert eng.last_stats.prefix_hit_rate == 0.0
    warm = [o.tolist() for o in eng.generate(reqs)]
    assert warm == cold
    st = eng.last_stats
    assert st.prefix_hit_rate > 0
    assert st.cow_copies > 0


@needs4
def test_tp4_stats_per_shard_sums_to_global():
    """EngineStats reports GLOBAL bytes + a shards field; the per-shard
    projections must sum back to the global figures, match 1/4 of the
    dense-equivalent reservation, and agree with the physical pool
    placement."""
    cfg, spec, params = _setup()
    reqs = _mixed_requests(cfg)
    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    _, eng1 = _serve(cfg, rt, reqs)
    _, eng4 = _serve(cfg, rt, reqs, mesh=(1, 4))
    s1, s4 = eng1.last_stats, eng4.last_stats
    assert (s1.shards, s4.shards) == (1, 4)
    # global accounting is mesh-independent
    assert s4.block_bytes == s1.block_bytes
    assert s4.kv_bytes_peak == s1.kv_bytes_peak
    # per-shard figures sum to global, and are global/4 under TP=4
    assert s4.block_bytes_per_shard * s4.shards == s4.block_bytes
    assert s4.kv_bytes_peak_per_shard * s4.shards == s4.kv_bytes_peak
    assert s4.kv_bytes_peak_per_shard == s4.kv_bytes_peak // 4
    assert s1.kv_bytes_peak_per_shard == s1.kv_bytes_peak
    # device truth: each shard holds a 1/4 kv-head stripe of every pool
    for leaf in jax.tree_util.tree_leaves(eng4._paged_caches):
        local = leaf.addressable_shards[0].data.shape
        assert local[3] == leaf.shape[3] // 4, (leaf.shape, local)


def test_mesh_validation_errors():
    cfg, spec, params = _setup(variant="4d", num_tasks=0)
    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    with pytest.raises(ValueError):     # not a (data, model) pair
        ServeConfig(mesh_shape=(2,)).validate()
    with pytest.raises(ValueError):     # unknown TP axis name
        ServeConfig(mesh_shape=(1, 1), tp_axis="pod").validate()
    with pytest.raises(ValueError):     # more devices than the host has
        Engine(cfg, rt, serve=ServeConfig(
            mesh_shape=(1, 4096), cache_len=32, out_cap=8))


@needs4
def test_mesh_rejects_indivisible_heads():
    """Heads that do not divide the model axis must fail loudly — a
    silent replicated fallback would void the per-shard KV-bytes
    claim."""
    import dataclasses
    cfg, spec, params = _setup(variant="4d", num_tasks=0)
    bad = dataclasses.replace(registry.get_smoke_config("stablelm-1.6b"),
                              num_heads=2, num_kv_heads=2)
    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    with pytest.raises(ValueError, match="num_heads"):
        Engine(bad, rt, serve=ServeConfig(mesh_shape=(1, 4),
                                          cache_len=32, out_cap=8))
