"""Host-side paged-KV bookkeeping (serving/block_manager.py, scheduler.py).

Coverage pinned by the paged-cache refactor:
  * BlockManager alloc/free/refcount invariants,
  * prefix-cache chain match/register at full-page and partial-page
    granularity (longest-common-prefix partial matching),
  * copy-on-write planning on shared-prefix divergence,
  * LRU eviction only touches unpinned leaf blocks,
  * out-of-blocks admission backpressure (scheduler returns None and
    takes no refs).
"""
import pytest

from repro.serving.block_manager import BlockManager, PrefixCache
from repro.serving.scheduler import Scheduler


def test_block_manager_alloc_free_refcount():
    bm = BlockManager(4, 8)
    assert bm.free_blocks == 4
    a, b = bm.alloc(), bm.alloc()
    assert bm.used_blocks == 2 and bm.refcount(a) == 1
    bm.ref(a)
    assert bm.refcount(a) == 2
    assert bm.deref(a) is False          # still shared
    assert bm.deref(a) is True           # freed
    assert bm.free_blocks == 3
    with pytest.raises(ValueError):
        bm.deref(a)                      # double free
    with pytest.raises(ValueError):
        bm.ref(a)                        # ref of a free block
    assert bm.writable(b)
    bm.ref(b)
    assert not bm.writable(b)            # shared -> COW before writing
    # exhaust the pool
    while bm.free_blocks:
        bm.alloc()
    with pytest.raises(RuntimeError):
        bm.alloc()


def test_prefix_cache_full_page_chain_match():
    bm = BlockManager(8, 4)
    pc = PrefixCache(bm)
    prompt = list(range(10))             # 2 full pages + partial(2)
    table = [bm.alloc() for _ in range(3)]
    assert pc.register(prompt, table) == 3
    # identical prompt: both full pages + the partial page match
    m = pc.match(prompt)
    assert m.tokens == 10 and m.blocks == table
    for bid in m.blocks:
        bm.deref(bid)
    # longer prompt sharing the 2 full pages only (page 3 differs)
    m = pc.match(list(range(8)) + [99, 98, 97])
    assert m.tokens == 8 and m.blocks == table[:2]
    for bid in m.blocks:
        bm.deref(bid)
    # divergence inside page 1 stops the chain at page 0
    m = pc.match([0, 1, 2, 3, 4, 99, 6, 7])
    assert m.tokens == 4 and m.blocks == table[:1]
    bm.deref(m.blocks[0])


def test_prefix_cache_partial_page_longest_common_prefix():
    bm = BlockManager(8, 4)
    pc = PrefixCache(bm)
    table = [bm.alloc(), bm.alloc()]
    pc.register([0, 1, 2, 3, 4, 5, 6], table)      # page + partial(3)
    # shares 2 of the partial page's 3 tokens, then diverges -> the
    # partial block is matched (the sharer copies-on-write before writing)
    m = pc.match([0, 1, 2, 3, 4, 5, 99])
    assert m.tokens == 6 and m.blocks == table
    assert bm.refcount(table[1]) == 3              # slot + cache + sharer
    for bid in m.blocks:
        bm.deref(bid)


def test_prefix_cache_register_dedups_and_keeps_one_cache_ref():
    bm = BlockManager(8, 4)
    pc = PrefixCache(bm)
    t1 = [bm.alloc()]
    pc.register([1, 2, 3, 4], t1)
    assert bm.refcount(t1[0]) == 2                 # slot + cache
    bm.deref(t1[0])                                # slot releases
    # a second request computed the same page cold: registration dedups,
    # its block stays owned by the request alone
    t2 = [bm.alloc()]
    assert pc.register([1, 2, 3, 4], t2) == 0
    assert bm.refcount(t2[0]) == 1
    assert len(pc) == 1


def test_prefix_cache_lru_evicts_unpinned_leaves_only():
    bm = BlockManager(6, 4)
    pc = PrefixCache(bm)
    t1 = [bm.alloc(), bm.alloc()]                  # chain a: 2 pages
    pc.register(list(range(8)), t1)
    t2 = [bm.alloc()]
    pc.register([9, 9, 9], t2)                     # chain b: partial page
    for bid in t1 + t2:
        bm.deref(bid)
    assert bm.free_blocks == 3
    # pin chain b by matching it (simulates a live slot using it)
    m = pc.match([9, 9, 9])
    assert m.tokens == 3
    # chain a's leaf (page 1) is LRU-evictable; its parent only after;
    # the pinned chain b must survive any demand
    freed = pc.evict_lru(10)
    assert freed == 2                              # both chain-a pages
    assert bm.free_blocks == 5
    assert pc.match([9, 9, 9]).tokens == 3         # still cached
    assert pc.match(list(range(8))).tokens == 0    # gone


def test_scheduler_admission_by_free_blocks_and_backpressure():
    bm = BlockManager(4, 4)
    sched = Scheduler(bm, PrefixCache(bm))
    # 6 prompt + 6 new = 12 tokens -> 3 pages
    p1 = sched.plan(list(range(6)), 6)
    assert p1 is not None and p1.total_pages == 3 and p1.n_cached == 0
    # next request needs 2 pages, only 1 free -> backpressure, no refs
    free_before = bm.free_blocks
    assert sched.plan([7] * 4, 4) is None
    assert bm.free_blocks == free_before
    assert sched.stats.backpressure_waits == 1
    # release the first -> its pages go to the prefix cache / free list
    sched.release(list(range(6)), p1.blocks)
    assert sched.plan([7] * 4, 4) is not None      # now admits (LRU evict)


def test_futile_backpressure_retry_does_not_drain_prefix_cache():
    """A head request that cannot fit even after full cache drain must
    not destroy cached blocks on every retry — eviction only runs when
    it can make the allocation succeed."""
    bm = BlockManager(4, 4)
    pc = PrefixCache(bm)
    sched = Scheduler(bm, pc)
    bm.alloc(), bm.alloc()                    # pinned by a live slot
    t = [bm.alloc()]
    pc.register([1, 2, 3, 4], t)
    bm.deref(t[0])                            # cached only: drainable
    # needs 3 pages; free=1 + drainable=1 < 3 -> infeasible: no eviction
    for _ in range(3):                        # retries must be harmless
        assert sched.plan([9] * 8, 4) is None
    assert len(pc) == 1
    m = pc.match([1, 2, 3, 4])                # cached block survived
    assert m.tokens == 4
    bm.deref(m.blocks[0])                     # drop the probe's ref
    # feasible 2-page request: eviction now runs and admission succeeds
    assert sched.plan([5] * 4, 4) is not None
    assert sched.stats.cache_evictions >= 1


def test_scheduler_cow_on_shared_partial_page():
    bm = BlockManager(8, 4)
    pc = PrefixCache(bm)
    sched = Scheduler(bm, pc)
    p1 = sched.plan([0, 1, 2, 3, 4, 5], 2)         # 2 pages, partial(2)
    assert p1.cow is None
    sched.release([0, 1, 2, 3, 4, 5], p1.blocks)
    # warm request diverging inside the shared partial page: the partial
    # block must be COW'd (fresh dst, cached src untouched)
    p2 = sched.plan([0, 1, 2, 3, 4, 99], 2)
    assert p2.n_cached == 5                        # 4 full + 1 partial tok
    assert p2.cow is not None
    src, dst = p2.cow
    assert p2.blocks[1] == dst and src != dst
    assert bm.refcount(dst) == 1                   # private writable copy
    assert pc.match([0, 1, 2, 3, 4, 5]).tokens == 6  # original intact
