"""Fused-dispatch vs reference parity (interpret mode on CPU).

Pins the kernel-dispatch refactor's acceptance criteria:
  * full-model forward/loss agreement between
    ``KernelConfig(backend="pallas", interpret=True)`` and the ref path
    across adapter kinds (metatt 4d / 4+1d, lora, vera, lotr), dtypes and
    deliberately non-tile-multiple shapes,
  * serving-engine decode parity with the fused batched-A kernel
    (per-slot task routing stays inside one kernel),
  * ops-level tile padding on every dim (N/K for tt_linear, odd sequence
    lengths for flash/decode attention),
  * gradients through the fused custom VJPs (the *train* hot path),
  * the two-site DMRG sweep's exact resplit + per-bond gradient count.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as registry
from repro.config.base import KernelConfig, RunConfig, SHAPES
from repro.core import dmrg as dmrg_lib
from repro.core import tt as ttlib
from repro.kernels import dispatch, ops
from repro.models import model as M
from repro.models import transformer as T
from repro.peft import api as peft_api
from repro.serving import AdapterRuntime, Engine, Request

KEY = jax.random.PRNGKey(0)
PALLAS = dispatch.resolve(KernelConfig(backend="pallas", interpret=True))
REF = dispatch.resolve(KernelConfig(backend="ref"))


def _setup(kind="metatt", variant="4d", num_tasks=0, model_cfg=None,
           matrices=(), rank=4, scale=0.5):
    cfg = model_cfg or registry.get_smoke_config("stablelm-1.6b")
    run = RunConfig(model=cfg, shape=SHAPES["train_4k"], adapter_kind=kind,
                    adapter_variant=variant, num_tasks=num_tasks,
                    adapter_rank=rank, adapter_matrices=matrices)
    spec = M.build_adapter_spec(run)
    params = M.init_params(cfg, spec, KEY)
    if kind == "metatt":
        params["adapter"] = {"cores": ttlib.random_tt(
            KEY, spec.cfg.mode_sizes, rank, scale=scale)}
    else:   # zero-init B/g/S factors would make the fused route vacuous
        params["adapter"] = jax.tree_util.tree_map(
            lambda a: scale * jax.random.normal(KEY, a.shape, a.dtype),
            params["adapter"])
    return cfg, spec, params


def _forward(cfg, spec, params, tokens, policy, task=None):
    bc, pl = peft_api.adapter_factors(spec, params["adapter"],
                                      params["frozen"])
    return T.forward(params["base"], cfg, spec, bc, pl, tokens, task=task,
                     policy=policy)


# ---------------------------------------------------------------------------
# full-model forward / loss parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind,variant,num_tasks", [
    ("metatt", "4d", 0),
    ("metatt", "4+1d", 2),
    ("lora", "4d", 0),
    ("vera", "4d", 0),
    ("lotr", "4d", 0),
])
def test_forward_loss_parity_across_adapter_kinds(kind, variant, num_tasks):
    cfg, spec, params = _setup(kind, variant, num_tasks)
    tokens = jax.random.randint(KEY, (2, 9), 0, cfg.vocab_size)
    task = jnp.int32(1) if variant == "4+1d" else None
    out_p = _forward(cfg, spec, params, tokens, PALLAS, task)
    out_r = _forward(cfg, spec, params, tokens, REF, task)
    out_legacy = _forward(cfg, spec, params, tokens, None, task)
    np.testing.assert_allclose(out_p.logits, out_r.logits,
                               atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(out_p.logits, out_legacy.logits,
                               atol=5e-5, rtol=5e-5)
    batch = {"tokens": tokens, "task": task}
    loss_p = M.loss_fn(params["adapter"], params["base"], params["frozen"],
                       batch, cfg, spec, policy=PALLAS)[0]
    loss_r = M.loss_fn(params["adapter"], params["base"], params["frozen"],
                       batch, cfg, spec, policy=None)[0]
    np.testing.assert_allclose(loss_p, loss_r, atol=1e-5, rtol=1e-5)


def test_forward_parity_batched_task_vector():
    """Per-example (B,) task ids (4+1d) hit the batched-A seam in train
    shape (T > 1) — the dispatch falls back to the batched-einsum leg of
    the SAME entry point."""
    cfg, spec, params = _setup("metatt", "4+1d", num_tasks=3)
    tokens = jax.random.randint(KEY, (3, 6), 0, cfg.vocab_size)
    tv = jnp.array([0, 2, 1], jnp.int32)
    out_p = _forward(cfg, spec, params, tokens, PALLAS, tv)
    out_l = _forward(cfg, spec, params, tokens, None, tv)
    np.testing.assert_allclose(out_p.logits, out_l.logits,
                               atol=5e-5, rtol=5e-5)


def test_forward_parity_bf16():
    cfg = dataclasses.replace(registry.get_smoke_config("stablelm-1.6b"),
                              param_dtype=jnp.bfloat16,
                              compute_dtype=jnp.bfloat16)
    cfg2, spec, params = _setup(model_cfg=cfg)
    tokens = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    out_p = _forward(cfg, spec, params, tokens, PALLAS)
    out_r = _forward(cfg, spec, params, tokens, REF)
    np.testing.assert_allclose(np.asarray(out_p.logits, np.float32),
                               np.asarray(out_r.logits, np.float32),
                               atol=5e-2, rtol=5e-2)


def test_forward_parity_non_tile_multiple_shapes():
    """GeGLU d_ff, odd d_model/vocab/seq — nothing is a 128 multiple, so
    every kernel call exercises the pad-and-slice path, including the
    ffn_* adapted matrices."""
    cfg = dataclasses.replace(
        registry.get_smoke_config("stablelm-1.6b"), name="odd-smoke",
        d_model=40, num_heads=4, num_kv_heads=2, d_ff=72, vocab_size=77,
        mlp="geglu")
    cfg2, spec, params = _setup(
        model_cfg=cfg,
        matrices=("attn_q", "attn_v", "ffn_up", "ffn_down", "ffn_gate"))
    tokens = jax.random.randint(KEY, (2, 9), 0, cfg.vocab_size)
    out_p = _forward(cfg, spec, params, tokens, PALLAS)
    out_r = _forward(cfg, spec, params, tokens, REF)
    np.testing.assert_allclose(out_p.logits, out_r.logits,
                               atol=5e-5, rtol=5e-5)


def test_grad_parity_through_fused_vjp():
    """The TRAIN hot path: value_and_grad through the fused kernels (the
    custom VJP whose dx GEMM is the fused kernel itself) must match the
    reference autodiff."""
    cfg, spec, params = _setup()
    batch = {"tokens": jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)}

    def run(policy):
        def f(adapter):
            return M.loss_fn(adapter, params["base"], params["frozen"],
                             batch, cfg, spec, policy=policy)[0]
        return jax.value_and_grad(f)(params["adapter"])

    (loss_p, grads_p) = run(PALLAS)
    (loss_r, grads_r) = run(None)
    np.testing.assert_allclose(loss_p, loss_r, atol=1e-5, rtol=1e-5)
    for gp, gr in zip(jax.tree_util.tree_leaves(grads_p),
                      jax.tree_util.tree_leaves(grads_r)):
        np.testing.assert_allclose(gp, gr, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# serving engine: fused batched-A decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["live", "lora"])
def test_engine_decode_fused_batched_a_matches_ref(mode):
    """Mixed-task continuous batching with the fused kernels (decode runs
    ``tt_linear_batched_a`` with the slot-gathered A) must be
    token-identical to the unfused engine."""
    cfg, spec, params = _setup("metatt", "4+1d", num_tasks=3, scale=0.8)
    rt = AdapterRuntime.build(mode, params["base"], spec,
                              params["adapter"], params["frozen"])
    prompts = [jax.random.randint(jax.random.PRNGKey(i), (4 + i,), 0,
                                  cfg.vocab_size) for i in range(4)]
    reqs = [Request(p, 5, task=i % 3) for i, p in enumerate(prompts)]
    kw = dict(max_batch=3, cache_len=32, out_cap=8)
    ref_out = Engine(cfg, rt, **kw).generate(reqs)
    fused_out = Engine(cfg, rt, kernels=KernelConfig(
        backend="pallas", interpret=True), **kw).generate(reqs)
    for r, f in zip(ref_out, fused_out):
        assert r.tolist() == f.tolist()


# ---------------------------------------------------------------------------
# ops-level tile padding
# ---------------------------------------------------------------------------


def test_ops_tt_linear_pads_n_and_k():
    """Non-multiple N and K (GeGLU d_ff / odd vocab slices) used to trip
    the kernel assert — now they pad with zeros and slice back."""
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (3, 5, 200), jnp.float32)
    w = jax.random.normal(ks[1], (200, 391), jnp.float32) / 14
    a = jax.random.normal(ks[2], (200, 9), jnp.float32) / 14
    b = jax.random.normal(ks[3], (9, 391), jnp.float32) / 3
    y = ops.tt_linear(x, w, a, b, alpha=1.3, backend="pallas",
                      interpret=True)
    want = ops.tt_linear(x, w, a, b, alpha=1.3, backend="ref")
    np.testing.assert_allclose(y, want, atol=1e-4, rtol=1e-4)
    assert y.shape == (3, 5, 391)


def test_ops_tt_linear_batched_a_pads_all_dims():
    s, k, n, r = 5, 96, 130, 6
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (s, k))
    w = jax.random.normal(ks[1], (k, n)) / 10
    a = jax.random.normal(ks[2], (s, k, r)) / 10
    b = jax.random.normal(ks[3], (r, n)) / 2
    y = ops.tt_linear_batched_a(x, w, a, b, alpha=0.7, backend="pallas",
                                interpret=True)
    want = ops.tt_linear_batched_a(x, w, a, b, alpha=0.7, backend="ref")
    np.testing.assert_allclose(y, want, atol=1e-4, rtol=1e-4)
    # decode layout (S, 1, K) round-trips too
    y3 = ops.tt_linear_batched_a(x[:, None], w, a, b, alpha=0.7,
                                 backend="pallas", interpret=True)
    np.testing.assert_allclose(y3[:, 0], want, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("causal", [True, False])
def test_ops_flash_attention_pads_odd_seq_lens(causal):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 70, 4, 32))
    k = jax.random.normal(ks[1], (2, 70, 2, 32))
    v = jax.random.normal(ks[2], (2, 70, 2, 32))
    y = ops.flash_attention(q, k, v, causal=causal, backend="pallas",
                            interpret=True)
    want = ops.flash_attention(q, k, v, causal=causal, backend="ref")
    np.testing.assert_allclose(y, want, atol=2e-4, rtol=2e-4)
    assert y.shape == q.shape


def test_ops_decode_attention_matches_ref():
    b, s, h, kv, d = 3, 40, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, 1, h, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))
    pos = jnp.array([0, 7, 39])     # includes a fresh slot and a full cache
    y = ops.decode_attention(q, k, v, pos, backend="pallas",
                             interpret=True)
    want = ops.decode_attention(q, k, v, pos, backend="ref")
    np.testing.assert_allclose(y, want, atol=2e-4, rtol=2e-4)
    assert y.shape == (b, 1, h, d)


# ---------------------------------------------------------------------------
# DMRG two-site sweep fixes
# ---------------------------------------------------------------------------


def test_two_site_sweep_exact_resplit_and_grad_count():
    cores = ttlib.random_tt(KEY, (12, 3, 2, 12), rank=6, scale=0.3)
    calls = {"n": 0}

    def loss_fn(params):
        calls["n"] += 1
        return ttlib.tt_norm(params["cores"]) ** 2

    inner = 3
    res = dmrg_lib.two_site_sweep({"cores": cores}, loss_fn, target_rank=4,
                                  inner_steps=inner)
    assert res.ranks == (4, 4, 4)
    # the local problem descends the loss, so the norm must shrink
    assert float(ttlib.tt_norm(res.params["cores"])) < \
        float(ttlib.tt_norm(cores))
    # exactly inner_steps gradient traces per bond, two passes over the
    # d-1 bonds (the old loop computed one wasted extra gradient each)
    d = len(cores)
    assert calls["n"] == 2 * (d - 1) * inner
