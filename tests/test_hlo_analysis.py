"""Unit tests for the trip-count-aware HLO roofline analyzer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as H


def _compile_text(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_scan_flops_multiplied_by_trip_count():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y
    sds = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    r = H.analyze(_compile_text(f, sds, sds))
    assert r["flops"] == 10 * 2 * 128 ** 3


def test_nested_scan_flops():
    def g(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y
    sds = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    r = H.analyze(_compile_text(g, sds, sds))
    assert r["flops"] == 15 * 2 * 128 ** 3


def test_plain_matmul_flops_and_bytes():
    def f(x, w):
        return x @ w
    x = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 16), jnp.float32)
    r = H.analyze(_compile_text(f, x, w))
    assert r["flops"] == 2 * 64 * 32 * 16
    # result + both operands read at least once
    assert r["bytes"] >= 4 * (64 * 16 + 64 * 32 + 32 * 16)


def test_shape_bytes_parsing():
    assert H._nbytes("bf16[2,3]{1,0}") == 12
    assert H._nbytes("(f32[4], s32[2])") == 24
    assert H._nbytes("pred[]") == 1
    assert H._nbytes("token[]") == 0


def test_wire_models():
    assert H._wire("all-gather", 100, 4) == 75
    assert H._wire("all-reduce", 100, 4) == 150
    assert H._wire("reduce-scatter", 100, 4) == 300
    assert H._wire("collective-permute", 100, 4) == 100


def test_instr_parser_handles_tuple_types_with_comments():
    line = ("  %while.175 = (s32[], bf16[8,2]{1,0}, /*index=5*/f32[2,4]{1,0})"
            " while(%tuple.244), condition=%c, body=%b, "
            'backend_config={"known_trip_count":{"n":"28"}}')
    ins = H._parse_instr(line)
    assert ins.op == "while"
    assert H._TRIP_RE.search(ins.attrs).group(1) == "28"
    assert H._FLOW_CALLS.findall(ins.attrs) == ["%c", "%b"]
