"""Unit tests for the trip-count-aware HLO roofline analyzer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as H


def _compile_text(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_scan_flops_multiplied_by_trip_count():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y
    sds = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    r = H.analyze(_compile_text(f, sds, sds))
    assert r["flops"] == 10 * 2 * 128 ** 3


def test_nested_scan_flops():
    def g(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y
    sds = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    r = H.analyze(_compile_text(g, sds, sds))
    assert r["flops"] == 15 * 2 * 128 ** 3


def test_plain_matmul_flops_and_bytes():
    def f(x, w):
        return x @ w
    x = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 16), jnp.float32)
    r = H.analyze(_compile_text(f, x, w))
    assert r["flops"] == 2 * 64 * 32 * 16
    # result + both operands read at least once
    assert r["bytes"] >= 4 * (64 * 16 + 64 * 32 + 32 * 16)


def test_shape_bytes_parsing():
    assert H._nbytes("bf16[2,3]{1,0}") == 12
    assert H._nbytes("(f32[4], s32[2])") == 24
    assert H._nbytes("pred[]") == 1
    assert H._nbytes("token[]") == 0


def test_wire_models():
    assert H._wire("all-gather", 100, 4) == 75
    assert H._wire("all-reduce", 100, 4) == 150
    assert H._wire("reduce-scatter", 100, 4) == 300
    assert H._wire("collective-permute", 100, 4) == 100


def test_instr_parser_handles_tuple_types_with_comments():
    line = ("  %while.175 = (s32[], bf16[8,2]{1,0}, /*index=5*/f32[2,4]{1,0})"
            " while(%tuple.244), condition=%c, body=%b, "
            'backend_config={"known_trip_count":{"n":"28"}}')
    ins = H._parse_instr(line)
    assert ins.op == "while"
    assert H._TRIP_RE.search(ins.attrs).group(1) == "28"
    assert H._FLOW_CALLS.findall(ins.attrs) == ["%c", "%b"]


# ---------------------------------------------------------------------------
# training memory regression: no (T, T) score matrix in the flash backward
# ---------------------------------------------------------------------------


class TestFlashBackwardMemory:
    """The blockwise backward must keep the (T, S) score matrix out of the
    compiled graph entirely — recompute happens tile-by-tile inside the
    kernel, so at T=2048 no [.., 2048, 2048] buffer may exist in the HLO.
    The reference path is the positive control: its autodiff materializes
    the scores, proving the scan actually detects them."""

    T = 2048
    _PAT = None  # compiled lazily to keep import side-effect free

    @classmethod
    def _tt_buffers(cls, text):
        import re
        if cls._PAT is None:
            t = cls.T
            cls._PAT = re.compile(r"\[(?:\d+,)*%d,%d\]" % (t, t))
        return cls._PAT.findall(text)

    def _grad_text(self, policy):
        from repro.kernels import dispatch

        t = self.T
        q = jax.ShapeDtypeStruct((1, t, 1, 64), jnp.float32)

        def loss(q, k, v):
            return jnp.sum(dispatch.flash_attention(q, k, v, causal=True,
                                                    policy=policy))

        return jax.jit(jax.grad(loss, argnums=(0, 1, 2))) \
            .lower(q, q, q).compile().as_text()

    def test_pallas_backward_has_no_tt_buffer(self):
        from repro.config.base import KernelConfig
        from repro.kernels import dispatch

        pol = dispatch.resolve(KernelConfig(backend="pallas",
                                            interpret=True))
        hits = self._tt_buffers(self._grad_text(pol))
        assert hits == [], f"(T,T) buffers live in flash backward: {hits}"

    def test_ref_backward_materializes_tt_buffer(self):
        hits = self._tt_buffers(self._grad_text(None))
        assert hits, "positive control: ref backward should show (T,T)"
