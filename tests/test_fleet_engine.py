"""Fleet-scale serving: data-axis request striping, disaggregated
prefill/decode with paged-KV block handoff, row-parallel TP
(DESIGN.md §11).

Acceptance criteria:

  * data-parallel striping (dp2 x tp2) is TOKEN-IDENTICAL to the
    single-replica engine for greedy decode under deterministic
    routing — each data shard decodes only its own slot stripe and
    the paged pools are physically striped over the data axis,
  * disaggregated prefill/decode (dedicated prefill worker pool,
    host-side block-table handoff + pool-to-pool block migration)
    preserves tokens, leaks no blocks, and keeps ``decode_traces == 1``
    (the prefill worker reuses the decode trace),
  * the row-parallel TP variant matches the column-only oracle
    (deterministic CPU math makes "near-parity <= 1e-3" exact token
    identity here), and is exact on a mesh of 1,
  * the Router places requests deterministically (least-loaded with
    lowest-index tie-break, or strict round-robin).

The 4-device cases need fake host devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        python -m pytest -q tests/test_fleet_engine.py

(the scripts/ci.sh ``fleet-parity`` job runs them under 8). On a
single device they skip; the mesh(1,1) and single-device disagg cases
still run in the tier-1 suite.
"""
import jax
import numpy as np
import pytest

from repro import configs as registry
from repro.config.base import (QuantConfig, RunConfig, SHAPES,
                               ServeConfig)
from repro.core import tt as ttlib
from repro.models import model as M
from repro.serving import AdapterRuntime, Engine, Request, Router

KEY = jax.random.PRNGKey(0)

needs4 = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs 4 (fake) devices: run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=4 "
           "(scripts/ci.sh fleet-parity job)")


def _setup(variant="4+1d", num_tasks=3):
    cfg = registry.get_smoke_config("stablelm-1.6b")
    run = RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                    adapter_kind="metatt", adapter_variant=variant,
                    num_tasks=num_tasks, adapter_rank=4)
    spec = M.build_adapter_spec(run)
    params = M.init_params(cfg, spec, KEY)
    params["adapter"] = {"cores": ttlib.random_tt(
        KEY, spec.cfg.mode_sizes, 4, scale=0.8)}
    return cfg, spec, params


def _runtime():
    cfg, spec, params = _setup()
    rt = AdapterRuntime.build("live", params["base"], spec,
                              params["adapter"], params["frozen"])
    return cfg, rt


def _mixed_requests(cfg, n=5, tasks=3):
    prompts = [jax.random.randint(jax.random.PRNGKey(i), (4 + i,), 0,
                                  cfg.vocab_size) for i in range(n)]
    return [Request(p, 5 + (i % 3), task=i % tasks)
            for i, p in enumerate(prompts)]


def _serve(cfg, rt, reqs, *, mesh=(), **kw):
    base = dict(max_batch=2, cache_len=32, out_cap=8, page_size=8,
                prefill_chunk=4, mesh_shape=mesh)
    base.update(kw)
    eng = Engine(cfg, rt, serve=ServeConfig(**base))
    return [o.tolist() for o in eng.generate(reqs)], eng


# ---------------------------------------------------------------------------
# Router units (pure host-side, tier-1)
# ---------------------------------------------------------------------------

def test_router_round_robin_cycles():
    r = Router(3, "round_robin")
    assert [r.route(10) for _ in range(7)] == [0, 1, 2, 0, 1, 2, 0]
    # cost is tracked but never consulted by the round-robin policy
    assert r.loads() == [30, 20, 20]


def test_router_least_loaded_deterministic_tie_break():
    r = Router(3, "least_loaded")
    # ties break toward the lowest replica index
    assert r.route(5) == 0
    assert r.route(3) == 1
    assert r.route(1) == 2
    # loads now [5, 3, 1] -> replica 2 is least loaded
    assert r.route(10) == 2
    assert r.loads() == [5, 3, 11]
    # completion decrements the replica's outstanding cost
    r.complete(2, 10)
    assert r.route(1) == 2


def test_router_validation():
    with pytest.raises(ValueError):
        Router(0, "round_robin")
    with pytest.raises(ValueError):
        Router(2, "nope")


def test_router_out_of_order_completions():
    """Completions arrive in ANY order relative to routing (one replica
    can fully drain while another holds earlier requests): load drains
    exactly per replica and later ties stay deterministic."""
    r = Router(2, "least_loaded")
    assert [r.route(c) for c in (4, 2, 3)] == [0, 1, 1]
    # replica 1's SECOND request completes before its first
    r.complete(1, 3)
    r.complete(1, 2)
    assert r.loads() == [4, 0]
    r.complete(0, 4)
    assert r.loads() == [0, 0]
    # fully drained: the tie breaks toward replica 0 again
    assert r.route(1) == 0


def test_router_interleaved_route_complete():
    """route/complete interleaving mid-stream: refunds reshuffle the
    least-loaded ordering deterministically."""
    r = Router(3, "least_loaded")
    assert [r.route(c) for c in (6, 3, 3)] == [0, 1, 2]
    assert r.route(1) == 1            # tie 3,3 -> lowest index
    r.complete(2, 3)                  # replica 2 drains first
    assert r.route(2) == 2
    r.complete(0, 6)
    assert r.route(1) == 0
    assert r.loads() == [1, 4, 2]


def test_router_complete_rejects_bad_refunds():
    """Bookkeeping violations raise (never silently clamp): unknown
    replica, negative cost, refund exceeding the replica's outstanding
    load (double complete) — and load can never go negative."""
    r = Router(2, "least_loaded")
    r.route(5)
    with pytest.raises(ValueError):
        r.complete(2, 1)              # unknown replica
    with pytest.raises(ValueError):
        r.complete(-1, 1)
    with pytest.raises(ValueError):
        r.complete(0, -1)             # negative cost
    with pytest.raises(ValueError):
        r.complete(0, 6)              # over-refund
    r.complete(0, 5)
    with pytest.raises(ValueError):
        r.complete(0, 5)              # double complete
    assert r.loads() == [0, 0]


def test_fleet_config_validation():
    cfg, rt = _runtime()
    with pytest.raises(ValueError):
        ServeConfig(disagg=True, cache_mode="dense").validate()
    with pytest.raises(ValueError):
        ServeConfig(router="random").validate()
    with pytest.raises(ValueError):
        ServeConfig(row_parallel=True).validate()   # needs a mesh
    with pytest.raises(ValueError):
        ServeConfig(mesh_shape=(1, 1), row_parallel=True,
                    quant=QuantConfig(weights="int8",
                                      group_size=64)).validate()
    with pytest.raises(ValueError):             # dp>1 needs paged KV
        Engine(cfg, rt, serve=ServeConfig(mesh_shape=(2, 1),
                                          cache_mode="dense"))


# ---------------------------------------------------------------------------
# Disaggregated prefill/decode — single device (tier-1)
# ---------------------------------------------------------------------------

def test_disagg_token_identical_single_device():
    """The prefill-worker pool + block handoff must be invisible in the
    output: same tokens as the co-batched engine, one decode trace
    (the worker reuses it), and a correct latency phase split."""
    cfg, rt = _runtime()
    reqs = _mixed_requests(cfg)
    reqs.append(Request(reqs[0].prompt, 1, task=0))  # finishes at prefill
    ref, _ = _serve(cfg, rt, reqs)
    got, eng = _serve(cfg, rt, reqs, disagg=True)
    assert got == ref
    st = eng.last_stats
    assert st.decode_traces == 1
    assert st.ttft_s > 0.0 and st.tpot_s > 0.0
    # the prefill worker reports as replica -1 with a handoff count:
    # 5 decode-bound requests handed off, the max_new==1 one finished
    # at prefill harvest and never touched a decode replica
    pf = st.replica_stats[-1]
    assert pf["replica"] == -1 and pf["handoffs"] == 5
    assert st.replica_stats[0]["evicted"] == 5 and pf["evicted"] == 6


def test_disagg_leaks_no_blocks():
    cfg, rt = _runtime()
    reqs = _mixed_requests(cfg)
    _, eng = _serve(cfg, rt, reqs, disagg=True, prefix_cache=False)
    for bm in eng.bms + eng._pf_bms:
        assert bm.free_blocks == eng._num_blocks
    # with the prefix cache on, pinned prefix blocks live in the
    # PREFILL pool only; decode pools always drain to empty
    _, eng = _serve(cfg, rt, reqs, disagg=True)
    assert all(bm.free_blocks == eng._num_blocks for bm in eng.bms)
    for bm, px in zip(eng._pf_bms, eng._pf_prefixes):
        assert bm.free_blocks + px.cached_blocks == eng._num_blocks


def test_disagg_warm_prefix_reuse():
    """A second pass through the same prompts must hit the prefill
    worker's prefix cache and still emit identical tokens — without
    retracing."""
    cfg, rt = _runtime()
    reqs = _mixed_requests(cfg)
    ref, _ = _serve(cfg, rt, reqs)
    _, eng = _serve(cfg, rt, reqs, disagg=True)
    warm = [o.tolist() for o in eng.generate(reqs)]
    assert warm == ref
    assert eng.last_stats.prefix_hit_rate > 0.0
    assert eng.last_stats.decode_traces == 1


def test_disagg_pool_budget_reported():
    cfg, rt = _runtime()
    _, eng = _serve(cfg, rt, _mixed_requests(cfg), disagg=True)
    # two pools of _num_blocks each on a mesh of 1
    assert eng.last_stats.num_blocks == 2 * eng._num_blocks


# ---------------------------------------------------------------------------
# Fleet transparency on a mesh of 1 (tier-1)
# ---------------------------------------------------------------------------

def test_mesh_1x1_row_parallel_exact():
    """Row-parallel sharding of wo/wd with a size-1 psum epilogue must
    be bit-transparent — tier-1 evidence the rp math is exact."""
    cfg, rt = _runtime()
    reqs = _mixed_requests(cfg)
    ref, _ = _serve(cfg, rt, reqs)
    got, eng = _serve(cfg, rt, reqs, mesh=(1, 1), row_parallel=True)
    assert got == ref
    assert eng.last_stats.data_shards == 1


def test_mesh_1x1_disagg_transparent():
    cfg, rt = _runtime()
    reqs = _mixed_requests(cfg)
    ref, _ = _serve(cfg, rt, reqs)
    got, eng = _serve(cfg, rt, reqs, mesh=(1, 1), disagg=True)
    assert got == ref
    assert eng.last_stats.decode_traces == 1


# ---------------------------------------------------------------------------
# 4-device fleet cases
# ---------------------------------------------------------------------------

@needs4
def test_dp2_tp2_token_identical_to_dp1_tp1():
    """The headline fleet invariant: striping requests over two data
    replicas (each a tp2 shard group) under deterministic routing
    changes NOTHING about greedy tokens."""
    cfg, rt = _runtime()
    reqs = _mixed_requests(cfg)
    ref, _ = _serve(cfg, rt, reqs, mesh=(1, 1))
    got, eng = _serve(cfg, rt, reqs, mesh=(2, 2))
    assert got == ref
    st = eng.last_stats
    assert st.data_shards == 2 and st.shards == 2
    assert st.decode_traces == 1
    # every request landed on exactly one replica
    reps = [r for r in st.replica_stats if r["replica"] >= 0]
    assert sorted(r["replica"] for r in reps) == [0, 1]
    assert sum(r["admitted"] for r in reps) == len(reqs)
    assert sum(r["evicted"] for r in reps) == len(reqs)
    assert all(r["queue_depth"] == 0 for r in reps)


@needs4
def test_dp2_pools_physically_striped():
    """Each data replica owns a private 1/|data| stripe of every pool
    leaf (on top of the 1/|model| kv-head stripe)."""
    cfg, rt = _runtime()
    _, eng = _serve(cfg, rt, _mixed_requests(cfg), mesh=(2, 2))
    assert eng.last_stats.num_blocks == 2 * eng._num_blocks
    for leaf in jax.tree_util.tree_leaves(eng._paged_caches):
        shard = leaf.addressable_shards[0].data
        assert leaf.shape[1] == 2 * eng._num_blocks
        assert shard.shape[1] == eng._num_blocks


@needs4
def test_dp2_round_robin_token_identical():
    cfg, rt = _runtime()
    reqs = _mixed_requests(cfg)
    ref, _ = _serve(cfg, rt, reqs, mesh=(1, 1))
    got, _ = _serve(cfg, rt, reqs, mesh=(2, 2), router="round_robin")
    assert got == ref


@needs4
def test_dp2_disagg_token_identical():
    """Striping AND disaggregation composed: per-replica prefill
    worker pools hand finished sequences to their decode twins."""
    cfg, rt = _runtime()
    reqs = _mixed_requests(cfg)
    ref, _ = _serve(cfg, rt, reqs, mesh=(1, 1))
    got, eng = _serve(cfg, rt, reqs, mesh=(2, 2), disagg=True)
    assert got == ref
    st = eng.last_stats
    assert st.decode_traces == 1
    assert st.replica_stats[-1]["handoffs"] == len(reqs)
    assert all(bm.free_blocks == eng._num_blocks for bm in eng.bms)
    for bm, px in zip(eng._pf_bms, eng._pf_prefixes):
        assert bm.free_blocks + px.cached_blocks == eng._num_blocks


@needs4
def test_tp4_row_parallel_matches_column_oracle():
    """Row-parallel wo/wd/FFN-down with an all-reduce epilogue vs the
    column-only oracle. CPU float math is deterministic, so the
    <=1e-3 near-parity bar is witnessed as exact token identity."""
    cfg, rt = _runtime()
    reqs = _mixed_requests(cfg)
    col, _ = _serve(cfg, rt, reqs, mesh=(1, 4))
    row, _ = _serve(cfg, rt, reqs, mesh=(1, 4), row_parallel=True)
    assert row == col


@needs4
def test_dp4_token_identical():
    """Pure data axis: four single-shard replicas."""
    cfg, rt = _runtime()
    reqs = _mixed_requests(cfg)
    ref, _ = _serve(cfg, rt, reqs, mesh=(1, 1))
    got, eng = _serve(cfg, rt, reqs, mesh=(4, 1))
    assert got == ref
    assert eng.last_stats.data_shards == 4
