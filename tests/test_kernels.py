"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention as flash_raw
from repro.kernels.tt_linear import tt_linear as tt_raw

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    # tt_linear bf16 was 2e-2 while the kernel cast its f32 P accumulator
    # down to bf16 before the delta GEMM; with the epilogue kept in f32
    # the only residual error is bf16 input rounding (measured max 2.5e-4
    # across the sweep below)
    return 1e-3 if dtype == jnp.bfloat16 else 2e-4


def _flash_tol(dtype):
    # flash stores softmax probs in the input dtype before the PV dot —
    # bf16 rounding there bounds the attention kernels at ~1e-2
    return 2e-2 if dtype == jnp.bfloat16 else 2e-4


@pytest.mark.parametrize("m,k,n,r", [
    (128, 128, 128, 8),
    (256, 512, 256, 16),
    (128, 256, 384, 64),
    (384, 128, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_tt_linear_shapes_dtypes(m, k, n, r, dtype):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (m, k), dtype)
    w = (jax.random.normal(ks[1], (k, n), jnp.float32)
         / np.sqrt(k)).astype(dtype)
    a = (jax.random.normal(ks[2], (k, r), jnp.float32)
         / np.sqrt(k)).astype(dtype)
    b = (jax.random.normal(ks[3], (r, n), jnp.float32)
         / np.sqrt(r)).astype(dtype)
    y = tt_raw(x, w, a, b, alpha=0.7, bm=128, bn=128, bk=128,
               interpret=True)
    want = ref.tt_linear_ref(x, w, a, b, alpha=0.7)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_tt_linear_epilogue_stays_f32():
    """The delta GEMM must consume the f32 P = X·A accumulator directly:
    with bf16 B factors and f32 everything else, casting P down to bf16
    first (the old epilogue) loses ~1e-2 of delta — the f32 epilogue
    matches the reference to f32 roundoff."""
    ks = jax.random.split(KEY, 4)
    m, k, n, r = 128, 256, 128, 16
    x = jax.random.normal(ks[0], (m, k), jnp.float32)
    w = jax.random.normal(ks[1], (k, n), jnp.float32) / np.sqrt(k)
    a = jax.random.normal(ks[2], (k, r), jnp.float32) / np.sqrt(k)
    b = (jax.random.normal(ks[3], (r, n), jnp.float32)
         / np.sqrt(r)).astype(jnp.bfloat16)
    y = tt_raw(x, w, a, b, alpha=4.0, bm=128, bn=128, bk=128,
               interpret=True)
    want = ref.tt_linear_ref(x, w, a, b, alpha=4.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               atol=5e-5, rtol=5e-5)


def test_tt_linear_zero_adapter_equals_base_matmul():
    x = jax.random.normal(KEY, (128, 256), jnp.float32)
    w = jax.random.normal(KEY, (256, 128), jnp.float32) / 16
    a = jnp.zeros((256, 16))
    b = jax.random.normal(KEY, (16, 128), jnp.float32)
    y = tt_raw(x, w, a, b, alpha=4.0, bm=128, bn=128, bk=128,
               interpret=True)
    np.testing.assert_allclose(y, x @ w, atol=1e-4)


def test_tt_linear_ops_wrapper_pads_and_batches():
    x = jax.random.normal(KEY, (3, 5, 256), jnp.float32)  # ragged leading
    w = jax.random.normal(KEY, (256, 128), jnp.float32) / 16
    a = jax.random.normal(KEY, (256, 9), jnp.float32) / 16  # odd rank
    b = jax.random.normal(KEY, (9, 128), jnp.float32) / 3
    y = ops.tt_linear(x, w, a, b, alpha=1.3, backend="pallas",
                      interpret=True)
    want = ref.tt_linear_ref(x, w, a, b, alpha=1.3)
    np.testing.assert_allclose(y, want, atol=1e-4)
    assert y.shape == (3, 5, 128)


@pytest.mark.parametrize("t,s,d,causal", [
    (256, 256, 64, True),
    (256, 256, 64, False),
    (128, 384, 128, False),
    (512, 512, 64, True),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes_dtypes(t, s, d, causal, dtype):
    bh = 4
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (bh, t, d), dtype)
    k = jax.random.normal(ks[1], (bh, s, d), dtype)
    v = jax.random.normal(ks[2], (bh, s, d), dtype)
    y = flash_raw(q, k, v, causal=causal, bq=128, bkv=128, interpret=True)
    want = ref.flash_attention_ref(
        q.reshape(1, bh, t, d).astype(jnp.float32),
        k.reshape(1, bh, s, d).astype(jnp.float32),
        v.reshape(1, bh, s, d).astype(jnp.float32),
        causal=causal).reshape(bh, t, d)
    np.testing.assert_allclose(np.asarray(y, np.float32), want,
                               atol=_flash_tol(dtype), rtol=_flash_tol(dtype))


def test_flash_gqa_wrapper():
    b, t, h, kv, d = 2, 128, 8, 2, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, t, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, kv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, kv, d), jnp.float32)
    y = ops.flash_attention(q, k, v, causal=True, backend="pallas",
                            interpret=True)
    want = ops.flash_attention(q, k, v, causal=True, backend="ref")
    np.testing.assert_allclose(y, want, atol=2e-4, rtol=2e-4)
    assert y.shape == (b, t, h, d)


def test_flash_matches_model_attention_path():
    """The kernel and the model's chunked XLA path agree (same math)."""
    from repro.models.attention import _chunked_attend
    b, t, kvh, g, d = 1, 256, 2, 2, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, t, kvh, g, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, kvh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, kvh, d), jnp.float32)
    xla = _chunked_attend(q, k, v, d ** -0.5, True, 128)
    q4 = q.reshape(b, t, kvh * g, d)
    pal = ops.flash_attention(q4, k, v, causal=True, backend="pallas",
                              interpret=True)
    np.testing.assert_allclose(
        xla.reshape(b, t, kvh * g, d), pal, atol=2e-4, rtol=2e-4)
