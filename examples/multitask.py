"""Multi-task learning with MetaTT-(4+1)D (paper §3.2 + App. B).

Pipeline: (1) "pre-train" the base on the MIXED task distribution (the three
tasks' rules conflict, so no frozen model solves all of them), (2) freeze it,
(3) joint-train ONE MetaTT-(4+1)D adapter whose task core disambiguates.

    PYTHONPATH=src python examples/multitask.py [--grad-heatmap]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as registry
from repro.config.base import OptimizerConfig, RunConfig, SHAPES, TrainConfig
from repro.data import ClassificationTasks
from repro.models import model as M, transformer as T
from repro.optim import adamw
from repro.peft import api as peft_api
from repro.train import train_step as ts
from repro.train.trainer import Trainer


def core_grad_norms(tr, batch):
    """App. B heatmap: ||∇G||_F / sqrt(|G|) per TT core."""
    def loss(adapter):
        return M.loss_fn(adapter, tr.base, tr.frozen, batch, tr.cfg,
                         tr.spec)[0]
    g = jax.grad(loss)(tr.state.adapter)
    return [float(jnp.linalg.norm(c) / np.sqrt(c.size))
            for c in g["cores"]]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--grad-heatmap", action="store_true")
    ap.add_argument("--pretrain-steps", type=int, default=150)
    ap.add_argument("--adapt-steps", type=int, default=240)
    args = ap.parse_args()

    cfg = registry.get_smoke_config("roberta-base")
    tasks = ClassificationTasks(vocab_size=cfg.vocab_size, seq_len=8,
                                batch=32, num_tasks=3, seed=9)
    key = jax.random.PRNGKey(0)

    print("[1/3] pre-training the base on mixed tasks (full FT)...")
    base = T.init_base_params(cfg, key)
    ft = ts.make_full_ft_step(cfg, OptimizerConfig(lr=3e-3,
                                                   warmup_ratio=0.05),
                              TrainConfig(remat="none"),
                              args.pretrain_steps)
    opt = adamw.init_state(base)
    for i in range(args.pretrain_steps):
        b = tasks.sample(i % 3)
        base, opt, m = ft(base, opt, {"tokens": jnp.asarray(b["tokens"]),
                                      "mask": jnp.asarray(b["mask"])})
    print(f"    pre-train loss: {float(m['loss']):.3f}")

    print("[2/3] freezing base; joint-training MetaTT-(4+1)D adapter...")
    run = RunConfig(model=cfg, shape=SHAPES["train_4k"],
                    adapter_kind="metatt", adapter_variant="4+1d",
                    adapter_rank=8, adapter_alpha=4.0, num_tasks=3,
                    optimizer=OptimizerConfig(lr=2e-2, warmup_ratio=0.05),
                    train=TrainConfig(remat="none", seed=42))
    tr = Trainer(run=run, data=tasks, total_steps=args.adapt_steps,
                 task_cycle=(0, 1, 2))
    tr.base = base
    tr.train()
    n = peft_api.count_trainable(tr.spec, tr.state.adapter)

    print("[3/3] evaluating per task...")
    bc, pl = peft_api.adapter_factors(tr.spec, tr.state.adapter, tr.frozen)
    accs = []
    for t in range(3):
        b = tasks.sample(t, split="eval")
        out = T.forward(base, cfg, tr.spec, bc, pl,
                        jnp.asarray(b["tokens"]), task=jnp.int32(t))
        acc = tasks.accuracy(np.asarray(out.logits[:, -2]), b["labels"],
                             tasks.class_token_base, tasks.n_classes)
        accs.append(acc)
        print(f"    task {t}: accuracy {acc:.3f}")
    print(f"\none adapter, {n} trainable params, "
          f"mean accuracy {np.mean(accs):.3f}")

    if args.grad_heatmap:
        b = tasks.sample(2)
        batch = {"tokens": jnp.asarray(b["tokens"]),
                 "mask": jnp.asarray(b["mask"]), "task": jnp.int32(2)}
        norms = core_grad_norms(tr, batch)
        names = ["G1(D)", "G2(L)", "G3(T)", "G4(M)", "G5(D)"]
        print("\nnormalized gradient per TT core (App. B heatmap, task 2):")
        for nm, v in zip(names, norms):
            print(f"    {nm:7s} {'#' * int(200 * v)} {v:.4f}")


if __name__ == "__main__":
    main()
