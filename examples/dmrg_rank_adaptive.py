"""Rank-adaptive fine-tuning via DMRG-inspired sweeps (paper §3.3, Fig. 2).

Start at rank 10, intersperse Algorithm-1 sweeps after chosen epochs to walk
ranks down 10 -> 8 -> 6 -> 4 while AdamW keeps training (moments rebuilt
after each truncation, as the paper requires).

    PYTHONPATH=src python examples/dmrg_rank_adaptive.py
"""
import numpy as np

from repro import configs as registry
from repro.config.base import OptimizerConfig, RunConfig, SHAPES, TrainConfig
from repro.core import tt
from repro.core.dmrg import RankSchedule
from repro.data import LMStream
from repro.peft import api as peft_api
from repro.train.trainer import Trainer


def main():
    cfg = registry.get_smoke_config("roberta-base")
    run = RunConfig(model=cfg, shape=SHAPES["train_4k"],
                    adapter_kind="metatt", adapter_rank=10,
                    adapter_alpha=4.0,
                    optimizer=OptimizerConfig(lr=2e-2, warmup_ratio=0.1),
                    train=TrainConfig(remat="none", seed=42))
    data = LMStream(vocab_size=cfg.vocab_size, seq_len=32, batch=8, seed=5,
                    branching=2)
    steps_per_epoch = 15
    sched = RankSchedule(milestones=((1, 8), (2, 6), (3, 4)))
    tr = Trainer(run=run, data=data, total_steps=5 * steps_per_epoch,
                 steps_per_epoch=steps_per_epoch, rank_schedule=sched)

    ranks_log = []
    orig_metrics = tr.on_metrics
    def log(step, m):
        if step % steps_per_epoch == 0:
            ranks_log.append((step, tt.ranks(tr.state.adapter["cores"]),
                              peft_api.count_trainable(tr.spec,
                                                       tr.state.adapter)))
    tr.on_metrics = log
    tr.train()

    losses = tr.losses()
    print("\nrank trajectory (paper Fig. 2 arrows):")
    for step, ranks, n in ranks_log:
        print(f"    step {step:3d}: ranks={ranks} trainable={n}")
    print(f"final ranks: {tt.ranks(tr.state.adapter['cores'])}")
    print(f"loss: {losses[0]:.3f} -> {np.mean(losses[-5:]):.3f} "
          f"(fixed-rank-4 training from scratch would have "
          f"{'fewer' if True else ''} params the whole time but the paper "
          f"shows the high->low schedule reaches better optima)")


if __name__ == "__main__":
    main()
