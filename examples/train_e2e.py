"""End-to-end training driver: any assigned architecture (reduced config on
CPU), MetaTT adapter, synthetic data, checkpointing + auto-resume.

    PYTHONPATH=src python examples/train_e2e.py --arch gemma-7b --steps 100
    # kill it mid-run, run the same command again -> resumes from the
    # latest checkpoint with identical data order.
"""
import argparse

import numpy as np

from repro import configs as registry
from repro.config.base import OptimizerConfig, RunConfig, SHAPES, TrainConfig
from repro.data import LMStream
from repro.peft import api as peft_api
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b",
                    choices=list(registry.ARCH_IDS) + ["roberta-base"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--adapter", default="metatt",
                    choices=("metatt", "lora", "vera", "lotr"))
    ap.add_argument("--variant", default="4d")
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    ap.add_argument("--grad-compression", default="none",
                    choices=("none", "int8", "topk"))
    args = ap.parse_args()

    cfg = registry.get_smoke_config(args.arch)
    run = RunConfig(model=cfg, shape=SHAPES["train_4k"],
                    adapter_kind=args.adapter, adapter_variant=args.variant,
                    adapter_rank=args.rank, adapter_alpha=4.0,
                    optimizer=OptimizerConfig(lr=1e-2, warmup_ratio=0.06),
                    train=TrainConfig(remat="none", seed=42,
                                      ckpt_dir=args.ckpt_dir, ckpt_every=20,
                                      grad_compression=args.grad_compression))
    data = LMStream(vocab_size=cfg.vocab_size, seq_len=32, batch=8, seed=5,
                    branching=2)
    tr = Trainer(run=run, data=data, total_steps=args.steps)
    n = peft_api.count_trainable(tr.spec, tr.state.adapter)
    print(f"arch={args.arch} adapter={args.adapter}-{args.variant} "
          f"rank={args.rank} trainable={n}")
    tr.train()
    losses = tr.losses()
    if len(losses):
        print(f"loss: {losses[0]:.3f} -> {np.mean(losses[-5:]):.3f} "
              f"over {len(losses)} steps (resumed runs show only new steps)")
    if tr.straggler_events:
        print(f"straggler watchdog events: {tr.straggler_events}")
    print(f"checkpoints in {args.ckpt_dir}: {tr.ckpt.all_steps()}")


if __name__ == "__main__":
    main()
