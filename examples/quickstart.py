"""Quickstart: fine-tune a frozen transformer with a single global MetaTT
adapter and compare against LoRA at the same rank (paper Table 1 in
miniature — synthetic data, CPU-sized model).

    PYTHONPATH=src python examples/quickstart.py [--steps 80]
"""
import argparse

import numpy as np

from repro import configs as registry
from repro.config.base import OptimizerConfig, RunConfig, SHAPES, TrainConfig
from repro.data import LMStream
from repro.peft import api as peft_api
from repro.train.trainer import Trainer


def train_one(adapter_kind: str, steps: int):
    cfg = registry.get_smoke_config("roberta-base")
    run = RunConfig(model=cfg, shape=SHAPES["train_4k"],
                    adapter_kind=adapter_kind, adapter_rank=4,
                    adapter_alpha=4.0,
                    optimizer=OptimizerConfig(lr=2e-2, warmup_ratio=0.1),
                    train=TrainConfig(remat="none", seed=42))
    data = LMStream(vocab_size=cfg.vocab_size, seq_len=32, batch=8, seed=5,
                    branching=2)
    tr = Trainer(run=run, data=data, total_steps=steps)
    tr.train()
    n = peft_api.count_trainable(tr.spec, tr.state.adapter)
    return tr, n


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    args = ap.parse_args()
    print("== MetaTT (one global TT for ALL layers) ==")
    tr_tt, n_tt = train_one("metatt", args.steps)
    print("== LoRA (per-matrix A·B) ==")
    tr_lora, n_lora = train_one("lora", args.steps)

    def curve(tr):
        l = tr.losses()
        return l[0], float(np.mean(l[-5:]))

    l0, l1 = curve(tr_tt)
    print(f"\nMetaTT : {n_tt:6d} trainable params | loss {l0:.3f} -> {l1:.3f}")
    l0, l1 = curve(tr_lora)
    print(f"LoRA   : {n_lora:6d} trainable params | loss {l0:.3f} -> {l1:.3f}")
    print(f"\ncompression: {n_lora / n_tt:.1f}x fewer trainable parameters "
          f"(paper: up to 20x at RoBERTa scale)")


if __name__ == "__main__":
    main()
