"""Continuous-batching serving with a MetaTT adapter (paper §2.4 + §3.2).

Serves a mixed-task request stream through the slot engine
(repro/serving/engine.py) under each adapter runtime:

  * live   — the TT contraction runs per decode step; a (B,) task-id vector
             gathers per-slot C[l, t, m] slices from ONE shared 4+1d TT, so
             a single decode batch mixes tasks.
  * lora   — middle cores pre-folded into the left boundary (two GEMMs per
             adapted matrix; "matching the speeds of LoRA" per the paper).
  * merged — ΔW of one task folded into the frozen weights (zero overhead);
             single-task streams only.

``--tp N`` serves through the tensor-parallel engine (DESIGN.md §9):
shard_map over a (1, N) ("data", "model") mesh, KV pools kv-head-sharded
per device — token-identical output, per-shard KV bytes = global / N.
Needs N devices (on CPU:
``XLA_FLAGS=--xla_force_host_platform_device_count=N``).

``--spec-k K`` turns on speculative decode (DESIGN.md §10): a
rank-truncated slice of the SAME shared TT (``--draft-rank r``, 0 = full
rank; ``--draft-layer-stride s`` keeps every s-th block) drafts K tokens
per engine step and one verifier pass accepts a prefix — greedy output
stays token-identical to the non-speculative run, which the example
checks.

Fleet serving (DESIGN.md §11): ``--dp N`` stripes requests over N decode
replicas on the "data" mesh axis (composes with ``--tp``; needs dp*tp
devices) and prints per-replica admission/eviction/peak-block stats;
``--disagg`` splits prefill onto a dedicated worker pool that hands
paged KV blocks to the decode replicas; ``--row-parallel`` shards the
second matmul of each pair (wo/wd) row-parallel with an all-reduce
epilogue instead of all-gathering activations.

    PYTHONPATH=src python examples/serve.py [--tokens 16] [--requests 8]
"""
import argparse
import time

import jax

from repro import configs as registry
from repro.config.base import (RegistryConfig, RunConfig, SHAPES,
                               ServeConfig)
from repro.core import tt as ttlib
from repro.models import model as M
from repro.serving import (AdapterRuntime, ChaosInjector, Engine, FINISHED,
                           Request, SpecConfig, audit)


def serve(cfg, runtime, reqs, *, max_batch, cache_len, out_cap, tp=0,
          dp=0, disagg=False, row_parallel=False, spec=None, slots=0,
          chaos=None):
    mesh = (dp or 1, tp or 1) if (tp or dp or row_parallel) else ()
    sv = ServeConfig(max_batch=max_batch, cache_len=cache_len,
                     out_cap=out_cap, mesh_shape=mesh, disagg=disagg,
                     row_parallel=row_parallel,
                     spec=spec or SpecConfig(),
                     registry=RegistryConfig(max_resident_tasks=slots))
    eng = Engine(cfg, runtime, serve=sv)
    eng.generate(reqs)   # warm-up: compile once + populate the prefix cache
    t0 = time.perf_counter()
    outs = eng.generate(reqs, chaos=chaos)
    dt = time.perf_counter() - t0
    toks = sum(len(o) for o in outs)
    # per-generate observability: KV blocks in use, prefix-cache hit rate,
    # admit/evict/COW counts (serving/stats.py)
    st = eng.last_stats
    print(f"  stats: {st.summary()}")
    if st.data_shards > 1 or disagg:
        # per-replica placement/pressure figures (replica -1 is the
        # dedicated prefill worker under --disagg)
        for r in st.replica_stats:
            print(f"    replica {r['replica']:>2}: "
                  f"admitted={r['admitted']} evicted={r['evicted']} "
                  f"kv_blocks_peak={r['kv_blocks_peak']} "
                  f"waits={r['backpressure_waits']}"
                  + (f" handoffs={r['handoffs']}" if "handoffs" in r
                     else ""))
    # request lifecycle (DESIGN.md §13): per-request terminal status —
    # printed whenever something other than a clean FINISH happened
    # (deadline sweep, scripted cancel, chaos fault, preemption)
    if chaos is not None or any(rr.status != FINISHED or rr.preemptions
                                for rr in eng.last_results):
        for i, rr in enumerate(eng.last_results):
            print(f"    request {i:>2}: {rr.status:<9} "
                  f"tokens={rr.n_generated:<3} "
                  f"preemptions={rr.preemptions}")
    if chaos is not None:
        audit(eng)  # host-pool invariants hold at rest after the faults
        print(f"  chaos: alloc_faults={chaos.alloc_faults} "
              f"scatter_faults={chaos.scatter_faults} "
              f"killed={chaos.killed}")
    return outs, dt, toks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4, help="decode slots")
    ap.add_argument("--tasks", type=int, default=3)
    ap.add_argument("--tp", type=int, default=0,
                    help="tensor-parallel shards on the 'model' mesh "
                         "axis (0 = single device)")
    ap.add_argument("--dp", type=int, default=0,
                    help="decode replicas on the 'data' mesh axis — "
                         "requests are striped by the deterministic "
                         "router (0 = no data axis; needs dp*tp devices)")
    ap.add_argument("--disagg", action="store_true",
                    help="disaggregate prefill onto a dedicated worker "
                         "pool with paged-KV block handoff to decode")
    ap.add_argument("--row-parallel", action="store_true",
                    help="row-parallel wo/wd with a psum epilogue "
                         "instead of the all-gather (needs --tp/--dp)")
    ap.add_argument("--max-resident-tasks", type=int, default=0,
                    help="adapter pool slots per replica (DESIGN.md "
                         "§12): serve --tasks tasks through a fixed "
                         "K-slot device pool with LRU paging (0 = whole "
                         "task axis resident). Applies to the live and "
                         "lora runtimes; merged folds one task into the "
                         "weights and has no task axis to page")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request wall-clock budget measured from "
                         "generate() (0 = none): requests past it are "
                         "aborted between steps and finish with status "
                         "TIMEOUT plus whatever tokens they produced "
                         "(DESIGN.md §13)")
    ap.add_argument("--chaos", action="store_true",
                    help="re-run the live stream under a seeded "
                         "ChaosInjector (forced allocation backpressure, "
                         "one scripted cancel, one NaN-logit fault) and "
                         "check survivors stay token-identical "
                         "(DESIGN.md §13)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="draft tokens per engine step (0 = speculative "
                         "decode off)")
    ap.add_argument("--draft-rank", type=int, default=0,
                    help="drafter TT bond rank — leading slice of the "
                         "shared cores (0 = full rank)")
    ap.add_argument("--draft-layer-stride", type=int, default=1,
                    help="drafter keeps every s-th transformer block")
    args = ap.parse_args()

    cfg = registry.get_smoke_config("stablelm-1.6b")
    run = RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                    adapter_kind="metatt", adapter_variant="4+1d",
                    num_tasks=args.tasks, adapter_rank=8)
    spec = M.build_adapter_spec(run)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, spec, key)
    params["adapter"] = {"cores": ttlib.random_tt(
        key, spec.cfg.mode_sizes, 8, scale=0.5)}
    base, adapter, frozen = (params["base"], params["adapter"],
                             params["frozen"])

    keys = jax.random.split(key, args.requests)
    deadline = args.deadline_ms / 1e3 if args.deadline_ms > 0 else None
    reqs = [Request(jax.random.randint(keys[i], (4 + i % 5,), 0,
                                       cfg.vocab_size),
                    args.tokens, task=i % args.tasks,
                    deadline_s=deadline, request_id=f"r{i}")
            for i in range(args.requests)]
    cache_len = 16 + args.tokens
    kw = dict(max_batch=args.batch, cache_len=cache_len,
              out_cap=args.tokens, tp=args.tp, dp=args.dp,
              disagg=args.disagg, row_parallel=args.row_parallel)
    # adapter paging applies to the TASKED runtimes only (see --help)
    tasked_kw = dict(kw, slots=args.max_resident_tasks)

    rt_live = AdapterRuntime.build("live", base, spec, adapter, frozen)
    live, t_live, toks = serve(cfg, rt_live, reqs, **tasked_kw)

    if args.chaos:
        # seeded fault schedule (DESIGN.md §13): backpressure on the
        # first two host steps, cancel r1 mid-flight, NaN-fail r2 after
        # its second token — survivors must match the clean run exactly
        inj = ChaosInjector(seed=0, alloc_fail_steps=(0, 1),
                            alloc_fail_rate=0.2,
                            cancel_at={1: ["r1"]},
                            nan_after={"r2": 2} if args.requests > 2
                            else None)
        chaosed, _, _ = serve(cfg, rt_live, reqs, chaos=inj, **tasked_kw)
        faulted = {"r1", "r2"}
        same_chaos = all(a.tolist() == b.tolist()
                         for r, a, b in zip(reqs, live, chaosed)
                         if r.request_id not in faulted)
        print(f"  chaos survivors identical to clean run: {same_chaos}")

    spec_cfg = None
    if args.spec_k:
        spec_cfg = SpecConfig(spec_k=args.spec_k,
                              draft_rank=args.draft_rank,
                              draft_layer_stride=args.draft_layer_stride)
        speced, t_spec, _ = serve(cfg, rt_live, reqs, spec=spec_cfg,
                                  **tasked_kw)
        same_spec = all(a.tolist() == b.tolist()
                        for a, b in zip(live, speced))

    rt_lora = AdapterRuntime.build("lora", base, spec, adapter, frozen)
    lora, t_lora, _ = serve(cfg, rt_lora, reqs, **tasked_kw)

    # merged: one task's ΔW folded into the weights -> zero-overhead stream
    # for that task (mixed-task streams need live/lora)
    rt_merged = AdapterRuntime.build("merged", base, spec, adapter, frozen,
                                     model_cfg=cfg, task=0)
    t0_reqs = [r for r in reqs if r.task == 0]
    merged, t_merged, _ = serve(cfg, rt_merged, t0_reqs, **kw)

    same_lora = all(a.tolist() == b.tolist() for a, b in zip(live, lora))
    live_t0 = [o for r, o in zip(reqs, live) if r.task == 0]
    same_merged = all(a.tolist() == b.tolist()
                      for a, b in zip(live_t0, merged))
    print(f"served {args.requests} requests x {args.tokens} tokens through "
          f"{args.batch} slots, {args.tasks} tasks mixed per batch")
    print(f"live TT runtime   : {t_live:.2f}s  {toks/t_live:7.1f} tok/s "
          "(steady state)")
    if spec_cfg is not None:
        print(f"live + spec k={args.spec_k:<2}: {t_spec:.2f}s  "
              f"{toks/t_spec:7.1f} tok/s "
              f"(identical output: {same_spec})")
    print(f"lora-form runtime : {t_lora:.2f}s  {toks/t_lora:7.1f} tok/s "
          f"(identical output: {same_lora})")
    print(f"merged (task 0)   : {t_merged:.2f}s "
          f"(identical output: {same_merged})")
    for i in range(min(3, len(reqs))):
        print(f"request {i} (task {reqs[i].task}): {live[i].tolist()}")


if __name__ == "__main__":
    main()
