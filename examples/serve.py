"""Batched serving with a MetaTT adapter (paper §2.4).

Demonstrates the two serving modes:
  * live   — the TT contraction runs per decode step (two small GEMMs),
  * merged — ΔW folded into the frozen weights once (zero overhead;
             "matching the speeds of LoRA" per the paper).

    PYTHONPATH=src python examples/serve.py [--tokens 16]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs as registry
from repro.config.base import RunConfig, SHAPES
from repro.core import tt as ttlib
from repro.core.merge import fold_into_dense
from repro.models import model as M
from repro.peft import api as peft_api
from repro.train import train_step as ts


def generate(base, cfg, spec, adapter, prompt, steps, cache_len):
    """Greedy prefill + decode."""
    prefill = ts.make_prefill(cfg, spec, cache_len)
    logits, caches, _ = prefill(base, adapter, {}, prompt)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    out = [tok]
    pos = prompt.shape[1]
    step = ts.make_serve_step(cfg, spec)
    for i in range(steps - 1):
        lg, caches = step(base, adapter, {}, tok, caches,
                          jnp.int32(pos + i))
        tok = jnp.argmax(lg, axis=-1)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = registry.get_smoke_config("stablelm-1.6b")
    run = RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                    adapter_kind="metatt", adapter_rank=8)
    spec = M.build_adapter_spec(run)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, spec, key)
    params["adapter"] = {"cores": ttlib.random_tt(
        key, spec.cfg.mode_sizes, 8, scale=0.1)}
    prompt = jax.random.randint(key, (args.batch, 8), 0, cfg.vocab_size)
    cache_len = prompt.shape[1] + args.tokens

    t0 = time.perf_counter()
    live = generate(params["base"], cfg, spec, params["adapter"], prompt,
                    args.tokens, cache_len)
    t_live = time.perf_counter() - t0

    # merge ΔW into q/v once, then serve with NO adapter at all
    folded = dict(params["base"])
    blk = dict(folded["blocks"][0])
    mixer = dict(blk["mixer"])
    merged = fold_into_dense(params["adapter"], spec.cfg,
                             {"attn_q": mixer["wq"], "attn_v": mixer["wv"]})
    mixer["wq"], mixer["wv"] = merged["attn_q"], merged["attn_v"]
    blk["mixer"] = mixer
    folded["blocks"] = [blk]
    t0 = time.perf_counter()
    merged_out = generate(folded, cfg, peft_api.NONE, {}, prompt,
                          args.tokens, cache_len)
    t_merged = time.perf_counter() - t0

    same = bool(jnp.all(live == merged_out))
    print(f"generated {args.tokens} tokens x batch {args.batch}")
    print(f"live TT adapter : {t_live:.2f}s (incl. compile)")
    print(f"merged weights  : {t_merged:.2f}s (incl. compile)")
    print(f"identical greedy output: {same}")
    print(f"first sequence: {live[0].tolist()}")


if __name__ == "__main__":
    main()
